//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//! Proves all three layers compose: JAX+Pallas AOT artifacts (L1+L2,
//! built once by `make artifacts`) are loaded by the Rust PJRT runtime
//! and served by the power-budget coordinator (L3) — Python never runs
//! here. The driver replays the test set as a request stream, then
//! *changes the energy budget at runtime* and shows the coordinator
//! hopping between operating points, reporting accuracy, latency
//! percentiles, throughput and energy for each phase.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use pann::coordinator::{EnginePoint, Server, ServerConfig};
use pann::data::Dataset;
use pann::runtime::{ArtifactManifest, CpuRuntime};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "cnn-s".to_string());
    let artifacts = std::path::PathBuf::from("artifacts");
    let manifest = ArtifactManifest::load(&artifacts.join("hlo"))
        .map_err(|e| anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first"))?;
    let specs: Vec<_> = manifest.points_for(&model).into_iter().cloned().collect();
    anyhow::ensure!(!specs.is_empty(), "no executables for {model}");
    let sample_len: usize = specs[0].input_shape[1..].iter().product();

    let srv = Server::start(
        move || {
            let rt = CpuRuntime::new()?;
            eprintln!("PJRT platform: {}", rt.platform());
            let mut points = Vec::new();
            for spec in &specs {
                let lm = rt.load(&spec.file, &spec.input_shape)?;
                eprintln!(
                    "  loaded {:<12} ({:.5} Gflips/sample)",
                    spec.variant, spec.giga_flips_per_sample
                );
                points.push(EnginePoint {
                    name: spec.variant.clone(),
                    giga_flips_per_sample: if spec.variant == "fp32" {
                        f64::INFINITY
                    } else {
                        spec.giga_flips_per_sample
                    },
                    engine: Box::new(lm),
                });
            }
            Ok(points)
        },
        sample_len,
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            budget_gflips: f64::INFINITY,
        },
    )?;
    let h = srv.handle();

    let ds_name = pann::experiments::dataset_for(&model);
    let ds = Dataset::load(&artifacts.join("data").join(ds_name), "test")?;
    let n_phase = 256.min(ds.len());

    // Three budget phases: unlimited (fp32), generous (8-bit PANN
    // budget), tight (2-bit budget). The menu never reloads — only the
    // (b̃x, R) operating point changes, the paper's deployment claim.
    let macs = pann::experiments::qat::num_macs(&model) as f64;
    let phases = [
        ("unlimited", f64::INFINITY),
        ("8-bit budget", 64.0 * macs / 1e9),
        ("2-bit budget", 10.0 * macs / 1e9),
    ];
    println!("\nserving {model} over {ds_name}, {n_phase} requests per phase");
    let clients = 4usize;
    for (label, budget) in phases {
        h.set_budget(budget);
        let t0 = std::time::Instant::now();
        let correct = std::thread::scope(|s| -> anyhow::Result<usize> {
            let mut js = Vec::new();
            for c in 0..clients {
                let h = h.clone();
                let ds = &ds;
                js.push(s.spawn(move || -> anyhow::Result<(usize, String)> {
                    let mut ok = 0;
                    let mut point = String::new();
                    for i in (c..n_phase).step_by(clients) {
                        let r = h.infer(ds.sample(i).to_vec())?;
                        let pred = r
                            .output
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(j, _)| j)
                            .unwrap_or(0);
                        if pred == ds.y[i] as usize {
                            ok += 1;
                        }
                        point = r.point;
                    }
                    Ok((ok, point))
                }));
            }
            let mut total = 0;
            let mut point = String::new();
            for j in js {
                let (ok, p) = j.join().expect("client panicked")?;
                total += ok;
                point = p;
            }
            println!(
                "  phase {label:<14} -> point {point:<10} accuracy {:.3}  ({:.2}s)",
                total as f64 / n_phase as f64,
                t0.elapsed().as_secs_f64()
            );
            Ok(total)
        })?;
        let _ = correct;
    }
    println!("\n{}", h.metrics().report());
    srv.shutdown();
    Ok(())
}
