"""QAT primitive tests: STE, LSQ, PANN, po2, adder gradients."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import quantize as Q


def test_ste_round_passes_gradient():
    g = jax.grad(lambda x: Q.ste_round(x * 3.0))(1.234)
    assert abs(float(g) - 3.0) < 1e-6


def test_pann_budget_np():
    rng = np.random.default_rng(0)
    w = rng.standard_normal(4096).astype(np.float32) * 0.1
    for r in (1.0, 2.0, 4.0):
        codes, gamma, adds = Q.pann_quantize_np(w, r)
        assert abs(adds - r) / r < 0.12, (r, adds)
        np.testing.assert_allclose(codes * gamma, w, atol=gamma / 2 + 1e-7)


def test_pann_fake_quant_matches_np():
    rng = np.random.default_rng(1)
    w = rng.standard_normal(256).astype(np.float32)
    fq = np.asarray(Q.pann_fake_quant(jnp.asarray(w), 2.0))
    codes, gamma, _ = Q.pann_quantize_np(w, 2.0)
    np.testing.assert_allclose(fq, codes * gamma, rtol=2e-4, atol=2e-6)


def test_po2_weights_are_powers_of_two():
    rng = np.random.default_rng(2)
    w = rng.standard_normal(64).astype(np.float32) * 0.3
    ws = np.asarray(Q.po2_fake_quant(jnp.asarray(w), 4))
    mags = np.abs(ws[ws != 0])
    logs = np.log2(mags)
    np.testing.assert_allclose(logs, np.round(logs), atol=1e-5)


def test_lsq_quant_levels():
    x = jnp.linspace(0, 1, 100)
    y = np.asarray(Q.lsq_quant(x, jnp.asarray(0.1), 3, unsigned=True))
    levels = np.unique(np.round(y / 0.1).astype(int))
    assert levels.min() >= 0 and levels.max() <= 7


def test_adder_dense_values_and_grads():
    x = jnp.asarray([[1.0, 2.0]])
    w = jnp.asarray([[0.0, 0.0], [1.0, 2.0]])
    y = Q.adder_dense(x, w)
    np.testing.assert_allclose(np.asarray(y), [[-3.0, 0.0]], atol=1e-6)
    gw = jax.grad(lambda w: Q.adder_dense(x, w).sum())(w)
    # AdderNet: dy/dw = (x - w)
    np.testing.assert_allclose(np.asarray(gw), [[1.0, 2.0], [0.0, 0.0]], atol=1e-6)


def test_fake_quant_signed_symmetric():
    x = jnp.asarray([-1.0, -0.5, 0.0, 0.5, 1.0])
    y = np.asarray(Q.fake_quant_signed(x, 0.25, 3))
    assert (np.abs(y) <= 1.0 + 1e-6).all()
    np.testing.assert_allclose(y, np.clip(np.rint(x / 0.25), -4, 3) * 0.25)
