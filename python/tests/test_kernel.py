"""L1 correctness: Pallas kernels vs the pure-numpy oracle.

This is the CORE correctness signal of the compile path — hypothesis
sweeps shapes and value ranges; integer kernels must match *exactly*.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pann_matmul import pann_matmul, quantize_act, quantized_linear

dims = st.integers(min_value=1, max_value=40)


@settings(max_examples=30, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1), wmax=st.integers(1, 64))
def test_pann_matmul_matches_ref(m, k, n, seed, wmax):
    rng = np.random.default_rng(seed)
    xq = rng.integers(0, 256, size=(m, k)).astype(np.int32)
    wp = rng.integers(0, wmax, size=(n, k)).astype(np.int32)
    wn = rng.integers(0, wmax, size=(n, k)).astype(np.int32)
    out = np.asarray(pann_matmul(xq, wp, wn))
    np.testing.assert_array_equal(out, ref.ref_pann_matmul(xq, wp, wn))


@settings(max_examples=30, deadline=None)
@given(
    m=dims,
    k=dims,
    seed=st.integers(0, 2**31 - 1),
    bits=st.integers(2, 8),
    scale=st.floats(1e-3, 1.0),
)
def test_quantize_act_matches_ref(m, k, seed, bits, scale):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    qmax = 2**bits - 1
    out = np.asarray(quantize_act(x, scale, qmax))
    expect = ref.ref_quantize_act(x, scale, qmax)
    np.testing.assert_array_equal(out, expect)


@settings(max_examples=15, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_fused_linear_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = np.abs(rng.standard_normal((m, k))).astype(np.float32)
    wp = rng.integers(0, 9, size=(n, k)).astype(np.int32)
    wn = rng.integers(0, 9, size=(n, k)).astype(np.int32)
    bias = rng.standard_normal(n).astype(np.float32)
    y = np.asarray(quantized_linear(x, wp, wn, 0.05, 63, 0.013, bias))
    yr = ref.ref_quantized_linear(x, wp, wn, 0.05, 63, 0.013, bias)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


def test_large_tile_boundary():
    """Shapes straddling the 128 tile boundary."""
    rng = np.random.default_rng(0)
    for m, n, k in [(128, 128, 64), (129, 130, 31), (256, 10, 200)]:
        xq = rng.integers(0, 64, size=(m, k)).astype(np.int32)
        wp = rng.integers(0, 8, size=(n, k)).astype(np.int32)
        wn = rng.integers(0, 8, size=(n, k)).astype(np.int32)
        out = np.asarray(pann_matmul(xq, wp, wn))
        np.testing.assert_array_equal(out, ref.ref_pann_matmul(xq, wp, wn))


def test_negative_inputs_clip_to_zero():
    x = np.array([[-1.0, 0.0, 0.5]], dtype=np.float32)
    q = np.asarray(quantize_act(x, 0.1, 7))
    assert q.tolist() == [[0, 0, 5]]


def test_zero_weights_zero_output():
    xq = np.ones((3, 4), dtype=np.int32)
    z = np.zeros((2, 4), dtype=np.int32)
    out = np.asarray(pann_matmul(xq, z, z))
    assert (out == 0).all()
