"""L2 model graph tests: shapes, MAC counts, QAT paths, stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import quantize as Q
from compile import train as T


@pytest.mark.parametrize("name", list(M.ARCHS))
def test_forward_shapes(name):
    arch = M.ARCHS[name]
    p = M.init_params(arch, 0)
    x = jnp.zeros([3] + arch["input"])
    y = M.forward(arch, p, x)
    classes = arch["layers"][-1]["out"]
    assert y.shape == (3, classes)


@pytest.mark.parametrize("name", list(M.ARCHS))
def test_num_macs_positive(name):
    assert M.num_macs(M.ARCHS[name]) > 10_000


def test_num_macs_cnn_s_exact():
    # conv1 8*1*9*256 + conv2 16*8*9*64 + fc 10*256 (matches rust test)
    assert M.num_macs(M.ARCHS["cnn-s"]) == 8 * 9 * 256 + 16 * 8 * 9 * 64 + 10 * 256


def test_act_stats_structure():
    arch = M.ARCHS["mlp"]
    p = M.init_params(arch, 0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (16, 64)))
    stats = M.act_stats(arch, p, x)
    assert set(stats.keys()) == set(range(len(arch["layers"])))
    assert len(stats[0]["mean"]) == 96  # first linear output channels


@pytest.mark.parametrize("method", ["lsq", "pann", "adder", "shiftadd"])
def test_qat_forward_runs(method):
    arch = M.ARCHS["mlp"]
    p = M.init_params(arch, 0)
    p = T.init_qat_params(arch, p, method, 4, 4, 0)
    mac = T.make_mac(method, 4, 4, 1.5)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (4, 64)))
    y = M.forward(arch, p, x, mac=mac)
    assert y.shape == (4, 10)
    assert bool(jnp.isfinite(y).all())


@pytest.mark.parametrize("method", ["lsq", "pann"])
def test_qat_gradients_finite(method):
    arch = M.ARCHS["mlp"]
    p = M.init_params(arch, 0)
    p = T.init_qat_params(arch, p, method, 3, 3, 0)
    mac = T.make_mac(method, 3, 3, 2.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (8, 64)))
    yb = jnp.zeros(8, jnp.int32)

    def loss(p):
        lo = M.forward(arch, p, x, mac=mac)
        return -jnp.mean(jax.nn.log_softmax(lo)[:, 0])

    g = jax.grad(loss)(p)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)
    # weight gradients must be nonzero (STE passes through)
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves)


def test_im2col_matches_conv():
    """conv via im2col rows @ w == lax conv."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 3, 8, 8))
    w = jax.random.normal(key, (5, 3, 3, 3))
    rows, (n, oh, ow) = Q.im2col(x, 3, 1, 1)
    y1 = (rows @ w.reshape(5, -1).T).reshape(n, oh, ow, 5).transpose(0, 3, 1, 2)
    y2 = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)], dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
