"""AOT path tests: PANN graph vs fp32 graph, HLO text emission."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.tensor_io import write_tensor


@pytest.fixture(scope="module")
def tiny_model(tmp_path_factory):
    """A small trained-ish mlp manifest on disk."""
    d = tmp_path_factory.mktemp("models") / "mlp"
    d.mkdir()
    rng = np.random.default_rng(0)
    arch = M.ARCHS["mlp"]
    layers = []
    stats = {}
    for i, l in enumerate(arch["layers"]):
        e = {"op": l["op"], "input": l.get("input", i - 1)}
        if l["op"] == "linear":
            w = rng.standard_normal((l["out"], l["in"])).astype(np.float32) * 0.1
            b = np.zeros(l["out"], np.float32)
            e.update(w=f"n{i}_w.ptns", b=f"n{i}_b.ptns")
            write_tensor(d / e["w"], w)
            write_tensor(d / e["b"], b)
        layers.append(e)
        out_ch = l.get("out", 96)
        stats[str(i)] = {"mean": [0.2] * out_ch, "std": [0.3] * out_ch}
    manifest = {
        "name": "mlp", "input": arch["input"], "dataset": "blobs",
        "num_macs": M.num_macs(arch), "layers": layers, "act_stats": stats,
    }
    (d / "manifest.json").write_text(json.dumps(manifest))
    return d


def test_pann_fn_tracks_fp32(tiny_model):
    manifest, weights = aot.load_model(tiny_model.parent, "mlp")
    fp = aot.build_fp32_fn(manifest, weights)
    pann, r_achieved = aot.build_pann_fn(manifest, weights, bx=8, r=7.5)
    x = jnp.asarray(np.random.default_rng(1).random((4, 64)).astype(np.float32))
    yf = np.asarray(fp(x)[0])
    yp = np.asarray(pann(x)[0])
    assert r_achieved > 5.0
    scale = np.abs(yf).max() + 1e-6
    assert np.abs(yf - yp).max() / scale < 0.15, np.abs(yf - yp).max() / scale


def test_hlo_text_emitted(tiny_model):
    manifest, weights = aot.load_model(tiny_model.parent, "mlp")
    pann, _ = aot.build_pann_fn(manifest, weights, bx=6, r=2.0)
    text = aot.to_hlo_text(pann, manifest["input"])
    assert "HloModule" in text
    assert len(text) > 1000


def test_operating_points_cover_budgets():
    assert set(aot.TABLE14_POINTS) == {2, 3, 4, 5, 6, 8}
    for bits, (bx, r) in aot.TABLE14_POINTS.items():
        # Eq. 13: (R + 0.5) * bx == P = 0.5 bits^2 + 4 bits
        p = 0.5 * bits**2 + 4 * bits
        assert abs((r + 0.5) * bx - p) < 1e-6, bits
