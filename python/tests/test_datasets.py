"""Dataset generator tests: determinism, ranges, signal."""

import numpy as np

from compile import datasets as D


def test_deterministic():
    rng1 = np.random.default_rng(42)
    rng2 = np.random.default_rng(42)
    x1, y1 = D.digits(32, rng1)
    x2, y2 = D.digits(32, rng2)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_ranges_zero_one():
    rng = np.random.default_rng(0)
    for fn in (D.digits, D.blobs, D.har):
        x, y = fn(64, rng)
        assert x.min() >= 0.0 and x.max() <= 1.0, fn.__name__
        assert x.dtype == np.float32
        assert y.dtype == np.int32


def test_shapes():
    rng = np.random.default_rng(1)
    x, y = D.digits(8, rng)
    assert x.shape == (8, 1, 16, 16)
    x, y = D.blobs(8, rng)
    assert x.shape == (8, 64)
    x, y = D.har(8, rng)
    assert x.shape == (8, 192)
    assert y.max() < 12


def test_classes_carry_signal():
    """Nearest-class-mean classifier must beat chance by a margin."""
    rng = np.random.default_rng(2)
    xtr, ytr = D.digits(600, rng)
    xte, yte = D.digits(200, rng)
    xtr = xtr.reshape(len(xtr), -1)
    xte = xte.reshape(len(xte), -1)
    means = np.stack([xtr[ytr == c].mean(axis=0) for c in range(10)])
    pred = ((xte[:, None, :] - means[None]) ** 2).sum(-1).argmin(1)
    acc = (pred == yte).mean()
    assert acc > 0.5, acc


def test_generate_writes_files(tmp_path):
    # temporarily shrink specs for speed
    old = D.SPECS
    D.SPECS = {"blobs": (D.blobs, {"train": 32, "test": 16, "calib": 8})}
    try:
        D.generate(tmp_path, seed=0)
    finally:
        D.SPECS = old
    from compile.tensor_io import read_tensor

    x = read_tensor(tmp_path / "blobs" / "train_x.ptns")
    assert x.shape == (32, 64)
