"""L1 Pallas kernels: the PANN multiplier-free hot path.

`pann_matmul` is the integer W+/W- split matmul of Sec. 4/5: activation
codes are loaded into VMEM once per tile and reused for *both* weight
banks — the kernel-level analog of holding Q_x(x_i) on the accumulator
input bus for the whole addition burst (Eq. 13) and of the activation
reuse the paper leans on in App. A.8.

TPU adaptation (DESIGN.md §Hardware-Adaptation): on real TPU hardware
the integer products land on the MXU; the *power* story of repeated
addition is accounted analytically (exactly as the paper does for its
GPU-run experiments), while the BlockSpec tiling expresses the
HBM->VMEM schedule. Kernels run with interpret=True: the CPU PJRT
plugin cannot execute Mosaic custom calls (see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-friendly tile sizes. 128 matches the MXU lane width; shapes in
# this repo are small so most calls use a single tile.
BM = 128
BN = 128


def _matmul_kernel(x_ref, p_ref, n_ref, o_ref):
    """One (BM, BN) output tile: acc_pos - acc_neg with a shared x tile."""
    x = x_ref[...]  # [bm, K] int32 — loaded once, reused for both banks
    pos = jnp.dot(x, p_ref[...].T, preferred_element_type=jnp.int32)
    neg = jnp.dot(x, n_ref[...].T, preferred_element_type=jnp.int32)
    o_ref[...] = pos - neg


def _pad_to(a: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("interpret",))
def pann_matmul(xq: jax.Array, wpos: jax.Array, wneg: jax.Array, interpret: bool = True) -> jax.Array:
    """Integer PANN matmul: `xq @ (wpos - wneg)^T`.

    xq: [M, K] int32 (non-negative codes), wpos/wneg: [N, K] int32.
    Returns [M, N] int32.
    """
    m, k = xq.shape
    n, k2 = wpos.shape
    assert k == k2 and wneg.shape == wpos.shape, (xq.shape, wpos.shape, wneg.shape)
    bm, bn = min(BM, m), min(BN, n)
    xp = _pad_to(xq.astype(jnp.int32), bm, 1)
    pp = _pad_to(wpos.astype(jnp.int32), bn, 1)
    np_ = _pad_to(wneg.astype(jnp.int32), bn, 1)
    mp, npad = xp.shape[0], pp.shape[0]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, npad // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, npad), jnp.int32),
        interpret=interpret,
    )(xp, pp, np_)
    return out[:m, :n]


def _quantize_kernel(x_ref, o_ref, *, inv_scale: float, qmax: int):
    q = jnp.rint(x_ref[...] * inv_scale)
    o_ref[...] = jnp.clip(q, 0.0, float(qmax)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("scale", "qmax", "interpret"))
def quantize_act(x: jax.Array, scale: float, qmax: int, interpret: bool = True) -> jax.Array:
    """Unsigned activation quantization kernel: clip(round(x/scale), 0, qmax).

    x: [M, K] f32 -> [M, K] int32 codes.
    """
    m, k = x.shape
    bm = min(BM, m)
    xp = _pad_to(x, bm, 1)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, inv_scale=1.0 / float(scale), qmax=int(qmax)),
        grid=(xp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.int32),
        interpret=interpret,
    )(xp)
    return out[:m, :k]


def quantized_linear(
    x: jax.Array,
    wpos: jax.Array,
    wneg: jax.Array,
    x_scale: float,
    x_qmax: int,
    w_scale: float,
    bias: jax.Array,
    interpret: bool = True,
) -> jax.Array:
    """Fused layer: quantize activations -> integer matmul -> dequant+bias.

    The building block `aot.py` lowers for every MAC layer of the
    serving graph.
    """
    xq = quantize_act(x, x_scale, x_qmax, interpret=interpret)
    acc = pann_matmul(xq, wpos, wneg, interpret=interpret)
    return acc.astype(jnp.float32) * jnp.float32(x_scale * w_scale) + bias.astype(jnp.float32)
