"""Pure-jnp / numpy oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; the
pytest suite asserts allclose (exact, for integer kernels) between the
two over hypothesis-generated shapes. The Rust engine implements the
same semantics (rust/src/nn/gemm.rs), so these oracles pin all three
layers together.
"""

from __future__ import annotations

import numpy as np


def ref_pann_matmul(xq: np.ndarray, wpos: np.ndarray, wneg: np.ndarray) -> np.ndarray:
    """Integer PANN matmul with the unsigned W+/W- split.

    xq: [M, K] non-negative int32 activation codes
    wpos/wneg: [N, K] non-negative int32 weight codes
    returns [M, N] int32 = xq @ (wpos - wneg)^T
    """
    x = xq.astype(np.int64)
    w = wpos.astype(np.int64) - wneg.astype(np.int64)
    return (x @ w.T).astype(np.int32)


def ref_quantize_act(x: np.ndarray, scale: float, qmax: int) -> np.ndarray:
    """Unsigned activation quantization: clip(round(x/scale), 0, qmax)."""
    q = np.rint(x / scale)
    return np.clip(q, 0, qmax).astype(np.int32)


def ref_dequant_bias(acc: np.ndarray, scale: float, bias: np.ndarray) -> np.ndarray:
    """Dequantize integer accumulators and add a per-column bias."""
    return acc.astype(np.float32) * np.float32(scale) + bias.astype(np.float32)


def ref_quantized_linear(
    x: np.ndarray,
    wpos: np.ndarray,
    wneg: np.ndarray,
    x_scale: float,
    x_qmax: int,
    w_scale: float,
    bias: np.ndarray,
) -> np.ndarray:
    """Full fused reference: quantize -> integer matmul -> dequant+bias."""
    xq = ref_quantize_act(x, x_scale, x_qmax)
    acc = ref_pann_matmul(xq, wpos, wneg)
    return ref_dequant_bias(acc, np.float32(x_scale) * np.float32(w_scale), bias)
