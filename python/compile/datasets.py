"""Synthetic dataset generation (build-time substitute for the paper's
ImageNet / CIFAR / MHEALTH corpora — see DESIGN.md substitution table).

All inputs are normalized to [0, 1]: the paper's unsigned-arithmetic
conversion (Sec. 4) assumes non-negative layer inputs, which holds for
post-ReLU activations and, by this normalization, for the model input.

Usage: python -m compile.datasets --out ../artifacts/data [--seed 0]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from .tensor_io import write_tensor

# 4x4 cell glyph masks, loosely seven-segment-like (shared local
# features across classes). Mirrors rust/src/data/synth.rs in spirit.
GLYPHS = np.array(
    [
        [1, 1, 1, 1, 1, 0, 0, 1, 1, 0, 0, 1, 1, 1, 1, 1],
        [0, 0, 1, 0, 0, 1, 1, 0, 0, 0, 1, 0, 0, 1, 1, 1],
        [1, 1, 1, 0, 0, 0, 1, 0, 0, 1, 0, 0, 1, 1, 1, 1],
        [1, 1, 1, 1, 0, 0, 1, 1, 0, 0, 0, 1, 1, 1, 1, 1],
        [1, 0, 0, 1, 1, 0, 0, 1, 1, 1, 1, 1, 0, 0, 0, 1],
        [1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 1, 1, 1, 1, 1, 0],
        [0, 1, 1, 0, 1, 0, 0, 0, 1, 1, 1, 0, 1, 1, 1, 1],
        [1, 1, 1, 1, 0, 0, 0, 1, 0, 0, 1, 0, 0, 1, 0, 0],
        [0, 1, 1, 0, 1, 1, 1, 1, 1, 0, 0, 1, 0, 1, 1, 0],
        [1, 1, 1, 1, 1, 0, 1, 1, 0, 0, 0, 1, 0, 1, 1, 0],
    ],
    dtype=np.float32,
).reshape(10, 4, 4)


def digits(n: int, rng: np.random.Generator, noise: float = 0.35) -> tuple[np.ndarray, np.ndarray]:
    """16x16 single-channel glyph images, 10 classes, values in [0,1]."""
    y = rng.integers(0, 10, size=n)
    x = np.zeros((n, 1, 16, 16), dtype=np.float32)
    yy, xx = np.mgrid[0:16, 0:16]
    for i in range(n):
        dy, dx = rng.integers(-2, 3, size=2)
        gain = 0.45 + 0.55 * rng.random()
        gy = np.clip(yy - dy, 0, 15) // 4
        gx = np.clip(xx - dx, 0, 15) // 4
        img = GLYPHS[y[i]][gy, gx] * gain + noise * rng.standard_normal((16, 16))
        x[i, 0] = np.clip(img, 0.0, 1.0)
    return x, y.astype(np.int32)


def blobs(n: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """64-d Gaussian mixture, 10 classes, affinely squashed into [0,1]."""
    dim, classes = 64, 10
    means = np.random.default_rng(77).standard_normal((classes, dim)).astype(np.float32) * 0.75
    y = rng.integers(0, classes, size=n)
    x = means[y] + 2.0 * rng.standard_normal((n, dim)).astype(np.float32)
    x = np.clip((x + 5.0) / 10.0, 0.0, 1.0)  # [0,1] contract
    return x.astype(np.float32), y.astype(np.int32)


def har(n: int, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """MHEALTH-like 6-channel x 32-step activity windows, 12 classes."""
    ch, t, classes = 6, 32, 12
    y = rng.integers(0, classes, size=n)
    tt = np.arange(t, dtype=np.float32) / t
    x = np.zeros((n, ch * t), dtype=np.float32)
    for i in range(n):
        c = int(y[i])
        freq = 0.4 + 0.28 * c
        amp = 0.55 + 0.1 * (c % 4)
        phase = rng.random() * 2 * np.pi
        for cc in range(ch):
            sig = (
                amp * np.sin(freq * 2 * np.pi * tt * 4.0 + phase + cc * 0.7)
                + 0.25 * c / classes
                + 0.45 * rng.standard_normal(t)
            )
            x[i, cc * t : (cc + 1) * t] = sig
    x = np.clip((x + 2.0) / 4.0, 0.0, 1.0)  # [0,1] contract
    return x.astype(np.float32), y.astype(np.int32)


SPECS = {
    "digits": (digits, {"train": 12000, "test": 2000, "calib": 64}),
    "blobs": (blobs, {"train": 8000, "test": 2000, "calib": 64}),
    "har": (har, {"train": 8000, "test": 2000, "calib": 64}),
}


def generate(out_dir: Path, seed: int = 0) -> None:
    for name, (fn, splits) in SPECS.items():
        d = out_dir / name
        d.mkdir(parents=True, exist_ok=True)
        meta = {"name": name, "splits": {}}
        for si, (split, n) in enumerate(splits.items()):
            rng = np.random.default_rng(seed * 1000 + si * 97 + sum(map(ord, name)))
            x, y = fn(n, rng)
            write_tensor(d / f"{split}_x.ptns", x)
            write_tensor(d / f"{split}_y.ptns", y)
            meta["splits"][split] = {"n": n, "shape": list(x.shape[1:])}
        meta["classes"] = int(y.max()) + 1
        (d / "meta.json").write_text(json.dumps(meta, indent=1))
        print(f"dataset {name}: {meta['splits']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/data")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    generate(Path(args.out), args.seed)


if __name__ == "__main__":
    main()
