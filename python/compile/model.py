"""L2: model graphs in JAX.

Architectures are declared as SSA node lists in exactly the format the
Rust engine loads (rust/src/nn/model.rs), so one spec drives training,
manifest export and the Rust-side experiments. The forward interpreter
supports a `mac` hook that QAT methods override (fake-quant, PANN,
AdderNet, ShiftAddNet — see quantize.py) and the AOT path replaces with
the Pallas kernels.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Architecture specs (SSA; "input": -1 = model input; default = prev node)
# ---------------------------------------------------------------------------

ARCHS: dict[str, dict] = {
    # digits (1x16x16) — stand-in for ResNet-18 rows
    "cnn-s": {
        "dataset": "digits",
        "input": [1, 16, 16],
        "layers": [
            {"op": "conv", "co": 8, "ci": 1, "k": 3, "stride": 1, "pad": 1, "input": -1},
            {"op": "relu"},
            {"op": "maxpool", "k": 2},
            {"op": "conv", "co": 16, "ci": 8, "k": 3, "stride": 1, "pad": 1},
            {"op": "relu"},
            {"op": "maxpool", "k": 2},
            {"op": "flatten"},
            {"op": "linear", "out": 10, "in": 16 * 4 * 4},
        ],
    },
    # digits residual CNN — stand-in for ResNet-50 rows
    "cnn-r": {
        "dataset": "digits",
        "input": [1, 16, 16],
        "layers": [
            {"op": "conv", "co": 12, "ci": 1, "k": 3, "stride": 1, "pad": 1, "input": -1},  # 0
            {"op": "relu"},                                                                  # 1
            {"op": "conv", "co": 12, "ci": 12, "k": 3, "stride": 1, "pad": 1},               # 2
            {"op": "relu"},                                                                  # 3
            {"op": "add", "rhs": 1},                                                         # 4
            {"op": "maxpool", "k": 2},                                                       # 5
            {"op": "conv", "co": 24, "ci": 12, "k": 3, "stride": 1, "pad": 1},               # 6
            {"op": "relu"},                                                                  # 7
            {"op": "maxpool", "k": 2},                                                       # 8
            {"op": "flatten"},                                                               # 9
            {"op": "linear", "out": 10, "in": 24 * 4 * 4},                                   # 10
        ],
    },
    # digits VGG-ish — stand-in for VGG-16bn rows
    "vgg-t": {
        "dataset": "digits",
        "input": [1, 16, 16],
        "layers": [
            {"op": "conv", "co": 8, "ci": 1, "k": 3, "stride": 1, "pad": 1, "input": -1},
            {"op": "relu"},
            {"op": "conv", "co": 8, "ci": 8, "k": 3, "stride": 1, "pad": 1},
            {"op": "relu"},
            {"op": "maxpool", "k": 2},
            {"op": "conv", "co": 16, "ci": 8, "k": 3, "stride": 1, "pad": 1},
            {"op": "relu"},
            {"op": "maxpool", "k": 2},
            {"op": "flatten"},
            {"op": "linear", "out": 10, "in": 16 * 4 * 4},
        ],
    },
    # blobs MLP — stand-in for MobileNet-V2 rows (small-MAC regime)
    "mlp": {
        "dataset": "blobs",
        "input": [64],
        "layers": [
            {"op": "linear", "out": 96, "in": 64, "input": -1},
            {"op": "relu"},
            {"op": "linear", "out": 96, "in": 96},
            {"op": "relu"},
            {"op": "linear", "out": 10, "in": 96},
        ],
    },
    # har MLP — MHEALTH substitute
    "har-mlp": {
        "dataset": "har",
        "input": [192],
        "layers": [
            {"op": "linear", "out": 64, "in": 192, "input": -1},
            {"op": "relu"},
            {"op": "linear", "out": 64, "in": 64},
            {"op": "relu"},
            {"op": "linear", "out": 12, "in": 64},
        ],
    },
}


def mac_nodes(arch: dict) -> list[int]:
    """Indices of conv/linear nodes."""
    return [i for i, l in enumerate(arch["layers"]) if l["op"] in ("conv", "linear")]


def init_params(arch: dict, seed: int = 0) -> dict[int, dict[str, jnp.ndarray]]:
    """He-init weights for every MAC node."""
    key = jax.random.PRNGKey(seed)
    params: dict[int, dict[str, jnp.ndarray]] = {}
    for i in mac_nodes(arch):
        l = arch["layers"][i]
        key, k1 = jax.random.split(key)
        if l["op"] == "conv":
            shape = (l["co"], l["ci"], l["k"], l["k"])
            fan_in = l["ci"] * l["k"] * l["k"]
            b = jnp.zeros((l["co"],), jnp.float32)
        else:
            shape = (l["out"], l["in"])
            fan_in = l["in"]
            b = jnp.zeros((l["out"],), jnp.float32)
        w = jax.random.normal(k1, shape, jnp.float32) * math.sqrt(2.0 / fan_in)
        params[i] = {"w": w, "b": b}
    return params


# ---------------------------------------------------------------------------
# Forward interpreter
# ---------------------------------------------------------------------------

MacFn = Callable[[int, dict, jnp.ndarray, dict[str, jnp.ndarray]], jnp.ndarray]


def _conv(x, w, b, stride, pad):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def default_mac(i: int, l: dict, x: jnp.ndarray, p: dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Plain fp32 conv/linear."""
    if l["op"] == "conv":
        return _conv(x, p["w"], p["b"], l["stride"], l["pad"])
    return x @ p["w"].T + p["b"]


def forward(arch: dict, params: dict, x: jnp.ndarray, mac: MacFn = default_mac,
            collect: bool = False):
    """Interpret the SSA spec. Returns logits, or all node outputs when
    `collect=True` (activation-statistics capture)."""
    outs: list[jnp.ndarray] = []
    for i, l in enumerate(arch["layers"]):
        src = l.get("input", i - 1)
        inp = x if src == -1 else outs[src]
        op = l["op"]
        if op in ("conv", "linear"):
            y = mac(i, l, inp, params[i])
        elif op == "relu":
            y = jax.nn.relu(inp)
        elif op == "maxpool":
            k = l["k"]
            y = jax.lax.reduce_window(
                inp, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, k, k), "VALID"
            )
        elif op == "gap":
            y = inp.mean(axis=(2, 3))
        elif op == "flatten":
            y = inp.reshape(inp.shape[0], -1)
        elif op == "add":
            y = inp + outs[l["rhs"]]
        else:
            raise ValueError(f"unknown op {op}")
        outs.append(y)
    return outs if collect else outs[-1]


def num_macs(arch: dict) -> int:
    """Total MACs per sample (matches rust Model::num_macs)."""
    shape = list(arch["input"])
    total = 0
    outs: list[list[int]] = []
    for i, l in enumerate(arch["layers"]):
        src = l.get("input", i - 1)
        s = shape if src == -1 else outs[src]
        op = l["op"]
        if op == "conv":
            oh = (s[1] + 2 * l["pad"] - l["k"]) // l["stride"] + 1
            ow = (s[2] + 2 * l["pad"] - l["k"]) // l["stride"] + 1
            total += l["co"] * l["ci"] * l["k"] * l["k"] * oh * ow
            out = [l["co"], oh, ow]
        elif op == "linear":
            total += l["out"] * l["in"]
            out = [l["out"]]
        elif op == "maxpool":
            out = [s[0], s[1] // l["k"], s[2] // l["k"]]
        elif op == "gap":
            out = [s[0]]
        elif op == "flatten":
            out = [int(np.prod(s))]
        else:  # relu, add
            out = list(s)
        outs.append(out)
    return total


def act_stats(arch: dict, params: dict, x: jnp.ndarray) -> dict[int, dict[str, list[float]]]:
    """Per-node output per-channel mean/std (rust BnStats source)."""
    outs = forward(arch, params, x, collect=True)
    stats = {}
    for i, y in enumerate(outs):
        y = np.asarray(y)
        if y.ndim == 4:
            mean = y.mean(axis=(0, 2, 3))
            std = y.std(axis=(0, 2, 3))
        else:
            mean = y.mean(axis=0)
            std = y.std(axis=0)
        stats[i] = {"mean": [float(v) for v in mean], "std": [float(v) for v in std]}
    return stats
