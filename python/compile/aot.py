"""AOT lowering: PANN serving graphs -> HLO text for the Rust runtime.

For each trained model and each power budget, the Alg.-1 operating
point (b̃x, R) is materialized as a self-contained inference function:
PANN weight codes (Eq. 12) baked as constants in W+/W- split form, the
Pallas `quantized_linear` kernel on every MAC layer, jnp glue for
relu/pool/add. Lowered once to HLO *text* (not serialized proto — the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit ids; see
/opt/xla-example/README.md) and loaded by rust/src/runtime/.

Usage: python -m compile.aot --out ../artifacts/hlo [--models cnn-s,mlp]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.pann_matmul import quantized_linear
from .quantize import im2col, pann_quantize_np
from .tensor_io import read_tensor

BATCH = 8

# ACIQ Gaussian clip multipliers for bits 2..8 (mirrors rust aciq.rs).
GAUSS_ALPHA = {2: 1.71, 3: 2.15, 4: 2.55, 5: 2.93, 6: 3.28, 7: 3.61, 8: 3.92}

# Alg.-1 operating points per unsigned-MAC power budget (Table 14):
# budget bits -> (b̃x, R = P/b̃x - 0.5)
TABLE14_POINTS = {2: (6, 10 / 6 - 0.5), 3: (6, 16.5 / 6 - 0.5), 4: (7, 24 / 7 - 0.5),
                  5: (8, 32.5 / 8 - 0.5), 6: (8, 42 / 8 - 0.5), 8: (8, 64 / 8 - 0.5)}


def act_scale_from_stats(stats: dict, bits: int) -> float:
    """Data-free activation scale (mirrors rust BnStats::fit_activations)."""
    alpha = GAUSS_ALPHA[max(2, min(8, bits))]
    clip = max(
        max((m + alpha * s) for m, s in zip(stats["mean"], stats["std"])), 1e-6
    )
    return clip / (2.0**bits - 1.0)


def load_model(models_dir: Path, name: str):
    d = models_dir / name
    manifest = json.loads((d / "manifest.json").read_text())
    weights = {}
    for i, l in enumerate(manifest["layers"]):
        if l["op"] in ("conv", "linear"):
            weights[i] = (
                read_tensor(d / l["w"]).astype(np.float32),
                read_tensor(d / l["b"]).astype(np.float32),
            )
    return manifest, weights


def build_pann_fn(manifest: dict, weights: dict, bx: int, r: float):
    """Inference function with PANN codes baked in. Returns (fn, meta)."""
    layers = manifest["layers"]
    stats = manifest["act_stats"]
    qmax = 2**bx - 1
    baked = {}
    total_l1 = 0.0
    total_elems = 0
    for i, (w, b) in weights.items():
        codes, gamma, adds = pann_quantize_np(w, r)
        pos = np.maximum(codes, 0).astype(np.int32)
        neg = np.maximum(-codes, 0).astype(np.int32)
        src = layers[i].get("input", i - 1)
        if src == -1:
            x_scale = 1.0 / qmax  # inputs are in [0,1] by the data contract
        else:
            x_scale = act_scale_from_stats(stats[str(src)], bx)
        baked[i] = dict(pos=pos, neg=neg, gamma=gamma, x_scale=float(x_scale), bias=b, adds=adds)
        total_l1 += adds * codes.size
        total_elems += codes.size

    def fn(x):
        outs = []
        for i, l in enumerate(layers):
            src = l.get("input", i - 1)
            inp = x if src == -1 else outs[src]
            op = l["op"]
            if op == "conv":
                bk = baked[i]
                co = bk["bias"].shape[0]
                k = int(np.sqrt(bk["pos"].shape[0] * 0 + 1))  # placeholder
                kk = l.get("k", 3)
                rows, (n, oh, ow) = im2col(inp, kk, l["stride"], l["pad"])
                wp = jnp.asarray(bk["pos"].reshape(co, -1))
                wn = jnp.asarray(bk["neg"].reshape(co, -1))
                y = quantized_linear(
                    rows, wp, wn, bk["x_scale"], qmax, bk["gamma"], jnp.asarray(bk["bias"])
                )
                y = y.reshape(n, oh, ow, co).transpose(0, 3, 1, 2)
            elif op == "linear":
                bk = baked[i]
                y = quantized_linear(
                    inp,
                    jnp.asarray(bk["pos"]),
                    jnp.asarray(bk["neg"]),
                    bk["x_scale"],
                    qmax,
                    bk["gamma"],
                    jnp.asarray(bk["bias"]),
                )
            elif op == "relu":
                y = jax.nn.relu(inp)
            elif op == "maxpool":
                kk = l["k"]
                y = jax.lax.reduce_window(inp, -jnp.inf, jax.lax.max, (1, 1, kk, kk), (1, 1, kk, kk), "VALID")
            elif op == "gap":
                y = inp.mean(axis=(2, 3))
            elif op == "flatten":
                y = inp.reshape(inp.shape[0], -1)
            elif op == "add":
                y = inp + outs[l["rhs"]]
            else:
                raise ValueError(op)
            outs.append(y)
        return (outs[-1],)

    r_achieved = total_l1 / max(total_elems, 1)
    return fn, r_achieved


def build_fp32_fn(manifest: dict, weights: dict):
    layers = manifest["layers"]

    def fn(x):
        outs = []
        for i, l in enumerate(layers):
            src = l.get("input", i - 1)
            inp = x if src == -1 else outs[src]
            op = l["op"]
            if op == "conv":
                w, b = weights[i]
                y = jax.lax.conv_general_dilated(
                    inp, jnp.asarray(w), (l["stride"], l["stride"]),
                    [(l["pad"], l["pad"])] * 2, dimension_numbers=("NCHW", "OIHW", "NCHW"),
                ) + jnp.asarray(b)[None, :, None, None]
            elif op == "linear":
                w, b = weights[i]
                y = inp @ jnp.asarray(w).T + jnp.asarray(b)
            elif op == "relu":
                y = jax.nn.relu(inp)
            elif op == "maxpool":
                kk = l["k"]
                y = jax.lax.reduce_window(inp, -jnp.inf, jax.lax.max, (1, 1, kk, kk), (1, 1, kk, kk), "VALID")
            elif op == "gap":
                y = inp.mean(axis=(2, 3))
            elif op == "flatten":
                y = inp.reshape(inp.shape[0], -1)
            elif op == "add":
                y = inp + outs[l["rhs"]]
            else:
                raise ValueError(op)
            outs.append(y)
        return (outs[-1],)

    return fn


def to_hlo_text(fn, input_shape) -> str:
    spec = jax.ShapeDtypeStruct((BATCH, *input_shape), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # as_hlo_text() elides large constants as "{...}", which the xla
    # 0.5.1 text parser silently turns into zeros — print them fully.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the 0.5.1 parser rejects newer metadata attrs (source_end_line…)
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/hlo")
    ap.add_argument("--models-dir", default="../artifacts/models")
    ap.add_argument("--models", default="cnn-s,mlp")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    entries = []
    for name in args.models.split(","):
        manifest, weights = load_model(Path(args.models_dir), name)
        num_macs = manifest["num_macs"]
        # fp32 reference executable
        text = to_hlo_text(build_fp32_fn(manifest, weights), manifest["input"])
        f = f"{name}_fp32.hlo.txt"
        (out / f).write_text(text)
        entries.append(dict(model=name, variant="fp32", file=f, batch=BATCH,
                            input=manifest["input"], giga_flips_per_sample=0.0))
        print(f"wrote {f} ({len(text)} chars)")
        # PANN operating points
        for budget_bits, (bx, r) in TABLE14_POINTS.items():
            fn, r_achieved = build_pann_fn(manifest, weights, bx, r)
            text = to_hlo_text(fn, manifest["input"])
            f = f"{name}_p{budget_bits}.hlo.txt"
            (out / f).write_text(text)
            per_elem = (r_achieved + 0.5) * bx
            entries.append(dict(
                model=name, variant=f"pann-p{budget_bits}", file=f, batch=BATCH,
                budget_bits=budget_bits, bx_tilde=bx, r=r, r_achieved=r_achieved,
                input=manifest["input"],
                giga_flips_per_sample=per_elem * num_macs / 1e9,
            ))
            print(f"wrote {f} (b̃x={bx} R={r:.2f} achieved {r_achieved:.2f})")
    (out / "manifest.json").write_text(json.dumps({"executables": entries}, indent=1))
    print(f"wrote {out}/manifest.json with {len(entries)} executables")


if __name__ == "__main__":
    main()
