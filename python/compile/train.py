"""Build-time training: fp32 reference models + the QAT grid.

Outputs (under --out, default ../artifacts/models):
  <arch>/manifest.json + *.ptns      fp32 weights + act stats (Rust PTQ input)
  qat_results.json                   accuracy of every QAT run (Rust tables
                                     3/4/10/11/12 attach power columns)

Runs are cached by config key; delete the artifacts to retrain.
Usage: python -m compile.train --out ../artifacts/models [--quick]
"""

from __future__ import annotations

import argparse
import json
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import quantize as Q
from .tensor_io import read_tensor, write_tensor


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def load_dataset(data_dir: Path, name: str):
    d = data_dir / name
    if not (d / "train_x.ptns").exists():
        from . import datasets

        datasets.generate(data_dir)
    out = {}
    for split in ("train", "test", "calib"):
        out[split] = (read_tensor(d / f"{split}_x.ptns"), read_tensor(d / f"{split}_y.ptns"))
    return out


# ---------------------------------------------------------------------------
# QAT mac functions
# ---------------------------------------------------------------------------

def make_mac(method: str, bits_w: int, bits_x: int, r: float):
    """Build the mac-hook for the given QAT method. Extra trainable
    tensors (LSQ scales, adder weights, affine) live in params[i]."""

    def quant_acts(x, p):
        return Q.lsq_quant(x, p["sx"], bits_x, unsigned=True)

    def mac(i, l, x, p):
        if method == "fp32":
            return M.default_mac(i, l, x, p)
        if method == "lsq":
            wq = Q.lsq_quant(p["w"], p["sw"], bits_w, unsigned=False)
            xq = quant_acts(x, p)
            return M.default_mac(i, l, xq, {"w": wq, "b": p["b"]})
        if method == "pann":
            wq = Q.pann_fake_quant(p["w"], r)
            xq = quant_acts(x, p)
            return M.default_mac(i, l, xq, {"w": wq, "b": p["b"]})
        if method in ("adder", "shiftadd"):
            # flatten to rows
            if l["op"] == "conv":
                rows, (n, oh, ow) = Q.im2col(x, l["k"], l["stride"], l["pad"])
                w2 = p["w"].reshape(p["w"].shape[0], -1)
            else:
                rows, (n, oh, ow) = x, (x.shape[0], 1, 1)
                w2 = p["w"]
            rows = Q.fake_quant_unsigned(rows, p["sx"], bits_x)
            if method == "adder":
                wq = Q.fake_quant_signed(w2, p["sw"], bits_w)
                y = Q.adder_dense(rows, wq)
            else:  # shiftadd: shift layer then adder layer
                ws = Q.po2_fake_quant(w2, bits_w)
                # normalize the shift layer's output so the adder
                # layer's L1 geometry sees unit-scale inputs
                h = rows @ ws.T / jnp.sqrt(float(w2.shape[1]))
                aq = Q.fake_quant_signed(p["a"], p["sa"], bits_w)
                y = Q.adder_dense(h, aq)
            # AdderNet/ShiftAddNet rely on batch normalization after the
            # L1 layers (their outputs are large negatives); we use batch
            # statistics + learnable affine, as in the original papers.
            y = (y - y.mean(axis=0, keepdims=True)) / (y.std(axis=0, keepdims=True) + 1e-5)
            y = y * p["g"][None, :] + p["b"][None, :]
            if l["op"] == "conv":
                y = y.reshape(n, oh, ow, -1).transpose(0, 3, 1, 2)
            return y
        raise ValueError(method)

    return mac


def init_qat_params(arch, params, method, bits_w, bits_x, seed=0):
    """Augment fp32 params with the method's trainable extras."""
    key = jax.random.PRNGKey(seed + 1)
    x_probe = jnp.ones([1] + arch["input"]) * 0.5
    for i in M.mac_nodes(arch):
        p = params[i]
        if method in ("lsq", "pann"):
            p["sx"] = jnp.asarray(0.5 / (2.0**bits_x - 1) * 2, jnp.float32)
            if method == "lsq":
                p["sw"] = Q.lsq_init_scale(p["w"], bits_w, unsigned=False)
        if method in ("adder", "shiftadd"):
            out = p["w"].shape[0]
            # activation step: cover ~[0, 2.5] post-BN-relu range
            p["sx"] = jnp.asarray(2.5 / (2.0**bits_x - 1.0), jnp.float32)
            # min/max step: weights span +-max|w| over 2^{b-1}-1 codes
            qmax = 2.0 ** (bits_w - 1) - 1.0
            p["sw"] = jnp.max(jnp.abs(p["w"])) / qmax
            p["g"] = jnp.ones((out,), jnp.float32)
            if method == "shiftadd":
                key, k = jax.random.split(key)
                a = jax.random.normal(k, (out, out), jnp.float32) * 0.3
                p["a"] = a
                p["sa"] = jnp.max(jnp.abs(a)) / qmax
    del x_probe
    return params


# ---------------------------------------------------------------------------
# training loop
# ---------------------------------------------------------------------------

def train_model(arch, data, method="fp32", bits=8, r=1.0, epochs=6, batch=128,
                lr=0.05, seed=0, bits_x=None):
    bits_x = bits_x if bits_x is not None else bits
    params = M.init_params(arch, seed)
    params = init_qat_params(arch, params, method, bits, bits_x, seed)
    mac = make_mac(method, bits, bits_x, r)
    if method in ("adder", "shiftadd"):
        # L1-similarity layers train slowly even with AdderNet's
        # adaptive local lr; give them a longer schedule.
        batch = min(batch, 64)
        lr = 0.01
        epochs = epochs * 3

    def loss_fn(p, xb, yb):
        logits = M.forward(arch, p, xb, mac=mac)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))

    adaptive = method in ("adder", "shiftadd")

    @jax.jit
    def step(p, mom, xb, yb, lr_now):
        loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        if adaptive:
            # AdderNet's adaptive local learning rate: scale each
            # layer's gradient to norm sqrt(k) (Chen et al., 2020).
            # matrices: AdderNet adaptive norm; scalars (quantizer
            # steps): frozen — the originals use fixed quant grids.
            g = jax.tree.map(
                lambda gg: gg * jnp.sqrt(gg.size) / (jnp.linalg.norm(gg) + 1e-12)
                if gg.ndim >= 2
                else (gg if gg.ndim == 1 else jnp.zeros_like(gg)),
                g,
            )
        mom = jax.tree.map(lambda m, gg: 0.9 * m + gg, mom, g)
        p = jax.tree.map(lambda pp, m: pp - lr_now * m, p, mom)
        return p, mom, loss

    xtr, ytr = data["train"]
    xtr = jnp.asarray(xtr)
    ytr = jnp.asarray(ytr.astype(np.int32))
    n = xtr.shape[0]
    mom = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    losses = []
    for ep in range(epochs):
        order = rng.permutation(n)
        lr_now = lr * (0.2 ** (ep // max(1, epochs // 2)))
        for s in range(0, n - batch + 1, batch):
            idx = order[s : s + batch]
            params, mom, loss = step(params, mom, xtr[idx], ytr[idx], lr_now)
        losses.append(float(loss))
    acc = evaluate(arch, params, data["test"], mac)
    classes = arch["layers"][-1]["out"]
    if acc < 1.5 / classes and lr > 0.005 and method != "fp32":
        # diverged (quantization-aware training is lr-sensitive at some
        # operating points): retry once with a 5x smaller step
        return train_model(arch, data, method, bits, r, epochs, batch,
                           lr / 5.0, seed, bits_x)
    return params, acc, losses


def evaluate(arch, params, split, mac=M.default_mac, batch=256):
    x, y = split
    x = jnp.asarray(x)
    correct = 0
    fwd = jax.jit(lambda p, xb: M.forward(arch, p, xb, mac=mac))
    for s in range(0, x.shape[0], batch):
        logits = fwd(params, x[s : s + batch])
        correct += int((np.asarray(logits).argmax(axis=1) == y[s : s + batch]).sum())
    return correct / x.shape[0]


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

def export_manifest(arch_name, arch, params, out_dir: Path, data):
    d = out_dir / arch_name
    d.mkdir(parents=True, exist_ok=True)
    layers = []
    for i, l in enumerate(arch["layers"]):
        e = {"op": l["op"], "input": l.get("input", i - 1)}
        if l["op"] == "conv":
            e.update(stride=l["stride"], pad=l["pad"], w=f"n{i}_w.ptns", b=f"n{i}_b.ptns")
            write_tensor(d / e["w"], np.asarray(params[i]["w"], dtype=np.float32))
            write_tensor(d / e["b"], np.asarray(params[i]["b"], dtype=np.float32))
        elif l["op"] == "linear":
            e.update(w=f"n{i}_w.ptns", b=f"n{i}_b.ptns")
            write_tensor(d / e["w"], np.asarray(params[i]["w"], dtype=np.float32))
            write_tensor(d / e["b"], np.asarray(params[i]["b"], dtype=np.float32))
        elif l["op"] == "maxpool":
            e["k"] = l["k"]
        elif l["op"] == "add":
            e["rhs"] = l["rhs"]
        layers.append(e)
    # activation stats on a training subset (data-free quantizer source)
    stats = M.act_stats(arch, params, jnp.asarray(data["train"][0][:512]))
    manifest = {
        "name": arch_name,
        "input": arch["input"],
        "dataset": arch["dataset"],
        "num_macs": M.num_macs(arch),
        "layers": layers,
        "act_stats": {str(k): v for k, v in stats.items()},
    }
    (d / "manifest.json").write_text(json.dumps(manifest))
    return d


# ---------------------------------------------------------------------------
# the QAT grid (tables 3/4/10/11/12)
# ---------------------------------------------------------------------------

# Table 13's (b̃x, R) operating points per LSQ bit width (power-matched).
PANN_QAT_POINTS = {2: (3, 2.83), 3: (6, 2.5), 4: (6, 3.5)}


def qat_grid(quick: bool):
    epochs = 2 if quick else 6
    grid = []
    # Tables 3/10: LSQ vs PANN on the three CNNs at 2/3/4 bits.
    for arch in ("cnn-s", "cnn-r", "vgg-t"):
        for bits in (2, 3, 4):
            bx, r = PANN_QAT_POINTS[bits]
            grid.append(dict(arch=arch, method="lsq", bits=bits, r=0.0, bits_x=bits, epochs=epochs))
            grid.append(dict(arch=arch, method="pann", bits=bits, r=r, bits_x=bx, epochs=epochs))
    # Tables 4/11/12: multiplier-free comparison on three datasets at
    # 3..6 bits, PANN at addition factors 1/1.5/2.
    for arch in ("cnn-s", "mlp", "har-mlp"):
        for bits in (3, 4, 5, 6):
            for rf in (1.0, 1.5, 2.0):
                grid.append(dict(arch=arch, method="pann", bits=bits, r=rf, bits_x=bits, epochs=epochs))
            grid.append(dict(arch=arch, method="shiftadd", bits=bits, r=1.5, bits_x=bits, epochs=epochs))
            grid.append(dict(arch=arch, method="adder", bits=bits, r=2.0, bits_x=bits, epochs=epochs))
    return grid


def run_key(c):
    return f"{c['arch']}_{c['method']}_b{c['bits']}_bx{c['bits_x']}_r{c['r']}_e{c['epochs']}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/models")
    ap.add_argument("--data", default="../artifacts/data")
    ap.add_argument("--quick", action="store_true", help="2-epoch smoke grid")
    ap.add_argument("--skip-qat", action="store_true", help="fp32 exports only")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    data_dir = Path(args.data)

    epochs_fp = 3 if args.quick else 8
    results_path = out_dir / "qat_results.json"
    results = json.loads(results_path.read_text()) if results_path.exists() else {}

    # --- fp32 reference models + manifests ---
    for arch_name, arch in M.ARCHS.items():
        if (out_dir / arch_name / "manifest.json").exists() and f"fp32_{arch_name}" in results:
            print(f"[skip] fp32 {arch_name}")
            continue
        data = load_dataset(data_dir, arch["dataset"])
        params, acc, losses = train_model(arch, data, "fp32", epochs=epochs_fp)
        export_manifest(arch_name, arch, params, out_dir, data)
        results[f"fp32_{arch_name}"] = {"arch": arch_name, "method": "fp32", "acc": acc}
        print(f"fp32 {arch_name}: acc={acc:.4f} loss={losses[-1]:.3f}")
        results_path.write_text(json.dumps(results, indent=1))

    # --- QAT grid ---
    if not args.skip_qat:
        for c in qat_grid(args.quick):
            key = run_key(c)
            if key in results:
                print(f"[skip] {key}")
                continue
            data = load_dataset(data_dir, M.ARCHS[c["arch"]]["dataset"])
            _, acc, _ = train_model(
                M.ARCHS[c["arch"]], data, c["method"], bits=c["bits"], r=c["r"],
                epochs=c["epochs"], bits_x=c["bits_x"],
            )
            results[key] = {**c, "acc": acc}
            print(f"{key}: acc={acc:.4f}")
            results_path.write_text(json.dumps(results, indent=1))

    print(f"wrote {results_path}")


if __name__ == "__main__":
    main()
