"""QAT building blocks: STE fake-quant, LSQ, PANN weight quantization,
AdderNet and ShiftAddNet layers (the paper's Sec. 6 training baselines;
see DESIGN.md for the substitution notes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Straight-through rounding
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_round(x):
    return jnp.rint(x)


def _ste_fwd(x):
    return jnp.rint(x), None


def _ste_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_fwd, _ste_bwd)


def fake_quant_unsigned(x, scale, bits):
    """Unsigned fake quantization with STE (activations after ReLU)."""
    qmax = 2.0**bits - 1.0
    q = jnp.clip(ste_round(x / scale), 0.0, qmax)
    return q * scale


def fake_quant_signed(x, scale, bits):
    """Symmetric signed fake quantization with STE (weights)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(ste_round(x / scale), -qmax - 1.0, qmax)
    return q * scale


# ---------------------------------------------------------------------------
# LSQ — learned step size quantization (Esser et al., 2019)
# ---------------------------------------------------------------------------

def lsq_init_scale(x, bits, unsigned=False):
    """LSQ's initialization: 2<|x|>/sqrt(qmax)."""
    qmax = (2.0**bits - 1.0) if unsigned else (2.0 ** (bits - 1) - 1.0)
    return 2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(qmax) + 1e-9


def lsq_quant(x, scale, bits, unsigned):
    """LSQ fake-quant with the paper's gradient scale on `scale`."""
    qmax = (2.0**bits - 1.0) if unsigned else (2.0 ** (bits - 1) - 1.0)
    qmin = 0.0 if unsigned else -qmax - 1.0
    g = 1.0 / jnp.sqrt(x.size * qmax)
    s = scale * g + jax.lax.stop_gradient(scale * (1.0 - g))  # grad rescale trick
    q = jnp.clip(ste_round(x / s), qmin, qmax)
    return q * s


# ---------------------------------------------------------------------------
# PANN weight quantization (Eq. 12) with STE
# ---------------------------------------------------------------------------

def pann_gamma(w, r):
    """gamma_w = ||w||_1 / (R d)."""
    return jnp.sum(jnp.abs(w)) / (r * w.size) + 1e-12


def pann_fake_quant(w, r):
    """PANN fake quantization (unbounded codes, budgeted L1)."""
    g = pann_gamma(w, r)
    return ste_round(w / g) * g


def pann_quantize_np(w, r):
    """Non-differentiable PANN quantization for export (numpy).

    Returns (codes int32, gamma float, adds_per_element float)."""
    import numpy as np

    w = np.asarray(w, dtype=np.float64)
    l1 = np.abs(w).sum()
    gamma = l1 / (r * w.size) if l1 > 0 else 1.0
    codes = np.rint(w / gamma).astype(np.int64)
    adds = np.abs(codes).sum() / w.size
    return codes.astype(np.int32), float(gamma), float(adds)


# ---------------------------------------------------------------------------
# AdderNet (Chen et al., 2020): y_j = -sum_i |x_i - w_ji|
# ---------------------------------------------------------------------------

@jax.custom_vjp
def adder_dense(x, w):
    """x: [M, K], w: [N, K] -> [M, N] = -sum_k |x - w| (L1 similarity)."""
    return -jnp.sum(jnp.abs(x[:, None, :] - w[None, :, :]), axis=-1)


def _adder_fwd(x, w):
    return adder_dense(x, w), (x, w)


def _adder_bwd(res, g):
    # AdderNet's gradients: full-precision (x - w) for the weights,
    # HardTanh-clipped (w - x) for the activations.
    x, w = res
    diff = x[:, None, :] - w[None, :, :]  # [M, N, K]
    gw = jnp.einsum("mn,mnk->nk", g, diff)
    gx = jnp.einsum("mn,mnk->mk", g, jnp.clip(-diff, -1.0, 1.0))
    return gx, gw


adder_dense.defvjp(_adder_fwd, _adder_bwd)


# ---------------------------------------------------------------------------
# ShiftAddNet (You et al., 2020): power-of-two (shift) layer + adder layer
# ---------------------------------------------------------------------------

def po2_fake_quant(w, bits):
    """Round weights to sign * 2^k with STE; k range set by `bits`."""
    sign = jnp.sign(w)
    mag = jnp.abs(w) + 1e-12
    k = jnp.clip(ste_round(jnp.log2(mag)), -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1.0)
    # STE through the rounding of the exponent
    po2 = 2.0**k
    return sign * (mag + jax.lax.stop_gradient(po2 - mag))


def im2col(x, k, stride, pad):
    """[N,C,H,W] -> [N*OH*OW, C*k*k] matching rust/src/nn/gemm.rs."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            cols.append(
                jax.lax.dynamic_slice(
                    xp, (0, 0, ky, kx), (n, c, (oh - 1) * stride + 1, (ow - 1) * stride + 1)
                )[:, :, ::stride, ::stride]
            )
    # [k*k, N, C, OH, OW] -> [N, OH, OW, C, k*k] -> rows
    stack = jnp.stack(cols, axis=-1)  # [N, C, OH, OW, k*k]
    stack = stack.transpose(0, 2, 3, 1, 4)  # [N, OH, OW, C, k*k]
    return stack.reshape(n * oh * ow, c * k * k), (n, oh, ow)
