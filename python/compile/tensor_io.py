"""`.ptns` binary tensor format — Python side of rust/src/data/tensor_io.rs.

Layout (little endian):
    magic   4 bytes  "PTNS"
    version 1 byte   (1)
    dtype   1 byte   0 = f32, 1 = i32, 2 = u8
    ndim    1 byte
    pad     1 byte   (0)
    dims    ndim x u32
    data    product(dims) x sizeof(dtype)
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"PTNS"
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}


def write_tensor(path: str | Path, arr: np.ndarray) -> None:
    """Write an array (f32 / i32 / u8) as a .ptns file."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _CODES:
        if np.issubdtype(arr.dtype, np.floating):
            arr = arr.astype(np.float32)
        elif np.issubdtype(arr.dtype, np.integer):
            arr = arr.astype(np.int32)
        else:
            raise TypeError(f"unsupported dtype {arr.dtype}")
    code = _CODES[arr.dtype]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<BBBB", 1, code, arr.ndim, 0))
        for d in arr.shape:
            f.write(struct.pack("<I", d))
        f.write(arr.tobytes())


def read_tensor(path: str | Path) -> np.ndarray:
    """Read a .ptns file back into a numpy array."""
    raw = Path(path).read_bytes()
    if raw[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic")
    version, code, ndim, _pad = struct.unpack("<BBBB", raw[4:8])
    if version != 1:
        raise ValueError(f"{path}: unsupported version {version}")
    dims = struct.unpack(f"<{ndim}I", raw[8 : 8 + 4 * ndim])
    dtype = _DTYPES[code]
    data = np.frombuffer(raw[8 + 4 * ndim :], dtype=dtype)
    expect = int(np.prod(dims)) if ndim else 1
    if data.size != expect:
        raise ValueError(f"{path}: payload {data.size} != {expect}")
    return data.reshape(dims).copy()
