//! The PANN weight quantizer (paper Sec. 5.1, Eq. 12) and the unsigned
//! W⁺/W⁻ split (Sec. 4).
//!
//! Given a budget of `R` additions per input element, the quantization
//! step is `γ_w = ‖w‖₁ / (R·d)` and `Q(w_i) = round(w_i/γ_w)`. The
//! codes are *not* confined to a power-of-two range — what is bounded
//! is `‖w_q‖₁/d`, the average number of additions each element costs
//! on the multiplier-free datapath.

use super::ruq::QParams;

/// PANN weight quantizer configuration.
#[derive(Clone, Copy, Debug)]
pub struct PannQuant {
    /// Budget: average additions per input element.
    pub r: f64,
}

/// Quantized weights in PANN form: integer codes plus the step γ_w,
/// split into non-negative W⁺ and W⁻ parts for unsigned arithmetic.
#[derive(Clone, Debug)]
pub struct PannWeights {
    /// Quantization step γ_w.
    pub gamma: f32,
    /// Signed integer codes Q(w_i).
    pub codes: Vec<i64>,
    /// Achieved additions per element: ‖w_q‖₁ / d.
    pub adds_per_element: f64,
    /// Largest |code| — determines the bits b_R needed to *store* the
    /// codes (Table 14's weights-memory column).
    pub max_code: i64,
}

impl PannQuant {
    /// Quantizer at additions budget `R = r` per element (must be > 0).
    pub fn new(r: f64) -> Self {
        assert!(r > 0.0, "additions budget must be positive");
        PannQuant { r }
    }

    /// Quantize weights per Eq. (12).
    pub fn quantize(&self, w: &[f32]) -> PannWeights {
        assert!(!w.is_empty());
        let d = w.len() as f64;
        let l1: f64 = w.iter().map(|&x| x.abs() as f64).sum();
        // The f64→f32 cast underflows to 0.0 for very small-magnitude
        // tensors (‖w‖₁/(R·d) below ~1e-45), which would make x/γ
        // infinite and saturate every code to i64::MAX. Floor at the
        // smallest normal f32, as the RUQ quantizers already do.
        let gamma = if l1 > 0.0 {
            ((l1 / (self.r * d)) as f32).max(f32::MIN_POSITIVE)
        } else {
            1.0
        };
        let codes: Vec<i64> = w.iter().map(|&x| (x / gamma).round() as i64).collect();
        let adds: u64 = codes.iter().map(|c| c.unsigned_abs()).sum();
        let max_code = codes.iter().map(|c| c.abs()).max().unwrap_or(0);
        PannWeights {
            gamma,
            codes,
            adds_per_element: adds as f64 / d,
            max_code,
        }
    }

    /// Dequantized (fake-quantized) weights.
    pub fn fake_quantize(&self, w: &[f32]) -> Vec<f32> {
        let pw = self.quantize(w);
        pw.codes.iter().map(|&c| pw.gamma * c as f32).collect()
    }
}

impl PannWeights {
    /// The unsigned split of Sec. 4: `(W⁺, W⁻)` with
    /// `codes = W⁺ − W⁻`, both non-negative.
    pub fn unsigned_split(&self) -> (Vec<u64>, Vec<u64>) {
        let pos = self.codes.iter().map(|&c| c.max(0) as u64).collect();
        let neg = self.codes.iter().map(|&c| (-c).max(0) as u64).collect();
        (pos, neg)
    }

    /// Bits needed to store a code (sign handled by bank membership
    /// after the split): ceil(log2(max_code + 1)).
    pub fn code_bits(&self) -> u32 {
        (64 - (self.max_code as u64).leading_zeros()).max(1)
    }

    /// Dequantize code i.
    pub fn dequant(&self, i: usize) -> f32 {
        self.gamma * self.codes[i] as f32
    }
}

/// Fake-quantize weights with a plain signed RUQ at `bits` — the
/// baseline the tables compare against (equal weight/activation bits).
pub fn ruq_weights(w: &[f32], bits: u32) -> (QParams, Vec<i64>) {
    let q = super::ruq::fit_signed(w, bits);
    let codes = q.quantize_slice(w);
    (q, codes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gauss(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32 * 0.1).collect()
    }

    #[test]
    fn adds_budget_respected() {
        // ‖w_q‖₁/d must land close to the prescribed R (Sec. 5.1:
        // "as close as possible to the prescribed R").
        let w = gauss(4096, 1);
        for r in [1.0, 2.0, 4.0, 7.5] {
            let pw = PannQuant::new(r).quantize(&w);
            assert!(
                (pw.adds_per_element - r).abs() / r < 0.1,
                "R={r} achieved {}",
                pw.adds_per_element
            );
        }
        // Below R = 1, rounding sends many weights to code 0, so the
        // achieved budget undershoots ("as close as possible", Sec 5.1).
        let pw = PannQuant::new(0.5).quantize(&w);
        assert!(pw.adds_per_element <= 0.5 && pw.adds_per_element > 0.3);
    }

    #[test]
    fn error_shrinks_with_r() {
        let w = gauss(4096, 2);
        let mut last = f64::INFINITY;
        for r in [0.5, 1.0, 2.0, 4.0, 8.0] {
            let fq = PannQuant::new(r).fake_quantize(&w);
            let mse: f64 = w
                .iter()
                .zip(&fq)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / w.len() as f64;
            assert!(mse < last, "R={r}: {mse} !< {last}");
            last = mse;
        }
    }

    #[test]
    fn unsigned_split_reconstructs() {
        let w = gauss(512, 3);
        let pw = PannQuant::new(2.0).quantize(&w);
        let (pos, neg) = pw.unsigned_split();
        for i in 0..w.len() {
            assert_eq!(pos[i] as i64 - neg[i] as i64, pw.codes[i]);
            // at most one side nonzero
            assert!(pos[i] == 0 || neg[i] == 0);
        }
    }

    #[test]
    fn rounding_error_bounded_by_half_gamma() {
        let w = gauss(1024, 4);
        let pw = PannQuant::new(3.0).quantize(&w);
        for (i, &wi) in w.iter().enumerate() {
            let e = (wi - pw.dequant(i)).abs();
            assert!(e <= pw.gamma * 0.5 + 1e-6);
        }
    }

    #[test]
    fn codes_not_range_limited() {
        // Unlike RUQ, a single huge weight may get a code far beyond
        // 2^b — the budget constrains the average, not the max.
        let mut w = vec![0.001f32; 1000];
        w[0] = 10.0;
        let pw = PannQuant::new(1.0).quantize(&w);
        assert!(pw.max_code > 100, "max code {}", pw.max_code);
        assert!(pw.code_bits() > 6);
    }

    #[test]
    fn tiny_weights_do_not_underflow_gamma() {
        // Regression: subnormal-magnitude weights at a large R used to
        // underflow the f64→f32 cast of γ to 0.0, sending every code
        // to ±i64::MAX through x/0. γ must stay a positive normal and
        // the codes finite and budget-bounded.
        let w = vec![1.0e-45f32; 32]; // rounds to the smallest subnormal
        assert!(w[0] > 0.0, "test weights must be nonzero subnormals");
        let pw = PannQuant::new(64.0).quantize(&w);
        assert!(pw.gamma >= f32::MIN_POSITIVE, "gamma {} underflowed", pw.gamma);
        assert!(pw.max_code < i64::MAX, "codes saturated: {}", pw.max_code);
        assert!(pw.adds_per_element <= 64.0 + 0.5);
        for (i, _) in w.iter().enumerate() {
            assert!(pw.dequant(i).is_finite());
        }
    }

    #[test]
    fn zero_weights_safe() {
        let w = vec![0.0f32; 64];
        let pw = PannQuant::new(1.0).quantize(&w);
        assert_eq!(pw.adds_per_element, 0.0);
        assert!(pw.codes.iter().all(|&c| c == 0));
    }
}
