//! Quantization-error theory of Sec. 5.3 (Eqs. 14–19) and the Monte
//! Carlo machinery behind Figs. 4 and 16.
//!
//! At a fixed power budget `P`, the RUQ and PANN mean squared errors of
//! a length-`d` dot product are (uniform weights in `[-M_w/2, M_w/2]`,
//! uniform ReLU activations in `[0, M_x]`):
//!
//! - Eq. (16): `MSE_RUQ  = d·M_x²·M_w²/144 · (2^{-2b_x} + 4·2^{-2b_w})`
//! - Eq. (19): `MSE_PANN = d·M_x²·M_w²/144 · (2^{-2b̃_x} + b̃_x²/(2P − b̃_x)²)`

use crate::power::model::mac_power_unsigned_total;
use crate::util::Rng;

/// Eq. (16) with `b_w = b_x = b` (the configuration the paper uses in
/// Fig. 4, since the multiplier power is governed by the max anyway).
pub fn mse_ruq(d: usize, m_x: f64, m_w: f64, b: u32) -> f64 {
    let c = d as f64 * m_x * m_x * m_w * m_w / 144.0;
    c * (2f64.powi(-2 * b as i32) + 4.0 * 2f64.powi(-2 * b as i32))
}

/// Eq. (18): PANN MSE at explicit `(b̃_x, R)`.
pub fn mse_pann_r(d: usize, m_x: f64, m_w: f64, bx_tilde: u32, r: f64) -> f64 {
    let c = d as f64 * m_x * m_x * m_w * m_w / 144.0;
    c * (2f64.powi(-2 * bx_tilde as i32) + 1.0 / (4.0 * r * r))
}

/// Eq. (19): PANN MSE at power budget `P` (with `R = P/b̃_x − 0.5`).
/// Returns `None` when the budget can't afford width `b̃_x`.
pub fn mse_pann(d: usize, m_x: f64, m_w: f64, bx_tilde: u32, p: f64) -> Option<f64> {
    let bt = bx_tilde as f64;
    let denom = 2.0 * p - bt;
    if denom <= 0.0 {
        return None;
    }
    let c = d as f64 * m_x * m_x * m_w * m_w / 144.0;
    Some(c * (2f64.powi(-2 * bx_tilde as i32) + bt * bt / (denom * denom)))
}

/// Optimal activation width for PANN at budget `P`: argmin of Eq. (19)
/// over `b̃_x ∈ [2, 16]`.
pub fn optimal_bx_tilde(d: usize, m_x: f64, m_w: f64, p: f64) -> (u32, f64) {
    (2..=16)
        .filter_map(|bt| mse_pann(d, m_x, m_w, bt, p).map(|e| (bt, e)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("budget too small for any bit width")
}

/// The Fig. 4 ratio `MSE_RUQ / MSE_PANN` at the power of a `b`-bit
/// unsigned MAC, with PANN's `b̃_x` chosen optimally.
pub fn fig4_ratio_uniform(d: usize, b: u32) -> f64 {
    let p = mac_power_unsigned_total(b);
    let ruq = mse_ruq(d, 1.0, 1.0, b);
    let (_, pann) = optimal_bx_tilde(d, 1.0, 1.0, p);
    ruq / pann
}

/// Monte-Carlo estimate of the dot-product MSE for RUQ at `b` bits on
/// the uniform model of Sec. 5.3. Used to validate Eq. (16).
pub fn mc_mse_ruq(d: usize, b: u32, trials: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut acc = 0.0;
    // Ideal mid-rise uniform quantizers over the model's known ranges,
    // exactly matching Eq. 15's assumptions: errors are U[-γ/2, γ/2]
    // and unbiased *conditionally on the value* (a clipping quantizer
    // would add a boundary bias whose cross terms grow as d², which the
    // paper's derivation explicitly excludes via E[ε|w] = 0).
    let gw = 1.0f64 / (1i64 << b) as f64; // M_w / 2^b, M_w = 1
    let gx = 1.0f64 / (1i64 << b) as f64; // M_x / 2^b, M_x = 1
    let midrise = |v: f64, g: f64| ((v / g).floor() + 0.5) * g;
    for _ in 0..trials {
        let w: Vec<f64> = (0..d).map(|_| rng.f64() - 0.5).collect();
        let x: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let y: f64 = w.iter().zip(&x).map(|(&a, &c)| a * c).sum();
        let yq: f64 = w
            .iter()
            .zip(&x)
            .map(|(&a, &c)| midrise(a, gw) * midrise(c, gx))
            .sum();
        acc += (y - yq).powi(2);
    }
    acc / trials as f64
}

/// Monte-Carlo estimate of the PANN dot-product MSE at `(b̃_x, R)` on
/// the uniform model. Validates Eqs. (17)–(18).
pub fn mc_mse_pann(d: usize, bx_tilde: u32, r: f64, trials: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let quant = super::pann::PannQuant::new(r);
    let qx = super::ruq::fit_unsigned_clipped(1.0, bx_tilde);
    let mut acc = 0.0;
    for _ in 0..trials {
        let w: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let wq = quant.fake_quantize(&w);
        let y: f64 = w.iter().zip(&x).map(|(&a, &c)| (a * c) as f64).sum();
        let yq: f64 = wq
            .iter()
            .zip(&x)
            .map(|(&a, &c)| (a * qx.dequantize(qx.quantize(c))) as f64)
            .sum();
        acc += (y - yq).powi(2);
    }
    acc / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pann_beats_ruq_at_low_bits() {
        // Fig. 4: ratio > 1 at the low bit widths.
        for b in [2u32, 3] {
            let ratio = fig4_ratio_uniform(1000, b);
            assert!(ratio > 1.0, "b={b} ratio {ratio}");
        }
    }

    #[test]
    fn ruq_better_at_high_bits() {
        // Fig. 4: at high bit widths RUQ is relatively better (<1).
        let ratio = fig4_ratio_uniform(1000, 8);
        assert!(ratio < 1.0, "ratio {ratio}");
    }

    #[test]
    fn optimal_width_grows_with_budget() {
        // Fig. 16 / App. A.9: optimal b̃_x increases with P.
        let (b_lo, _) = optimal_bx_tilde(1000, 1.0, 1.0, 10.0);
        let (b_hi, _) = optimal_bx_tilde(1000, 1.0, 1.0, 64.0);
        assert!(b_hi > b_lo, "{b_lo} -> {b_hi}");
    }

    #[test]
    fn mc_validates_ruq_theory() {
        let d = 256;
        let b = 4;
        let mc = mc_mse_ruq(d, b, 3000, 11);
        let th = mse_ruq(d, 1.0, 1.0, b);
        assert!(
            (mc / th - 1.0).abs() < 0.35,
            "mc {mc} vs theory {th} (ratio {})",
            mc / th
        );
    }

    #[test]
    fn mc_validates_pann_theory() {
        let d = 256;
        let (bt, r) = (5u32, 2.0);
        let mc = mc_mse_pann(d, bt, r, 3000, 12);
        let th = mse_pann_r(d, 1.0, 1.0, bt, r);
        assert!(
            (mc / th - 1.0).abs() < 0.35,
            "mc {mc} vs theory {th} (ratio {})",
            mc / th
        );
    }

    #[test]
    fn eq19_equals_eq18_at_matching_r() {
        let (d, mx, mw, bt) = (100, 1.0, 1.0, 4u32);
        let p = 24.0;
        let r = p / bt as f64 - 0.5;
        let via_p = mse_pann(d, mx, mw, bt, p).unwrap();
        let via_r = mse_pann_r(d, mx, mw, bt, r);
        // Eq. 19 substitutes R = P/b - 0.5 -> denominator 2P - b means
        // R ≈ (2P-b)/(2b); check they agree to the paper's approximation.
        assert!((via_p / via_r - 1.0).abs() < 0.02, "{via_p} vs {via_r}");
    }
}
