//! Quantizers and quantization-error theory.
//!
//! The paper evaluates PANN against a family of post-training
//! quantization (PTQ) baselines. We implement each family member the
//! paper compares to (see DESIGN.md's substitution table for how the
//! closed-source baselines are mapped):
//!
//! - [`ruq`] — the regular uniform quantizer of Sec. 5.3, also used as
//!   the "Dynamic" baseline (ranges fitted on the fly per tensor).
//! - [`aciq`] — analytic clipping (Banner et al. 2019): optimal clip
//!   for Gaussian/Laplace data at a given bit width.
//! - [`bnstats`] — data-free range estimation from batch-norm
//!   statistics (the distilled-data core of ZeroQ).
//! - [`dfq`] — weight equalization + bias correction (Nagel et al.
//!   2019), our stand-in for the generative data-free method.
//! - [`recon`] — AdaRound-style rounding reconstruction on a small
//!   calibration set, our stand-in for BRECQ.
//! - [`pann`] — the paper's weight quantizer (Eq. 12): quantization
//!   step `γ_w = ‖w‖₁/(R·d)` tuned to a budget of `R` additions per
//!   element, plus the unsigned W⁺/W⁻ split of Sec. 4.
//! - [`error`] — the MSE theory of Sec. 5.3 (Eqs. 14–19) with Monte
//!   Carlo validation (Figs. 4 and 16).

pub mod aciq;
pub mod bnstats;
pub mod dfq;
pub mod error;
pub mod pann;
pub mod recon;
pub mod ruq;

pub use pann::{PannQuant, PannWeights};
pub use ruq::QParams;

/// Which range-fitting method a PTQ baseline uses for activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActQuantMethod {
    /// Min/max on the fly (per batch) — "Dynamic".
    Dynamic,
    /// Analytic clipping on a calibration set — "ACIQ".
    Aciq,
    /// Data-free, from batch-norm statistics — "BN-Stats" (ZeroQ core).
    BnStats,
    /// Weight equalization + bias correction — "DFQ" (data-free).
    Dfq,
    /// Rounding reconstruction on a calibration set — "Recon" (BRECQ
    /// family).
    Recon,
}

impl ActQuantMethod {
    /// Every method, in the paper's reporting order.
    pub const ALL: [ActQuantMethod; 5] = [
        ActQuantMethod::Dynamic,
        ActQuantMethod::Aciq,
        ActQuantMethod::BnStats,
        ActQuantMethod::Dfq,
        ActQuantMethod::Recon,
    ];

    /// Stable lower-case label (reports, artifacts).
    pub fn name(&self) -> &'static str {
        match self {
            ActQuantMethod::Dynamic => "dynamic",
            ActQuantMethod::Aciq => "aciq",
            ActQuantMethod::BnStats => "bn-stats",
            ActQuantMethod::Dfq => "dfq",
            ActQuantMethod::Recon => "recon",
        }
    }

    /// Inverse of [`Self::name`], for artifacts that persist the
    /// method (e.g. `menu.json`).
    pub fn from_name(name: &str) -> Option<ActQuantMethod> {
        Self::ALL.into_iter().find(|m| m.name() == name)
    }
}
