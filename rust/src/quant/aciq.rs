//! ACIQ — Analytic Clipping for Integer Quantization
//! (Banner, Nahshan & Soudry, 2019). The paper uses ACIQ as its
//! small-calibration-set PTQ baseline and as the activation quantizer
//! inside PANN for several experiments (Tables 2, 15; Fig. 16).
//!
//! ACIQ picks a clipping value `α` that minimizes the expected MSE
//! `E[(x - clip_quant(x))²]` assuming the data is Gaussian or Laplace;
//! the optimum trades clipping distortion (tails) against quantization
//! noise (α²/(3·4^b) for a 2α range).

use super::ruq::{fit_unsigned_clipped, QParams};

/// Optimal clip multipliers α*/σ for zero-mean *Gaussian* data at
/// bit widths 2..=8 (numerically derived; Banner et al. Table 1 region).
const GAUSS_ALPHA: [f64; 7] = [1.71, 2.15, 2.55, 2.93, 3.28, 3.61, 3.92];

/// Optimal clip multipliers α*/b for zero-mean *Laplace(b)* data.
const LAPLACE_ALPHA: [f64; 7] = [2.83, 3.89, 5.03, 6.20, 7.41, 8.64, 9.89];

/// Assumed distribution family for the analytic clip.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Zero-mean Gaussian data (σ scale parameter).
    Gauss,
    /// Zero-mean Laplace data (b scale parameter).
    Laplace,
}

/// Analytic optimal clipping value for `bits`-bit quantization of data
/// with the given scale parameter (σ for Gauss, b for Laplace).
pub fn optimal_clip(family: Family, scale_param: f64, bits: u32) -> f64 {
    let idx = (bits.clamp(2, 8) - 2) as usize;
    match family {
        Family::Gauss => GAUSS_ALPHA[idx] * scale_param,
        Family::Laplace => LAPLACE_ALPHA[idx] * scale_param,
    }
}

/// Fit an unsigned ACIQ quantizer for ReLU activations from calibration
/// samples: estimates σ on the *pre-clip* data and clips at α*(σ).
///
/// ReLU activations are half-Gaussian; we estimate the underlying σ via
/// the second moment (E[x²] of a half-Gaussian equals σ²).
pub fn fit_relu_activations(xs: &[f32], bits: u32) -> QParams {
    assert!(!xs.is_empty());
    let m2 = xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / xs.len() as f64;
    let sigma = m2.sqrt().max(1e-12);
    let clip = optimal_clip(Family::Gauss, sigma, bits);
    let mx = xs.iter().fold(0.0f32, |m, &x| m.max(x)) as f64;
    fit_unsigned_clipped(clip.min(mx.max(1e-12)) as f32, bits)
}

/// Fit a signed ACIQ quantizer for weights (zero-mean, Gaussian-ish).
pub fn fit_weights(ws: &[f32], bits: u32) -> QParams {
    assert!(!ws.is_empty());
    let m = ws.iter().map(|&w| w as f64).sum::<f64>() / ws.len() as f64;
    let var = ws.iter().map(|&w| (w as f64 - m).powi(2)).sum::<f64>() / ws.len() as f64;
    let sigma = var.sqrt().max(1e-12);
    let clip = optimal_clip(Family::Gauss, sigma, bits);
    let hi = ((1i64 << (bits - 1)) - 1) as f32;
    QParams::signed((clip as f32 / hi).max(f32::MIN_POSITIVE), bits)
}

/// Numerically search the clip that minimizes empirical quantization
/// MSE on the given samples (used as a general fallback and to test
/// the analytic values).
pub fn empirical_optimal_clip(xs: &[f32], bits: u32, unsigned: bool) -> f32 {
    let mx = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-12);
    let mut best = (f64::INFINITY, mx);
    let steps = 60;
    for i in 1..=steps {
        let clip = mx * i as f32 / steps as f32;
        let q = if unsigned {
            fit_unsigned_clipped(clip, bits)
        } else {
            let hi = ((1i64 << (bits - 1)) - 1) as f32;
            QParams::signed(clip / hi, bits)
        };
        let mse = q.mse(xs);
        if mse < best.0 {
            best = (mse, clip);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn clip_grows_with_bits() {
        for fam in [Family::Gauss, Family::Laplace] {
            let mut last = 0.0;
            for bits in 2..=8 {
                let c = optimal_clip(fam, 1.0, bits);
                assert!(c > last);
                last = c;
            }
        }
    }

    #[test]
    fn analytic_clip_near_empirical_gauss() {
        // The tabulated Gaussian α* should be close to the empirical
        // MSE-optimal clip on large Gaussian samples.
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..100_000).map(|_| r.normal() as f32).collect();
        for bits in [3u32, 4, 6] {
            let emp = empirical_optimal_clip(&xs, bits, false) as f64;
            let ana = optimal_clip(Family::Gauss, 1.0, bits);
            assert!(
                (emp - ana).abs() / ana < 0.25,
                "bits {bits}: empirical {emp} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn aciq_beats_minmax_on_gaussian_low_bits() {
        // The whole point of clipping: at low bit widths ACIQ's MSE is
        // smaller than plain min/max RUQ on heavy-ish tailed data.
        let mut r = Rng::new(6);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal() as f32).collect();
        for bits in [2u32, 3, 4] {
            let aciq = fit_weights(&xs, bits);
            let ruq = super::super::ruq::fit_signed(&xs, bits);
            assert!(
                aciq.mse(&xs) < ruq.mse(&xs),
                "bits {bits}: {} !< {}",
                aciq.mse(&xs),
                ruq.mse(&xs)
            );
        }
    }

    #[test]
    fn relu_activation_fit() {
        let mut r = Rng::new(7);
        let xs: Vec<f32> = (0..50_000).map(|_| (r.normal() as f32).max(0.0) * 3.0).collect();
        let q = fit_relu_activations(&xs, 4);
        assert!(q.qmin == 0);
        assert!(q.scale > 0.0);
        // quantizing in-range data must be lossy but sane
        let mse = q.mse(&xs);
        assert!(mse > 0.0 && mse < 1.0, "mse {mse}");
    }
}
