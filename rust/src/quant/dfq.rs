//! Data-free quantization: cross-layer weight equalization and bias
//! correction (Nagel et al., 2019) — our stand-in for the paper's
//! generative data-free baseline (GDFQ), per DESIGN.md.
//!
//! **Equalization.** For consecutive layers `y = W₂·relu(W₁x + b₁)`,
//! ReLU is positively homogeneous, so scaling output channel `c` of
//! `W₁` by `1/s_c` and column `c` of `W₂` by `s_c` leaves the function
//! unchanged. Choosing `s_c = sqrt(r₁_c / r₂_c)` equalizes the dynamic
//! ranges, which shrinks the per-channel range spread that breaks
//! low-bit per-tensor quantization.
//!
//! **Bias correction.** Quantizing `W → W + ε` shifts layer outputs by
//! `E[ε·x] = ε·E[x]`; with BN statistics, `E[x]` per input channel is
//! known data-free, so the shift can be folded out of the bias.

/// Per-output-channel max-abs ranges of a weight matrix stored row
/// major as `[out][in]`.
pub fn channel_ranges(w: &[f32], out_ch: usize, in_ch: usize) -> Vec<f32> {
    assert_eq!(w.len(), out_ch * in_ch);
    (0..out_ch)
        .map(|o| w[o * in_ch..(o + 1) * in_ch].iter().fold(0.0f32, |m, &x| m.max(x.abs())))
        .collect()
}

/// Equalize a pair of layers in place. `w1` is `[mid][in]`, `b1` is
/// `[mid]`, `w2` is `[out][mid]`. Returns the applied scales.
pub fn equalize_pair(
    w1: &mut [f32],
    b1: &mut [f32],
    w2: &mut [f32],
    in_ch: usize,
    mid_ch: usize,
    out_ch: usize,
) -> Vec<f32> {
    assert_eq!(w1.len(), mid_ch * in_ch);
    assert_eq!(b1.len(), mid_ch);
    assert_eq!(w2.len(), out_ch * mid_ch);
    let r1 = channel_ranges(w1, mid_ch, in_ch);
    // ranges of w2 *columns* (input channel c of layer 2)
    let r2: Vec<f32> = (0..mid_ch)
        .map(|c| (0..out_ch).fold(0.0f32, |m, o| m.max(w2[o * mid_ch + c].abs())))
        .collect();
    let scales: Vec<f32> = r1
        .iter()
        .zip(&r2)
        .map(|(&a, &b)| {
            if a <= 1e-12 || b <= 1e-12 {
                1.0
            } else {
                (a / b).sqrt().clamp(1e-4, 1e4)
            }
        })
        .collect();
    for c in 0..mid_ch {
        let s = scales[c];
        for i in 0..in_ch {
            w1[c * in_ch + i] /= s;
        }
        b1[c] /= s;
        for o in 0..out_ch {
            w2[o * mid_ch + c] *= s;
        }
    }
    scales
}

/// Bias correction: subtract the expected output shift caused by the
/// weight quantization error. `w_err = W_q − W` is `[out][in]`,
/// `mean_in` the per-input-channel expected activation.
pub fn bias_correction(w_err: &[f32], mean_in: &[f32], out_ch: usize, in_ch: usize) -> Vec<f32> {
    assert_eq!(w_err.len(), out_ch * in_ch);
    assert_eq!(mean_in.len(), in_ch);
    (0..out_ch)
        .map(|o| {
            (0..in_ch)
                .map(|i| w_err[o * in_ch + i] * mean_in[i])
                .sum::<f32>()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Reference two-layer fp32 forward.
    fn fwd(w1: &[f32], b1: &[f32], w2: &[f32], x: &[f32], inc: usize, mid: usize, out: usize) -> Vec<f32> {
        let mut h = vec![0.0f32; mid];
        for c in 0..mid {
            let mut s = b1[c];
            for i in 0..inc {
                s += w1[c * inc + i] * x[i];
            }
            h[c] = s.max(0.0);
        }
        let mut y = vec![0.0f32; out];
        for o in 0..out {
            for c in 0..mid {
                y[o] += w2[o * mid + c] * h[c];
            }
        }
        y
    }

    #[test]
    fn equalization_preserves_function() {
        let (inc, mid, out) = (6, 8, 4);
        let mut r = Rng::new(9);
        let mut w1: Vec<f32> = (0..mid * inc).map(|_| r.normal() as f32).collect();
        // inject wildly imbalanced channels
        for i in 0..inc {
            w1[i] *= 50.0;
        }
        let mut b1: Vec<f32> = (0..mid).map(|_| r.normal() as f32).collect();
        let mut w2: Vec<f32> = (0..out * mid).map(|_| r.normal() as f32).collect();
        let x: Vec<f32> = (0..inc).map(|_| r.normal() as f32).collect();
        let before = fwd(&w1, &b1, &w2, &x, inc, mid, out);
        equalize_pair(&mut w1, &mut b1, &mut w2, inc, mid, out);
        let after = fwd(&w1, &b1, &w2, &x, inc, mid, out);
        for (a, b) in before.iter().zip(&after) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn equalization_shrinks_range_spread() {
        let (inc, mid, out) = (4, 16, 4);
        let mut r = Rng::new(10);
        let mut w1: Vec<f32> = (0..mid * inc).map(|_| r.normal() as f32).collect();
        for i in 0..inc {
            w1[i] *= 100.0; // one huge channel
        }
        let mut b1 = vec![0.0f32; mid];
        let mut w2: Vec<f32> = (0..out * mid).map(|_| r.normal() as f32).collect();
        let spread = |w: &[f32]| {
            let rr = channel_ranges(w, mid, inc);
            let (mut lo, mut hi) = (f32::INFINITY, 0.0f32);
            for v in rr {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            hi / lo.max(1e-9)
        };
        let before = spread(&w1);
        equalize_pair(&mut w1, &mut b1, &mut w2, inc, mid, out);
        let after = spread(&w1);
        assert!(after < before / 2.0, "spread {before} -> {after}");
    }

    #[test]
    fn bias_correction_centers_error() {
        let (out, inc) = (3, 5);
        let mut r = Rng::new(11);
        let w: Vec<f32> = (0..out * inc).map(|_| r.normal() as f32).collect();
        let q = crate::quant::ruq::fit_signed(&w, 3);
        let wq = q.fake_quantize(&w);
        let err: Vec<f32> = wq.iter().zip(&w).map(|(a, b)| a - b).collect();
        let mean_in: Vec<f32> = (0..inc).map(|_| r.f32() + 0.5).collect();
        let corr = bias_correction(&err, &mean_in, out, inc);
        // After subtracting corr from the quantized layer's output, the
        // *expected* output equals the fp32 expectation exactly (the
        // estimator is exact for deterministic mean_in).
        for o in 0..out {
            let shift: f32 = (0..inc).map(|i| err[o * inc + i] * mean_in[i]).sum();
            assert!((corr[o] - shift).abs() < 1e-6);
        }
    }
}
