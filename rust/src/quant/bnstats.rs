//! Data-free range estimation from batch-norm statistics — the
//! distilled-data core of ZeroQ (Cai et al., 2020), our stand-in for
//! the paper's ZeroQ baseline (see DESIGN.md).
//!
//! A layer that follows `BN(μ, σ²) → ReLU` produces activations whose
//! distribution is known without any data: a rectified Gaussian with
//! per-channel mean `μ_c` and std `σ_c`. We derive the activation
//! clipping range directly from the stored statistics, then fit an
//! unsigned RUQ to it.

use super::ruq::{fit_unsigned_clipped, QParams};

/// Batch-norm running statistics of one layer (per output channel).
#[derive(Clone, Debug)]
pub struct BnStats {
    /// Per-channel running mean.
    pub mean: Vec<f32>,
    /// Per-channel running standard deviation.
    pub std: Vec<f32>,
}

impl BnStats {
    /// Pair per-channel means and standard deviations (equal length).
    pub fn new(mean: Vec<f32>, std: Vec<f32>) -> Self {
        assert_eq!(mean.len(), std.len());
        BnStats { mean, std }
    }

    /// The `α`-sigma clip of the post-ReLU activation range implied by
    /// the statistics: `max_c (μ_c + α·σ_c)` clamped at 0.
    pub fn relu_clip(&self, alpha: f32) -> f32 {
        self.mean
            .iter()
            .zip(&self.std)
            .map(|(&m, &s)| (m + alpha * s).max(0.0))
            .fold(0.0f32, f32::max)
            .max(1e-6)
    }

    /// Fit an unsigned quantizer for the post-ReLU activations of this
    /// layer without seeing any data.
    pub fn fit_activations(&self, bits: u32) -> QParams {
        // α follows the ACIQ Gaussian table so BN-Stats and ACIQ use
        // the same clipping philosophy, only the σ source differs
        // (stored statistics vs calibration samples).
        let alpha = super::aciq::optimal_clip(super::aciq::Family::Gauss, 1.0, bits) as f32;
        fit_unsigned_clipped(self.relu_clip(alpha), bits)
    }

    /// Sample synthetic calibration activations from the statistics
    /// (ZeroQ's distilled data, one gaussian per channel + ReLU).
    pub fn sample_activations(&self, per_channel: usize, rng: &mut crate::util::Rng) -> Vec<f32> {
        let mut out = Vec::with_capacity(per_channel * self.mean.len());
        for (&m, &s) in self.mean.iter().zip(&self.std) {
            for _ in 0..per_channel {
                out.push((rng.normal_ms(m as f64, s as f64) as f32).max(0.0));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn clip_covers_most_mass() {
        let bn = BnStats::new(vec![1.0, 0.5], vec![0.5, 0.2]);
        let q = bn.fit_activations(4);
        let mut r = Rng::new(1);
        let xs = bn.sample_activations(20_000, &mut r);
        let clipped = xs.iter().filter(|&&x| x > q.scale * q.qmax as f32).count();
        let frac = clipped as f64 / xs.len() as f64;
        assert!(frac < 0.02, "clipped fraction {frac}");
    }

    #[test]
    fn range_estimate_close_to_empirical() {
        let bn = BnStats::new(vec![2.0], vec![1.0]);
        let mut r = Rng::new(2);
        let xs = bn.sample_activations(50_000, &mut r);
        let data_free = bn.fit_activations(6);
        let with_data = super::super::ruq::fit_unsigned(&xs, 6);
        let ratio = data_free.scale / with_data.scale;
        assert!(ratio > 0.5 && ratio < 2.0, "scale ratio {ratio}");
    }

    #[test]
    fn all_negative_means_still_positive_clip() {
        let bn = BnStats::new(vec![-3.0], vec![0.1]);
        assert!(bn.relu_clip(3.0) > 0.0);
    }
}
