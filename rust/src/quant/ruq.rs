//! Regular uniform quantizer (RUQ) — the paper's baseline quantizer
//! (Sec. 5.3) and the machinery shared by every other method.

/// Uniform quantization parameters: `q = clamp(round(x/scale), qmin..qmax)`,
/// `x̂ = scale·q`. Symmetric (no zero point), like the paper's `γ·Q(·)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    /// Step size `γ`.
    pub scale: f32,
    /// Smallest representable code.
    pub qmin: i64,
    /// Largest representable code.
    pub qmax: i64,
}

impl QParams {
    /// Signed symmetric range for `bits`: `[-2^{b-1}, 2^{b-1} - 1]`.
    pub fn signed(scale: f32, bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 31);
        let hi = (1i64 << (bits - 1)) - 1;
        QParams { scale: scale.max(f32::MIN_POSITIVE), qmin: -hi - 1, qmax: hi }
    }

    /// Unsigned range for `bits`: `[0, 2^b - 1]` (ReLU activations).
    pub fn unsigned(scale: f32, bits: u32) -> Self {
        assert!(bits >= 1 && bits <= 31);
        QParams { scale: scale.max(f32::MIN_POSITIVE), qmin: 0, qmax: (1i64 << bits) - 1 }
    }

    /// Quantize one value to an integer code.
    #[inline]
    pub fn quantize(&self, x: f32) -> i64 {
        let q = (x / self.scale).round() as i64;
        q.clamp(self.qmin, self.qmax)
    }

    /// Dequantize a code.
    #[inline]
    pub fn dequantize(&self, q: i64) -> f32 {
        self.scale * q as f32
    }

    /// Quantize a slice to integer codes.
    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i64> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Fake-quantize (quantize then dequantize) a slice.
    pub fn fake_quantize(&self, xs: &[f32]) -> Vec<f32> {
        xs.iter().map(|&x| self.dequantize(self.quantize(x))).collect()
    }

    /// Mean squared quantization error over a slice.
    pub fn mse(&self, xs: &[f32]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter()
            .map(|&x| {
                let e = (x - self.dequantize(self.quantize(x))) as f64;
                e * e
            })
            .sum::<f64>()
            / xs.len() as f64
    }
}

/// Fit a signed symmetric RUQ to data: scale = max|x| / (2^{b-1}-1).
pub fn fit_signed(xs: &[f32], bits: u32) -> QParams {
    let mx = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let hi = ((1i64 << (bits - 1)) - 1) as f32;
    QParams::signed(if mx > 0.0 { mx / hi } else { 1.0 }, bits)
}

/// Fit an unsigned RUQ to non-negative data: scale = max / (2^b - 1).
pub fn fit_unsigned(xs: &[f32], bits: u32) -> QParams {
    let mx = xs.iter().fold(0.0f32, |m, &x| m.max(x));
    let hi = ((1i64 << bits) - 1) as f32;
    QParams::unsigned(if mx > 0.0 { mx / hi } else { 1.0 }, bits)
}

/// Fit an unsigned RUQ with an explicit clipping value (used by the
/// analytic methods): scale = clip / (2^b - 1).
pub fn fit_unsigned_clipped(clip: f32, bits: u32) -> QParams {
    let hi = ((1i64 << bits) - 1) as f32;
    QParams::unsigned((clip / hi).max(f32::MIN_POSITIVE), bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn codes_within_range() {
        let mut r = Rng::new(1);
        let xs: Vec<f32> = (0..1000).map(|_| r.normal() as f32).collect();
        for bits in 2..=8 {
            let q = fit_signed(&xs, bits);
            for &x in &xs {
                let c = q.quantize(x);
                assert!(c >= q.qmin && c <= q.qmax);
            }
        }
    }

    #[test]
    fn error_bounded_by_half_step() {
        let mut r = Rng::new(2);
        let xs: Vec<f32> = (0..1000).map(|_| (r.f64() as f32) * 4.0 - 2.0).collect();
        let q = fit_signed(&xs, 6);
        for &x in &xs {
            let e = (x - q.dequantize(q.quantize(x))).abs();
            // In-range values err at most half a step (+eps).
            assert!(e <= q.scale * 0.5 + 1e-6, "x={x} e={e} scale={}", q.scale);
        }
    }

    #[test]
    fn mse_decreases_with_bits() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..4000).map(|_| r.normal() as f32).collect();
        let mut last = f64::INFINITY;
        for bits in 2..=8 {
            let q = fit_signed(&xs, bits);
            let mse = q.mse(&xs);
            assert!(mse < last, "bits={bits} mse={mse} last={last}");
            last = mse;
        }
    }

    #[test]
    fn unsigned_rejects_negative_to_zero() {
        let q = fit_unsigned(&[0.0, 1.0, 2.0], 4);
        assert_eq!(q.quantize(-5.0), 0);
    }

    #[test]
    fn uniform_mse_matches_theory() {
        // For U[0, M] data, RUQ at b bits has MSE ≈ Δ²/12.
        let mut r = Rng::new(4);
        let m = 8.0f32;
        let xs: Vec<f32> = (0..200_000).map(|_| r.f32() * m).collect();
        let bits = 5;
        let q = fit_unsigned_clipped(m, bits);
        let delta = (m / ((1 << bits) - 1) as f32) as f64;
        let mse = q.mse(&xs);
        let theory = delta * delta / 12.0;
        assert!((mse / theory - 1.0).abs() < 0.05, "mse {mse} theory {theory}");
    }
}
