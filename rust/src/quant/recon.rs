//! Rounding reconstruction — AdaRound-style layer-wise optimization
//! (our stand-in for BRECQ; see DESIGN.md substitution table).
//!
//! Nearest rounding is not MSE-optimal for the *layer output*. Given a
//! calibration batch `X` (`[n][d]`) and a weight row `w` (`[d]`), we
//! choose per-weight rounding direction (floor vs ceil) to minimize
//! `‖(ŵ − w)ᵀX‖²` by greedy coordinate descent — the same objective
//! family BRECQ optimizes per block with gradients.

use super::ruq::QParams;

/// Optimize the rounding of one weight vector against calibration
/// activations. `x` is `[n][d]` flattened row-major (n samples).
/// Returns the optimized integer codes.
pub fn reconstruct_row(w: &[f32], q: &QParams, x: &[f32], n: usize, max_sweeps: usize) -> Vec<i64> {
    let d = w.len();
    assert_eq!(x.len(), n * d);
    // start from nearest rounding
    let mut codes: Vec<i64> = w.iter().map(|&v| q.quantize(v)).collect();
    if n == 0 {
        return codes;
    }
    // residual r_j = sum_i (ŵ_i - w_i) x[j][i]  for each sample j
    let mut resid = vec![0.0f64; n];
    for j in 0..n {
        for i in 0..d {
            resid[j] += (q.dequantize(codes[i]) - w[i]) as f64 * x[j * d + i] as f64;
        }
    }
    let step = q.scale as f64;
    for _sweep in 0..max_sweeps {
        let mut improved = false;
        for i in 0..d {
            // candidate moves: code ± 1 (stay within range)
            let mut best_delta = 0i64;
            let mut best_gain = 0.0f64;
            for delta in [-1i64, 1] {
                let nc = codes[i] + delta;
                if nc < q.qmin || nc > q.qmax {
                    continue;
                }
                // new loss - old loss = sum_j (r_j + delta*step*x_ji)^2 - r_j^2
                let mut diff = 0.0f64;
                for j in 0..n {
                    let xi = x[j * d + i] as f64;
                    let t = delta as f64 * step * xi;
                    diff += t * (2.0 * resid[j] + t);
                }
                if diff < best_gain - 1e-12 {
                    best_gain = diff;
                    best_delta = delta;
                }
            }
            if best_delta != 0 {
                for j in 0..n {
                    resid[j] += best_delta as f64 * step * x[j * d + i] as f64;
                }
                codes[i] += best_delta;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    codes
}

/// Layer-output MSE of integer codes on a calibration batch.
pub fn layer_mse(w: &[f32], codes: &[i64], q: &QParams, x: &[f32], n: usize) -> f64 {
    let d = w.len();
    let mut acc = 0.0;
    for j in 0..n {
        let mut r = 0.0f64;
        for i in 0..d {
            r += (q.dequantize(codes[i]) - w[i]) as f64 * x[j * d + i] as f64;
        }
        acc += r * r;
    }
    acc / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn never_worse_than_nearest() {
        let mut r = Rng::new(21);
        let d = 32;
        let n = 24;
        let w: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
        let x: Vec<f32> = (0..n * d).map(|_| (r.normal() as f32).max(0.0)).collect();
        for bits in [2u32, 3, 4] {
            let q = crate::quant::ruq::fit_signed(&w, bits);
            let nearest: Vec<i64> = w.iter().map(|&v| q.quantize(v)).collect();
            let opt = reconstruct_row(&w, &q, &x, n, 10);
            let m_nearest = layer_mse(&w, &nearest, &q, &x, n);
            let m_opt = layer_mse(&w, &opt, &q, &x, n);
            assert!(m_opt <= m_nearest + 1e-9, "bits {bits}: {m_opt} > {m_nearest}");
        }
    }

    #[test]
    fn improves_at_low_bits() {
        let mut r = Rng::new(22);
        let d = 64;
        let n = 32;
        let w: Vec<f32> = (0..d).map(|_| r.normal() as f32).collect();
        let x: Vec<f32> = (0..n * d).map(|_| (r.normal() as f32).max(0.0)).collect();
        let q = crate::quant::ruq::fit_signed(&w, 2);
        let nearest: Vec<i64> = w.iter().map(|&v| q.quantize(v)).collect();
        let opt = reconstruct_row(&w, &q, &x, n, 20);
        let m_nearest = layer_mse(&w, &nearest, &q, &x, n);
        let m_opt = layer_mse(&w, &opt, &q, &x, n);
        assert!(m_opt < m_nearest * 0.95, "{m_opt} vs {m_nearest}");
    }

    #[test]
    fn codes_stay_in_range() {
        let mut r = Rng::new(23);
        let d = 16;
        let n = 8;
        let w: Vec<f32> = (0..d).map(|_| r.normal() as f32 * 3.0).collect();
        let x: Vec<f32> = (0..n * d).map(|_| r.normal() as f32).collect();
        let q = crate::quant::ruq::fit_signed(&w, 3);
        let codes = reconstruct_row(&w, &q, &x, n, 10);
        for c in codes {
            assert!(c >= q.qmin && c <= q.qmax);
        }
    }

    #[test]
    fn empty_calibration_falls_back_to_nearest() {
        let w = [0.3f32, -0.7, 0.1];
        let q = crate::quant::ruq::fit_signed(&w, 4);
        let codes = reconstruct_row(&w, &q, &[], 0, 5);
        let nearest: Vec<i64> = w.iter().map(|&v| q.quantize(v)).collect();
        assert_eq!(codes, nearest);
    }
}
