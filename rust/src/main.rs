//! `pann-cli` — leader entrypoint.
//!
//! ```text
//! pann-cli experiment <id>|all [--quick] [--artifacts DIR]
//! pann-cli power-report [--bits B] [--acc-bits B]
//! pann-cli serve --model NAME [--requests N] [--budget GFLIPS]
//!               [--queue-depth D] [--deadline-ms MS]
//! pann-cli sweep --model NAME [--quick]
//! pann-cli list
//! ```
//!
//! (Hand-rolled argument parsing: the offline registry for this build
//! carries no `clap`.)

use anyhow::{bail, Context, Result};
use pann::coordinator::{EnginePoint, InferRequest, Menu, ServeError, ServerBuilder};
use pann::experiments::{self, Ctx};
use pann::runtime::{ArtifactManifest, CpuRuntime};
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    cmd: String,
    flags: std::collections::BTreeMap<String, String>,
    positional: Vec<String>,
}

fn parse_args() -> Args {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_else(|| "help".to_string());
    let mut flags = std::collections::BTreeMap::new();
    let mut positional = Vec::new();
    let rest: Vec<String> = argv.collect();
    let mut i = 0;
    while i < rest.len() {
        if let Some(name) = rest[i].strip_prefix("--") {
            let has_val = i + 1 < rest.len() && !rest[i + 1].starts_with("--");
            if has_val {
                flags.insert(name.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(rest[i].clone());
            i += 1;
        }
    }
    Args { cmd, flags, positional }
}

fn run() -> Result<()> {
    let args = parse_args();
    let ctx = Ctx {
        artifacts: PathBuf::from(
            args.flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()),
        ),
        quick: args.flags.contains_key("quick"),
    };
    match args.cmd.as_str() {
        "list" => {
            println!("experiments: {}", experiments::ids().join(" "));
            Ok(())
        }
        "experiment" => {
            let id = args
                .positional
                .first()
                .context("usage: pann-cli experiment <id>|all")?;
            if id == "all" {
                for (name, _) in experiments::ALL {
                    if let Err(e) = experiments::run(name, &ctx) {
                        println!("[{name} skipped: {e}]");
                    }
                    println!();
                }
                Ok(())
            } else {
                experiments::run(id, &ctx)
            }
        }
        "power-report" => {
            let bits: u32 = args.flags.get("bits").map_or(Ok(4), |s| s.parse())?;
            let acc: u32 = args.flags.get("acc-bits").map_or(Ok(32), |s| s.parse())?;
            power_report(bits, acc)
        }
        "serve" => {
            let model = args.flags.get("model").cloned().unwrap_or_else(|| "cnn-s".into());
            let n: usize = args.flags.get("requests").map_or(Ok(256), |s| s.parse())?;
            let budget: f64 = args
                .flags
                .get("budget")
                .map_or(Ok(f64::INFINITY), |s| s.parse())?;
            let queue_depth: usize = args
                .flags
                .get("queue-depth")
                .map_or(Ok(256), |s| s.parse())?;
            let deadline_ms: Option<u64> = match args.flags.get("deadline-ms") {
                Some(s) => Some(s.parse()?),
                None => None,
            };
            serve(&ctx, &model, n, budget, queue_depth, deadline_ms)
        }
        "sweep" => {
            let model = args.flags.get("model").cloned().unwrap_or_else(|| "cnn-s".into());
            sweep(&ctx, &model)
        }
        _ => {
            println!(
                "pann-cli — power-aware neural networks (PANN reproduction)\n\
                 commands:\n\
                 \x20 experiment <id>|all [--quick]   regenerate a paper table/figure\n\
                 \x20 list                            list experiment ids\n\
                 \x20 power-report [--bits B]         per-MAC power model summary\n\
                 \x20 serve --model M [--requests N] [--budget G]\n\
                 \x20       [--queue-depth D] [--deadline-ms MS]\n\
                 \x20 sweep --model M [--quick]       power-accuracy sweep (Fig. 1)\n"
            );
            Ok(())
        }
    }
}

/// Print the analytic per-MAC power breakdown at a bit width.
fn power_report(bits: u32, acc_bits: u32) -> Result<()> {
    use pann::power::model::*;
    let s = mac_power_signed(bits, acc_bits);
    let u = mac_power_unsigned(bits);
    println!("per-MAC power at b={bits}, B={acc_bits} (bit flips):");
    println!("  signed:   mult {:>6.1} + acc {:>6.1} = {:>6.1}", s.mult, s.acc, s.total());
    println!("  unsigned: mult {:>6.1} + acc {:>6.1} = {:>6.1}", u.mult, u.acc, u.total());
    println!("  unsigned save: {:.0}%", 100.0 * (1.0 - u.total() / s.total()));
    println!("PANN equal-power points (P = {}):", mac_power_unsigned_total(bits));
    for bt in 2..=8u32 {
        if let Some(r) = pann::power::budget::equal_power_r(mac_power_unsigned_total(bits), bt) {
            if r > 0.0 {
                println!("  b̃x={bt}: R={r:.2}");
            }
        }
    }
    Ok(())
}

/// End-to-end serving demo over the AOT artifacts.
fn serve(
    ctx: &Ctx,
    model: &str,
    n_requests: usize,
    budget: f64,
    queue_depth: usize,
    deadline_ms: Option<u64>,
) -> Result<()> {
    let hlo_dir = ctx.artifacts.join("hlo");
    let manifest = ArtifactManifest::load(&hlo_dir)
        .context("load artifacts/hlo/manifest.json — run `make artifacts` first")?;
    let specs: Vec<_> = manifest.points_for(model).into_iter().cloned().collect();
    if specs.is_empty() {
        bail!("no executables for model '{model}' in {}", hlo_dir.display());
    }
    let model_name = model.to_string();
    let srv = ServerBuilder::new()
        .queue_depth(queue_depth)
        .budget_gflips(budget)
        .serve(Menu::local(move || {
            let rt = CpuRuntime::new()?;
            println!("PJRT platform: {}", rt.platform());
            let mut points = Vec::new();
            for spec in &specs {
                let lm = rt.load(&spec.file, &spec.input_shape)?;
                println!(
                    "loaded {}/{} ({} GF/sample)",
                    model_name, spec.variant, spec.giga_flips_per_sample
                );
                points.push(EnginePoint {
                    name: spec.variant.clone(),
                    giga_flips_per_sample: if spec.variant == "fp32" {
                        f64::INFINITY
                    } else {
                        spec.giga_flips_per_sample
                    },
                    engine: Box::new(lm),
                });
            }
            Ok(points)
        }))?;
    let client = srv.client();
    // drive with test data, measure accuracy + latency
    let ds = pann::data::Dataset::load(
        &ctx.artifacts.join("data").join(experiments::dataset_for(model)),
        "test",
    )?;
    let n = n_requests.min(ds.len());
    let mut correct = 0usize;
    let mut expired = 0usize;
    for i in 0..n {
        let mut req = InferRequest::new(ds.sample(i).to_vec());
        if let Some(ms) = deadline_ms {
            req = req.deadline(std::time::Duration::from_millis(ms));
        }
        match client.submit(req)?.wait() {
            Ok(r) => {
                let pred = r
                    .output
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                if pred == ds.y[i] as usize {
                    correct += 1;
                }
            }
            Err(ServeError::DeadlineExceeded) => expired += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let served = n - expired;
    println!("accuracy {:.3} over {served} served requests", correct as f64 / served.max(1) as f64);
    if expired > 0 {
        println!("{expired} requests rejected past their {}ms deadline", deadline_ms.unwrap_or(0));
    }
    println!("{}", client.metrics().report());
    srv.shutdown();
    Ok(())
}

/// Fig. 1 power–accuracy sweep on the native engine.
fn sweep(ctx: &Ctx, model: &str) -> Result<()> {
    use pann::pann::{algorithm1, convert};
    use pann::quant::ActQuantMethod;
    let (m, test) = ctx.load_model(model)?;
    let test = test.take(ctx.eval_n());
    let calib = convert::calib_tensor(&test, 32);
    println!("{:<8} {:>12} {:>8} | {:>12} {:>8}", "budget", "base GF", "acc", "pann GF", "acc");
    for bits in [2u32, 3, 4, 6, 8] {
        let (_, base) =
            convert::unsigned_of(&m, bits, ActQuantMethod::BnStats, Some(&calib), &test)?;
        let p = pann::power::model::mac_power_unsigned_total(bits);
        let op = algorithm1::choose_operating_point(
            &m,
            p,
            ActQuantMethod::BnStats,
            Some(&calib),
            &test.take(96),
            2..=8,
        )?;
        let (_, our) = convert::pann_at_budget(
            &m,
            op.bx_tilde,
            op.r,
            ActQuantMethod::BnStats,
            Some(&calib),
            &test,
        )?;
        println!(
            "{:<8} {:>12.4} {:>8.3} | {:>12.4} {:>8.3}",
            format!("{bits}-bit"),
            base.giga_flips,
            base.accuracy(),
            our.giga_flips,
            our.accuracy()
        );
    }
    Ok(())
}
