//! `pann-cli` — leader entrypoint.
//!
//! ```text
//! pann-cli experiment <id>|all [--quick] [--artifacts DIR]
//! pann-cli power-report [--bits B] [--acc-bits B]
//! pann-cli compile-menu --model NAME [--budget-bits 2,4,8] [--out menu.json] [--quick]
//!                [--per-layer] [--sensitivity-samples N] [--max-mixed-points K]
//! pann-cli serve --model NAME [--menu menu.json] [--requests N] [--budget GFLIPS]
//!               [--queue-depth D] [--deadline-ms MS]
//!               [--envelope-gflips RATE] [--governor-window-ms MS]
//!               [--calibrate-out menu.json (requires --menu)]
//! pann-cli serve --menu NAME=menu.json --menu NAME2=menu2.json ...   (fleet mode)
//!               [--requests N] [--budget GFLIPS] [--queue-depth D]
//!               [--deadline-ms MS] [--envelope-gflips RATE] [--governor-window-ms MS]
//! pann-cli serve --menu menu.json --listen 127.0.0.1:8080 [--shards N] [--hold]
//!               [--budget GFLIPS] [--queue-depth D]
//!               [--envelope-gflips RATE] [--governor-window-ms MS]
//! pann-cli sweep --model NAME [--quick]
//! pann-cli replay --trace trace.json --menu menu.json [--device jetson|server]
//!                [--shards N] [--envelope-gflips RATE] [--governor-window-ms MS]
//!                [--quick] [--out report.json]
//! pann-cli list
//! ```
//!
//! `replay` is the scenario harness's CLI surface: a `pann-trace/v1`
//! workload replays through the deterministic virtual-clock rig
//! ([`pann::scenario`]) against the compiled menu on a named device
//! profile. The human summary prints to stderr and the
//! `scenario-report/v1` JSON to stdout; exit codes follow the verify
//! contract (0 invariants hold, 1 operational error, 2 findings). Two
//! runs with the same inputs print byte-identical reports.
//!
//! `--listen` switches `serve` from a local replay to the network
//! edge: the compiled menu is served over HTTP (`POST /v1/infer`,
//! `GET /v1/models`, `GET /v1/governor`, `GET /metrics`), sharded
//! across `--shards` in-process servers. With `--hold` the edge stays
//! up until stdin reaches EOF (or the process is signalled), then
//! drains gracefully; without it the command binds, prints the
//! address and exits — a configuration smoke test.
//!
//! `--menu` is repeatable: one plain `--menu menu.json` serves a single
//! model exactly as before, while `NAME=path` entries register each
//! artifact as a named model in one fleet server
//! (`ServerBuilder::register` + `serve_fleet`) — every NAME is loaded
//! with `Ctx::load_model(NAME)` and fingerprint-checked against its
//! artifact.
//!
//! (Hand-rolled argument parsing: the offline registry for this build
//! carries no `clap`.)

// The panic ban in clippy.toml targets the serving layer
// (coordinator/, net/); CLI/test/bench crates may assert freely.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

use anyhow::{bail, Context, Result};
use pann::coordinator::{
    Client, EnergyEnvelope, EnginePoint, InferRequest, Menu, ServeError, ServerBuilder,
};
use pann::experiments::{self, Ctx};
use pann::net::{NetConfig, NetServer, ShardRouter};
use pann::runtime::{ArtifactManifest, CpuRuntime};
use std::path::PathBuf;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    cmd: String,
    /// Every occurrence of each flag, in order — `--menu` is
    /// repeatable (fleet mode); single-valued flags read the last.
    flags: std::collections::BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
}

impl Args {
    /// Last value of a single-valued flag.
    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    /// Every value of a repeatable flag.
    fn all(&self, name: &str) -> &[String] {
        self.flags.get(name).map_or(&[], Vec::as_slice)
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn parse_args() -> Args {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_else(|| "help".to_string());
    let mut flags: std::collections::BTreeMap<String, Vec<String>> =
        std::collections::BTreeMap::new();
    let mut positional = Vec::new();
    let rest: Vec<String> = argv.collect();
    let mut i = 0;
    while i < rest.len() {
        if let Some(name) = rest[i].strip_prefix("--") {
            let has_val = i + 1 < rest.len() && !rest[i + 1].starts_with("--");
            if has_val {
                flags.entry(name.to_string()).or_default().push(rest[i + 1].clone());
                i += 2;
            } else {
                flags.entry(name.to_string()).or_default().push("true".to_string());
                i += 1;
            }
        } else {
            positional.push(rest[i].clone());
            i += 1;
        }
    }
    Args { cmd, flags, positional }
}

fn run() -> Result<()> {
    let args = parse_args();
    let ctx = Ctx {
        artifacts: PathBuf::from(
            args.get("artifacts").map(str::to_string).unwrap_or_else(|| "artifacts".into()),
        ),
        quick: args.has("quick"),
    };
    match args.cmd.as_str() {
        "list" => {
            println!("experiments: {}", experiments::ids().join(" "));
            Ok(())
        }
        "experiment" => {
            let id = args
                .positional
                .first()
                .context("usage: pann-cli experiment <id>|all")?;
            if id == "all" {
                for (name, _) in experiments::ALL {
                    if let Err(e) = experiments::run(name, &ctx) {
                        println!("[{name} skipped: {e}]");
                    }
                    println!();
                }
                Ok(())
            } else {
                experiments::run(id, &ctx)
            }
        }
        "power-report" => {
            let bits: u32 = args.get("bits").map_or(Ok(4), |s| s.parse())?;
            let acc: u32 = args.get("acc-bits").map_or(Ok(32), |s| s.parse())?;
            power_report(bits, acc)
        }
        "serve" => {
            let model = args.get("model").map(str::to_string).unwrap_or_else(|| "cnn-s".into());
            let n: usize = args.get("requests").map_or(Ok(256), |s| s.parse())?;
            let budget: f64 = args.get("budget").map_or(Ok(f64::INFINITY), |s| s.parse())?;
            let queue_depth: usize = args.get("queue-depth").map_or(Ok(256), |s| s.parse())?;
            let deadline_ms: Option<u64> = match args.get("deadline-ms") {
                Some(s) => Some(s.parse()?),
                None => None,
            };
            // closed-loop governor: a sustained-energy envelope in
            // Gflips/sec, with an optional decision-window override
            let governor = match args.get("envelope-gflips") {
                Some(s) => {
                    let rate: f64 = s.parse().context("parse --envelope-gflips")?;
                    let window_ms: u64 = args
                        .get("governor-window-ms")
                        .map_or(Ok(100), |s| s.parse())
                        .context("parse --governor-window-ms")?;
                    if window_ms == 0 {
                        bail!("--governor-window-ms must be at least 1");
                    }
                    Some(GovernorCli { rate, window_ms })
                }
                None => {
                    if args.has("governor-window-ms") {
                        eprintln!(
                            "warning: --governor-window-ms requires --envelope-gflips \
                             (no governor runs without an envelope); ignoring"
                        );
                    }
                    None
                }
            };
            let calibrate_out = args.get("calibrate-out").map(str::to_string);
            let menus = args.all("menu");
            // network edge: --listen serves the menu over a socket
            // instead of replaying local test data against it
            if let Some(addr) = args.get("listen") {
                let shards: usize = args.get("shards").map_or(Ok(1), |s| s.parse())?;
                if shards == 0 {
                    bail!("--shards must be at least 1");
                }
                let Some(menu_path) = menus.first() else {
                    bail!(
                        "--listen requires --menu menu.json \
                         (compile one with `pann-cli compile-menu`)"
                    );
                };
                if menus.len() >= 2 || menu_path.contains('=') {
                    bail!(
                        "--listen serves one compiled menu across --shards copies of one \
                         model; fleet NAME=path entries are not supported over the socket"
                    );
                }
                if calibrate_out.is_some() {
                    eprintln!("warning: --calibrate-out applies to replay serving only; ignoring");
                }
                if deadline_ms.is_some() {
                    eprintln!(
                        "warning: --deadline-ms is a replay flag; network clients set \
                         per-request deadlines via the wire field deadline_ms; ignoring"
                    );
                }
                return serve_listen(
                    &ctx,
                    &model,
                    menu_path,
                    addr,
                    shards,
                    budget,
                    queue_depth,
                    governor,
                    args.has("hold"),
                );
            }
            if args.has("shards") || args.has("hold") {
                eprintln!("warning: --shards/--hold only apply with --listen; ignoring");
            }
            // fleet mode: several --menu flags, or any NAME=path entry
            if menus.len() >= 2 || menus.first().is_some_and(|m| m.contains('=')) {
                let mut entries = Vec::with_capacity(menus.len());
                for m in menus {
                    let (name, path) = m.split_once('=').with_context(|| {
                        format!(
                            "fleet mode: every --menu must be NAME=path (got '{m}'); \
                             a single plain --menu path serves one model"
                        )
                    })?;
                    entries.push((name.to_string(), path.to_string()));
                }
                if calibrate_out.is_some() {
                    eprintln!(
                        "warning: --calibrate-out applies to single-menu serving only; ignoring"
                    );
                }
                if args.has("model") {
                    eprintln!(
                        "warning: fleet mode loads each model from its --menu NAME; \
                         --model is ignored"
                    );
                }
                serve_fleet_cli(&ctx, &entries, n, budget, queue_depth, deadline_ms, governor)
            } else if let Some(menu_path) = menus.first() {
                serve_menu(
                    &ctx,
                    &model,
                    menu_path,
                    n,
                    budget,
                    queue_depth,
                    deadline_ms,
                    governor,
                    calibrate_out,
                )
            } else {
                if calibrate_out.is_some() {
                    eprintln!(
                        "warning: --calibrate-out requires --menu (nothing to calibrate \
                         without a menu artifact); ignoring"
                    );
                }
                serve(&ctx, &model, n, budget, queue_depth, deadline_ms, governor)
            }
        }
        "compile-menu" => {
            let model = args.get("model").map(str::to_string).unwrap_or_else(|| "cnn-s".into());
            let bits: Vec<u32> = args
                .get("budget-bits")
                .unwrap_or("2,4,8")
                .split(',')
                .map(|s| s.trim().parse().context("parse --budget-bits"))
                .collect::<Result<_>>()?;
            let out = args.get("out").map(str::to_string).unwrap_or_else(|| "menu.json".into());
            let per_layer = if args.has("per-layer") {
                let mut search = pann::pann::PerLayerSearch::default();
                if let Some(s) = args.get("sensitivity-samples") {
                    search.sensitivity_samples = s.parse().context("parse --sensitivity-samples")?;
                }
                if let Some(s) = args.get("max-mixed-points") {
                    search.max_mixed_points = s.parse().context("parse --max-mixed-points")?;
                }
                Some(search)
            } else {
                None
            };
            compile_menu_cmd(&ctx, &model, &bits, &out, per_layer)
        }
        "sweep" => {
            let model = args.get("model").map(str::to_string).unwrap_or_else(|| "cnn-s".into());
            sweep(&ctx, &model)
        }
        "verify" => {
            let menu = args
                .get("menu")
                .context("usage: pann-cli verify --menu menu.json [--model NAME]")?
                .to_string();
            verify_menu(&ctx, &menu, args.get("model"))
        }
        "replay" => {
            let usage = "usage: pann-cli replay --trace trace.json --menu menu.json \
                         [--device jetson|server] [--shards N] [--envelope-gflips RATE] \
                         [--governor-window-ms MS] [--quick] [--out report.json]";
            let trace_path = args.get("trace").context(usage)?;
            let menu_path = args.get("menu").context(usage)?;
            let device = args.get("device").unwrap_or("server");
            let shards: usize = args.get("shards").map_or(Ok(1), |s| s.parse())?;
            if shards == 0 {
                bail!("--shards must be at least 1");
            }
            let envelope: Option<f64> = match args.get("envelope-gflips") {
                Some(s) => Some(s.parse().context("parse --envelope-gflips")?),
                None => None,
            };
            let window_ms: Option<u64> = match args.get("governor-window-ms") {
                Some(s) => Some(s.parse().context("parse --governor-window-ms")?),
                None => None,
            };
            replay_cmd(
                trace_path,
                menu_path,
                device,
                shards,
                envelope,
                window_ms,
                args.has("quick"),
                args.get("out"),
            )
        }
        _ => {
            println!(
                "pann-cli — power-aware neural networks (PANN reproduction)\n\
                 commands:\n\
                 \x20 experiment <id>|all [--quick]   regenerate a paper table/figure\n\
                 \x20 list                            list experiment ids\n\
                 \x20 power-report [--bits B]         per-MAC power model summary\n\
                 \x20 compile-menu --model M [--budget-bits 2,4,8] [--out menu.json]\n\
                 \x20              [--per-layer] [--sensitivity-samples N] [--max-mixed-points K]\n\
                 \x20                                 compile + Pareto-prune the operating-point menu;\n\
                 \x20                                 --per-layer adds sensitivity-guided mixed-\n\
                 \x20                                 precision candidates (pann-menu/v3)\n\
                 \x20 serve --model M [--menu menu.json] [--requests N] [--budget G]\n\
                 \x20       [--queue-depth D] [--deadline-ms MS]\n\
                 \x20       [--envelope-gflips RATE] [--governor-window-ms MS]\n\
                 \x20       [--calibrate-out menu.json (requires --menu)]\n\
                 \x20 serve --menu NAME=menu.json --menu NAME2=menu2.json ...\n\
                 \x20                                 fleet: N models on one pool + one envelope\n\
                 \x20 serve --menu menu.json --listen ADDR [--shards N] [--hold]\n\
                 \x20                                 HTTP edge: POST /v1/infer, GET /v1/models,\n\
                 \x20                                 GET /v1/governor, GET /metrics; --hold keeps\n\
                 \x20                                 serving until stdin EOF, then drains\n\
                 \x20 sweep --model M [--quick]       power-accuracy sweep (Fig. 1)\n\
                 \x20 verify --menu menu.json [--model M]\n\
                 \x20                                 static overflow audit of a menu artifact\n\
                 \x20                                 (exit 0 sound / 1 error / 2 findings,\n\
                 \x20                                 pann-verify/v1 JSON report on stdout)\n\
                 \x20 replay --trace t.json --menu menu.json [--device jetson|server]\n\
                 \x20        [--shards N] [--envelope-gflips RATE] [--quick] [--out r.json]\n\
                 \x20                                 deterministic trace replay through the\n\
                 \x20                                 scenario rig (exit 0 sound / 1 error /\n\
                 \x20                                 2 findings, scenario-report/v1 on stdout)\n"
            );
            Ok(())
        }
    }
}

/// Print the analytic per-MAC power breakdown at a bit width.
fn power_report(bits: u32, acc_bits: u32) -> Result<()> {
    use pann::power::model::*;
    let s = mac_power_signed(bits, acc_bits);
    let u = mac_power_unsigned(bits);
    println!("per-MAC power at b={bits}, B={acc_bits} (bit flips):");
    println!("  signed:   mult {:>6.1} + acc {:>6.1} = {:>6.1}", s.mult, s.acc, s.total());
    println!("  unsigned: mult {:>6.1} + acc {:>6.1} = {:>6.1}", u.mult, u.acc, u.total());
    println!("  unsigned save: {:.0}%", 100.0 * (1.0 - u.total() / s.total()));
    println!("PANN equal-power points (P = {}):", mac_power_unsigned_total(bits));
    for bt in 2..=8u32 {
        if let Some(r) = pann::power::budget::equal_power_r(mac_power_unsigned_total(bits), bt) {
            if r > 0.0 {
                println!("  b̃x={bt}: R={r:.2}");
            }
        }
    }
    Ok(())
}

/// Closed-loop governor flags (`--envelope-gflips`,
/// `--governor-window-ms`).
struct GovernorCli {
    rate: f64,
    window_ms: u64,
}

impl GovernorCli {
    /// Apply to a builder (no-op when the flags were absent).
    fn configure(opt: &Option<GovernorCli>, mut b: ServerBuilder) -> ServerBuilder {
        if let Some(g) = opt {
            b = b
                .envelope(EnergyEnvelope::gflips_per_sec(g.rate))
                .governor_window(std::time::Duration::from_millis(g.window_ms));
        }
        b
    }
}

/// Print the governor's end-of-run report, if one governed.
fn print_governor(client: &Client) {
    if let Some(g) = client.governor() {
        print!("{}", g.report());
    }
}

/// End-to-end serving demo over the AOT artifacts.
fn serve(
    ctx: &Ctx,
    model: &str,
    n_requests: usize,
    budget: f64,
    queue_depth: usize,
    deadline_ms: Option<u64>,
    governor: Option<GovernorCli>,
) -> Result<()> {
    let hlo_dir = ctx.artifacts.join("hlo");
    let manifest = ArtifactManifest::load(&hlo_dir)
        .context("load artifacts/hlo/manifest.json — run `make artifacts` first")?;
    let specs: Vec<_> = manifest.points_for(model).into_iter().cloned().collect();
    if specs.is_empty() {
        bail!("no executables for model '{model}' in {}", hlo_dir.display());
    }
    let model_name = model.to_string();
    let builder = GovernorCli::configure(
        &governor,
        ServerBuilder::new().queue_depth(queue_depth).budget_gflips(budget),
    );
    let srv = builder
        .serve(Menu::local(move || {
            let rt = CpuRuntime::new()?;
            println!("PJRT platform: {}", rt.platform());
            let mut points = Vec::new();
            for spec in &specs {
                let lm = rt.load(&spec.file, &spec.input_shape)?;
                println!(
                    "loaded {}/{} ({} GF/sample)",
                    model_name, spec.variant, spec.giga_flips_per_sample
                );
                points.push(EnginePoint {
                    name: spec.variant.clone(),
                    giga_flips_per_sample: if spec.variant == "fp32" {
                        f64::INFINITY
                    } else {
                        spec.giga_flips_per_sample
                    },
                    engine: Box::new(lm),
                });
            }
            Ok(points)
        }))?;
    let client = srv.client();
    // drive with test data, measure accuracy + latency
    let ds = pann::data::Dataset::load(
        &ctx.artifacts.join("data").join(experiments::dataset_for(model)),
        "test",
    )?;
    let n = n_requests.min(ds.len());
    let (correct, expired, _) = replay(&client, None, &ds, n, deadline_ms)?;
    let served = n - expired;
    println!("accuracy {:.3} over {served} served requests", correct as f64 / served.max(1) as f64);
    if expired > 0 {
        println!("{expired} requests rejected past their {}ms deadline", deadline_ms.unwrap_or(0));
    }
    println!("{}", client.metrics().report());
    print_governor(&client);
    srv.shutdown();
    Ok(())
}

/// Replay the first `n` test samples through a serving client: returns
/// (correct predictions, deadline-expired requests, last serving
/// point). Shared by `serve`, `serve_menu` and `serve_fleet_cli` so
/// accuracy/deadline accounting cannot diverge between the paths;
/// `model` routes every request to one registered fleet model (`None`
/// on single-model servers).
fn replay(
    client: &Client,
    model: Option<&str>,
    ds: &pann::data::Dataset,
    n: usize,
    deadline_ms: Option<u64>,
) -> Result<(usize, usize, String)> {
    let mut correct = 0usize;
    let mut expired = 0usize;
    let mut point = String::new();
    for i in 0..n {
        let mut req = InferRequest::new(ds.sample(i).to_vec());
        if let Some(name) = model {
            req = req.model(name);
        }
        if let Some(ms) = deadline_ms {
            req = req.deadline(std::time::Duration::from_millis(ms));
        }
        match client.submit(req)?.wait() {
            Ok(r) => {
                let pred = r
                    .output
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                if pred == ds.y[i] as usize {
                    correct += 1;
                }
                point = r.point;
            }
            Err(ServeError::DeadlineExceeded) => expired += 1,
            Err(e) => return Err(e.into()),
        }
    }
    Ok((correct, expired, point))
}

/// Compile, Pareto-prune and persist the operating-point menu
/// (`pann-cli compile-menu`). With `--per-layer`, the uniform sweep is
/// augmented by the sensitivity-guided mixed-precision search
/// ([`pann::pann::compile_menu_per_layer`]) before pruning.
fn compile_menu_cmd(
    ctx: &Ctx,
    model_name: &str,
    bits: &[u32],
    out: &str,
    per_layer: Option<pann::pann::PerLayerSearch>,
) -> Result<()> {
    use pann::quant::ActQuantMethod;
    let (model, test) = ctx.load_model(model_name)?;
    let val = test.take(ctx.eval_n().min(128));
    let calib = pann::pann::convert::calib_tensor(&test, 32);
    let t0 = std::time::Instant::now();
    let menu = match per_layer {
        Some(search) => pann::pann::compile_menu_per_layer(
            &model,
            bits,
            ActQuantMethod::BnStats,
            Some(&calib),
            &val,
            2..=8,
            search,
        )?,
        None => pann::pann::compile_menu(
            &model,
            bits,
            ActQuantMethod::BnStats,
            Some(&calib),
            &val,
            2..=8,
        )?,
    };
    let dt = t0.elapsed().as_secs_f64();
    menu.save(std::path::Path::new(out))?;
    let mixed = menu.points.iter().filter(|p| p.layer_bits.is_some()).count();
    println!(
        "compiled menu for '{model_name}' in {dt:.2}s: swept {} candidates, kept {} frontier \
         points ({} pruned, {} mixed-precision) -> {out}",
        menu.swept,
        menu.points.len(),
        menu.pruned(),
        mixed
    );
    for line in menu.frontier_lines() {
        println!("  {line}");
    }
    Ok(())
}

/// Statically audit a menu artifact for overflow soundness
/// (`pann-cli verify --menu menu.json [--model NAME]`).
///
/// Exit contract: **0** — every point is provably sound; **1** —
/// operational error (unreadable or corrupt artifact, model load
/// failure), reported on stderr by `main`; **2** — the audit produced
/// findings: the artifact declares operand widths whose codes cannot
/// fit the kernels' operand slabs, or (with `--model`) the recompiled
/// plans' per-layer certificates do not admit the kernels that would
/// be selected. The machine-readable report (`pann-verify/v1`) goes
/// to stdout in every non-error case.
///
/// The width audit is model-free: activation codes span
/// `[0, 2^b̃x − 1]` under dynamic quantization and weight codes span
/// `±2^(bR−1)`, so `b̃x ∉ 1..=31` or `bR > 31` already proves the i32
/// operand slabs can wrap before any model is consulted. `--model`
/// additionally recompiles every point and re-derives the per-layer
/// [`pann::analysis::KernelCert`]s, cross-checking each selected
/// kernel against its certificate.
fn verify_menu(ctx: &Ctx, menu_path: &str, model_name: Option<&str>) -> Result<()> {
    use pann::nn::GemmKernel;
    use pann::util::Json;
    let artifact = pann::pann::MenuArtifact::load(std::path::Path::new(menu_path))
        .with_context(|| format!("load menu artifact {menu_path}"))?;
    let mut findings: Vec<Json> = Vec::new();
    let mut report = |point: &str, kind: &str, detail: String| {
        findings.push(Json::obj(vec![
            ("point", Json::from(point)),
            ("kind", Json::from(kind)),
            ("detail", Json::from(detail)),
        ]));
    };

    // model-free width audit: reject artifacts whose declared operand
    // widths already overflow the kernels' operand slabs
    for p in &artifact.points {
        if p.bx_tilde == 0 || p.bx_tilde > 31 {
            report(
                &p.name,
                "act-width",
                format!(
                    "activation width b̃x={} is outside 1..=31: dynamic activation \
                     codes span [0, 2^b̃x − 1], which cannot be represented in the \
                     i32 operand slab",
                    p.bx_tilde
                ),
            );
        }
        if p.weight_code_bits > 31 {
            report(
                &p.name,
                "weight-width",
                format!(
                    "weight code width bR={} exceeds 31 bits: split-bank codes \
                     cannot be represented in the i32 operand slab",
                    p.weight_code_bits
                ),
            );
        }
    }

    // with a model: recompile every point and re-derive the per-layer
    // overflow certificates the kernel selection was proven against
    let mut points_recompiled = 0usize;
    if let Some(name) = model_name {
        let (model, test) = ctx.load_model(name)?;
        if model.fingerprint() != artifact.model_fingerprint {
            report(
                "(menu)",
                "fingerprint",
                format!(
                    "menu was compiled for model '{}' (fingerprint {:016x}), \
                     '{name}' has fingerprint {:016x}",
                    artifact.model_name,
                    artifact.model_fingerprint,
                    model.fingerprint()
                ),
            );
        } else {
            let calib = pann::pann::convert::calib_tensor(&test, 32);
            for p in &artifact.points {
                let cfg = pann::nn::QuantConfig::pann(p.bx_tilde, p.r, p.quant_method);
                // mixed (v3) points recompile through the per-layer
                // path, facing exactly the same certificate prover
                let plan = match pann::nn::ExecutionPlan::compile_with_layers(
                    &model,
                    cfg,
                    p.layer_bits.as_deref(),
                    Some(&calib),
                ) {
                    Ok(plan) => plan,
                    Err(e) => {
                        report(
                            &p.name,
                            "compile",
                            format!("point does not recompile into a provably safe plan: {e:#}"),
                        );
                        continue;
                    }
                };
                points_recompiled += 1;
                for (node, kernel, cert) in plan.layer_certs() {
                    let admitted = match kernel {
                        GemmKernel::Wide | GemmKernel::SplitWide => cert.admits_wide(),
                        GemmKernel::Narrow | GemmKernel::SplitNarrow => cert.admits_narrow(),
                    };
                    if !admitted {
                        report(
                            &p.name,
                            "certificate",
                            format!(
                                "node {node}: selected kernel {kernel:?} is not admitted \
                                 by its overflow certificate (acc hull [{}, {}])",
                                cert.acc.lo, cert.acc.hi
                            ),
                        );
                    }
                }
            }
        }
    }

    let sound = findings.is_empty();
    let out = Json::obj(vec![
        ("schema", Json::from("pann-verify/v1")),
        ("menu", Json::from(menu_path)),
        (
            "model",
            model_name.map_or(Json::Null, Json::from),
        ),
        ("points_checked", Json::from(artifact.points.len())),
        ("points_recompiled", Json::from(points_recompiled)),
        ("sound", Json::from(sound)),
        ("findings", Json::Arr(findings)),
    ]);
    println!("{out}");
    if !sound {
        std::process::exit(2);
    }
    Ok(())
}

/// Serve a compiled menu artifact on the native worker pool
/// (`pann-cli serve --menu menu.json`), sweeping the global budget
/// across the frontier to demonstrate deployment-time traversal —
/// or, with `--envelope-gflips`, letting the closed-loop governor
/// own the budget while the replayed load runs.
///
/// The model must be loaded exactly as it was for `compile-menu`
/// (same `--model`, same `--quick`ness when falling back to the
/// built-in reference models) — the artifact's fingerprint check
/// rejects anything else. With `--calibrate-out PATH`, the measured
/// per-point Gflips/sample observed while serving are written back
/// into the artifact as the `pann-menu/v2` calibration field.
#[allow(clippy::too_many_arguments)]
fn serve_menu(
    ctx: &Ctx,
    model: &str,
    menu_path: &str,
    n_requests: usize,
    budget: f64,
    queue_depth: usize,
    deadline_ms: Option<u64>,
    governor: Option<GovernorCli>,
    calibrate_out: Option<String>,
) -> Result<()> {
    let (m, test) = ctx.load_model(model)?;
    let mut artifact = pann::pann::MenuArtifact::load(std::path::Path::new(menu_path))?;
    println!(
        "menu {menu_path}: {} frontier points ({} candidates swept) for model '{}'",
        artifact.points.len(),
        artifact.swept,
        artifact.model_name
    );
    let calib = pann::pann::convert::calib_tensor(&test, 32);
    let max_batch = 16;
    // build the serving points from the artifact already in hand (one
    // read: the sweep below and the served menu cannot diverge)
    let menu = Menu::shared(artifact.shared_points(&m, Some(&calib), max_batch)?);
    let workers = pann::nn::eval::n_threads();
    let governed = governor.is_some();
    let builder = GovernorCli::configure(
        &governor,
        ServerBuilder::new()
            .workers(workers)
            .queue_depth(queue_depth)
            .max_batch(max_batch)
            .budget_gflips(budget),
    );
    let srv = builder.serve(menu)?;
    let client = srv.client();
    let n = n_requests.min(test.len()).max(1);
    let run_phase = |phase_budget: Option<f64>| -> Result<(String, f64, usize, usize)> {
        if let Some(b) = phase_budget {
            client.set_budget(b);
        }
        let (correct, expired, served_by) = replay(&client, None, &test, n, deadline_ms)?;
        let served = n - expired;
        let acc = correct as f64 / served.max(1) as f64;
        Ok((served_by, acc, served, expired))
    };
    if governed {
        // the governor owns the budget cell: replay the load and let
        // it pick the point, instead of sweeping budgets it would
        // immediately overwrite
        println!("closed-loop replay ({workers} workers, {n} requests, governor active):");
        let (served_by, acc, served, expired) = run_phase(None)?;
        println!(
            "  governed -> last point {:<18} test acc {acc:.3} ({served} served{})",
            served_by,
            if expired > 0 { format!(", {expired} expired") } else { String::new() }
        );
    } else {
        println!(
            "sweeping the global budget across the frontier ({workers} workers, {n} requests per point):"
        );
        for p in &artifact.points {
            // a budget fractionally above the point's cost must land on it
            let (served_by, acc, served, expired) =
                run_phase(Some(p.gflips_per_sample * (1.0 + 1e-9)))?;
            println!(
                "  budget {:>12.6} GF -> point {:<18} test acc {acc:.3} ({served} served{})",
                p.gflips_per_sample,
                served_by,
                if expired > 0 { format!(", {expired} expired") } else { String::new() }
            );
            if served > 0 && served_by != p.name {
                println!("    (warn: expected point {} to serve this budget)", p.name);
            }
        }
        // finish at the caller's --budget so the flag is honored (the
        // frontier sweep above deliberately overrides the global budget)
        if budget.is_finite() {
            let (served_by, acc, served, expired) = run_phase(Some(budget))?;
            println!(
                "  --budget {:>10.6} GF -> point {:<18} test acc {acc:.3} ({served} served{})",
                budget,
                served_by,
                if expired > 0 { format!(", {expired} expired") } else { String::new() }
            );
        }
    }
    let snapshot = client.metrics();
    println!("{}", snapshot.report());
    print_governor(&client);
    srv.shutdown();
    // measured-cost calibration write-back: the pann-menu/v2 loop
    if let Some(out) = calibrate_out {
        let measured: Vec<(&str, f64)> = snapshot
            .per_point_measured
            .iter()
            .filter_map(|(name, gf)| gf.map(|g| (name.as_str(), g)))
            .collect();
        let updated = artifact.apply_calibration(measured);
        artifact.save(std::path::Path::new(&out))?;
        println!("calibrated {updated}/{} menu points -> {out}", artifact.points.len());
    }
    Ok(())
}

/// Serve a *fleet*: every `NAME=path` entry registers one compiled
/// menu artifact under its model name, all served from one worker pool
/// and one bounded queue (`pann-cli serve --menu a=a.json --menu
/// b=b.json`). Each NAME is loaded via [`Ctx::load_model`] and
/// fingerprint-verified against its artifact when the fleet starts.
/// With `--envelope-gflips` the global envelope is split across the
/// models by observed demand (a hot model degrades along its own
/// frontier before starving a cold one); the per-model governor
/// snapshots are printed at the end.
#[allow(clippy::too_many_arguments)]
fn serve_fleet_cli(
    ctx: &Ctx,
    entries: &[(String, String)],
    n_requests: usize,
    budget: f64,
    queue_depth: usize,
    deadline_ms: Option<u64>,
    governor: Option<GovernorCli>,
) -> Result<()> {
    let workers = pann::nn::eval::n_threads();
    let max_batch = 16;
    let mut builder = GovernorCli::configure(
        &governor,
        ServerBuilder::new()
            .workers(workers)
            .queue_depth(queue_depth)
            .max_batch(max_batch)
            .budget_gflips(budget),
    );
    let mut test_sets = Vec::with_capacity(entries.len());
    for (name, path) in entries {
        let (model, test) = ctx.load_model(name)?;
        let artifact = pann::pann::MenuArtifact::load(std::path::Path::new(path))?;
        println!(
            "model {name}: menu {path} with {} frontier points ({} candidates swept)",
            artifact.points.len(),
            artifact.swept
        );
        let calib = pann::pann::convert::calib_tensor(&test, 32);
        // register from the artifact already in hand (one read per
        // model: the printed header and the served menu cannot
        // diverge); shared_points verifies the model fingerprint
        builder = builder.register(
            name.clone(),
            Menu::shared(artifact.shared_points(&model, Some(&calib), max_batch)?),
        );
        test_sets.push((name.clone(), test));
    }
    let srv = builder.serve_fleet()?;
    let client = srv.client();
    println!(
        "fleet of {} models on one pool ({workers} workers, {n_requests} requests per model):",
        entries.len()
    );
    for (name, test) in &test_sets {
        let n = n_requests.min(test.len()).max(1);
        let (correct, expired, served_by) =
            replay(&client, Some(name.as_str()), test, n, deadline_ms)?;
        let served = n - expired;
        let acc = correct as f64 / served.max(1) as f64;
        println!(
            "  model {name:<10} -> last point {:<18} test acc {acc:.3} ({served} served{})",
            served_by,
            if expired > 0 { format!(", {expired} expired") } else { String::new() }
        );
    }
    println!("{}", client.metrics().report());
    if let Some(fleet) = client.fleet() {
        print!("{}", fleet.report());
    }
    srv.shutdown();
    Ok(())
}

/// Serve a compiled menu over the network edge (`pann-cli serve
/// --menu menu.json --listen ADDR [--shards N] [--hold]`): the menu is
/// compiled once per shard (engines are per-shard, plans cheap to
/// share), the shards sit behind a [`ShardRouter`] (rendezvous
/// affinity, shed retry), and a [`NetServer`] exposes them over
/// HTTP/1.1. With `--envelope-gflips` the cluster envelope is split
/// across the shards by observed demand, each shard running its own
/// governor on its slice. Prints `listening on http://ADDR` (with the
/// real port when bound to `:0`) so scripts can discover the address.
#[allow(clippy::too_many_arguments)]
fn serve_listen(
    ctx: &Ctx,
    model: &str,
    menu_path: &str,
    addr: &str,
    shards: usize,
    budget: f64,
    queue_depth: usize,
    governor: Option<GovernorCli>,
    hold: bool,
) -> Result<()> {
    let (m, test) = ctx.load_model(model)?;
    let artifact = pann::pann::MenuArtifact::load(std::path::Path::new(menu_path))?;
    println!(
        "menu {menu_path}: {} frontier points ({} candidates swept) for model '{}'",
        artifact.points.len(),
        artifact.swept,
        artifact.model_name
    );
    let calib = pann::pann::convert::calib_tensor(&test, 32);
    let max_batch = 16;
    // split the native thread pool across the shards instead of
    // oversubscribing it shards-fold
    let workers = (pann::nn::eval::n_threads() / shards).max(1);
    // price shard demand at the most accurate (most expensive) finite
    // frontier point: what serving everything at full accuracy would
    // cost per sample
    let top_cost = artifact
        .points
        .iter()
        .map(|p| p.gflips_per_sample)
        .filter(|g| g.is_finite())
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let mut rb = ShardRouter::builder();
    if let Some(g) = &governor {
        rb = rb
            .envelope(EnergyEnvelope::gflips_per_sec(g.rate), top_cost)
            // re-split demand a few governor windows apart so each
            // governor settles between re-targets
            .window(std::time::Duration::from_millis(g.window_ms * 4));
    }
    let router = rb.build(shards, |i, slice| {
        let mut b = ServerBuilder::new()
            .workers(workers)
            .queue_depth(queue_depth)
            .max_batch(max_batch)
            .budget_gflips(budget);
        if let Some(e) = slice {
            b = b.envelope(e);
            if let Some(g) = &governor {
                b = b.governor_window(std::time::Duration::from_millis(g.window_ms));
            }
        }
        // fresh engines per shard off the same verified artifact
        let srv = b.serve(Menu::shared(artifact.shared_points(&m, Some(&calib), max_batch)?))?;
        println!("shard {i}: {workers} workers, queue depth {queue_depth}");
        Ok(srv)
    })?;
    let net = NetServer::bind(addr, router, NetConfig::default())
        .with_context(|| format!("binding the edge on {addr}"))?;
    println!("listening on http://{}", net.local_addr());
    println!("endpoints: POST /v1/infer  GET /v1/models  GET /v1/governor  GET /metrics");
    if hold {
        println!("holding until stdin EOF (pipe `sleep N |` in scripts, or Ctrl-D)...");
        hold_until_stdin_eof();
        println!("stdin closed: draining in-flight requests and stopping shards");
    } else {
        println!("no --hold: configuration verified, shutting the edge down");
    }
    net.shutdown();
    println!("edge stopped");
    Ok(())
}

/// Block until stdin reaches EOF (the `--hold` lifetime).
fn hold_until_stdin_eof() {
    use std::io::Read;
    let mut stdin = std::io::stdin();
    let mut buf = [0u8; 1024];
    loop {
        match stdin.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}

/// Deterministic scenario replay (`pann-cli replay`): feed a
/// `pann-trace/v1` workload through the virtual-clock rig against a
/// compiled menu on a named device profile. Human summary on stderr,
/// `scenario-report/v1` JSON on stdout; exit 0 when the report's
/// accounting invariants hold, 1 on operational errors, 2 with
/// findings (printed to stderr).
#[allow(clippy::too_many_arguments)]
fn replay_cmd(
    trace_path: &str,
    menu_path: &str,
    device_name: &str,
    shards: usize,
    envelope: Option<f64>,
    governor_window_ms: Option<u64>,
    quick: bool,
    out: Option<&str>,
) -> Result<()> {
    use pann::scenario::{frontier_from_menu, DeviceProfile, ReplayConfig, Trace};
    let trace = Trace::load(std::path::Path::new(trace_path))
        .with_context(|| format!("load trace {trace_path}"))?;
    let artifact = pann::pann::MenuArtifact::load(std::path::Path::new(menu_path))
        .with_context(|| format!("load menu artifact {menu_path}"))?;
    let device = DeviceProfile::by_name(device_name).with_context(|| {
        let names: Vec<&str> = DeviceProfile::all().iter().map(|d| d.name).collect();
        format!("unknown device '{device_name}' (known: {})", names.join(", "))
    })?;
    let frontier = frontier_from_menu(&artifact, &device);
    if frontier.is_empty() {
        bail!("menu {menu_path} has no frontier points to replay");
    }
    let mut cfg = ReplayConfig::new(device);
    cfg.shards = shards;
    cfg.envelope_gflips_per_sec = envelope;
    if let Some(ms) = governor_window_ms {
        if ms == 0 {
            bail!("--governor-window-ms must be at least 1");
        }
        cfg.governor_window_us = ms * 1_000;
    }
    if quick {
        cfg.max_events = Some(64);
    }
    let report = pann::scenario::replay(&trace, &frontier, &cfg)?;
    eprint!("{}", report.summary());
    let doc = report.to_json();
    if let Some(path) = out {
        pann::util::bench::write_json(path, &doc)
            .with_context(|| format!("write report {path}"))?;
        eprintln!("report written to {path}");
    }
    println!("{doc}");
    let findings = report.invariants();
    if !findings.is_empty() {
        for f in &findings {
            eprintln!("finding: {f}");
        }
        std::process::exit(2);
    }
    Ok(())
}

/// Fig. 1 power–accuracy sweep on the native engine.
fn sweep(ctx: &Ctx, model: &str) -> Result<()> {
    use pann::pann::{algorithm1, convert};
    use pann::quant::ActQuantMethod;
    let (m, test) = ctx.load_model(model)?;
    let test = test.take(ctx.eval_n());
    let calib = convert::calib_tensor(&test, 32);
    println!("{:<8} {:>12} {:>8} | {:>12} {:>8}", "budget", "base GF", "acc", "pann GF", "acc");
    for bits in [2u32, 3, 4, 6, 8] {
        let (_, base) =
            convert::unsigned_of(&m, bits, ActQuantMethod::BnStats, Some(&calib), &test)?;
        let p = pann::power::model::mac_power_unsigned_total(bits);
        let op = algorithm1::choose_operating_point(
            &m,
            p,
            ActQuantMethod::BnStats,
            Some(&calib),
            &test.take(96),
            2..=8,
        )?;
        let (_, our) = convert::pann_at_budget(
            &m,
            op.bx_tilde,
            op.r,
            ActQuantMethod::BnStats,
            Some(&calib),
            &test,
        )?;
        println!(
            "{:<8} {:>12.4} {:>8.3} | {:>12.4} {:>8.3}",
            format!("{bits}-bit"),
            base.giga_flips,
            base.accuracy(),
            our.giga_flips,
            our.accuracy()
        );
    }
    Ok(())
}
