//! Fixed-width two's-complement word helpers.
//!
//! A *word* is the low `width` bits of an `i64`, stored in a `u64`.
//! Sign extension / truncation follow two's-complement semantics, so a
//! negative value has all bits above its magnitude set — the property
//! responsible for the paper's Observation 1 (sign bits dominate
//! accumulator-input toggling).

/// Mask of the low `width` bits.
#[inline]
pub fn mask(width: u32) -> u64 {
    debug_assert!(width <= 64);
    if width == 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// Encode `v` as a `width`-bit two's-complement word.
#[inline]
pub fn to_word(v: i64, width: u32) -> u64 {
    (v as u64) & mask(width)
}

/// Decode a `width`-bit word back to a signed value.
#[inline]
pub fn from_word(w: u64, width: u32) -> i64 {
    let m = mask(width);
    let w = w & m;
    if width < 64 && (w >> (width - 1)) & 1 == 1 {
        (w | !m) as i64
    } else {
        w as i64
    }
}

/// Hamming distance between two words (toggle count of a register).
#[inline]
pub fn hamming(a: u64, b: u64) -> u64 {
    (a ^ b).count_ones() as u64
}

/// Does `v` fit in a signed `width`-bit word?
pub fn fits_signed(v: i64, width: u32) -> bool {
    if width >= 64 {
        return true;
    }
    let lo = -(1i64 << (width - 1));
    let hi = (1i64 << (width - 1)) - 1;
    (lo..=hi).contains(&v)
}

/// Does `v` fit in an unsigned `width`-bit word?
pub fn fits_unsigned(v: i64, width: u32) -> bool {
    if v < 0 {
        return false;
    }
    if width >= 63 {
        return true;
    }
    v < (1i64 << width)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_signed() {
        for width in [2u32, 4, 8, 16, 32] {
            let lo = -(1i64 << (width - 1));
            let hi = (1i64 << (width - 1)) - 1;
            for v in [lo, lo + 1, -1, 0, 1, hi - 1, hi] {
                assert_eq!(from_word(to_word(v, width), width), v, "w={width} v={v}");
            }
        }
    }

    #[test]
    fn negative_words_have_high_bits() {
        // -1 in 4 bits inside an 8-bit register view is 0b00001111,
        // but sign-extended to 8 bits it is 0b11111111.
        assert_eq!(to_word(-1, 4), 0b1111);
        assert_eq!(to_word(-1, 8), 0b1111_1111);
        assert_eq!(to_word(from_word(to_word(-1, 4), 4), 8), 0b1111_1111);
    }

    #[test]
    fn hamming_basic() {
        assert_eq!(hamming(0, 0), 0);
        assert_eq!(hamming(0b1010, 0b0101), 4);
        assert_eq!(hamming(u64::MAX, 0), 64);
    }

    #[test]
    fn fits() {
        assert!(fits_signed(-8, 4));
        assert!(!fits_signed(8, 4));
        assert!(fits_unsigned(15, 4));
        assert!(!fits_unsigned(16, 4));
        assert!(!fits_unsigned(-1, 4));
    }

    #[test]
    fn wrap_mul_matches_word_math() {
        // Products mod 2^(2b) equal word-encoded wrapping products.
        for (a, b) in [(-8i64, 7i64), (3, -5), (-8, -8), (7, 7)] {
            let p = a.wrapping_mul(b);
            assert_eq!(from_word(to_word(p, 8), 8), p); // fits in 2b=8
        }
    }
}
