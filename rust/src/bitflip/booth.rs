//! Radix-2 Booth-encoding multiplier with toggle accounting.
//!
//! The Booth encoder examines consecutive bit pairs of the multiplicand
//! and emits a signed digit per position: `(w_i, w_{i-1})` → `+x`, `-x`
//! or `0` (App. A.2's example: `x × 15` becomes `x × (2⁴ − 2⁰)`,
//! saving two additions relative to the serial multiplier). Runs of
//! ones — including the sign extension of negative numbers — recode to
//! zero rows, which is why Booth is the toggle-efficient choice the
//! paper simulates (Asif & Kong, 2015).
//!
//! The datapath model shares the [`Chain`] of the serial multiplier:
//! row registers, running-sum registers and carry chains, `2b` bits
//! wide. Booth rows can be *negative* even for unsigned operands, so
//! the unsigned power save from shrinking one operand is smaller here
//! than for the serial multiplier — the effect of the paper's Fig. 10
//! vs. Fig. 11.

use super::serial_mult::Chain;
use super::word::{from_word, hamming, to_word};
use super::{MultToggles, Multiplier};

/// `b×b` Radix-2 Booth multiplier.
#[derive(Clone, Debug)]
pub struct BoothMultiplier {
    chain: Chain,
    prev_w: u64,
    prev_x: u64,
    prev_out: u64,
    prev_digits: u64, // 2 bits per digit position, for encoder toggles
    signed: bool,
}

impl BoothMultiplier {
    /// New `b×b` Booth multiplier; `signed` selects operand encoding.
    pub fn new(b: u32, signed: bool) -> Self {
        BoothMultiplier {
            chain: Chain::new(b),
            prev_w: 0,
            prev_x: 0,
            prev_out: 0,
            prev_digits: 0,
            signed,
        }
    }

    /// Booth-recoded digits of `w` (values in {-1, 0, +1} per position).
    fn digits(&self, w: i64) -> Vec<i64> {
        let b = self.chain.b;
        let ww = to_word(w, b);
        // For unsigned operands one extra implicit zero bit above the
        // msb would be needed to represent w == 2^b - 1; we instead give
        // the top pair its unsigned weight directly (hardware: a b+1-th
        // column), keeping products exact for both encodings.
        (0..b)
            .map(|i| {
                let wi = ((ww >> i) & 1) as i64;
                let wim1 = if i == 0 { 0 } else { ((ww >> (i - 1)) & 1) as i64 };
                if self.signed || i < b - 1 {
                    wim1 - wi
                } else {
                    // top position of an unsigned operand: weight +1 for
                    // the bit itself plus the pending carry digit.
                    wim1 + wi
                }
            })
            .collect()
    }
}

impl Multiplier for BoothMultiplier {
    fn mul(&mut self, w: i64, x: i64) -> (i64, MultToggles) {
        let b = self.chain.b;
        if self.signed {
            debug_assert!(super::word::fits_signed(w, b) && super::word::fits_signed(x, b));
        } else {
            debug_assert!(super::word::fits_unsigned(w, b) && super::word::fits_unsigned(x, b));
        }
        let ww = to_word(w, b);
        let xw = to_word(x, b);
        let mut inputs = hamming(ww, self.prev_w) + hamming(xw, self.prev_x);
        self.prev_w = ww;
        self.prev_x = xw;

        let digits = self.digits(w);
        // Encoder output register: 2 bits per digit (sign, nonzero).
        let mut dig_word = 0u64;
        for (i, d) in digits.iter().enumerate() {
            let bits = match d {
                0 => 0u64,
                1 => 0b01,
                -1 => 0b11,
                2 => 0b10, // unsigned top-position carry case
                _ => 0b10,
            };
            dig_word |= bits << (2 * i);
        }
        inputs += hamming(dig_word, self.prev_digits);
        self.prev_digits = dig_word;

        let rows: Vec<i64> = digits.iter().enumerate().map(|(i, d)| d * (x << i)).collect();
        let (prod_word, internal) = self.chain.accumulate(&rows);
        let output = hamming(prod_word, self.prev_out);
        self.prev_out = prod_word;

        let prod = if self.signed {
            from_word(prod_word, 2 * b)
        } else {
            // Unsigned product fits in 2b bits by construction.
            prod_word as i64
        };
        (prod, MultToggles { inputs, internal, output })
    }

    fn out_width(&self) -> u32 {
        2 * self.chain.b
    }

    fn reset(&mut self) {
        self.chain.reset();
        self.prev_w = 0;
        self.prev_x = 0;
        self.prev_out = 0;
        self.prev_digits = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_products_signed_exhaustive_small() {
        for b in [2u32, 3, 4, 5] {
            let mut m = BoothMultiplier::new(b, true);
            let lo = -(1i64 << (b - 1));
            let hi = 1i64 << (b - 1);
            for w in lo..hi {
                for x in lo..hi {
                    let (p, _) = m.mul(w, x);
                    assert_eq!(p, w * x, "b={b} {w}*{x}");
                }
            }
        }
    }

    #[test]
    fn exact_products_unsigned_exhaustive_small() {
        for b in [2u32, 3, 4] {
            let mut m = BoothMultiplier::new(b, false);
            for w in 0..(1i64 << b) {
                for x in 0..(1i64 << b) {
                    let (p, _) = m.mul(w, x);
                    assert_eq!(p, w * x, "b={b} {w}*{x}");
                }
            }
        }
    }

    #[test]
    fn exact_products_signed_random_b8() {
        let mut m = BoothMultiplier::new(8, true);
        let mut r = Rng::new(13);
        for _ in 0..5000 {
            let w = r.range_i64(-128, 128);
            let x = r.range_i64(-128, 128);
            let (p, _) = m.mul(w, x);
            assert_eq!(p, w * x);
        }
    }

    #[test]
    fn booth_beats_serial_on_runs_of_ones() {
        // 15 = 0b1111 recodes to two rows (+16x, -x): fewer active rows
        // than the serial multiplier's four.
        let booth = BoothMultiplier::new(8, true);
        let digits = booth.digits(15);
        let active = digits.iter().filter(|d| **d != 0).count();
        assert_eq!(active, 2, "digits {digits:?}");
    }

    #[test]
    fn negative_sign_extension_recodes_to_zero_rows() {
        let booth = BoothMultiplier::new(8, true);
        let digits = booth.digits(-1); // 0b11111111 -> single -x row
        let active = digits.iter().filter(|d| **d != 0).count();
        assert_eq!(active, 1, "digits {digits:?}");
    }

    #[test]
    fn unsigned_bw_save_smaller_than_serial() {
        // Fig. 10 vs 11: Booth's unsigned save from shrinking b_w is
        // present but smaller than the serial multiplier's.
        use super::super::serial_mult::SerialMultiplier;
        let b = 8u32;
        let run = |mult: &mut dyn Multiplier, bw: u32, seed: u64| {
            let mut r = Rng::new(seed);
            let n = 6000;
            let mut tot = 0u64;
            for _ in 0..n {
                let w = r.range_i64(0, 1i64 << (bw - 1));
                let x = r.range_i64(0, 1i64 << (b - 1));
                let (_, t) = mult.mul(w, x);
                tot += t.internal;
            }
            tot as f64 / n as f64
        };
        let mut booth8 = BoothMultiplier::new(b, false);
        let mut booth3 = BoothMultiplier::new(b, false);
        let mut ser8 = SerialMultiplier::new(b, false);
        let mut ser3 = SerialMultiplier::new(b, false);
        let booth_save = 1.0 - run(&mut booth3, 3, 1) / run(&mut booth8, 8, 1);
        let serial_save = 1.0 - run(&mut ser3, 3, 1) / run(&mut ser8, 8, 1);
        // In our register-level model the two saves are close (Booth's
        // negative rows keep some high-bit activity); the paper's
        // direction (serial ≥ booth) holds up to a small tolerance.
        assert!(
            serial_save > booth_save - 0.05,
            "serial {serial_save} booth {booth_save}"
        );
        assert!(booth_save > 0.0, "booth still saves a little: {booth_save}");
    }
}
