//! Input distributions for the toggle experiments (paper App. A.2).
//!
//! - `UniformSigned(b)` — uniform over `[-2^{b-1}, 2^{b-1})`.
//! - `UniformUnsigned(b)` — uniform over `[0, 2^{b-1})`; the paper uses
//!   half the range so the multiplier architecture is unchanged
//!   (App. A.4, last paragraph).
//! - `GaussianSigned(b)` / `GaussianUnsigned(b)` — N(0,1) samples
//!   normalized by the batch max-abs, scaled to `2^{b-1}`, rounded and
//!   clipped (the paper's exact recipe with N = 36000).

use crate::util::Rng;

/// A quantized input distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dist {
    /// Uniform over the full signed `b`-bit range.
    UniformSigned(u32),
    /// Uniform over half the unsigned `b`-bit range (App. A.4).
    UniformUnsigned(u32),
    /// Normalized/rounded N(0,1), signed.
    GaussianSigned(u32),
    /// Normalized/rounded |N(0,1)|, unsigned.
    GaussianUnsigned(u32),
}

impl Dist {
    /// Bit width of the distribution.
    pub fn bits(&self) -> u32 {
        match *self {
            Dist::UniformSigned(b)
            | Dist::UniformUnsigned(b)
            | Dist::GaussianSigned(b)
            | Dist::GaussianUnsigned(b) => b,
        }
    }

    /// Whether the distribution produces negative values.
    pub fn is_signed(&self) -> bool {
        matches!(self, Dist::UniformSigned(_) | Dist::GaussianSigned(_))
    }
}

/// Pre-generated sample stream from a [`Dist`].
pub struct Sampler {
    vals: Vec<i64>,
    idx: usize,
}

impl Sampler {
    /// Generate `n` samples (the paper uses N = 36000).
    pub fn new(dist: Dist, n: usize, rng: &mut Rng) -> Self {
        let b = dist.bits();
        assert!((2..=16).contains(&b));
        let half = 1i64 << (b - 1);
        let vals: Vec<i64> = match dist {
            Dist::UniformSigned(_) => (0..n).map(|_| rng.range_i64(-half, half)).collect(),
            Dist::UniformUnsigned(_) => (0..n).map(|_| rng.range_i64(0, half)).collect(),
            Dist::GaussianSigned(_) | Dist::GaussianUnsigned(_) => {
                let unsigned = !dist.is_signed();
                let raw: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let mx = raw.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-12);
                raw.iter()
                    .map(|&x| {
                        let v = (x / mx * half as f64).round() as i64;
                        let v = v.clamp(-half, half - 1);
                        if unsigned {
                            v.abs().min(half - 1)
                        } else {
                            v
                        }
                    })
                    .collect()
            }
        };
        Sampler { vals, idx: 0 }
    }

    /// Next sample (cycles through the buffer).
    pub fn next(&mut self) -> i64 {
        let v = self.vals[self.idx];
        self.idx = (self.idx + 1) % self.vals.len();
        v
    }

    /// Number of pre-generated samples in the buffer.
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(1);
        for dist in [
            Dist::UniformSigned(4),
            Dist::UniformUnsigned(4),
            Dist::GaussianSigned(4),
            Dist::GaussianUnsigned(4),
        ] {
            let mut s = Sampler::new(dist, 5000, &mut r);
            for _ in 0..5000 {
                let v = s.next();
                if dist.is_signed() {
                    assert!((-8..8).contains(&v), "{dist:?} -> {v}");
                } else {
                    assert!((0..8).contains(&v), "{dist:?} -> {v}");
                }
            }
        }
    }

    #[test]
    fn uniform_signed_covers_range() {
        let mut r = Rng::new(2);
        let mut s = Sampler::new(Dist::UniformSigned(3), 4000, &mut r);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4000 {
            seen.insert(s.next());
        }
        assert_eq!(seen.len(), 8); // all of [-4, 4)
    }

    #[test]
    fn gaussian_concentrated_near_zero() {
        let mut r = Rng::new(3);
        let mut s = Sampler::new(Dist::GaussianSigned(8), 36000, &mut r);
        let n = 36000;
        let small = (0..n).filter(|_| s.next().abs() < 64).count();
        // Most mass within half the range (the paper's Fig. 6b shape).
        assert!(small as f64 / n as f64 > 0.9);
    }
}
