//! Component-level ripple-carry adder with toggle accounting.
//!
//! The adder remembers the operand registers, the carry chain and the
//! sum register of the previous instruction and counts Hamming toggles
//! on each add — the methodology of the paper's App. A.2 / Fig. 7.

use super::word::{hamming, mask, to_word};

/// Toggle breakdown of one addition.
#[derive(Clone, Copy, Debug, Default)]
pub struct AddToggles {
    /// Toggles in the two operand input registers.
    pub inputs: u64,
    /// Toggles in the internal carry chain.
    pub carries: u64,
    /// Toggles in the sum output register.
    pub sum: u64,
}

impl AddToggles {
    /// Total toggles of the addition.
    pub fn total(&self) -> u64 {
        self.inputs + self.carries + self.sum
    }
}

/// A `width`-bit ripple-carry adder with remembered state.
#[derive(Clone, Debug)]
pub struct RippleAdder {
    width: u32,
    prev_a: u64,
    prev_b: u64,
    prev_sum: u64,
    prev_carry: u64,
}

impl RippleAdder {
    /// New adder with all registers cleared.
    pub fn new(width: u32) -> Self {
        assert!((1..=64).contains(&width));
        RippleAdder { width, prev_a: 0, prev_b: 0, prev_sum: 0, prev_carry: 0 }
    }

    /// Operand/sum bit width.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Clear remembered state.
    pub fn reset(&mut self) {
        self.prev_a = 0;
        self.prev_b = 0;
        self.prev_sum = 0;
        self.prev_carry = 0;
    }

    /// Carry bits generated when adding words `a + b` (bit i of the
    /// result is the carry *into* position i+1).
    fn carry_bits(a: u64, b: u64, width: u32) -> u64 {
        // carry_out = majority(a, b, carry_in) per position; compute via
        // the identity carries = (a + b) ^ a ^ b shifted? For full-width
        // words: sum = a ^ b ^ carries_in where carries_in = carry_vec<<1.
        // We can recover the internal carry vector bit-serially.
        let mut carry = 0u64;
        let mut c = 0u64;
        for i in 0..width {
            let ai = (a >> i) & 1;
            let bi = (b >> i) & 1;
            let cout = (ai & bi) | (c & (ai ^ bi));
            carry |= cout << i;
            c = cout;
        }
        carry
    }

    /// Add two `width`-bit words (wrapping); returns sum word + toggles.
    pub fn add_words(&mut self, a: u64, b: u64) -> (u64, AddToggles) {
        let m = mask(self.width);
        let a = a & m;
        let b = b & m;
        let sum = a.wrapping_add(b) & m;
        let carry = Self::carry_bits(a, b, self.width);
        let t = AddToggles {
            inputs: hamming(a, self.prev_a) + hamming(b, self.prev_b),
            carries: hamming(carry, self.prev_carry),
            sum: hamming(sum, self.prev_sum),
        };
        self.prev_a = a;
        self.prev_b = b;
        self.prev_sum = sum;
        self.prev_carry = carry;
        (sum, t)
    }

    /// Add two signed values (two's complement, wrapping at `width`).
    pub fn add(&mut self, a: i64, b: i64) -> (i64, AddToggles) {
        let (sum, t) = self.add_words(to_word(a, self.width), to_word(b, self.width));
        (super::word::from_word(sum, self.width), t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn adds_correctly() {
        let mut add = RippleAdder::new(16);
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let a = r.range_i64(-30000, 30000);
            let b = r.range_i64(-2000, 2000);
            let (s, _) = add.add(a, b);
            assert_eq!(s, (a + b) as i16 as i64);
        }
    }

    #[test]
    fn first_add_toggles_set_bits() {
        let mut add = RippleAdder::new(8);
        let (_, t) = add.add(0b1010, 0b0101);
        assert_eq!(t.inputs, 4); // from all-zero state
        assert_eq!(t.sum, 4); // sum = 0b1111: four bits rise from zero
    }

    #[test]
    fn same_operands_zero_toggles() {
        let mut add = RippleAdder::new(12);
        add.add(37, 21);
        let (_, t) = add.add(37, 21);
        assert_eq!(t.total(), 0);
    }

    #[test]
    fn unsigned_random_input_toggles_half_width() {
        // Table 1: b-bit random operands toggle ~0.5b bits each.
        let b = 8;
        let mut add = RippleAdder::new(b);
        let mut r = Rng::new(2);
        let n = 20000;
        let mut tot = 0u64;
        for _ in 0..n {
            let a = r.range_i64(0, 1 << b);
            let c = r.range_i64(0, 1 << b);
            let (_, t) = add.add(a, c);
            tot += t.inputs;
        }
        let avg = tot as f64 / n as f64;
        let expect = b as f64; // 0.5b per operand × 2 operands
        assert!((avg - expect).abs() < 0.2, "avg {avg} expect {expect}");
    }

    #[test]
    fn carry_bits_known_case() {
        // 0b011 + 0b001 = 0b100: carries into pos1 from pos0 (1&1),
        // then ripple through pos1.
        let c = RippleAdder::carry_bits(0b011, 0b001, 3);
        assert_eq!(c, 0b011);
    }
}
