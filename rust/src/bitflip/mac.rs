//! Multiply-accumulate datapath (paper Fig. 2) and the multiplier-free
//! PANN datapath (Sec. 5), both with exact toggle accounting.
//!
//! A MAC couples a `b×b` multiplier with a `B`-bit accumulator whose
//! previous sum waits in a flip-flop register. The paper's Observation 1
//! falls out structurally here: with signed operands the product is
//! negative half the time, and its sign extension onto the `B`-bit
//! accumulator input bus flips all high bits — ~`0.5B` toggles per
//! instruction — while unsigned operands leave the high bits at zero.

use super::word::{from_word, hamming, mask, to_word};
use super::{MultToggles, Multiplier};

/// Toggle breakdown of one MAC instruction (rows of paper Table 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct MacToggles {
    /// Multiplier toggles (inputs / internal / output).
    pub mult: MultToggles,
    /// Toggles on the accumulator's input bus (the sign-extended
    /// product): the paper's dominant signed-arithmetic cost (`0.5B`).
    pub acc_input: u64,
    /// Toggles at the accumulator sum output (`0.5·b_acc`).
    pub acc_sum: u64,
    /// Toggles in the flip-flop holding the previous sum (`0.5·b_acc`).
    pub acc_ff: u64,
    /// Toggles in the accumulator's internal carry chain (not part of
    /// the paper's Table 1 breakdown; reported separately).
    pub acc_carries: u64,
}

impl MacToggles {
    /// Total toggles counted by the paper's model
    /// (`P_mult + P_acc`; carries excluded to match Table 1).
    pub fn paper_total(&self) -> u64 {
        self.mult.inputs + self.mult.internal + self.acc_input + self.acc_sum + self.acc_ff
    }

    /// Total of everything the simulator observed.
    pub fn full_total(&self) -> u64 {
        self.paper_total() + self.mult.output + self.acc_carries
    }
}

/// A MAC unit: multiplier + `B`-bit accumulator + FF.
pub struct MacUnit<M: Multiplier> {
    mult: M,
    acc_width: u32,
    acc: u64,
    prev_in: u64,
    prev_sum: u64,
    prev_ff: u64,
    prev_carry: u64,
}

impl<M: Multiplier> MacUnit<M> {
    /// New MAC with accumulator width `acc_width` (e.g. 32).
    pub fn new(mult: M, acc_width: u32) -> Self {
        assert!((4..=64).contains(&acc_width));
        MacUnit {
            mult,
            acc_width,
            acc: 0,
            prev_in: 0,
            prev_sum: 0,
            prev_ff: 0,
            prev_carry: 0,
        }
    }

    /// Current accumulated value (signed).
    pub fn value(&self) -> i64 {
        from_word(self.acc, self.acc_width)
    }

    /// Clear the accumulated value (new dot product), keeping the
    /// remembered register states — a reset wire does not erase the
    /// physical toggling history.
    pub fn clear_acc(&mut self) {
        self.acc = 0;
    }

    /// One multiply-accumulate: `acc += w*x`. Returns toggle breakdown.
    pub fn mac(&mut self, w: i64, x: i64) -> MacToggles {
        let (prod, mult_t) = self.mult.mul(w, x);
        let bacc = self.mult.out_width();
        let bw = self.acc_width;
        // The product arrives on the B-bit input bus sign-extended from
        // b_acc to B bits (two's complement).
        let in_bus = to_word(from_word(to_word(prod, bacc), bacc), bw);
        let acc_input = hamming(in_bus, self.prev_in);
        self.prev_in = in_bus;

        let carry = super::serial_mult::carry_bits(self.acc, in_bus, bw);
        let acc_carries = hamming(carry, self.prev_carry);
        self.prev_carry = carry;

        let sum = self.acc.wrapping_add(in_bus) & mask(bw);
        let acc_sum = hamming(sum, self.prev_sum);
        self.prev_sum = sum;
        // The FF captures the sum at the clock edge: same transition.
        let acc_ff = hamming(sum, self.prev_ff);
        self.prev_ff = sum;
        self.acc = sum;

        MacToggles { mult: mult_t, acc_input, acc_sum, acc_ff, acc_carries }
    }
}

/// The PANN multiplier-free datapath (Sec. 5.1): each product
/// `Q_w(w_i)·Q_x(x_i)` is realized as `Q_w(w_i)` repeated additions of
/// `Q_x(x_i)`. The accumulator *input* register holds `Q_x(x_i)` for
/// the whole burst, so it toggles only once per element; the sum and FF
/// toggle on every addition (`≈ 0.5·b̃_x` each) — Eq. (13):
/// `P_PANN = (R + 0.5)·b̃_x` per element.
///
/// Negative quantized weights are handled as in Sec. 4: a second
/// accumulator receives the bursts of the negative weights and a single
/// final subtraction combines the two (its cost is counted).
pub struct PannDatapath {
    x_width: u32,
    acc_width: u32,
    /// positive and negative accumulators
    acc: [u64; 2],
    prev_in: [u64; 2],
    prev_sum: [u64; 2],
    prev_ff: [u64; 2],
    prev_carry: [u64; 2],
}

/// Toggle breakdown of one PANN element (one weight/activation pair).
#[derive(Clone, Copy, Debug, Default)]
pub struct PannToggles {
    /// Toggles loading `Q_x(x_i)` onto the accumulator input bus.
    pub input: u64,
    /// Sum-output toggles over the burst of additions.
    pub sum: u64,
    /// FF toggles over the burst.
    pub ff: u64,
    /// Carry-chain toggles (reported separately, as in [`MacToggles`]).
    pub carries: u64,
    /// Number of additions performed (|Q_w(w_i)|).
    pub additions: u64,
}

impl PannToggles {
    /// Paper-model total (input + sum + FF).
    pub fn paper_total(&self) -> u64 {
        self.input + self.sum + self.ff
    }
}

impl PannDatapath {
    /// `x_width` is the activation bit width b̃_x; `acc_width` the
    /// accumulator width `B`.
    pub fn new(x_width: u32, acc_width: u32) -> Self {
        assert!(x_width <= acc_width);
        PannDatapath {
            x_width,
            acc_width,
            acc: [0; 2],
            prev_in: [0; 2],
            prev_sum: [0; 2],
            prev_ff: [0; 2],
            prev_carry: [0; 2],
        }
    }

    /// Current value: positive accumulator minus negative accumulator
    /// (the single subtraction of Eq. (6), applied at read-out).
    pub fn value(&self) -> i64 {
        from_word(self.acc[0], self.acc_width) - from_word(self.acc[1], self.acc_width)
    }

    /// Start a new dot product.
    pub fn clear_acc(&mut self) {
        self.acc = [0; 2];
    }

    /// Process one element: add `qx` (non-negative, b̃_x bits) to the
    /// accumulator `|qw|` times, on the positive or negative bank
    /// according to `qw`'s sign.
    pub fn element(&mut self, qw: i64, qx: i64) -> PannToggles {
        debug_assert!(super::word::fits_unsigned(qx, self.x_width), "qx={qx} width={}", self.x_width);
        let bank = usize::from(qw < 0);
        let reps = qw.unsigned_abs();
        let bw = self.acc_width;
        let mut t = PannToggles::default();

        // Load the input register once for the whole burst.
        let in_bus = to_word(qx, bw);
        t.input = hamming(in_bus, self.prev_in[bank]);
        self.prev_in[bank] = in_bus;

        for _ in 0..reps {
            let carry = super::serial_mult::carry_bits(self.acc[bank], in_bus, bw);
            t.carries += hamming(carry, self.prev_carry[bank]);
            self.prev_carry[bank] = carry;
            let sum = self.acc[bank].wrapping_add(in_bus) & mask(bw);
            t.sum += hamming(sum, self.prev_sum[bank]);
            self.prev_sum[bank] = sum;
            t.ff += hamming(sum, self.prev_ff[bank]);
            self.prev_ff[bank] = sum;
            self.acc[bank] = sum;
            t.additions += 1;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitflip::{BoothMultiplier, SerialMultiplier};
    use crate::util::Rng;

    #[test]
    fn mac_accumulates_exactly() {
        let mut mac = MacUnit::new(BoothMultiplier::new(8, true), 32);
        let mut r = Rng::new(21);
        let mut expect = 0i64;
        for _ in 0..1000 {
            let w = r.range_i64(-128, 128);
            let x = r.range_i64(-128, 128);
            mac.mac(w, x);
            expect += w * x;
        }
        assert_eq!(mac.value(), expect);
    }

    #[test]
    fn signed_acc_input_near_half_b() {
        // Observation 1: signed uniform products toggle ~0.5B bits at
        // the accumulator input (B = 32 -> ~16).
        let b = 4u32;
        let mut mac = MacUnit::new(BoothMultiplier::new(b, true), 32);
        let mut r = Rng::new(3);
        let n = 20000;
        let mut tot = 0u64;
        for _ in 0..n {
            let w = r.range_i64(-8, 8);
            let x = r.range_i64(-8, 8);
            tot += mac.mac(w, x).acc_input;
        }
        let avg = tot as f64 / n as f64;
        assert!((avg - 16.0).abs() < 1.5, "avg acc-input toggles {avg}, expect ~16");
    }

    #[test]
    fn unsigned_acc_input_near_bacc_half() {
        // Unsigned: input toggles drop to ~0.5·b_acc = b.
        let b = 4u32;
        let mut mac = MacUnit::new(BoothMultiplier::new(b, false), 32);
        let mut r = Rng::new(4);
        let n = 20000;
        let mut tot = 0u64;
        for _ in 0..n {
            let w = r.range_i64(0, 8); // [0, 2^{b-1})
            let x = r.range_i64(0, 8);
            tot += mac.mac(w, x).acc_input;
        }
        let avg = tot as f64 / n as f64;
        assert!(avg < 6.0, "unsigned acc-input toggles {avg}, expect ~{b}");
    }

    #[test]
    fn pann_value_matches_integer_dot() {
        let mut dp = PannDatapath::new(6, 32);
        let mut r = Rng::new(5);
        let mut expect = 0i64;
        for _ in 0..300 {
            let qw = r.range_i64(-5, 6);
            let qx = r.range_i64(0, 32);
            dp.element(qw, qx);
            expect += qw * qx;
        }
        assert_eq!(dp.value(), expect);
    }

    #[test]
    fn pann_input_toggles_once_per_element() {
        // The input bus must not toggle during a burst: element with
        // qw=5 costs the same input toggles as qw=1.
        let run = |qw: i64| {
            let mut dp = PannDatapath::new(6, 32);
            let mut r = Rng::new(6);
            let n = 5000;
            let mut tot = 0u64;
            for _ in 0..n {
                tot += dp.element(qw, r.range_i64(0, 32)).input;
            }
            tot as f64 / n as f64
        };
        let one = run(1);
        let five = run(5);
        assert!((one - five).abs() < 0.3, "input toggles {one} vs {five}");
    }

    #[test]
    fn pann_sum_toggles_scale_with_reps() {
        let run = |qw: i64| {
            let mut dp = PannDatapath::new(6, 32);
            let mut r = Rng::new(8);
            let n = 4000;
            let mut tot = 0u64;
            for _ in 0..n {
                let t = dp.element(qw, r.range_i64(0, 32));
                tot += t.sum;
            }
            tot as f64 / n as f64
        };
        let r1 = run(1);
        let r4 = run(4);
        assert!(r4 > 3.0 * r1, "sum toggles should scale ~linearly: {r1} vs {r4}");
    }

    #[test]
    fn serial_mac_matches_booth_mac_values() {
        let mut a = MacUnit::new(BoothMultiplier::new(6, true), 24);
        let mut b = MacUnit::new(SerialMultiplier::new(6, true), 24);
        let mut r = Rng::new(9);
        for _ in 0..500 {
            let w = r.range_i64(-32, 32);
            let x = r.range_i64(-32, 32);
            a.mac(w, x);
            b.mac(w, x);
        }
        assert_eq!(a.value(), b.value());
    }
}
