//! Simple serial (shift-and-add) multiplier with toggle accounting.
//!
//! The paper's App. A.2: "A serial multiplier follows the long
//! multiplication concept in which each bit of the multiplicand
//! multiplies the multiplier word", producing `b` partial products that
//! are summed by a chain of adders. We model the datapath registers —
//! one partial-product row per multiplicand bit, one running-sum
//! register per chain stage, plus the carry chains — and count Hamming
//! toggles against the previous instruction's state.
//!
//! Signed values use two's complement; a negative running sum has all
//! high bits set, so sign changes of the (partial) product flip ~b high
//! bits in every stage register. This is the structural origin of the
//! paper's Observation 2: for signed inputs the internal activity is
//! governed by `max(b_w, b_x)`, not by the smaller width.

use super::word::{from_word, hamming, to_word};
use super::{MultToggles, Multiplier};

/// State of the partial-product accumulation chain shared by the serial
/// and Booth multipliers: `b` row registers and `b` running-sum stages,
/// all `2b` bits wide, with a carry chain per stage.
#[derive(Clone, Debug)]
pub(crate) struct Chain {
    pub b: u32,
    /// Previous-instruction row register contents (len b).
    rows: Vec<u64>,
    /// Previous-instruction running-sum registers (len b).
    sums: Vec<u64>,
    /// Previous-instruction carry chains (len b).
    carries: Vec<u64>,
}

impl Chain {
    pub fn new(b: u32) -> Self {
        assert!((2..=16).contains(&b), "b={b} outside supported 2..=16");
        Chain {
            b,
            rows: vec![0; b as usize],
            sums: vec![0; b as usize],
            carries: vec![0; b as usize],
        }
    }

    pub fn reset(&mut self) {
        self.rows.iter_mut().for_each(|r| *r = 0);
        self.sums.iter_mut().for_each(|r| *r = 0);
        self.carries.iter_mut().for_each(|r| *r = 0);
    }

    /// Feed the chain with this instruction's partial products
    /// (signed row values, already shifted). Returns (product word,
    /// internal toggle count).
    pub fn accumulate(&mut self, row_vals: &[i64]) -> (u64, u64) {
        debug_assert_eq!(row_vals.len(), self.b as usize);
        let w2 = 2 * self.b;
        let mut internal = 0u64;
        let mut running: u64 = 0;
        for (k, &rv) in row_vals.iter().enumerate() {
            let row = to_word(rv, w2);
            internal += hamming(row, self.rows[k]);
            self.rows[k] = row;
            let carry = carry_bits(running, row, w2);
            internal += hamming(carry, self.carries[k]);
            self.carries[k] = carry;
            running = running.wrapping_add(row) & super::word::mask(w2);
            internal += hamming(running, self.sums[k]);
            self.sums[k] = running;
        }
        (running, internal)
    }
}

/// Carry vector of `a + b` at `width` bits (bit i = carry out of i).
pub(crate) fn carry_bits(a: u64, b: u64, width: u32) -> u64 {
    let mut out = 0u64;
    let mut c = 0u64;
    for i in 0..width {
        let ai = (a >> i) & 1;
        let bi = (b >> i) & 1;
        let cout = (ai & bi) | (c & (ai ^ bi));
        out |= cout << i;
        c = cout;
    }
    out
}

/// `b×b` serial multiplier.
#[derive(Clone, Debug)]
pub struct SerialMultiplier {
    chain: Chain,
    prev_w: u64,
    prev_x: u64,
    prev_out: u64,
    signed: bool,
}

impl SerialMultiplier {
    /// New `b×b` multiplier. `signed` selects the operand encoding: a
    /// signed multiplier sign-extends the multiplicand (its top bit has
    /// negative weight), an unsigned one treats all bits as positive.
    pub fn new(b: u32, signed: bool) -> Self {
        SerialMultiplier { chain: Chain::new(b), prev_w: 0, prev_x: 0, prev_out: 0, signed }
    }

    fn rows_for(&self, w: i64, x: i64) -> Vec<i64> {
        let b = self.chain.b;
        let ww = to_word(w, b);
        (0..b)
            .map(|i| {
                let bit = (ww >> i) & 1;
                if bit == 0 {
                    0
                } else if self.signed && i == b - 1 {
                    // Two's complement: the top bit has weight -2^(b-1).
                    -(x << i)
                } else {
                    x << i
                }
            })
            .collect()
    }
}

impl Multiplier for SerialMultiplier {
    fn mul(&mut self, w: i64, x: i64) -> (i64, MultToggles) {
        let b = self.chain.b;
        if self.signed {
            debug_assert!(super::word::fits_signed(w, b) && super::word::fits_signed(x, b));
        } else {
            debug_assert!(super::word::fits_unsigned(w, b) && super::word::fits_unsigned(x, b));
        }
        let ww = to_word(w, b);
        let xw = to_word(x, b);
        let inputs = hamming(ww, self.prev_w) + hamming(xw, self.prev_x);
        self.prev_w = ww;
        self.prev_x = xw;

        let rows = self.rows_for(w, x);
        let (prod_word, internal) = self.chain.accumulate(&rows);
        let output = hamming(prod_word, self.prev_out);
        self.prev_out = prod_word;

        let prod = if self.signed {
            from_word(prod_word, 2 * b)
        } else {
            prod_word as i64
        };
        (prod, MultToggles { inputs, internal, output })
    }

    fn out_width(&self) -> u32 {
        2 * self.chain.b
    }

    fn reset(&mut self) {
        self.chain.reset();
        self.prev_w = 0;
        self.prev_x = 0;
        self.prev_out = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_products_signed() {
        for b in [3u32, 4, 6, 8] {
            let mut m = SerialMultiplier::new(b, true);
            let mut r = Rng::new(11);
            let lo = -(1i64 << (b - 1));
            let hi = 1i64 << (b - 1);
            for _ in 0..2000 {
                let w = r.range_i64(lo, hi);
                let x = r.range_i64(lo, hi);
                let (p, _) = m.mul(w, x);
                assert_eq!(p, w * x, "b={b} {w}*{x}");
            }
        }
    }

    #[test]
    fn exact_products_unsigned() {
        for b in [2u32, 4, 8] {
            let mut m = SerialMultiplier::new(b, false);
            let mut r = Rng::new(12);
            for _ in 0..2000 {
                let w = r.range_i64(0, 1 << b);
                let x = r.range_i64(0, 1 << b);
                let (p, _) = m.mul(w, x);
                assert_eq!(p, w * x, "b={b} {w}*{x}");
            }
        }
    }

    #[test]
    fn repeat_instruction_is_free() {
        let mut m = SerialMultiplier::new(8, true);
        m.mul(-77, 103);
        let (_, t) = m.mul(-77, 103);
        assert_eq!(t.total(), 0);
    }

    #[test]
    fn signed_internal_grows_quadratically() {
        // Internal toggles for signed uniform inputs should scale ~b².
        let measure = |b: u32| {
            let mut m = SerialMultiplier::new(b, true);
            let mut r = Rng::new(5);
            let lo = -(1i64 << (b - 1));
            let hi = 1i64 << (b - 1);
            let n = 4000;
            let mut tot = 0u64;
            for _ in 0..n {
                let (_, t) = m.mul(r.range_i64(lo, hi), r.range_i64(lo, hi));
                tot += t.internal;
            }
            tot as f64 / n as f64
        };
        let p4 = measure(4);
        let p8 = measure(8);
        let ratio = p8 / p4;
        assert!(ratio > 3.0 && ratio < 5.5, "quadratic-ish growth, got ratio {ratio}");
    }

    #[test]
    fn unsigned_saves_when_bw_shrinks_but_signed_does_not() {
        // Observation 2 (Fig. 11): with signed inputs, shrinking only
        // b_w barely changes internal power; with unsigned inputs the
        // save is substantial for the serial multiplier.
        let b = 8u32;
        let run = |signed: bool, bw: u32| {
            let mut m = SerialMultiplier::new(b, signed);
            let mut r = Rng::new(7);
            let n = 6000;
            let mut tot = 0u64;
            for _ in 0..n {
                let (wlo, whi, xlo, xhi) = if signed {
                    (-(1i64 << (bw - 1)), 1i64 << (bw - 1), -(1i64 << (b - 1)), 1i64 << (b - 1))
                } else {
                    (0, 1i64 << (bw - 1), 0, 1i64 << (b - 1))
                };
                let (_, t) = m.mul(r.range_i64(wlo, whi), r.range_i64(xlo, xhi));
                tot += t.internal;
            }
            tot as f64 / n as f64
        };
        let signed_full = run(true, 8);
        let signed_small = run(true, 3);
        let unsigned_full = run(false, 8);
        let unsigned_small = run(false, 3);
        // Signed: less than 35% reduction. Unsigned: more than 40%.
        assert!(
            signed_small > 0.65 * signed_full,
            "signed {signed_small} vs {signed_full}"
        );
        assert!(
            unsigned_small < 0.6 * unsigned_full,
            "unsigned {unsigned_small} vs {unsigned_full}"
        );
    }
}
