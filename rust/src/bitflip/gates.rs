//! Gate-level netlist simulation — the stand-in for the paper's 5nm
//! Synopsys synthesis + PrimeTime PX measurement (App. A.1).
//!
//! We elaborate real netlists out of 2-input AND/OR/XOR and NOT cells
//! (full adders → ripple-carry adders → array multipliers), evaluate
//! them combinationally, and count toggles at **every gate output**
//! between consecutive instructions. This is one abstraction level
//! below the component/register simulators in the sibling modules, so
//! comparing the two reproduces the paper's Fig. 5 agreement argument.
//!
//! Static power is modeled as a constant leakage per gate per cycle
//! ([`LEAKAGE_PER_GATE`], in bit-flip-equivalents). The constant is a
//! calibration knob standing in for the 5nm cell library; the paper's
//! Table 5 reports the resulting static/dynamic split.

use super::word::to_word;
use super::{Dist, Sampler};
use crate::util::Rng;

/// Leakage per gate per cycle, in bit-flip equivalents. Calibrated so
/// that the static share of an 8-bit multiplier lands in the paper's
/// Table-5 zone (static ≈ 40–50% of total).
pub const LEAKAGE_PER_GATE: f64 = 0.11;

/// A combinational gate; operand fields are node indices that are
/// always smaller than the gate's own index (topological by
/// construction).
#[derive(Clone, Copy, Debug)]
enum Gate {
    /// External input pin.
    Input,
    /// Constant zero (used for absent carry-ins).
    Zero,
    And(u32, u32),
    Or(u32, u32),
    Xor(u32, u32),
    #[allow(dead_code)] // full cell library; inverters appear in
    // subtractor netlists built by downstream users
    Not(u32),
}

/// A gate netlist with remembered node states for toggle counting.
pub struct Netlist {
    gates: Vec<Gate>,
    state: Vec<bool>,
    n_inputs: usize,
}

impl Netlist {
    fn new() -> Self {
        Netlist { gates: Vec::new(), state: Vec::new(), n_inputs: 0 }
    }

    fn push(&mut self, g: Gate) -> u32 {
        self.gates.push(g);
        self.state.push(false);
        (self.gates.len() - 1) as u32
    }

    fn input(&mut self) -> u32 {
        assert!(
            self.gates.iter().all(|g| matches!(g, Gate::Input | Gate::Zero)),
            "inputs must be allocated before logic gates"
        );
        self.n_inputs += 1;
        self.push(Gate::Input)
    }

    fn zero(&mut self) -> u32 {
        self.push(Gate::Zero)
    }

    /// Number of logic gates (excluding input pins and constants).
    pub fn gate_count(&self) -> usize {
        self.gates
            .iter()
            .filter(|g| !matches!(g, Gate::Input | Gate::Zero))
            .count()
    }

    /// Full adder out of 5 gates: sum = a⊕b⊕c, cout = ab ∨ c(a⊕b).
    fn full_adder(&mut self, a: u32, b: u32, c: u32) -> (u32, u32) {
        let axb = self.push(Gate::Xor(a, b));
        let sum = self.push(Gate::Xor(axb, c));
        let ab = self.push(Gate::And(a, b));
        let caxb = self.push(Gate::And(c, axb));
        let cout = self.push(Gate::Or(ab, caxb));
        (sum, cout)
    }

    /// Ripple-carry adder over equal-width bit vectors.
    fn ripple_adder(&mut self, a: &[u32], b: &[u32], cin: u32) -> (Vec<u32>, u32) {
        assert_eq!(a.len(), b.len());
        let mut c = cin;
        let mut sum = Vec::with_capacity(a.len());
        for i in 0..a.len() {
            let (s, cout) = self.full_adder(a[i], b[i], c);
            sum.push(s);
            c = cout;
        }
        (sum, c)
    }

    /// Evaluate with new input values; returns toggles at gate outputs
    /// (logic gates only; input-pin toggles are reported separately by
    /// the measurement drivers).
    fn eval(&mut self, inputs: &[bool]) -> u64 {
        assert_eq!(inputs.len(), self.n_inputs);
        let mut toggles = 0u64;
        let mut in_idx = 0usize;
        for i in 0..self.gates.len() {
            let v = match self.gates[i] {
                Gate::Input => {
                    let v = inputs[in_idx];
                    in_idx += 1;
                    v
                }
                Gate::Zero => false,
                Gate::And(a, b) => self.state[a as usize] & self.state[b as usize],
                Gate::Or(a, b) => self.state[a as usize] | self.state[b as usize],
                Gate::Xor(a, b) => self.state[a as usize] ^ self.state[b as usize],
                Gate::Not(a) => !self.state[a as usize],
            };
            if v != self.state[i] && !matches!(self.gates[i], Gate::Input | Gate::Zero) {
                toggles += 1;
            }
            self.state[i] = v;
        }
        toggles
    }

    fn read_bits(&self, nodes: &[u32]) -> u64 {
        nodes
            .iter()
            .enumerate()
            .fold(0u64, |acc, (i, &n)| acc | ((self.state[n as usize] as u64) << i))
    }
}

/// A gate-level `width`-bit adder circuit with its input pins.
pub struct AdderCircuit {
    net: Netlist,
    a: Vec<u32>,
    #[allow(dead_code)] // second operand pins (kept for netlist introspection)
    b: Vec<u32>,
    sum: Vec<u32>,
    prev_a: u64,
    prev_b: u64,
}

impl AdderCircuit {
    /// Build the netlist of a `width`-bit ripple-carry adder.
    pub fn new(width: u32) -> Self {
        let mut net = Netlist::new();
        let a: Vec<u32> = (0..width).map(|_| net.input()).collect();
        let b: Vec<u32> = (0..width).map(|_| net.input()).collect();
        let z = net.zero();
        let (sum, _) = net.ripple_adder(&a, &b, z);
        AdderCircuit { net, a, b, sum, prev_a: 0, prev_b: 0 }
    }

    /// Number of gates in the synthesized netlist.
    pub fn gate_count(&self) -> usize {
        self.net.gate_count()
    }

    /// Add; returns (sum word, gate toggles incl. input pins).
    pub fn add(&mut self, a: u64, b: u64) -> (u64, u64) {
        let w = self.a.len() as u32;
        let a = a & super::word::mask(w);
        let b = b & super::word::mask(w);
        let mut inputs = Vec::with_capacity(self.net.n_inputs);
        for i in 0..w {
            inputs.push((a >> i) & 1 == 1);
        }
        for i in 0..w {
            inputs.push((b >> i) & 1 == 1);
        }
        let gate_toggles = self.net.eval(&inputs);
        let pin_toggles = super::word::hamming(a, self.prev_a) + super::word::hamming(b, self.prev_b);
        self.prev_a = a;
        self.prev_b = b;
        (self.net.read_bits(&self.sum), gate_toggles + pin_toggles)
    }
}

/// A gate-level array multiplier. Operands are fed as `width`-bit
/// words; for signed operation the caller sign-extends to `2b` and
/// instantiates `width = 2b` (multiplication mod 2^2b is exact for
/// two's complement).
pub struct MultCircuit {
    net: Netlist,
    a: Vec<u32>,
    #[allow(dead_code)] // second operand pins (kept for netlist introspection)
    b: Vec<u32>,
    out: Vec<u32>,
    out_width: u32,
    prev_a: u64,
    prev_b: u64,
}

impl MultCircuit {
    /// `width`-bit unsigned array multiplier keeping the low
    /// `out_width` product bits.
    pub fn new(width: u32, out_width: u32) -> Self {
        assert!(width <= 24 && out_width <= 2 * width);
        let mut net = Netlist::new();
        let a: Vec<u32> = (0..width).map(|_| net.input()).collect();
        let b: Vec<u32> = (0..width).map(|_| net.input()).collect();
        let zero = net.zero();
        // Partial-product rows: row_i[j] = a_j & b_i, shifted left i.
        // Accumulate rows with ripple adders at out_width.
        let mut acc: Vec<u32> = vec![zero; out_width as usize];
        for i in 0..width.min(out_width) {
            let mut row: Vec<u32> = vec![zero; out_width as usize];
            for j in 0..width {
                let pos = i + j;
                if pos < out_width {
                    row[pos as usize] = net.push(Gate::And(a[j as usize], b[i as usize]));
                }
            }
            let z = net.zero();
            let (sum, _) = net.ripple_adder(&acc, &row, z);
            acc = sum;
        }
        MultCircuit { net, a, b, out: acc, out_width, prev_a: 0, prev_b: 0 }
    }

    /// Signed `b×b` multiplier: sign-extended operands on a `2b`-wide
    /// unsigned array (two's-complement exact mod 2^2b).
    pub fn new_signed(b: u32) -> Self {
        MultCircuit::new(2 * b, 2 * b)
    }

    /// Number of gates in the synthesized netlist.
    pub fn gate_count(&self) -> usize {
        self.net.gate_count()
    }

    /// Multiply two word-encoded operands; returns (product word,
    /// toggles incl. input pins).
    pub fn mul_words(&mut self, a: u64, b: u64) -> (u64, u64) {
        let w = self.a.len() as u32;
        let a = a & super::word::mask(w);
        let b = b & super::word::mask(w);
        let mut inputs = Vec::with_capacity(self.net.n_inputs);
        for i in 0..w {
            inputs.push((a >> i) & 1 == 1);
        }
        for i in 0..w {
            inputs.push((b >> i) & 1 == 1);
        }
        let gate_toggles = self.net.eval(&inputs);
        let pin_toggles = super::word::hamming(a, self.prev_a) + super::word::hamming(b, self.prev_b);
        self.prev_a = a;
        self.prev_b = b;
        (
            self.net.read_bits(&self.out) & super::word::mask(self.out_width),
            gate_toggles + pin_toggles,
        )
    }
}

/// Gate-level power measurement of a `b×b` multiplier under a
/// distribution: returns (avg dynamic toggles, static per cycle,
/// gate count).
pub fn measure_mult(b: u32, dist: Dist, n: usize, seed: u64) -> (f64, f64, usize) {
    let signed = dist.is_signed();
    let mut circ = if signed { MultCircuit::new_signed(b) } else { MultCircuit::new(b, 2 * b) };
    let mut rng = Rng::new(seed);
    let mut sw = Sampler::new(dist, n, &mut rng);
    let mut sx = Sampler::new(dist, n, &mut rng);
    let width = if signed { 2 * b } else { b };
    let mut tot = 0u64;
    for _ in 0..n {
        let (w, x) = (sw.next(), sx.next());
        let (p, t) = circ.mul_words(to_word(w, width), to_word(x, width));
        debug_assert_eq!(super::word::from_word(p, 2 * b), w * x, "{w}*{x}");
        tot += t;
    }
    let dynamic = tot as f64 / n as f64;
    let stat = circ.gate_count() as f64 * LEAKAGE_PER_GATE;
    (dynamic, stat, circ.gate_count())
}

/// Gate-level power measurement of a `width`-bit adder.
pub fn measure_adder(width: u32, dist: Dist, n: usize, seed: u64) -> (f64, f64, usize) {
    let mut circ = AdderCircuit::new(width);
    let mut rng = Rng::new(seed);
    let mut sa = Sampler::new(dist, n, &mut rng);
    let mut sb = Sampler::new(dist, n, &mut rng);
    let mut tot = 0u64;
    for _ in 0..n {
        let (a, b) = (sa.next(), sb.next());
        let (_, t) = circ.add(to_word(a, width), to_word(b, width));
        tot += t;
    }
    let dynamic = tot as f64 / n as f64;
    let stat = circ.gate_count() as f64 * LEAKAGE_PER_GATE;
    (dynamic, stat, circ.gate_count())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adder_circuit_correct() {
        let mut c = AdderCircuit::new(8);
        let mut r = Rng::new(1);
        for _ in 0..500 {
            let a = r.range_i64(0, 256) as u64;
            let b = r.range_i64(0, 256) as u64;
            let (s, _) = c.add(a, b);
            assert_eq!(s, (a + b) & 0xff);
        }
    }

    #[test]
    fn mult_circuit_correct_unsigned() {
        let mut c = MultCircuit::new(4, 8);
        for a in 0..16u64 {
            for b in 0..16u64 {
                let (p, _) = c.mul_words(a, b);
                assert_eq!(p, a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn mult_circuit_correct_signed() {
        let mut c = MultCircuit::new_signed(4);
        for a in -8i64..8 {
            for b in -8i64..8 {
                let (p, _) = c.mul_words(to_word(a, 8), to_word(b, 8));
                assert_eq!(super::super::word::from_word(p, 8), a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn no_toggles_on_repeat() {
        let mut c = MultCircuit::new(6, 12);
        c.mul_words(13, 27);
        let (_, t) = c.mul_words(13, 27);
        assert_eq!(t, 0);
    }

    #[test]
    fn quadratic_gate_count() {
        let g4 = MultCircuit::new(4, 8).gate_count() as f64;
        let g8 = MultCircuit::new(8, 16).gate_count() as f64;
        let ratio = g8 / g4;
        assert!(ratio > 3.0 && ratio < 5.0, "ratio {ratio}");
    }

    #[test]
    fn gate_level_agrees_with_component_level_shape() {
        // Fig. 5: gate-level power vs b and component-level power vs b
        // should have the same growth shape (quadratic in b). Compare
        // ratios at b=4 vs b=8.
        let (d4, _, _) = measure_mult(4, Dist::UniformSigned(4), 1500, 42);
        let (d8, _, _) = measure_mult(8, Dist::UniformSigned(8), 1500, 42);
        let ratio = d8 / d4;
        assert!(ratio > 2.8 && ratio < 6.0, "gate-level growth ratio {ratio}");
    }
}
