//! Bit-toggle activity simulators — the paper's power measurement
//! methodology (Sec. 3, App. A.1–A.2).
//!
//! Dynamic power of a CMOS node is `P = C·V²·f·α` where `α` is the
//! switching activity. Following the paper, we report power in units of
//! **bit flips per instruction**: we simulate arithmetic units at the
//! register level, remember the state every component held during the
//! *previous* instruction, and count Hamming toggles against the state
//! of the current instruction (cf. the paper's Fig. 7 walkthrough).
//!
//! Two fidelity levels are provided, mirroring the paper's two setups:
//!
//! - **Component level** ([`adder`], [`serial_mult`], [`booth`],
//!   [`mac`]) — registers of the datapath (operand inputs, partial
//!   product rows, running sums, carry chains, accumulator, flip-flop).
//!   This is the analog of the paper's "Python simulation".
//! - **Gate level** ([`gates`]) — an explicit netlist of AND/OR/XOR/NOT
//!   cells built into ripple-carry adders and array multipliers, with
//!   toggles counted at every gate output plus a per-gate leakage
//!   constant for static power. This stands in for the paper's 5nm
//!   Synopsys synthesis + PrimeTime PX measurement (see DESIGN.md
//!   substitution table).
//!
//! All simulators are deterministic given a seeded [`crate::util::Rng`].

pub mod adder;
pub mod booth;
pub mod gates;
pub mod mac;
pub mod sample;
pub mod serial_mult;
pub mod word;

pub use adder::RippleAdder;
pub use booth::BoothMultiplier;
pub use mac::{MacToggles, MacUnit, PannDatapath};
pub use sample::{Dist, Sampler};
pub use serial_mult::SerialMultiplier;

/// Toggle counts of one multiplier instruction, split by element
/// (matches the paper's Table 1 rows).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MultToggles {
    /// Toggles in the two operand input registers.
    pub inputs: u64,
    /// Toggles in the internal units (partial-product rows, internal
    /// adder sum registers and carry chains).
    pub internal: u64,
    /// Toggles in the product output register.
    pub output: u64,
}

impl MultToggles {
    /// Total toggles of the instruction.
    pub fn total(&self) -> u64 {
        self.inputs + self.internal + self.output
    }
}

/// Common interface of the two multiplier implementations.
pub trait Multiplier {
    /// Multiply, updating internal state; returns the toggle breakdown.
    fn mul(&mut self, w: i64, x: i64) -> (i64, MultToggles);
    /// Output bit width (`2b` for a `b×b` multiplier).
    fn out_width(&self) -> u32;
    /// Reset the remembered state to all-zeros.
    fn reset(&mut self);
}
