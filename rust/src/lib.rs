//! # PANN — Power-Aware Neural Networks
//!
//! Reproduction of *"Energy awareness in low precision neural networks"*
//! (Spingarn Eliezer, Banner, Hoffer, Ben-Yaakov, Michaeli; 2022).
//!
//! The crate is the L3 (coordination + substrate) layer of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - [`bitflip`] — bit-toggle simulators for adders, multipliers and MAC
//!   datapaths (the paper's "Python simulation" and a gate-level netlist
//!   simulator standing in for the paper's 5nm synthesis).
//! - [`power`] — the analytic power models of the paper: Eqs. (1)–(4)
//!   (signed/unsigned MAC), Eq. (7) (mixed widths), Eq. (13) (PANN),
//!   Eq. (20) (required accumulator width), and network-level accounting.
//! - [`quant`] — quantizers (RUQ, dynamic, ACIQ, BN-stats data-free, DFQ
//!   equalization + bias correction, rounding reconstruction) and the
//!   PANN weight quantizer of Eq. (12), plus the MSE theory of Sec. 5.3.
//! - [`nn`] — an integer inference engine (conv/linear/pool/bn) that can
//!   execute a model in fp32, signed-quantized, unsigned-split and PANN
//!   modes while metering the exact number of bit flips per layer. The
//!   engine is a plan/exec split: [`nn::plan::ExecutionPlan`] compiles a
//!   model + config once (weight banks, kernel selection, scratch
//!   geometry; `Send + Sync`), [`nn::exec`] executes whole batches
//!   through cache-blocked, row-parallel GEMM kernels with reusable
//!   per-thread [`nn::Scratch`] arenas.
//! - [`pann`] — the headline contribution: converting a pre-trained
//!   model to unsigned arithmetic (Sec. 4), removing the multiplier
//!   (Sec. 5), Algorithm 1 for choosing the operating point, and the
//!   menu compiler ([`pann::menu`]): sweep the `(b̃_x, R)` grid along
//!   equal-power curves, Pareto-prune to the accuracy-vs-energy
//!   frontier, persist it as a versioned `menu.json` artifact and
//!   recompile it for serving (`pann-cli compile-menu` →
//!   `pann-cli serve --menu`).
//! - [`runtime`] — PJRT execution of AOT-lowered JAX/Pallas artifacts
//!   (HLO text) produced by `python/compile/aot.py` (behind the `pjrt`
//!   feature; the default build uses an API-identical stub).
//! - [`coordinator`] — a QoS-aware serving runtime behind one entry
//!   point (`ServerBuilder` → `Menu` → `Client`): per-request QoS
//!   (deadline, `max_gflips` energy cap, priority, pinned point),
//!   bounded-queue admission control with typed failures
//!   (`ServeError`), point-coherent dynamic batching, runtime budget
//!   traversal, and a worker pool over shared `Arc<ExecutionPlan>`
//!   menus (or one worker owning `!Send` PJRT engines). Menus load
//!   straight from a compiled artifact via
//!   [`coordinator::Menu::from_artifact`], and budget selection can
//!   run closed-loop: [`coordinator::governor`] meters the energy
//!   actually served against an [`coordinator::EnergyEnvelope`]
//!   (Gflips/sec) and walks the budget along the frontier with
//!   hysteresis, so sustained load degrades accuracy gracefully and
//!   idle periods climb back. One server can host a **fleet** of
//!   models ([`coordinator::registry`]): `ServerBuilder::register`
//!   named menus, serve them all from one pool, and the shared
//!   envelope is split across models by observed demand, so a hot
//!   model degrades along its own frontier before starving a cold
//!   one.
//! - [`net`] — the L4 network edge: the same serving surface over a
//!   socket. A std-only HTTP/1.1 server (`POST /v1/infer` maps 1:1
//!   onto [`coordinator::InferRequest`], typed `ServeError` → HTTP
//!   status, Prometheus-style `/metrics`) in front of a
//!   [`net::ShardRouter`] that spreads one logical model across N
//!   in-process servers — rendezvous-hash affinity placement,
//!   deadline-aware retry of shed requests, and a cluster energy
//!   envelope split across shards by the fleet's demand-weighted
//!   water-filling ([`coordinator::arbiter`]).
//! - [`scenario`] — the trace-driven scenario harness: replayable
//!   workload traces (`pann-trace/v1`; seeded diurnal / flash-crowd /
//!   deadline-mix / tenant-skew generators), named device profiles
//!   (`jetson`, `server`) parameterizing the power model per
//!   deployment target, and a deterministic virtual-clock replay rig
//!   that drives the real governor/policy/rendezvous placement and
//!   emits byte-reproducible `scenario-report/v1` documents
//!   (`pann-cli replay`).
//! - [`analysis`] — the static soundness pass: exact i128 interval
//!   arithmetic ([`analysis::Interval`]) proving per-layer overflow
//!   bounds into [`analysis::KernelCert`] certificates. The plan
//!   compiler selects kernels *from* the certificate (a layer only
//!   runs narrow/packed arithmetic when provably exact), and
//!   `pann-cli verify --menu` re-derives the same certificates
//!   offline to audit a serialized artifact without running
//!   inference.
//! - [`experiments`] — one driver per table/figure of the paper.
//!
//! Power is reported in **bit flips**, exactly as in the paper
//! (footnote 2: pJ/flip is platform specific; flip counts are not).
//!
//! See `rust/README.md` for a quickstart and the crate map,
//! `rust/ARCHITECTURE.md` for the system document (request lifecycle,
//! module map, the paper→code table), and `rust/EXPERIMENTS.md` for
//! measurement protocols and every artifact schema (`menu.json`,
//! `BENCH_*.json`).

// Every public item in this crate is documented, and CI's
// `RUSTDOCFLAGS=-D warnings` doc job keeps it that way.
#![warn(missing_docs)]
// Unsafe operations must be spelled out (and carry `// SAFETY:`
// comments — CI greps for them) even inside `unsafe fn` bodies.
#![deny(unsafe_op_in_unsafe_fn)]
// `clippy.toml` bans `unwrap`/`expect`/`panic!` via disallowed-methods
// / disallowed-macros, which fire crate-wide once configured. The ban
// is *scoped*: allowed here at the root, re-denied per module in
// `coordinator/` and `net/` (the request-handling surface where a
// panic would poison locks and take down serving threads), and
// re-allowed inside their `#[cfg(test)]` modules.
#![allow(clippy::disallowed_methods, clippy::disallowed_macros)]

pub mod analysis;
pub mod bitflip;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod net;
pub mod nn;
pub mod pann;
pub mod power;
pub mod quant;
pub mod runtime;
pub mod scenario;
pub mod util;
