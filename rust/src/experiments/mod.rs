//! Experiment drivers — one per table/figure of the paper.
//!
//! `pann-cli experiment <id>` (or `cargo bench --bench tables`) prints
//! the same rows/series the paper reports. Absolute numbers differ —
//! the substrate is the synthetic stack of DESIGN.md, not the authors'
//! testbed — but the *shape* (who wins, by what factor, where the
//! crossovers fall) is the reproduction target. Every driver works
//! without `make artifacts` by falling back to the built-in reference
//! models and in-process synthetic data.

pub mod power_sims;
pub mod ptq;
pub mod qat;
pub mod theory;

use crate::data::Dataset;
use crate::nn::Model;
use anyhow::Result;
use std::path::PathBuf;

/// Shared experiment context.
pub struct Ctx {
    /// Artifacts root (default `artifacts/`).
    pub artifacts: PathBuf,
    /// Smaller sample counts for CI-speed runs.
    pub quick: bool,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx { artifacts: PathBuf::from("artifacts"), quick: false }
    }
}

impl Ctx {
    /// A default context with `quick` speed-ups enabled.
    pub fn quick() -> Self {
        Ctx { quick: true, ..Default::default() }
    }

    /// Toggle-simulation sample count (paper: N = 36000).
    pub fn sim_n(&self) -> usize {
        if self.quick {
            4000
        } else {
            36000
        }
    }

    /// PTQ evaluation subset size.
    pub fn eval_n(&self) -> usize {
        if self.quick {
            96
        } else {
            512
        }
    }

    /// Load a trained model + its test set; falls back to the built-in
    /// reference CNN + synthetic digits when artifacts are absent.
    pub fn load_model(&self, name: &str) -> Result<(Model, Dataset)> {
        let mdir = self.artifacts.join("models").join(name);
        if mdir.join("manifest.json").exists() {
            let model = Model::load(&mdir)?;
            let dataset = dataset_for(name);
            let ddir = self.artifacts.join("data").join(dataset);
            if ddir.join("test_x.ptns").exists() {
                let ds = Dataset::load(&ddir, "test")?;
                return Ok((model, ds));
            }
        }
        // fallback: reference model + synth data (stats recorded here)
        let mut model = match name {
            "cnn-r" => Model::reference_resnet(7),
            _ => Model::reference_cnn(7),
        };
        let ds = Dataset::from_synth(crate::data::synth::digits(if self.quick { 128 } else { 512 }, 11));
        let stats_x = crate::nn::eval::batch_tensor(&ds, 0, ds.len().min(64));
        model.record_act_stats(&stats_x)?;
        Ok((model, ds))
    }

    /// QAT results json written by `python -m compile.train`.
    pub fn qat_results(&self) -> Option<crate::util::Json> {
        let p = self.artifacts.join("models").join("qat_results.json");
        let text = std::fs::read_to_string(p).ok()?;
        crate::util::Json::parse(&text).ok()
    }
}

/// The dataset each trained model was fitted on.
pub fn dataset_for(model: &str) -> &'static str {
    match model {
        "mlp" => "blobs",
        "har-mlp" => "har",
        _ => "digits",
    }
}

/// One experiment driver.
pub type ExpFn = fn(&Ctx) -> Result<()>;

/// Every experiment id with its driver.
pub const ALL: &[(&str, ExpFn)] = &[
    ("table1", power_sims::table1),
    ("table5", power_sims::table5),
    ("fig5", power_sims::fig5),
    ("fig6", power_sims::fig6),
    ("fig8", power_sims::fig8),
    ("fig9", power_sims::fig9),
    ("fig10", power_sims::fig10),
    ("fig11", power_sims::fig11),
    ("table6", theory::table6),
    ("fig3", theory::fig3),
    ("fig4", theory::fig4),
    ("fig12", theory::fig12),
    ("fig16", theory::fig16),
    ("fig1", ptq::fig1),
    ("fig13", ptq::fig13),
    ("fig14", ptq::fig14),
    ("table2", ptq::table2),
    ("table7", ptq::table7),
    ("table8", ptq::table8),
    ("table9", ptq::table9),
    ("table14", ptq::table14),
    ("table15", ptq::table15),
    ("table10", qat::table10),
    ("table4", qat::table4),
    ("table11", qat::table11),
    ("table12", qat::table12),
    ("table13", qat::table13),
];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &Ctx) -> Result<()> {
    for (name, f) in ALL {
        if *name == id {
            println!("=== {id} ===");
            return f(ctx);
        }
    }
    anyhow::bail!("unknown experiment '{id}' (try: {})", ids().join(", "))
}

/// All experiment ids.
pub fn ids() -> Vec<&'static str> {
    ALL.iter().map(|(n, _)| *n).collect()
}
