//! Quantization-aware-training tables (3/4/10–13), assembled from the
//! accuracy grid `python -m compile.train` records in
//! `artifacts/models/qat_results.json` plus the Rust power models.

use super::Ctx;
use crate::power::model::mac_power_unsigned_total;
use crate::util::Json;
use anyhow::Result;

/// MACs per sample for the trained architectures (mirrors
/// `python/compile/model.py::num_macs`; used when the manifest is not
/// on disk).
pub fn num_macs(model: &str) -> u64 {
    match model {
        "cnn-s" => 94_720,
        "cnn-r" => 529_152,
        "vgg-t" => 242_176,
        "mlp" => 16_320,
        "har-mlp" => 17_152,
        _ => 0,
    }
}

fn acc_of(results: &Json, key: &str) -> Option<f64> {
    results.get(key)?.get("acc")?.as_f64()
}

fn require_results(ctx: &Ctx) -> Result<Json> {
    ctx.qat_results().ok_or_else(|| {
        anyhow::anyhow!(
            "qat_results.json not found under {} — run `make artifacts` first",
            ctx.artifacts.display()
        )
    })
}

/// Tables 3 + 10: LSQ vs PANN at the 2/3/4-bit power budgets.
pub fn table10(ctx: &Ctx) -> Result<()> {
    let results = require_results(ctx)?;
    // Table 13's operating points, as used by train.py
    let points = [(2u32, 3u32, 2.83), (3, 6, 2.5), (4, 6, 3.5)];
    println!(
        "{:<10} {:>6} {:>14} {:>10} {:>10}",
        "model", "bits", "power[Gflips]", "LSQ", "PANN"
    );
    for model in ["cnn-s", "cnn-r", "vgg-t"] {
        let fp = acc_of(&results, &format!("fp32_{model}")).unwrap_or(f64::NAN);
        println!("{model:<10} {:>6} {:>14} {:>10.3} {:>10}", "fp", "-", fp, "-");
        for (bits, bx, r) in points {
            let p = mac_power_unsigned_total(bits) * num_macs(model) as f64 / 1e9;
            let lsq = acc_of(&results, &format!("{model}_lsq_b{bits}_bx{bits}_r0_e6"))
                .or_else(|| acc_of(&results, &format!("{model}_lsq_b{bits}_bx{bits}_r0.0_e6")));
            let pann = acc_of(&results, &format!("{model}_pann_b{bits}_bx{bx}_r{r}_e6"));
            println!(
                "{model:<10} {bits:>6} {p:>14.4} {:>10} {:>10}",
                lsq.map_or("-".into(), |v| format!("{v:.3}")),
                pann.map_or("-".into(), |v| format!("{v:.3}"))
            );
        }
    }
    Ok(())
}

/// The multiplier-free comparison table for one model (Tables 4/11/12).
fn mf_table(ctx: &Ctx, model: &str) -> Result<()> {
    let results = require_results(ctx)?;
    let bits_grid = [6u32, 5, 4, 3];
    print!("{:<18}", "method");
    for b in bits_grid {
        print!("{:>9}", format!("{b}/{b}"));
    }
    println!();
    let rows: Vec<(String, String)> = vec![
        ("our (1x)".into(), "pann_b{b}_bx{b}_r1".into()),
        ("our (1.5x)".into(), "pann_b{b}_bx{b}_r1.5".into()),
        ("our (2x)".into(), "pann_b{b}_bx{b}_r2".into()),
        ("shiftadd (1.5x)".into(), "shiftadd_b{b}_bx{b}_r1.5".into()),
        ("adder (2x)".into(), "adder_b{b}_bx{b}_r2".into()),
    ];
    for (label, pat) in rows {
        print!("{label:<18}");
        for b in bits_grid {
            let frag = pat.replace("{b}", &b.to_string());
            // accept both "r1"/"r1.0" spellings from run_key
            let key_a = format!("{model}_{frag}.0_e6");
            let key_b = format!("{model}_{frag}_e6");
            let acc = acc_of(&results, &key_a).or_else(|| acc_of(&results, &key_b));
            print!("{:>9}", acc.map_or("-".into(), |v| format!("{v:.3}")));
        }
        println!();
    }
    Ok(())
}

/// Table 4 (CIFAR-10 → digits / cnn-s).
pub fn table4(ctx: &Ctx) -> Result<()> {
    mf_table(ctx, "cnn-s")
}

/// Table 11 (CIFAR-100 → blobs / mlp).
pub fn table11(ctx: &Ctx) -> Result<()> {
    mf_table(ctx, "mlp")
}

/// Table 12 (MHEALTH → har / har-mlp).
pub fn table12(ctx: &Ctx) -> Result<()> {
    mf_table(ctx, "har-mlp")
}

/// Table 13: the QAT operating points and power budgets.
pub fn table13(_ctx: &Ctx) -> Result<()> {
    println!(
        "{:<10} {:>10} {:>14} {:>6} {:>8}",
        "model", "lsq bits", "power[Gflips]", "b̃x", "R"
    );
    for model in ["cnn-s", "cnn-r", "vgg-t", "mlp", "har-mlp"] {
        for (bits, bx, r) in [(2u32, 3u32, 2.83), (3, 6, 2.5), (4, 6, 3.5)] {
            let p = mac_power_unsigned_total(bits) * num_macs(model) as f64 / 1e9;
            println!("{model:<10} {bits:>10} {p:>14.4} {bx:>6} {r:>8.2}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table13_runs_without_artifacts() {
        table13(&Ctx::quick()).unwrap();
    }

    #[test]
    fn qat_tables_error_cleanly_without_artifacts() {
        let ctx = Ctx { artifacts: std::path::PathBuf::from("/nonexistent"), quick: true };
        assert!(table10(&ctx).is_err());
        assert!(table4(&ctx).is_err());
    }

    #[test]
    fn num_macs_matches_python() {
        assert_eq!(num_macs("cnn-s"), 8 * 9 * 256 + 16 * 8 * 9 * 64 + 10 * 256);
    }
}
