//! Post-training-quantization experiments: Fig. 1/13/14, Tables 2/7/8/9
//! and the trade-off Tables 14/15.

use super::Ctx;
use crate::nn::quantized::Arithmetic;
use crate::pann::{algorithm1, convert, tradeoff};
use crate::power::model::mac_power_unsigned_total;
use crate::quant::ActQuantMethod;
use anyhow::Result;

/// The power-budget grid of the paper's PTQ tables.
const BUDGET_BITS: [u32; 6] = [2, 3, 4, 5, 6, 8];

fn budget_grid(ctx: &Ctx) -> Vec<u32> {
    if ctx.quick {
        vec![2, 4, 8]
    } else {
        BUDGET_BITS.to_vec()
    }
}

/// Fig. 1-style sweep: signed 4-bit → unsigned (←) → PANN (↑) for
/// every model, at the 4-bit budget with the data-free quantizer.
pub fn fig1(ctx: &Ctx) -> Result<()> {
    fig1_like(ctx, 4, 32)
}

/// Fig. 13: the same with reduced accumulator widths (Eq. 20).
pub fn fig13(ctx: &Ctx) -> Result<()> {
    println!("-- B = 21-bit accumulator, 4-bit nets --");
    fig1_like(ctx, 4, 21)?;
    println!("-- B = 17-bit accumulator, 2-bit nets --");
    fig1_like(ctx, 2, 17)
}

/// Fig. 14/15: the conversion arrows with the calibration-based
/// quantizer at 4-bit and 2-bit.
pub fn fig14(ctx: &Ctx) -> Result<()> {
    println!("-- ACIQ, 4-bit --");
    arrows(ctx, 4, 32, ActQuantMethod::Aciq)?;
    println!("-- ACIQ, 2-bit --");
    arrows(ctx, 2, 32, ActQuantMethod::Aciq)
}

fn fig1_like(ctx: &Ctx, bits: u32, acc_bits: u32) -> Result<()> {
    arrows(ctx, bits, acc_bits, ActQuantMethod::BnStats)
}

fn arrows(ctx: &Ctx, bits: u32, acc_bits: u32, method: ActQuantMethod) -> Result<()> {
    println!(
        "{:<8} {:>6} | {:>10} {:>7} | {:>10} {:>7} | {:>10} {:>7}",
        "model", "fp", "P signed", "acc", "P unsign", "acc", "P pann", "acc"
    );
    for name in ["cnn-s", "cnn-r", "vgg-t", "mlp"] {
        let (model, test) = ctx.load_model(name)?;
        let test = test.take(ctx.eval_n());
        let calib = convert::calib_tensor(&test, 32);
        let fp = crate::nn::eval::eval_fp32(&model, &test)?;
        let (_, signed) = convert::ptq_baseline(
            &model,
            bits,
            method,
            Arithmetic::SignedMac { acc_bits },
            Some(&calib),
            &test,
        )?;
        let (_, unsigned) = convert::unsigned_of(&model, bits, method, Some(&calib), &test)?;
        // PANN at the same unsigned budget, Alg.-1 point
        let p = mac_power_unsigned_total(bits);
        let val = test.take(ctx.eval_n().min(128));
        let op = algorithm1::choose_operating_point(&model, p, method, Some(&calib), &val, 2..=8)?;
        let (_, pann) = convert::pann_at_budget(&model, op.bx_tilde, op.r, method, Some(&calib), &test)?;
        println!(
            "{name:<8} {:>6.3} | {:>10.4} {:>7.3} | {:>10.4} {:>7.3} | {:>10.4} {:>7.3}  (b̃x={} R={:.2} achieved {:.2})",
            fp.accuracy(),
            signed.giga_flips / test.len() as f64 * 1000.0,
            signed.accuracy(),
            unsigned.giga_flips / test.len() as f64 * 1000.0,
            unsigned.accuracy(),
            pann.giga_flips / test.len() as f64 * 1000.0,
            pann.accuracy(),
            op.bx_tilde,
            op.r,
            op.achieved_adds_per_element
        );
    }
    println!("(P columns: Mega bit flips per sample)");
    Ok(())
}

/// The generic PTQ table (paper Tables 2/7/8/9): baselines at each
/// power budget vs PANN tuned to the same budget via Alg. 1.
fn ptq_table(ctx: &Ctx, model_name: &str) -> Result<()> {
    let (model, test) = ctx.load_model(model_name)?;
    let test = test.take(ctx.eval_n());
    let calib = convert::calib_tensor(&test, 32);
    let val = test.take(ctx.eval_n().min(128));
    let fp = crate::nn::eval::eval_fp32(&model, &test)?;
    let macs = model.num_macs();
    println!("model {model_name}: fp32 accuracy {:.3}, {macs} MACs/sample", fp.accuracy());
    print!("{:<14}", "power (bits)");
    let methods = [
        ActQuantMethod::Dynamic,
        ActQuantMethod::Aciq,
        ActQuantMethod::BnStats,
        ActQuantMethod::Dfq,
        ActQuantMethod::Recon,
    ];
    for m in methods {
        print!("{:>16}", format!("{}(base|our)", m.name()));
    }
    println!();
    for bits in budget_grid(ctx) {
        let p = mac_power_unsigned_total(bits);
        let giga = p * macs as f64 / 1e9;
        print!("{:<14}", format!("{giga:.3} ({bits})"));
        for m in methods {
            let (_, base) = convert::unsigned_of(&model, bits, m, Some(&calib), &test)?;
            let op = algorithm1::choose_operating_point(&model, p, m, Some(&calib), &val, 2..=8)?;
            let (_, our) =
                convert::pann_at_budget(&model, op.bx_tilde, op.r, m, Some(&calib), &test)?;
            print!(
                "{:>16}",
                format!("{:.3}|{:.3}", base.accuracy(), our.accuracy())
            );
        }
        println!();
    }
    Ok(())
}

/// Table 2 (ResNet-50 → cnn-r).
pub fn table2(ctx: &Ctx) -> Result<()> {
    ptq_table(ctx, "cnn-r")
}

/// Table 7 (ResNet-18 → cnn-s).
pub fn table7(ctx: &Ctx) -> Result<()> {
    ptq_table(ctx, "cnn-s")
}

/// Table 8 (MobileNet-V2 → mlp).
pub fn table8(ctx: &Ctx) -> Result<()> {
    ptq_table(ctx, "mlp")
}

/// Table 9 (VGG-16bn → vgg-t).
pub fn table9(ctx: &Ctx) -> Result<()> {
    ptq_table(ctx, "vgg-t")
}

/// Table 14: the Alg.-1 operating point per budget with memory /
/// latency factors.
pub fn table14(ctx: &Ctx) -> Result<()> {
    let (model, test) = ctx.load_model("cnn-r")?;
    let test = test.take(ctx.eval_n());
    let calib = convert::calib_tensor(&test, 32);
    let val = test.take(ctx.eval_n().min(128));
    println!(
        "{:<8} {:>5} {:>10} {:>5} {:>10} {:>10}",
        "budget", "b̃x", "latency=R", "b_R", "act mem", "w mem"
    );
    for bits in budget_grid(ctx) {
        let p = mac_power_unsigned_total(bits);
        let op = algorithm1::choose_operating_point(
            &model,
            p,
            ActQuantMethod::BnStats,
            Some(&calib),
            &val,
            2..=8,
        )?;
        let rows = tradeoff::budget_curve_table(
            &model,
            bits,
            ActQuantMethod::BnStats,
            Some(&calib),
            &val,
            op.bx_tilde..=op.bx_tilde,
        )?;
        let row = &rows[0];
        println!(
            "{:<8} {:>5} {:>10.2} {:>5} {:>10.2} {:>10.2}",
            format!("{bits}/{bits}"),
            row.bx_tilde,
            row.r,
            row.b_r,
            row.act_mem_factor,
            row.weight_mem_factor
        );
    }
    Ok(())
}

/// Table 15: the whole 2-bit equal-power curve with accuracies.
pub fn table15(ctx: &Ctx) -> Result<()> {
    let (model, test) = ctx.load_model("cnn-r")?;
    let test = test.take(ctx.eval_n());
    let calib = convert::calib_tensor(&test, 32);
    let rows = tradeoff::budget_curve_table(
        &model,
        2,
        ActQuantMethod::Aciq,
        Some(&calib),
        &test,
        2..=8,
    )?;
    println!(
        "{:<5} {:>10} {:>5} {:>10} {:>10} {:>10}",
        "b̃x", "latency=R", "b_R", "act mem", "w mem", "accuracy"
    );
    for r in rows {
        println!(
            "{:<5} {:>10.2} {:>5} {:>10.2} {:>10.2} {:>10.3}",
            r.bx_tilde, r.r, r.b_r, r.act_mem_factor, r.weight_mem_factor, r.accuracy
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_runs_on_fallback_models() {
        let ctx = Ctx { artifacts: std::path::PathBuf::from("/nonexistent"), quick: true };
        fig1(&ctx).unwrap();
    }

    #[test]
    fn ptq_table_runs_quick() {
        let ctx = Ctx { artifacts: std::path::PathBuf::from("/nonexistent"), quick: true };
        table7(&ctx).unwrap();
    }

    #[test]
    fn table15_runs_quick() {
        let ctx = Ctx { artifacts: std::path::PathBuf::from("/nonexistent"), quick: true };
        table15(&ctx).unwrap();
    }
}
