//! Toggle-simulation experiments: Table 1, Table 5, Figs. 5, 6, 8–11.

use super::Ctx;
use crate::bitflip::{
    gates, BoothMultiplier, Dist, MacUnit, Multiplier, Sampler, SerialMultiplier,
};
use crate::util::Rng;
use anyhow::Result;

/// Measure average MAC toggles for a distribution pair on a multiplier.
fn measure_mac<M: Multiplier>(
    mult: M,
    acc_bits: u32,
    dw: Dist,
    dx: Dist,
    n: usize,
    seed: u64,
) -> (f64, f64, f64, f64, f64) {
    let mut mac = MacUnit::new(mult, acc_bits);
    let mut rng = Rng::new(seed);
    let mut sw = Sampler::new(dw, n, &mut rng);
    let mut sx = Sampler::new(dx, n, &mut rng);
    let (mut mi, mut mint, mut ai, mut asum, mut aff) = (0u64, 0u64, 0u64, 0u64, 0u64);
    for i in 0..n {
        if i % 256 == 0 {
            mac.clear_acc(); // dot products of depth 256
        }
        let t = mac.mac(sw.next(), sx.next());
        mi += t.mult.inputs;
        mint += t.mult.internal;
        ai += t.acc_input;
        asum += t.acc_sum;
        aff += t.acc_ff;
    }
    let f = n as f64;
    (mi as f64 / f, mint as f64 / f, ai as f64 / f, asum as f64 / f, aff as f64 / f)
}

/// Table 1: average bit flips per signed MAC (B = 32), with the
/// paper's model values for comparison.
pub fn table1(ctx: &Ctx) -> Result<()> {
    let n = ctx.sim_n();
    println!("{:<4} {:>10} {:>10} {:>10} {:>10} {:>10}   (model: 0.5b+0.5b | 0.5b² | 0.5B | b | b)", "b", "mul-in", "mul-int", "acc-in", "acc-sum", "acc-ff");
    for b in 2..=8u32 {
        let (mi, mint, ai, asum, aff) = measure_mac(
            BoothMultiplier::new(b, true),
            32,
            Dist::UniformSigned(b),
            Dist::UniformSigned(b),
            n,
            42,
        );
        println!(
            "{b:<4} {mi:>10.2} {mint:>10.2} {ai:>10.2} {asum:>10.2} {aff:>10.2}   ({:>4.1} | {:>5.1} | {:>4.1} | {:>3.1} | {:>3.1})",
            b as f64,
            0.5 * (b * b) as f64,
            16.0,
            b as f64,
            b as f64
        );
    }
    Ok(())
}

/// Table 5: static vs dynamic power split from the gate-level
/// simulator (the 5nm-synthesis stand-in).
pub fn table5(ctx: &Ctx) -> Result<()> {
    let n = ctx.sim_n().min(3000);
    println!("{:<18} {:>8} {:>8} {:>8}", "unit", "dyn[%]", "stat[%]", "gates");
    for b in [2u32, 3, 4, 5, 6, 7, 8] {
        let (dynamic, stat, gates_n) = gates::measure_mult(b, Dist::UniformSigned(b), n, 7);
        let tot = dynamic + stat;
        println!(
            "{:<18} {:>8.0} {:>8.0} {:>8}",
            format!("mult {b}-bit"),
            100.0 * dynamic / tot,
            100.0 * stat / tot,
            gates_n
        );
    }
    for b in [4u32, 8, 32] {
        let (dynamic, stat, gates_n) = gates::measure_adder(b, Dist::UniformSigned(b.min(16)), n, 7);
        let tot = dynamic + stat;
        println!(
            "{:<18} {:>8.0} {:>8.0} {:>8}",
            format!("adder {b}-bit"),
            100.0 * dynamic / tot,
            100.0 * stat / tot,
            gates_n
        );
    }
    Ok(())
}

/// Fig. 5: gate-level vs component-level power agreement (scaled to
/// intersect at b = 4, as the paper scales its 5nm measurements).
pub fn fig5(ctx: &Ctx) -> Result<()> {
    let n = ctx.sim_n().min(3000);
    // component level (python-sim analog)
    let comp: Vec<(u32, f64)> = (2..=8)
        .map(|b| {
            let mut m = BoothMultiplier::new(b, true);
            let mut rng = Rng::new(3);
            let mut sw = Sampler::new(Dist::UniformSigned(b), n, &mut rng);
            let mut sx = Sampler::new(Dist::UniformSigned(b), n, &mut rng);
            let mut tot = 0u64;
            for _ in 0..n {
                let (_, t) = m.mul(sw.next(), sx.next());
                tot += t.inputs + t.internal;
            }
            (b, tot as f64 / n as f64)
        })
        .collect();
    let gate: Vec<(u32, f64)> = (2..=8)
        .map(|b| {
            let (d, _, _) = gates::measure_mult(b, Dist::UniformSigned(b), n, 3);
            (b, d)
        })
        .collect();
    let scale = comp[2].1 / gate[2].1; // intersect at b = 4
    println!("{:<4} {:>12} {:>14} {:>12}", "b", "component", "gate(scaled)", "model 0.5b²+b");
    for i in 0..comp.len() {
        let b = comp[i].0;
        println!(
            "{b:<4} {:>12.1} {:>14.1} {:>12.1}",
            comp[i].1,
            gate[i].1 * scale,
            0.5 * (b * b) as f64 + b as f64
        );
    }
    Ok(())
}

/// Fig. 6a: unsigned/signed multiplier power ratio (≈ 1).
pub fn fig6(ctx: &Ctx) -> Result<()> {
    let n = ctx.sim_n();
    println!("{:<4} {:>14} {:>14} {:>8}", "b", "signed", "unsigned", "ratio");
    for b in 4..=8u32 {
        let run = |signed: bool| {
            let mut m = BoothMultiplier::new(b, signed);
            let d = if signed { Dist::UniformSigned(b) } else { Dist::UniformUnsigned(b) };
            let mut rng = Rng::new(5);
            let mut sw = Sampler::new(d, n, &mut rng);
            let mut sx = Sampler::new(d, n, &mut rng);
            let mut tot = 0u64;
            for _ in 0..n {
                let (_, t) = m.mul(sw.next(), sx.next());
                tot += t.inputs + t.internal;
            }
            tot as f64 / n as f64
        };
        let s = run(true);
        let u = run(false);
        println!("{b:<4} {s:>14.1} {u:>14.1} {:>8.2}", u / s);
    }
    Ok(())
}

fn fig89(ctx: &Ctx, unsigned: bool) -> Result<()> {
    let n = ctx.sim_n();
    println!(
        "{:<10} {:>4} {:>10} {:>10} {:>10} {:>10}",
        "dist", "b", "mult", "acc-in", "acc-sum", "acc-ff"
    );
    for gauss in [false, true] {
        for b in 2..=8u32 {
            let d = match (gauss, unsigned) {
                (false, false) => Dist::UniformSigned(b),
                (false, true) => Dist::UniformUnsigned(b),
                (true, false) => Dist::GaussianSigned(b),
                (true, true) => Dist::GaussianUnsigned(b),
            };
            let (mi, mint, ai, asum, aff) =
                measure_mac(BoothMultiplier::new(b, !unsigned), 32, d, d, n, 9);
            println!(
                "{:<10} {b:>4} {:>10.1} {ai:>10.2} {asum:>10.2} {aff:>10.2}",
                if gauss { "gauss" } else { "uniform" },
                mi + mint
            );
        }
    }
    Ok(())
}

/// Fig. 8: signed toggles vs the analytic model.
pub fn fig8(ctx: &Ctx) -> Result<()> {
    fig89(ctx, false)
}

/// Fig. 9: unsigned toggles — the accumulator-input collapse.
pub fn fig9(ctx: &Ctx) -> Result<()> {
    fig89(ctx, true)
}

fn mixed_width(ctx: &Ctx, booth: bool) -> Result<()> {
    let n = ctx.sim_n();
    let bx = 8u32;
    println!("{:<10} {:>4} {:>12} {:>12}", "mode", "bw", "internal", "of bw=8 [%]");
    for signed in [true, false] {
        let full = mixed_one(booth, signed, bx, bx, n);
        for bw in [2u32, 3, 4, 5, 6, 7, 8] {
            let v = mixed_one(booth, signed, bw, bx, n);
            println!(
                "{:<10} {bw:>4} {v:>12.1} {:>12.0}",
                if signed { "signed" } else { "unsigned" },
                100.0 * v / full
            );
        }
    }
    Ok(())
}

fn mixed_one(booth: bool, signed: bool, bw: u32, bx: u32, n: usize) -> f64 {
    let mut rng = Rng::new(13);
    let dw = if signed { Dist::UniformSigned(bw) } else { Dist::UniformUnsigned(bw) };
    let dx = if signed { Dist::UniformSigned(bx) } else { Dist::UniformUnsigned(bx) };
    let mut sw = Sampler::new(dw, n, &mut rng);
    let mut sx = Sampler::new(dx, n, &mut rng);
    let mut tot = 0u64;
    if booth {
        let mut m = BoothMultiplier::new(bx, signed);
        for _ in 0..n {
            let (_, t) = m.mul(sw.next(), sx.next());
            tot += t.internal;
        }
    } else {
        let mut m = SerialMultiplier::new(bx, signed);
        for _ in 0..n {
            let (_, t) = m.mul(sw.next(), sx.next());
            tot += t.internal;
        }
    }
    tot as f64 / n as f64
}

/// Fig. 10: Booth multiplier, mixed operand widths (Observation 2).
pub fn fig10(ctx: &Ctx) -> Result<()> {
    mixed_width(ctx, true)
}

/// Fig. 11: serial multiplier, mixed operand widths.
pub fn fig11(ctx: &Ctx) -> Result<()> {
    mixed_width(ctx, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_power_sims_run_quick() {
        let ctx = Ctx::quick();
        table1(&ctx).unwrap();
        fig6(&ctx).unwrap();
        fig10(&ctx).unwrap();
    }

    #[test]
    fn observation1_holds_in_sim() {
        // signed acc-input toggles ~0.5B; unsigned collapse to ~b
        let (_, _, ai_s, _, _) = measure_mac(
            BoothMultiplier::new(4, true),
            32,
            Dist::UniformSigned(4),
            Dist::UniformSigned(4),
            6000,
            1,
        );
        let (_, _, ai_u, _, _) = measure_mac(
            BoothMultiplier::new(4, false),
            32,
            Dist::UniformUnsigned(4),
            Dist::UniformUnsigned(4),
            6000,
            1,
        );
        assert!(ai_s > 13.0, "signed acc-in {ai_s}");
        assert!(ai_u < 6.0, "unsigned acc-in {ai_u}");
    }

    #[test]
    fn observation2_holds_in_sim() {
        // Signed internal power is dominated by the larger width: our
        // register model retains ~60% of the b_w=8 activity at b_w=2
        // (the running-sum sign flips stay; Booth recoding quiets the
        // rows, so the save is larger than the paper's near-zero but
        // far from the naive b_w/b_x scaling of 25%).
        let full = mixed_one(true, true, 8, 8, 5000);
        let small = mixed_one(true, true, 2, 8, 5000);
        assert!(small / full > 0.45, "ratio {}", small / full);
        // the serial multiplier holds the observation more tightly
        let sfull = mixed_one(false, true, 8, 8, 5000);
        let ssmall = mixed_one(false, true, 3, 8, 5000);
        assert!(ssmall / sfull > 0.6, "serial ratio {}", ssmall / sfull);
    }
}
