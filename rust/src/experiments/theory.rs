//! Analytic-model experiments: Table 6, Figs. 3, 4, 12, 16.

use super::Ctx;
use crate::power::{accumulator, budget::EqualPowerCurve, model};
use crate::quant::error;
use anyhow::Result;

/// Table 6: required accumulator width and unsigned power save.
pub fn table6(_ctx: &Ctx) -> Result<()> {
    println!(
        "{:<6} {:>8} {:>18} {:>18}",
        "bits", "B req.", "save @ B-bit [%]", "save @ 32-bit [%]"
    );
    for bits in 2..=6u32 {
        // the paper floors log2(3*3*512) = 12 in its table rows
        let b_req = bits + bits + 1 + (4608f64).log2().floor() as u32;
        println!(
            "{bits:<6} {b_req:>8} {:>18.0} {:>18.0}",
            100.0 * accumulator::power_save_unsigned(bits, b_req),
            100.0 * accumulator::power_save_unsigned(bits, 32)
        );
    }
    Ok(())
}

/// Fig. 3: equal-power (b̃x, R) curves for several reference widths.
pub fn fig3(_ctx: &Ctx) -> Result<()> {
    print!("{:<6}", "b̃x");
    for bx in [2u32, 3, 4, 5, 6, 8] {
        print!("{:>10}", format!("P={}", model::mac_power_unsigned_total(bx)));
    }
    println!();
    for bt in 1..=16u32 {
        print!("{bt:<6}");
        for bx in [2u32, 3, 4, 5, 6, 8] {
            let c = EqualPowerCurve::for_unsigned_mac(bx);
            match c.r_at(bt) {
                Some(r) if r > 0.0 => print!("{r:>10.2}"),
                _ => print!("{:>10}", "-"),
            }
        }
        println!();
    }
    Ok(())
}

/// Fig. 4: MSE_RUQ / MSE_PANN at equal power, uniform + MC validation.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let d = 1000;
    let trials = if ctx.quick { 300 } else { 2000 };
    println!(
        "{:<4} {:>12} {:>12} {:>10} {:>14}",
        "b", "MSE_RUQ", "MSE_PANN", "ratio", "ratio (MC)"
    );
    for b in 2..=8u32 {
        let p = model::mac_power_unsigned_total(b);
        let ruq = error::mse_ruq(d, 1.0, 1.0, b);
        let (bt, pann) = error::optimal_bx_tilde(d, 1.0, 1.0, p);
        let r = p / bt as f64 - 0.5;
        let mc_ruq = error::mc_mse_ruq(d, b, trials, 17);
        let mc_pann = error::mc_mse_pann(d, bt, r, trials, 18);
        println!(
            "{b:<4} {ruq:>12.3e} {pann:>12.3e} {:>10.2} {:>14.2}",
            ruq / pann,
            mc_ruq / mc_pann
        );
    }
    Ok(())
}

/// Fig. 12a: unsigned/signed MAC power ratio vs bit width (B = 32).
pub fn fig12(_ctx: &Ctx) -> Result<()> {
    println!("{:<4} {:>10} {:>10} {:>10} {:>10}", "b", "signed", "unsigned", "ratio", "save[%]");
    for b in 2..=8u32 {
        let s = model::mac_power_signed(b, 32).total();
        let u = model::mac_power_unsigned(b).total();
        println!(
            "{b:<4} {s:>10.1} {u:>10.1} {:>10.2} {:>10.0}",
            u / s,
            100.0 * (1.0 - u / s)
        );
    }
    Ok(())
}

/// Fig. 16: MSE vs b̃x for several budgets — theory + Monte Carlo.
pub fn fig16(ctx: &Ctx) -> Result<()> {
    let d = 1000;
    let trials = if ctx.quick { 200 } else { 1500 };
    for p in [10.0, 16.5, 24.0, 42.0] {
        println!("-- power budget P = {p} flips/element --");
        println!("{:<6} {:>8} {:>14} {:>14}", "b̃x", "R", "MSE theory", "MSE MC");
        for bt in 2..=10u32 {
            let Some(th) = error::mse_pann(d, 1.0, 1.0, bt, p) else { continue };
            let r = p / bt as f64 - 0.5;
            if r <= 0.0 {
                continue;
            }
            let mc = error::mc_mse_pann(d, bt, r, trials, 23);
            println!("{bt:<6} {r:>8.2} {th:>14.4e} {mc:>14.4e}");
        }
        let (best, _) = error::optimal_bx_tilde(d, 1.0, 1.0, p);
        println!("   optimal b̃x = {best}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theory_experiments_run_quick() {
        let ctx = Ctx::quick();
        table6(&ctx).unwrap();
        fig3(&ctx).unwrap();
        fig4(&ctx).unwrap();
        fig12(&ctx).unwrap();
    }

    #[test]
    fn fig4_crossover_exists() {
        // the paper's Fig. 4: PANN wins at low bits, RUQ at high bits
        let lo = error::mse_ruq(1000, 1.0, 1.0, 2)
            / error::optimal_bx_tilde(1000, 1.0, 1.0, model::mac_power_unsigned_total(2)).1;
        let hi = error::mse_ruq(1000, 1.0, 1.0, 8)
            / error::optimal_bx_tilde(1000, 1.0, 1.0, model::mac_power_unsigned_total(8)).1;
        assert!(lo > 1.0 && hi < 1.0, "lo {lo} hi {hi}");
    }
}
