//! Size/deadline dynamic batching.
//!
//! The batcher blocks for the first request, then drains the queue up
//! to `max_batch` items or until `max_wait` elapses — the standard
//! serving trade-off between batching efficiency and tail latency.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Collect a batch from `rx`. Returns `None` when the channel closed
/// with nothing pending.
pub fn collect_batch<T>(rx: &Receiver<T>, max_batch: usize, max_wait: Duration) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + max_wait;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = collect_batch(&rx, 4, Duration::from_millis(5)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = collect_batch(&rx, 100, Duration::from_millis(5)).unwrap();
        assert_eq!(b.len(), 6);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        let b = collect_batch(&rx, 8, Duration::from_millis(20)).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(collect_batch(&rx, 4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn no_request_lost() {
        let (tx, rx) = channel();
        let n = 137;
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut got = Vec::new();
        while let Some(mut b) = collect_batch(&rx, 7, Duration::from_millis(1)) {
            assert!(b.len() <= 7);
            got.append(&mut b);
        }
        assert_eq!(got, (0..n).collect::<Vec<_>>());
    }
}
