//! Bounded admission queue + QoS-aware dynamic batching.
//!
//! One [`RequestQueue`] feeds every worker (single-worker and pool
//! alike — the seed's two hand-rolled batching loops are folded into
//! [`RequestQueue::collect`]). Admission control happens at `push`:
//! the queue is bounded and load-sheds with [`ServeError::QueueFull`]
//! instead of growing without bound; after `stop` it refuses with
//! [`ServeError::ServerStopped`].
//!
//! Batches are *point-coherent*: a worker picks the oldest request of
//! the highest non-empty priority lane as leader, asks the caller's
//! `classify` callback which operating point it maps to (pinned point,
//! or `PowerPolicy` under `min(global budget, request cap)`), then
//! tops the batch up — across all lanes, highest priority first —
//! with requests that map to the *same* point, waiting at most
//! `max_wait` (the standard batching/tail-latency trade-off). On a
//! fleet server ([`super::registry`]) the classifier returns indices
//! in a *global* point space where every registered model owns a
//! disjoint range, so batches are point-coherent **per model** by
//! construction — the queue itself needs no model awareness.
//!
//! Rejections are delivered here, typed, without executing: requests
//! whose deadline has already passed get [`ServeError::DeadlineExceeded`]
//! (counted as `expired`), unclassifiable ones (unknown pinned point)
//! get the classifier's error (counted as `unservable`), and requests
//! whose [`Ticket`] was dropped are discarded silently (counted as
//! `cancelled`) — all in [`Metrics`].
//!
//! [`ServeError::QueueFull`]: super::request::ServeError::QueueFull
//! [`ServeError::ServerStopped`]: super::request::ServeError::ServerStopped
//! [`ServeError::DeadlineExceeded`]: super::request::ServeError::DeadlineExceeded
//! [`Ticket`]: super::request::Ticket

// Request-handling surface: panics are banned (see clippy.toml); fail
// with a typed `ServeError` instead. Lock poisoning (a worker panicked
// while holding the queue) is handled explicitly: `push` answers with
// `ServeError::Internal`, `collect` drains to `None` so the worker
// exits cleanly, and `stop` recovers the guard to still flip the flag.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use super::metrics::Metrics;
use super::request::{Priority, Response, ServeError, N_PRIORITIES};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted request waiting for a worker.
pub(crate) struct Pending {
    pub input: Vec<f32>,
    /// Registry index of the model this request runs on (0 on a
    /// single-model server; resolved from [`InferRequest::model`] at
    /// admission so the hot path never does a name lookup).
    ///
    /// [`InferRequest::model`]: super::request::InferRequest::model
    pub model: usize,
    pub submitted: Instant,
    /// Absolute start-by deadline.
    pub deadline: Option<Instant>,
    pub priority: Priority,
    /// Per-request energy cap (Giga bit flips per sample).
    pub max_gflips: Option<f64>,
    /// Pinned operating-point name.
    pub pin: Option<String>,
    pub tag: Option<String>,
    /// Set when the client dropped its `Ticket`.
    pub cancelled: Arc<AtomicBool>,
    pub resp: mpsc::Sender<Result<Response, ServeError>>,
}

impl Pending {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| d <= now)
    }

    fn cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }
}

struct State {
    /// One FIFO lane per priority class, highest priority first.
    lanes: [VecDeque<Pending>; N_PRIORITIES],
    stopped: bool,
    /// Total admissions so far — lets a batching worker skip rescanning
    /// the lanes on wakeups that delivered nothing new.
    pushes: u64,
}

impl State {
    fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }
}

/// Bounded, priority-laned request queue shared by client and workers.
pub(crate) struct RequestQueue {
    depth: usize,
    state: Mutex<State>,
    cv: Condvar,
    metrics: Arc<Metrics>,
}

/// Maps a request to the operating-point index it should run on, or a
/// typed rejection (e.g. `UnknownPoint` for a bad pin).
pub(crate) type Classify<'a> = dyn FnMut(&Pending) -> Result<usize, ServeError> + 'a;

impl RequestQueue {
    pub(crate) fn new(depth: usize, metrics: Arc<Metrics>) -> RequestQueue {
        RequestQueue {
            depth: depth.max(1),
            state: Mutex::new(State {
                lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                stopped: false,
                pushes: 0,
            }),
            cv: Condvar::new(),
            metrics,
        }
    }

    pub(crate) fn depth(&self) -> usize {
        self.depth
    }

    /// Admit one request, or shed it.
    pub(crate) fn push(&self, p: Pending) -> Result<(), ServeError> {
        let mut s = self
            .state
            .lock()
            .map_err(|_| ServeError::Internal("request queue poisoned".into()))?;
        if s.stopped {
            return Err(ServeError::ServerStopped);
        }
        if s.len() >= self.depth {
            self.metrics.record_shed();
            return Err(ServeError::QueueFull { depth: self.depth });
        }
        s.lanes[p.priority.lane()].push_back(p);
        s.pushes += 1;
        drop(s);
        self.cv.notify_all();
        Ok(())
    }

    /// Refuse new requests and wake every waiting worker. Requests
    /// already admitted are still drained before workers exit.
    pub(crate) fn stop(&self) {
        // recover a poisoned guard: stop must always take effect
        self.state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .stopped = true;
        self.cv.notify_all();
    }

    /// Collect one point-coherent batch of at most `max_batch`
    /// requests, waiting at most `max_wait` to fill it. Returns the
    /// batch plus the operating-point index it must run on, or `None`
    /// when the queue is stopped and drained (worker exits).
    pub(crate) fn collect(
        &self,
        max_batch: usize,
        max_wait: Duration,
        classify: &mut Classify<'_>,
    ) -> Option<(Vec<Pending>, usize)> {
        let max_batch = max_batch.max(1);
        // a poisoned queue ends the worker exactly like stop + drained
        let mut s = self.state.lock().ok()?;
        // Phase 1: block until a leader emerges (or stop + drained).
        let (leader, point) = loop {
            match self.take_leader(&mut s, classify) {
                Some(found) => break found,
                None => {
                    if s.stopped {
                        return None;
                    }
                    s = self.cv.wait(s).ok()?;
                }
            }
        };
        let mut batch = vec![leader];
        // Phase 2: top up with same-point requests until full/deadline.
        // The fill wait never outlives the earliest deadline in the
        // batch — a tight-deadline request must start executing, not
        // batch-wait, in time (overshoot is bounded by scheduling
        // jitter instead of a full `max_wait`).
        let mut until = Instant::now() + max_wait;
        if let Some(d) = batch[0].deadline {
            until = until.min(d);
        }
        let mut spare = VecDeque::new();
        let mut seen_pushes: Option<u64> = None;
        while batch.len() < max_batch && !s.stopped {
            // rescan only when something was admitted since last scan
            if seen_pushes != Some(s.pushes) {
                seen_pushes = Some(s.pushes);
                let before = batch.len();
                self.take_matching(&mut s, point, max_batch, &mut batch, classify, &mut spare);
                for p in &batch[before..] {
                    if let Some(d) = p.deadline {
                        until = until.min(d);
                    }
                }
                if batch.len() >= max_batch {
                    break;
                }
            }
            let now = Instant::now();
            if now >= until {
                break;
            }
            let (guard, _) = self.cv.wait_timeout(s, until - now).ok()?;
            s = guard;
        }
        Some((batch, point))
    }

    /// Deliver a typed rejection without executing.
    fn reject(&self, p: Pending, e: ServeError) {
        match e {
            ServeError::DeadlineExceeded => self.metrics.record_expired(),
            _ => self.metrics.record_unservable(),
        }
        let _ = p.resp.send(Err(e));
    }

    /// Pop the first healthy request, highest priority lane first,
    /// pruning cancelled and rejecting expired / unclassifiable
    /// requests along the way.
    fn take_leader(&self, s: &mut State, classify: &mut Classify<'_>) -> Option<(Pending, usize)> {
        let now = Instant::now();
        for lane in s.lanes.iter_mut() {
            while let Some(p) = lane.pop_front() {
                if p.cancelled() {
                    self.metrics.record_cancelled();
                    continue;
                }
                if p.expired(now) {
                    self.reject(p, ServeError::DeadlineExceeded);
                    continue;
                }
                match classify(&p) {
                    Ok(point) => return Some((p, point)),
                    Err(e) => self.reject(p, e),
                }
            }
        }
        None
    }

    /// Move every request that classifies to `point` into `batch` (up
    /// to `max_batch` total), scanning lanes highest priority first.
    /// Prunes cancelled and expired requests from all lanes as a side
    /// effect; requests bound for other points stay queued in order.
    /// `spare` is a reusable (empty in/empty out) rebuild buffer so
    /// repeated scans within one collect allocate at most once.
    fn take_matching(
        &self,
        s: &mut State,
        point: usize,
        max_batch: usize,
        batch: &mut Vec<Pending>,
        classify: &mut Classify<'_>,
        spare: &mut VecDeque<Pending>,
    ) {
        let now = Instant::now();
        for lane in s.lanes.iter_mut() {
            debug_assert!(spare.is_empty());
            while let Some(p) = lane.pop_front() {
                if p.cancelled() {
                    self.metrics.record_cancelled();
                    continue;
                }
                if p.expired(now) {
                    self.reject(p, ServeError::DeadlineExceeded);
                    continue;
                }
                if batch.len() >= max_batch {
                    spare.push_back(p);
                    continue;
                }
                match classify(&p) {
                    Ok(k) if k == point => batch.push(p),
                    Ok(_) => spare.push_back(p),
                    Err(e) => self.reject(p, e),
                }
            }
            // the drained lane (now empty, capacity kept) becomes the
            // next lane's spare
            std::mem::swap(lane, spare);
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;

    fn queue(depth: usize) -> (RequestQueue, Arc<Metrics>) {
        let m = Arc::new(Metrics::new());
        (RequestQueue::new(depth, m.clone()), m)
    }

    fn pending(
        v: f32,
        priority: Priority,
    ) -> (Pending, mpsc::Receiver<Result<Response, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                input: vec![v],
                model: 0,
                submitted: Instant::now(),
                deadline: None,
                priority,
                max_gflips: None,
                pin: None,
                tag: None,
                cancelled: Arc::new(AtomicBool::new(false)),
                resp: tx,
            },
            rx,
        )
    }

    fn any_point(_: &Pending) -> Result<usize, ServeError> {
        Ok(0)
    }

    #[test]
    fn sheds_when_full_and_refuses_after_stop() {
        let (q, m) = queue(2);
        let (a, _ra) = pending(1.0, Priority::Normal);
        let (b, _rb) = pending(2.0, Priority::Normal);
        let (c, _rc) = pending(3.0, Priority::Normal);
        q.push(a).unwrap();
        q.push(b).unwrap();
        assert_eq!(q.push(c), Err(ServeError::QueueFull { depth: 2 }));
        assert_eq!(m.snapshot().shed, 1);
        q.stop();
        let (d, _rd) = pending(4.0, Priority::Normal);
        assert_eq!(q.push(d), Err(ServeError::ServerStopped));
    }

    #[test]
    fn batches_up_to_max_in_priority_order() {
        let (q, _m) = queue(64);
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (p, rx) = pending(i as f32, Priority::BestEffort);
            q.push(p).unwrap();
            rxs.push(rx);
        }
        let (p, rx) = pending(100.0, Priority::Hi);
        q.push(p).unwrap();
        rxs.push(rx);
        let (batch, point) = q
            .collect(3, Duration::from_millis(2), &mut any_point)
            .unwrap();
        assert_eq!(point, 0);
        assert_eq!(batch.len(), 3);
        // the Hi request leads despite arriving last
        assert_eq!(batch[0].input, vec![100.0]);
        assert_eq!(batch[1].input, vec![0.0]);
        assert_eq!(batch[2].input, vec![1.0]);
    }

    #[test]
    fn groups_by_point_and_leaves_other_groups_queued() {
        // odd inputs -> point 1, even -> point 0
        let (q, _m) = queue(64);
        let mut rxs = Vec::new();
        for i in 0..6 {
            let (p, rx) = pending(i as f32, Priority::Normal);
            q.push(p).unwrap();
            rxs.push(rx);
        }
        let mut classify = |p: &Pending| Ok(p.input[0] as usize % 2);
        let (batch, point) = q.collect(8, Duration::from_millis(1), &mut classify).unwrap();
        assert_eq!(point, 0);
        assert_eq!(
            batch.iter().map(|p| p.input[0]).collect::<Vec<_>>(),
            vec![0.0, 2.0, 4.0]
        );
        let (batch, point) = q.collect(8, Duration::from_millis(1), &mut classify).unwrap();
        assert_eq!(point, 1);
        assert_eq!(
            batch.iter().map(|p| p.input[0]).collect::<Vec<_>>(),
            vec![1.0, 3.0, 5.0]
        );
    }

    #[test]
    fn expired_requests_rejected_unexecuted() {
        let (q, m) = queue(8);
        let (mut p, rx) = pending(1.0, Priority::Normal);
        p.deadline = Some(Instant::now() - Duration::from_millis(1));
        q.push(p).unwrap();
        let (ok, _rx2) = pending(2.0, Priority::Normal);
        q.push(ok).unwrap();
        let (batch, _) = q
            .collect(4, Duration::from_millis(1), &mut any_point)
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].input, vec![2.0]);
        assert_eq!(rx.recv().unwrap(), Err(ServeError::DeadlineExceeded));
        assert_eq!(m.snapshot().expired, 1);
    }

    #[test]
    fn cancelled_requests_silently_dropped() {
        let (q, m) = queue(8);
        let (p, rx) = pending(1.0, Priority::Normal);
        p.cancelled.store(true, Ordering::Relaxed);
        q.push(p).unwrap();
        let (ok, _rx2) = pending(2.0, Priority::Normal);
        q.push(ok).unwrap();
        let (batch, _) = q
            .collect(4, Duration::from_millis(1), &mut any_point)
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].input, vec![2.0]);
        // no rejection delivered, but the drop is counted
        assert!(rx.try_recv().is_err());
        assert_eq!(m.snapshot().cancelled, 1);
    }

    #[test]
    fn stop_with_drained_queue_ends_collect() {
        let m = Arc::new(Metrics::new());
        let q = Arc::new(RequestQueue::new(8, m));
        let q2 = q.clone();
        let j = std::thread::spawn(move || {
            q2.collect(4, Duration::from_millis(1), &mut any_point)
        });
        // timing-sensitive: the sleep only makes it *likely* that the
        // collector is already parked when stop() lands; stop() must
        // end the collect either way, so generous slack beats a race
        std::thread::sleep(Duration::from_millis(50));
        q.stop();
        assert!(j.join().unwrap().is_none());
    }

    #[test]
    fn stop_drains_already_admitted_requests() {
        let (q, _m) = queue(8);
        let (p, _rx) = pending(1.0, Priority::Normal);
        q.push(p).unwrap();
        q.stop();
        let got = q.collect(4, Duration::from_millis(1), &mut any_point);
        assert_eq!(got.unwrap().0.len(), 1);
        assert!(q.collect(4, Duration::from_millis(1), &mut any_point).is_none());
    }

    /// Panic while holding the queue lock, poisoning it.
    fn poison(q: &RequestQueue) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = q.state.lock().unwrap();
            panic!("poison the queue");
        }));
        assert!(q.state.lock().is_err(), "queue mutex must be poisoned");
    }

    #[test]
    fn poisoned_queue_pushes_answer_internal_not_panic() {
        let (q, _m) = queue(8);
        poison(&q);
        let (p, _rx) = pending(1.0, Priority::Normal);
        match q.push(p) {
            Err(ServeError::Internal(msg)) => assert!(msg.contains("poisoned")),
            other => panic!("expected Internal, got {other:?}"),
        }
    }

    #[test]
    fn poisoned_queue_ends_collect_and_stop_still_flips_flag() {
        let (q, _m) = queue(8);
        poison(&q);
        // the worker exits cleanly instead of propagating the panic
        assert!(q.collect(4, Duration::from_millis(1), &mut any_point).is_none());
        // stop recovers the guard and still takes effect
        q.stop();
        assert!(q.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner).stopped);
    }

    #[test]
    fn unclassifiable_leader_rejected_and_scan_continues() {
        let (q, m) = queue(8);
        let (mut p, rx) = pending(1.0, Priority::Hi);
        p.pin = Some("nope".into());
        q.push(p).unwrap();
        let (ok, _rx2) = pending(2.0, Priority::Normal);
        q.push(ok).unwrap();
        let mut classify = |p: &Pending| match &p.pin {
            Some(name) => Err(ServeError::UnknownPoint(name.clone())),
            None => Ok(0),
        };
        let (batch, _) = q.collect(4, Duration::from_millis(1), &mut classify).unwrap();
        assert_eq!(batch[0].input, vec![2.0]);
        assert_eq!(rx.recv().unwrap(), Err(ServeError::UnknownPoint("nope".into())));
        assert_eq!(m.snapshot().unservable, 1);
    }
}
