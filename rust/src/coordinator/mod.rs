//! L3 serving coordinator: power-budget-aware batched inference.
//!
//! The deployment claim of the paper (Sec. 6) is that PANN traverses
//! the power–accuracy trade-off **without hardware changes** — moving
//! between equal-power curves only re-parameterizes `(b̃_x, R)`. This
//! coordinator operationalizes that: it owns a menu of compiled
//! operating points (fp32 + one PANN executable per power budget,
//! produced by `make artifacts`), batches incoming requests, and
//! serves each batch with the best point under the *current* energy
//! budget — which can be changed at runtime without reloading models.
//!
//! Components: [`policy`] (budget → operating point), [`batcher`]
//! (size/deadline batching), [`metrics`] (latency/energy accounting),
//! [`server`] (single worker for `!Send` PJRT engines, or a worker
//! *pool* sharing `Arc<ExecutionPlan>`-backed operating points).

pub mod batcher;
pub mod metrics;
pub mod policy;
pub mod server;

pub use metrics::MetricsSnapshot;
pub use policy::{Costed, EnginePoint, PowerPolicy};
pub use server::{
    BatchEngine, Engine, NativeEngine, PlanEngine, Server, ServerConfig, ServerHandle, SharedPoint,
};
