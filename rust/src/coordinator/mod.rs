//! L3 serving coordinator: QoS-aware, power-budget-aware batched
//! inference behind one entry point.
//!
//! The deployment claim of the paper (Sec. 6) is that PANN traverses
//! the power–accuracy trade-off **without hardware changes** — moving
//! between equal-power curves only re-parameterizes `(b̃_x, R)`. This
//! coordinator operationalizes that *per request*: a server owns a
//! menu of compiled operating points (fp32 + one PANN executable per
//! power budget), and every [`InferRequest`] can carry its own QoS —
//! a start-by `deadline`, an energy cap (`max_gflips`), a [`Priority`]
//! class, a pinned point, a trace tag. The scheduler groups queued
//! requests by the operating point [`PowerPolicy`] selects under
//! `min(global budget, request cap)`, drains higher-priority groups
//! first, sheds load on a bounded queue ([`ServeError::QueueFull`]),
//! and rejects already-expired requests without executing them.
//!
//! Entry point: [`ServerBuilder`] → [`Menu`] (`local` for `!Send`
//! PJRT engines on one worker, `shared` for an `Arc`-shared plan menu
//! on a worker pool) → [`Server`] → [`Client`] → [`Ticket`].
//! Failures are typed ([`ServeError`]); dropping a [`Ticket`] cancels
//! a still-queued request.
//!
//! Budget selection can also run **closed-loop**: with
//! [`ServerBuilder::envelope`] set, the [`governor`] watches the
//! *metered* flip energy of every executed batch against an
//! [`EnergyEnvelope`] (Gflips/sec) and walks the served budget along
//! the menu frontier with hysteresis — sustained load degrades
//! accuracy gracefully instead of blowing the envelope, idle periods
//! climb back to the most accurate point. Without an envelope the
//! budget only moves when a client calls [`Client::set_budget`]
//! (the open-loop default).
//!
//! A server can also host a **fleet** of models:
//! [`ServerBuilder::register`] named menus (repeatable) and start them
//! with [`ServerBuilder::serve_fleet`] — one worker pool and one
//! bounded queue serve every registered model, each on its own
//! compiled frontier with its own budget cell, batches staying
//! point-coherent per model. Under an envelope each model runs its own
//! [`Governor`] and the [`registry`]'s fleet arbiter splits the global
//! rate across models by observed demand (max-min fair), so a hot
//! model degrades along its frontier before starving a cold one.
//!
//! Components: [`request`] (the public request/response model),
//! [`policy`] (budget → operating point), [`batcher`] (bounded
//! admission queue + point-coherent QoS batching), [`governor`]
//! (closed-loop energy control), [`arbiter`] (demand-weighted max-min
//! envelope splitting — [`fair_shares`] water-filling plus the
//! windowed [`EnvelopeSplitter`], shared by the fleet and by
//! [`crate::net::ShardRouter`]), [`registry`] (the multi-model fleet:
//! named menus, per-model budgets/governors, envelope arbitration),
//! [`metrics`] (latency/energy/rejection accounting, per priority
//! class), [`server`] (builder, engines, worker loops).
//!
//! [`ServerBuilder::register`]: server::ServerBuilder::register
//! [`ServerBuilder::serve_fleet`]: server::ServerBuilder::serve_fleet

pub mod arbiter;
pub mod batcher;
pub mod governor;
pub mod metrics;
pub mod policy;
pub mod registry;
pub mod request;
pub mod server;

pub use arbiter::{demand_shares, fair_shares, Demand, EnvelopeSplitter, SplitterSnapshot};
pub use governor::{EnergyEnvelope, Governor, GovernorConfig, GovernorSnapshot};
pub use metrics::{MetricsSnapshot, PriorityLatency};
pub use policy::{Costed, EnginePoint, PowerPolicy};
pub use registry::{FleetSnapshot, ModelFleetStatus, ModelRegistry};
pub use request::{InferRequest, Priority, Response, ServeError, Ticket};
pub use server::{
    BatchEngine, Client, Engine, Menu, NativeEngine, PlanEngine, Server, ServerBuilder,
    ServerConfig, SharedPoint,
};
