//! The serving loop(s).
//!
//! Two execution models share one client [`ServerHandle`]:
//!
//! - [`Server::start`] — the seed's single worker thread owning a menu
//!   of boxed [`Engine`]s. Still required for engines that are not
//!   `Send` (PJRT executables must be constructed *inside* the worker
//!   via the factory and never cross a thread boundary).
//! - [`Server::start_pool`] — N workers sharing one request queue and
//!   one immutable menu of [`SharedPoint`]s. Because a compiled
//!   [`ExecutionPlan`] is `Send + Sync`, every worker serves every
//!   operating point through the same `Arc`, with its own reusable
//!   [`Scratch`] arena — "plan once, execute many, everywhere".

use super::metrics::{Metrics, MetricsSnapshot};
use super::policy::{Costed, EnginePoint, PowerPolicy};
use crate::nn::{ExecutionPlan, Scratch, Tensor};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// An inference backend behind one operating point — either a PJRT
/// executable ([`crate::runtime::LoadedModel`]) or the native integer
/// engine.
///
/// PJRT handles are not `Send`, so these engines are constructed
/// *inside* the worker thread via the factory passed to
/// [`Server::start`] and never cross a thread boundary afterwards.
pub trait Engine {
    /// Largest batch one call may carry.
    fn max_batch(&self) -> usize;
    /// Flattened per-sample input length.
    fn sample_len(&self) -> usize;
    /// Run `n` samples (`x.len() == n * sample_len()`); returns
    /// flattened outputs (`n × out_len`).
    fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>>;
}

impl Engine for crate::runtime::LoadedModel {
    fn max_batch(&self) -> usize {
        self.batch
    }
    fn sample_len(&self) -> usize {
        self.sample_len
    }
    fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        self.run_padded(x, n)
    }
}

/// A thread-safe batch engine for the worker pool: stateless `infer`
/// against shared immutable state, with caller-owned scratch.
pub trait BatchEngine: Send + Sync {
    /// Largest batch one call may carry.
    fn max_batch(&self) -> usize;
    /// Flattened per-sample input length.
    fn sample_len(&self) -> usize;
    /// Run `n` samples using the worker's scratch arena.
    fn infer_batch(&self, x: &[f32], n: usize, scratch: &mut Scratch) -> Result<Vec<f32>>;
}

/// One pool operating point: an `Arc`-shared batch engine plus its
/// energy cost.
pub struct SharedPoint {
    pub name: String,
    /// Energy per sample in Giga bit flips; `f64::INFINITY` for fp32.
    pub giga_flips_per_sample: f64,
    pub engine: Arc<dyn BatchEngine>,
}

impl Costed for SharedPoint {
    fn point_name(&self) -> &str {
        &self.name
    }
    fn cost_gflips(&self) -> f64 {
        self.giga_flips_per_sample
    }
}

/// Batch engine over a compiled [`ExecutionPlan`] — the native path of
/// the worker pool. GEMM-internal threading stays at 1: the pool
/// parallelizes across requests, not inside them.
pub struct PlanEngine {
    pub plan: Arc<ExecutionPlan>,
    pub sample_shape: Vec<usize>,
    pub max_batch: usize,
}

impl PlanEngine {
    pub fn new(plan: Arc<ExecutionPlan>, sample_shape: Vec<usize>) -> PlanEngine {
        PlanEngine { plan, sample_shape, max_batch: 64 }
    }
}

impl BatchEngine for PlanEngine {
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn sample_len(&self) -> usize {
        self.sample_shape.iter().product()
    }
    fn infer_batch(&self, x: &[f32], n: usize, scratch: &mut Scratch) -> Result<Vec<f32>> {
        let mut shape = vec![n];
        shape.extend_from_slice(&self.sample_shape);
        let t = Tensor::new(shape, x.to_vec())?;
        let mut meter = self.plan.new_meter();
        Ok(self.plan.forward_batch(&t, scratch, &mut meter, 1)?.data)
    }
}

/// Native-engine adapter for the single-worker server (serves without
/// PJRT artifacts). Owns its scratch arena, reused across requests.
pub struct NativeEngine {
    plan: Arc<ExecutionPlan>,
    sample_shape: Vec<usize>,
    scratch: Scratch,
}

impl NativeEngine {
    pub fn new(qm: &crate::nn::QuantizedModel, sample_shape: Vec<usize>) -> NativeEngine {
        NativeEngine { plan: qm.plan(), sample_shape, scratch: Scratch::new() }
    }

    pub fn from_plan(plan: Arc<ExecutionPlan>, sample_shape: Vec<usize>) -> NativeEngine {
        NativeEngine { plan, sample_shape, scratch: Scratch::new() }
    }
}

impl Engine for NativeEngine {
    fn max_batch(&self) -> usize {
        64
    }
    fn sample_len(&self) -> usize {
        self.sample_shape.iter().product()
    }
    fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let mut shape = vec![n];
        shape.extend_from_slice(&self.sample_shape);
        let t = Tensor::new(shape, x.to_vec())?;
        let mut meter = self.plan.new_meter();
        // single-worker server: the GEMMs may use the full thread budget
        let threads = crate::nn::eval::n_threads();
        Ok(self
            .plan
            .forward_batch(&t, &mut self.scratch, &mut meter, threads)?
            .data)
    }
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Initial energy budget per sample, Giga bit flips.
    pub budget_gflips: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            budget_gflips: f64::INFINITY,
        }
    }
}

struct Request {
    input: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

/// Worker mailbox message.
enum Msg {
    Req(Request),
    /// Graceful stop (cloned handles may outlive the server, so a
    /// sender-disconnect alone cannot signal shutdown). One `Stop`
    /// terminates exactly one worker.
    Stop,
}

/// Collect a batch of requests; returns (batch, stop_seen). `None`
/// means the channel closed or a stop arrived with nothing pending.
fn collect_requests(
    rx: &mpsc::Receiver<Msg>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<(Vec<Request>, bool)> {
    let first = loop {
        match rx.recv() {
            Ok(Msg::Req(r)) => break r,
            Ok(Msg::Stop) | Err(_) => return None,
        }
    };
    let mut batch = vec![first];
    let mut stop = false;
    let deadline = Instant::now() + max_wait;
    while batch.len() < max_batch && !stop {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(Msg::Req(r)) => batch.push(r),
            Ok(Msg::Stop) => stop = true,
            Err(_) => break,
        }
    }
    Some((batch, stop))
}

/// One served response.
#[derive(Clone, Debug)]
pub struct Response {
    pub output: Vec<f32>,
    /// Operating point that served the request.
    pub point: String,
    pub latency: Duration,
    /// Energy charged to this request (Giga bit flips).
    pub giga_flips: f64,
}

/// Client handle: submit requests, change the budget, read metrics.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    budget_bits: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
    sample_len: usize,
}

impl ServerHandle {
    /// Submit one sample; returns the channel the response arrives on.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        anyhow::ensure!(input.len() == self.sample_len, "bad input length {}", input.len());
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Req(Request { input, submitted: Instant::now(), resp: tx }))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, input: Vec<f32>) -> Result<Response> {
        Ok(self.submit(input)?.recv()?)
    }

    /// Change the per-sample energy budget at runtime — the paper's
    /// "traverse the power-accuracy trade-off at deployment time".
    pub fn set_budget(&self, gflips: f64) {
        self.budget_bits.store(gflips.to_bits(), Ordering::Relaxed);
    }

    pub fn budget(&self) -> f64 {
        f64::from_bits(self.budget_bits.load(Ordering::Relaxed))
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// The server: one or more worker threads behind a [`ServerHandle`].
pub struct Server {
    handle: ServerHandle,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the single-worker server. `factory` builds the
    /// operating-point menu on the worker thread (PJRT executables are
    /// not `Send`); `sample_len` is the flattened per-sample input
    /// length the menu expects.
    pub fn start<F>(factory: F, sample_len: usize, config: ServerConfig) -> Result<Server>
    where
        F: FnOnce() -> Result<Vec<EnginePoint>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let budget_bits = Arc::new(AtomicU64::new(config.budget_gflips.to_bits()));
        let metrics = Arc::new(Metrics::new());
        let handle = ServerHandle {
            tx,
            budget_bits: budget_bits.clone(),
            metrics: metrics.clone(),
            sample_len,
        };
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::spawn(move || {
            let mut policy = match factory() {
                Ok(points) if !points.is_empty() => {
                    let _ = ready_tx.send(Ok(()));
                    PowerPolicy::new(points)
                }
                Ok(_) => {
                    let _ = ready_tx.send(Err(anyhow::anyhow!("empty operating-point menu")));
                    return;
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Some((batch, stop)) = collect_requests(&rx, config.max_batch, config.max_wait)
            {
                let budget = f64::from_bits(budget_bits.load(Ordering::Relaxed));
                let idx = policy.select(budget);
                let (name, gf) = {
                    let p = policy.point(idx);
                    (p.name.clone(), p.giga_flips_per_sample)
                };
                serve_batch(policy.point_mut(idx), &name, gf, batch, &metrics);
                if stop {
                    break;
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker died during startup"))??;
        Ok(Server { handle, workers: vec![worker] })
    }

    /// Start a pool of `n_workers` threads over one shared menu. All
    /// workers serve all points; batching, point selection and budget
    /// traversal behave exactly as in the single-worker server, but
    /// batches execute concurrently.
    pub fn start_pool(
        points: Vec<SharedPoint>,
        sample_len: usize,
        config: ServerConfig,
        n_workers: usize,
    ) -> Result<Server> {
        anyhow::ensure!(!points.is_empty(), "empty operating-point menu");
        let n_workers = n_workers.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let budget_bits = Arc::new(AtomicU64::new(config.budget_gflips.to_bits()));
        let metrics = Arc::new(Metrics::new());
        let policy = Arc::new(PowerPolicy::new(points));
        let handle = ServerHandle {
            tx,
            budget_bits: budget_bits.clone(),
            metrics: metrics.clone(),
            sample_len,
        };
        let mut workers = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let rx = rx.clone();
            let policy = policy.clone();
            let metrics = metrics.clone();
            let budget_bits = budget_bits.clone();
            workers.push(std::thread::spawn(move || {
                let mut scratch = Scratch::new();
                loop {
                    // hold the queue lock only while batching; execution
                    // below runs in parallel across workers
                    let collected = {
                        let guard = rx.lock().expect("pool queue poisoned");
                        collect_requests(&guard, config.max_batch, config.max_wait)
                    };
                    let Some((batch, stop)) = collected else { break };
                    let budget = f64::from_bits(budget_bits.load(Ordering::Relaxed));
                    let point = policy.point(policy.select(budget));
                    serve_batch_shared(point, batch, &metrics, &mut scratch);
                    if stop {
                        break;
                    }
                }
            }));
        }
        Ok(Server { handle, workers })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Number of worker threads.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Stop all workers (requests already queued before the stops are
    /// drained; cloned handles then observe send errors).
    pub fn shutdown(mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.handle.tx.send(Msg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Respond to one collected batch, splitting it across engine calls of
/// at most `max_b` samples. `infer` runs one sub-batch.
fn respond_batch<F>(
    name: &str,
    gf_per_sample: f64,
    sample_len: usize,
    max_b: usize,
    batch: Vec<Request>,
    metrics: &Metrics,
    mut infer: F,
) where
    F: FnMut(&[f32], usize) -> Result<Vec<f32>>,
{
    let max_b = max_b.max(1);
    let mut start = 0;
    while start < batch.len() {
        let n = (batch.len() - start).min(max_b);
        let chunk = &batch[start..start + n];
        let mut flat = Vec::with_capacity(n * sample_len);
        for r in chunk {
            flat.extend_from_slice(&r.input);
        }
        match infer(&flat, n) {
            Ok(out) => {
                let ol = out.len() / n;
                let lats: Vec<f64> = chunk
                    .iter()
                    .map(|r| r.submitted.elapsed().as_secs_f64() * 1e6)
                    .collect();
                let batch_gf = if gf_per_sample.is_finite() {
                    gf_per_sample * n as f64
                } else {
                    0.0
                };
                // record *before* responding so a client that has its
                // response always observes it in the metrics
                metrics.record_batch(name, n, &lats, batch_gf);
                for (i, r) in chunk.iter().enumerate() {
                    let _ = r.resp.send(Response {
                        output: out[i * ol..(i + 1) * ol].to_vec(),
                        point: name.to_string(),
                        latency: Duration::from_secs_f64(lats[i] * 1e-6),
                        giga_flips: if gf_per_sample.is_finite() { gf_per_sample } else { 0.0 },
                    });
                }
            }
            Err(e) => {
                // drop the senders: receivers observe RecvError
                eprintln!("serve error on {name}: {e:#}");
            }
        }
        start += n;
    }
}

fn serve_batch(
    point: &mut EnginePoint,
    name: &str,
    gf_per_sample: f64,
    batch: Vec<Request>,
    metrics: &Metrics,
) {
    let eng = point.engine.as_mut();
    let sample_len = eng.sample_len();
    let max_b = eng.max_batch();
    respond_batch(name, gf_per_sample, sample_len, max_b, batch, metrics, |x, n| {
        eng.infer(x, n)
    });
}

fn serve_batch_shared(
    point: &SharedPoint,
    batch: Vec<Request>,
    metrics: &Metrics,
    scratch: &mut Scratch,
) {
    let eng = point.engine.as_ref();
    respond_batch(
        &point.name,
        point.giga_flips_per_sample,
        eng.sample_len(),
        eng.max_batch(),
        batch,
        metrics,
        |x, n| eng.infer_batch(x, n, scratch),
    );
}

/// Mock engines for unit tests.
#[cfg(test)]
pub(crate) mod tests_support {
    use super::*;

    /// Echo-sum engine: out[j] = sum(input) + j.
    pub struct MockEngine {
        pub max_b: usize,
        pub in_len: usize,
        pub out_len: usize,
    }

    impl MockEngine {
        pub fn new(max_b: usize, in_len: usize, out_len: usize) -> Self {
            MockEngine { max_b, in_len, out_len }
        }

        fn compute(&self, x: &[f32], n: usize) -> Vec<f32> {
            let mut out = Vec::with_capacity(n * self.out_len);
            for i in 0..n {
                let s: f32 = x[i * self.in_len..(i + 1) * self.in_len].iter().sum();
                for j in 0..self.out_len {
                    out.push(s + j as f32);
                }
            }
            out
        }
    }

    impl Engine for MockEngine {
        fn max_batch(&self) -> usize {
            self.max_b
        }
        fn sample_len(&self) -> usize {
            self.in_len
        }
        fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>> {
            Ok(self.compute(x, n))
        }
    }

    impl BatchEngine for MockEngine {
        fn max_batch(&self) -> usize {
            self.max_b
        }
        fn sample_len(&self) -> usize {
            self.in_len
        }
        fn infer_batch(&self, x: &[f32], n: usize, _scratch: &mut Scratch) -> Result<Vec<f32>> {
            Ok(self.compute(x, n))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::MockEngine;
    use super::*;

    fn points() -> Vec<EnginePoint> {
        vec![
            EnginePoint {
                name: "cheap".into(),
                giga_flips_per_sample: 0.1,
                engine: Box::new(MockEngine::new(4, 3, 2)),
            },
            EnginePoint {
                name: "rich".into(),
                giga_flips_per_sample: 0.9,
                engine: Box::new(MockEngine::new(4, 3, 2)),
            },
        ]
    }

    fn shared_points() -> Vec<SharedPoint> {
        vec![
            SharedPoint {
                name: "cheap".into(),
                giga_flips_per_sample: 0.1,
                engine: Arc::new(MockEngine::new(4, 3, 2)),
            },
            SharedPoint {
                name: "rich".into(),
                giga_flips_per_sample: 0.9,
                engine: Arc::new(MockEngine::new(4, 3, 2)),
            },
        ]
    }

    #[test]
    fn serves_and_responds() {
        let srv = Server::start(|| Ok(points()), 3, ServerConfig {
            budget_gflips: 1.0,
            ..Default::default()
        })
        .unwrap();
        let h = srv.handle();
        let r = h.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(r.output, vec![6.0, 7.0]);
        assert_eq!(r.point, "rich");
        srv.shutdown();
    }

    #[test]
    fn budget_traversal_switches_point() {
        let srv = Server::start(|| Ok(points()), 3, ServerConfig {
            budget_gflips: 1.0,
            ..Default::default()
        })
        .unwrap();
        let h = srv.handle();
        assert_eq!(h.infer(vec![0.0; 3]).unwrap().point, "rich");
        h.set_budget(0.2);
        assert_eq!(h.infer(vec![0.0; 3]).unwrap().point, "cheap");
        h.set_budget(5.0);
        assert_eq!(h.infer(vec![0.0; 3]).unwrap().point, "rich");
        let m = h.metrics();
        assert_eq!(m.requests, 3);
        srv.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let srv = Server::start(|| Ok(points()), 3, ServerConfig::default()).unwrap();
        let h = srv.handle();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25 {
                    let v = (t * 100 + i) as f32;
                    let r = h.infer(vec![v, 0.0, 0.0]).unwrap();
                    assert_eq!(r.output[0], v);
                    ok += 1;
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 200);
        let m = h.metrics();
        assert_eq!(m.requests, 200);
        assert!(m.batches <= 200);
        srv.shutdown();
    }

    #[test]
    fn rejects_bad_input_length() {
        let srv = Server::start(|| Ok(points()), 3, ServerConfig::default()).unwrap();
        let h = srv.handle();
        assert!(h.submit(vec![1.0]).is_err());
        srv.shutdown();
    }

    #[test]
    fn oversized_batches_split_across_engine_calls() {
        // engine max_batch = 4, server max_batch = 16: a burst of 10
        // must still produce 10 correct responses.
        let srv = Server::start(|| Ok(points()), 3, ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(30),
            budget_gflips: 1.0,
        })
        .unwrap();
        let h = srv.handle();
        let rxs: Vec<_> = (0..10)
            .map(|i| h.submit(vec![i as f32, 0.0, 0.0]).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().output[0], i as f32);
        }
        srv.shutdown();
    }

    #[test]
    fn pool_serves_and_responds() {
        let srv = Server::start_pool(shared_points(), 3, ServerConfig {
            budget_gflips: 1.0,
            ..Default::default()
        }, 4)
        .unwrap();
        assert_eq!(srv.n_workers(), 4);
        let h = srv.handle();
        let r = h.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(r.output, vec![6.0, 7.0]);
        assert_eq!(r.point, "rich");
        srv.shutdown();
    }

    #[test]
    fn pool_budget_traversal_switches_point() {
        let srv = Server::start_pool(shared_points(), 3, ServerConfig {
            budget_gflips: 1.0,
            ..Default::default()
        }, 3)
        .unwrap();
        let h = srv.handle();
        assert_eq!(h.infer(vec![0.0; 3]).unwrap().point, "rich");
        h.set_budget(0.2);
        assert_eq!(h.infer(vec![0.0; 3]).unwrap().point, "cheap");
        h.set_budget(5.0);
        assert_eq!(h.infer(vec![0.0; 3]).unwrap().point, "rich");
        srv.shutdown();
    }

    #[test]
    fn pool_concurrent_clients_all_served() {
        let srv = Server::start_pool(shared_points(), 3, ServerConfig::default(), 4).unwrap();
        let h = srv.handle();
        let mut joins = Vec::new();
        for t in 0..8 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25 {
                    let v = (t * 100 + i) as f32;
                    let r = h.infer(vec![v, 0.0, 0.0]).unwrap();
                    assert_eq!(r.output[0], v);
                    ok += 1;
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 200);
        let m = h.metrics();
        assert_eq!(m.requests, 200);
        srv.shutdown();
    }

    #[test]
    fn pool_shutdown_stops_every_worker() {
        let srv = Server::start_pool(shared_points(), 3, ServerConfig::default(), 5).unwrap();
        let h = srv.handle();
        let _ = h.infer(vec![0.0; 3]).unwrap();
        srv.shutdown(); // joins all 5 workers; hangs here if a Stop is lost
        assert!(h.submit(vec![0.0; 3]).is_err() || h.submit(vec![0.0; 3]).unwrap().recv().is_err());
    }
}
