//! The serving loop(s) behind one public entry point.
//!
//! [`ServerBuilder`] configures the server (`workers`, `queue_depth`,
//! `max_batch`, `max_wait`, `budget_gflips`) and [`ServerBuilder::serve`]
//! starts it over a [`Menu`] of operating points:
//!
//! - [`Menu::local`] — a factory that builds boxed [`Engine`]s *on the
//!   worker thread*. Required for engines that are not `Send` (PJRT
//!   executables must be constructed inside the worker and never cross
//!   a thread boundary); always runs exactly one worker.
//! - [`Menu::shared`] — [`SharedPoint`]s over `Send + Sync` batch
//!   engines (compiled [`ExecutionPlan`]s), served by `workers`
//!   threads that share one immutable menu through `Arc`s, each with
//!   its own [`Scratch`] arena — "plan once, execute many, everywhere".
//!
//! Both paths return the same [`Client`]. Requests carry per-request
//! QoS ([`InferRequest`]): the scheduler groups queued requests by the
//! operating point [`PowerPolicy`] selects under
//! `min(global budget, request.max_gflips)`, drains higher-priority
//! groups first, sheds on a bounded queue, and rejects already-expired
//! requests without executing them (see [`super::batcher`]).
//!
//! [`InferRequest`]: super::request::InferRequest

// Request-handling surface: panics are banned (see clippy.toml); fail
// with a typed `ServeError` (or recover poisoned guards) instead.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use super::batcher::{Pending, RequestQueue};
use super::governor::{EnergyEnvelope, Governor, GovernorConfig, GovernorSnapshot};
use super::metrics::{Metrics, MetricsSnapshot};
use super::policy::{Costed, EnginePoint, PowerPolicy};
use super::registry::{FleetSnapshot, ModelRegistry};
use super::request::{InferRequest, Priority, Response, ServeError, Ticket};
use crate::nn::{ExecutionPlan, PowerMeter, Scratch};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// An inference backend behind one operating point — either a PJRT
/// executable ([`crate::runtime::LoadedModel`]) or the native integer
/// engine.
///
/// PJRT handles are not `Send`, so these engines are constructed
/// *inside* the worker thread via the factory passed to [`Menu::local`]
/// and never cross a thread boundary afterwards.
pub trait Engine {
    /// Largest batch one call may carry.
    fn max_batch(&self) -> usize;
    /// Flattened per-sample input length.
    fn sample_len(&self) -> usize;
    /// Run `n` samples (`x.len() == n * sample_len()`); returns
    /// flattened outputs (`n × out_len`).
    fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>>;
    /// [`Engine::infer`] plus the energy the call *actually metered*
    /// (total Giga bit flips for the whole call), when the backend has
    /// a flip meter. The default forwards to `infer` and reports
    /// `None` — right for backends without metering (PJRT executables
    /// count no flips); the native engines override it with their
    /// [`crate::nn::PowerMeter`] totals, which is what feeds the
    /// closed-loop [`Governor`] and the measured-vs-modeled metrics.
    fn infer_metered(&mut self, x: &[f32], n: usize) -> Result<(Vec<f32>, Option<f64>)> {
        Ok((self.infer(x, n)?, None))
    }
}

impl Engine for crate::runtime::LoadedModel {
    fn max_batch(&self) -> usize {
        self.batch
    }
    fn sample_len(&self) -> usize {
        self.sample_len
    }
    fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        self.run_padded(x, n)
    }
}

/// A thread-safe batch engine for the worker pool: stateless `infer`
/// against shared immutable state, with caller-owned scratch.
pub trait BatchEngine: Send + Sync {
    /// Largest batch one call may carry.
    fn max_batch(&self) -> usize;
    /// Flattened per-sample input length.
    fn sample_len(&self) -> usize;
    /// Run `n` samples using the worker's scratch arena.
    fn infer_batch(&self, x: &[f32], n: usize, scratch: &mut Scratch) -> Result<Vec<f32>>;
    /// [`BatchEngine::infer_batch`] plus the metered energy of the
    /// call (total Giga bit flips), `None` when the backend does not
    /// meter flips — see [`Engine::infer_metered`].
    fn infer_batch_metered(
        &self,
        x: &[f32],
        n: usize,
        scratch: &mut Scratch,
    ) -> Result<(Vec<f32>, Option<f64>)> {
        Ok((self.infer_batch(x, n, scratch)?, None))
    }
}

/// One pool operating point: an `Arc`-shared batch engine plus its
/// energy cost.
pub struct SharedPoint {
    /// Point name (unique within its menu; pinnable).
    pub name: String,
    /// Energy per sample in Giga bit flips; `f64::INFINITY` for fp32.
    pub giga_flips_per_sample: f64,
    /// Serving-side measured energy per sample, when the menu artifact
    /// carries a `pann-menu/v2` calibration for this point. The policy
    /// uses it only to break ties between equal modeled costs
    /// ([`Costed::measured_gflips`]).
    pub measured_gflips_per_sample: Option<f64>,
    /// The engine executing this point, shared across workers.
    pub engine: Arc<dyn BatchEngine>,
}

impl Costed for SharedPoint {
    fn point_name(&self) -> &str {
        &self.name
    }
    fn cost_gflips(&self) -> f64 {
        self.giga_flips_per_sample
    }
    fn measured_gflips(&self) -> Option<f64> {
        self.measured_gflips_per_sample
    }
}

/// Batch engine over a compiled [`ExecutionPlan`] — the native path of
/// the worker pool. GEMM-internal threading stays at 1: the pool
/// parallelizes across requests, not inside them.
///
/// The max batch is threaded in from [`ServerBuilder::max_batch`] by
/// the caller; power meters are pooled and reused across calls instead
/// of being re-allocated per batch.
pub struct PlanEngine {
    plan: Arc<ExecutionPlan>,
    max_batch: usize,
    meters: Mutex<Vec<PowerMeter>>,
}

impl PlanEngine {
    /// Engine over `plan`, answering at most `max_batch` samples per
    /// call (clamped to ≥ 1).
    pub fn new(plan: Arc<ExecutionPlan>, max_batch: usize) -> PlanEngine {
        PlanEngine { plan, max_batch: max_batch.max(1), meters: Mutex::new(Vec::new()) }
    }

    /// The compiled plan this engine executes.
    pub fn plan(&self) -> &Arc<ExecutionPlan> {
        &self.plan
    }
}

impl BatchEngine for PlanEngine {
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn sample_len(&self) -> usize {
        self.plan.input_shape().iter().product()
    }
    fn infer_batch(&self, x: &[f32], n: usize, scratch: &mut Scratch) -> Result<Vec<f32>> {
        Ok(self.infer_batch_metered(x, n, scratch)?.0)
    }

    fn infer_batch_metered(
        &self,
        x: &[f32],
        n: usize,
        scratch: &mut Scratch,
    ) -> Result<(Vec<f32>, Option<f64>)> {
        // a poisoned pool just means a worker panicked holding it; the
        // pooled meters are reset before use, so recover the guard
        let mut meter = {
            let mut pool = self
                .meters
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            pool.pop().unwrap_or_else(|| self.plan.new_meter())
        };
        meter.reset();
        // borrowed-slice forward: no per-batch input copy
        let out = self.plan.forward_slice(x, n, scratch, &mut meter, 1);
        let measured = meter.giga();
        self.meters
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .push(meter);
        Ok((out?.data, Some(measured)))
    }
}

/// Native-engine adapter for the single-worker (local-menu) server.
/// Owns its scratch arena and meter, reused across requests.
pub struct NativeEngine {
    plan: Arc<ExecutionPlan>,
    max_batch: usize,
    scratch: Scratch,
    meter: PowerMeter,
}

impl NativeEngine {
    /// Engine over a prepared model's plan (see
    /// [`NativeEngine::from_plan`]).
    pub fn new(qm: &crate::nn::QuantizedModel, max_batch: usize) -> NativeEngine {
        NativeEngine::from_plan(qm.plan(), max_batch)
    }

    /// Engine over `plan` with its own scratch arena and meter,
    /// answering at most `max_batch` samples per call (clamped to ≥ 1).
    pub fn from_plan(plan: Arc<ExecutionPlan>, max_batch: usize) -> NativeEngine {
        let meter = plan.new_meter();
        NativeEngine { plan, max_batch: max_batch.max(1), scratch: Scratch::new(), meter }
    }
}

impl Engine for NativeEngine {
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn sample_len(&self) -> usize {
        self.plan.input_shape().iter().product()
    }
    fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        self.meter.reset();
        // single-worker server: the GEMMs may use the full thread budget
        let threads = crate::nn::eval::n_threads();
        Ok(self
            .plan
            .forward_slice(x, n, &mut self.scratch, &mut self.meter, threads)?
            .data)
    }

    fn infer_metered(&mut self, x: &[f32], n: usize) -> Result<(Vec<f32>, Option<f64>)> {
        let out = self.infer(x, n)?;
        // `infer` resets the meter on entry, so it now holds exactly
        // this call's flips
        Ok((out, Some(self.meter.giga())))
    }
}

/// Server configuration (all knobs of [`ServerBuilder`]).
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Worker threads (shared menus only; a local menu always runs 1).
    pub workers: usize,
    /// Bounded queue depth; admission sheds with `QueueFull` beyond it.
    pub queue_depth: usize,
    /// Largest batch the scheduler assembles.
    pub max_batch: usize,
    /// How long a worker waits to fill a batch.
    pub max_wait: Duration,
    /// Initial global energy budget per sample, Giga bit flips.
    pub budget_gflips: f64,
    /// Closed-loop energy envelope. `None` (the default) keeps the
    /// open-loop PR-3 behavior: the budget only moves when a client
    /// calls [`Client::set_budget`].
    pub envelope: Option<EnergyEnvelope>,
    /// Governor decision-window length (envelope only).
    pub governor_window: Duration,
    /// Consecutive over/under windows before the governor steps.
    pub governor_hysteresis: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            queue_depth: 256,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            budget_gflips: f64::INFINITY,
            envelope: None,
            governor_window: GovernorConfig::DEFAULT_WINDOW,
            governor_hysteresis: GovernorConfig::DEFAULT_HYSTERESIS,
        }
    }
}

/// The operating-point menu a server serves.
pub enum Menu {
    /// Engines built *on* the worker thread (may be `!Send`, e.g.
    /// PJRT executables). Always served by exactly one worker.
    Local(Box<dyn FnOnce() -> Result<Vec<EnginePoint>> + Send>),
    /// `Send + Sync` points shared by a worker pool through `Arc`s.
    Shared(Vec<SharedPoint>),
    /// Shared points built inside [`ServerBuilder::serve`], once the
    /// builder's `max_batch` is known (the closure's argument) — used
    /// by menus recompiled from artifacts so the engines' per-call
    /// batch bound always matches the server configuration.
    SharedDeferred(Box<dyn FnOnce(usize) -> Result<Vec<SharedPoint>> + Send>),
}

impl Menu {
    /// Menu built on the worker thread (single-worker; `!Send` safe).
    pub fn local<F>(factory: F) -> Menu
    where
        F: FnOnce() -> Result<Vec<EnginePoint>> + Send + 'static,
    {
        Menu::Local(Box::new(factory))
    }

    /// Shared menu for the worker pool.
    pub fn shared(points: Vec<SharedPoint>) -> Menu {
        Menu::Shared(points)
    }

    /// Load a compiled menu artifact (`menu.json`, written by
    /// [`crate::pann::menu::compile_menu`] / `pann-cli compile-menu`)
    /// to be served by the worker pool. The artifact is parsed (and
    /// its schema checked) immediately; each frontier point is
    /// recompiled into an [`ExecutionPlan`] inside
    /// [`ServerBuilder::serve`], so the engines' per-call batch bound
    /// is the builder's `max_batch`. The artifact's model fingerprint
    /// is verified against `model` then, so a menu can never be
    /// served against a different network than it was compiled for.
    ///
    /// Quantization methods that need calibration inputs (ACIQ, Recon)
    /// must go through [`Menu::from_artifact_calibrated`]; the
    /// data-free methods (Dynamic, BN-stats, DFQ) need none.
    ///
    /// ```
    /// use pann::coordinator::{Menu, ServerBuilder};
    /// use pann::data::{synth, Dataset};
    /// use pann::nn::Model;
    /// use pann::pann::compile_menu;
    /// use pann::quant::ActQuantMethod;
    ///
    /// let mut model = Model::reference_cnn(11);
    /// let ds = Dataset::from_synth(synth::digits(48, 12));
    /// let stats = pann::nn::eval::batch_tensor(&ds, 0, 24);
    /// model.record_act_stats(&stats)?;
    /// let path = std::env::temp_dir().join("pann_doc_from_artifact_menu.json");
    /// compile_menu(&model, &[2], ActQuantMethod::BnStats, None, &ds.take(32), 2..=4)?
    ///     .save(&path)?;
    ///
    /// let srv = ServerBuilder::new().serve(Menu::from_artifact(&path, &model)?)?;
    /// let client = srv.client();
    /// let resp = client.infer(ds.sample(0).to_vec())?;
    /// assert!(resp.point.starts_with("pt"));
    /// srv.shutdown();
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn from_artifact(
        path: impl AsRef<std::path::Path>,
        model: &crate::nn::Model,
    ) -> Result<Menu> {
        Menu::from_artifact_calibrated(path, model, None)
    }

    /// [`Menu::from_artifact`] with explicit calibration inputs.
    pub fn from_artifact_calibrated(
        path: impl AsRef<std::path::Path>,
        model: &crate::nn::Model,
        calib: Option<&crate::nn::Tensor>,
    ) -> Result<Menu> {
        let artifact = crate::pann::menu::MenuArtifact::load(path.as_ref())?;
        let model = model.clone();
        let calib = calib.cloned();
        Ok(Menu::SharedDeferred(Box::new(move |max_batch| {
            artifact.shared_points(&model, calib.as_ref(), max_batch)
        })))
    }
}

/// Builder for the one serving entry point.
///
/// The example below compiles one PANN operating point for the
/// built-in reference CNN and serves it on a two-worker pool:
///
/// ```
/// use pann::coordinator::{Menu, PlanEngine, ServerBuilder, SharedPoint};
/// use pann::data::{synth, Dataset};
/// use pann::nn::{Model, QuantConfig, QuantizedModel};
/// use pann::quant::ActQuantMethod;
/// use std::sync::Arc;
///
/// let mut model = Model::reference_cnn(1);
/// let ds = Dataset::from_synth(synth::digits(32, 2));
/// let stats = pann::nn::eval::batch_tensor(&ds, 0, 16);
/// model.record_act_stats(&stats)?;
/// let qm = QuantizedModel::prepare(
///     &model,
///     QuantConfig::pann(4, 2.0, ActQuantMethod::BnStats),
///     None,
/// )?;
///
/// let srv = ServerBuilder::new()
///     .workers(2)
///     .queue_depth(64)
///     .max_batch(8)
///     .budget_gflips(1.0)
///     .serve(Menu::shared(vec![SharedPoint {
///         name: "p4".into(),
///         measured_gflips_per_sample: None,
///         giga_flips_per_sample: 0.001,
///         engine: Arc::new(PlanEngine::new(qm.plan(), 8)),
///     }]))?;
/// let client = srv.client();
/// let resp = client.infer(ds.sample(0).to_vec())?;
/// assert_eq!(resp.point, "p4");
/// srv.shutdown();
/// # Ok::<(), anyhow::Error>(())
/// ```
///
/// With [`ServerBuilder::envelope`] set, a closed-loop [`Governor`]
/// additionally walks the served budget along the menu frontier so
/// sustained load degrades accuracy gracefully instead of blowing the
/// energy envelope (see [`super::governor`]).
///
/// A server can also host a **fleet**: [`ServerBuilder::register`]
/// named menus (repeatable) and start them with
/// [`ServerBuilder::serve_fleet`] — every model gets its own compiled
/// frontier and budget cell behind the same worker pool, and a shared
/// envelope is split across models by observed demand (see
/// [`super::registry`]).
pub struct ServerBuilder {
    config: ServerConfig,
    /// Named menus for fleet serving (`register`/`serve_fleet`).
    registrations: Vec<(String, Menu)>,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder::new()
    }
}

impl ServerBuilder {
    /// A builder with [`ServerConfig::default`] knobs.
    pub fn new() -> ServerBuilder {
        ServerBuilder { config: ServerConfig::default(), registrations: Vec::new() }
    }

    /// Start from an existing config.
    pub fn from_config(config: ServerConfig) -> ServerBuilder {
        ServerBuilder { config, registrations: Vec::new() }
    }

    /// Worker threads for shared menus (clamped to ≥ 1). Local menus
    /// always run exactly one worker regardless.
    pub fn workers(mut self, n: usize) -> Self {
        self.config.workers = n.max(1);
        self
    }

    /// Bounded queue depth (clamped to ≥ 1): admission control sheds
    /// with [`ServeError::QueueFull`] beyond it.
    pub fn queue_depth(mut self, d: usize) -> Self {
        self.config.queue_depth = d.max(1);
        self
    }

    /// Largest batch the scheduler assembles (engines may split it
    /// further across calls).
    pub fn max_batch(mut self, b: usize) -> Self {
        self.config.max_batch = b.max(1);
        self
    }

    /// How long a worker waits to fill a batch.
    pub fn max_wait(mut self, t: Duration) -> Self {
        self.config.max_wait = t;
        self
    }

    /// Initial global energy budget per sample (Giga bit flips).
    pub fn budget_gflips(mut self, g: f64) -> Self {
        self.config.budget_gflips = g;
        self
    }

    /// Enable the closed-loop energy [`Governor`]: defend a sustained
    /// energy envelope (Gflips/sec) by stepping the served budget
    /// down the menu frontier under load and back up when the load
    /// (or an idle period) leaves headroom. Without this call the
    /// server is open-loop: the budget moves only via
    /// [`Client::set_budget`]. With it, the governor co-owns the
    /// budget cell: each decision window starts from whatever point
    /// the cell currently selects (manual budgets are honored), and
    /// every governor step rewrites the cell.
    pub fn envelope(mut self, e: EnergyEnvelope) -> Self {
        self.config.envelope = Some(e);
        self
    }

    /// Governor decision-window length (default 100 ms). Only
    /// meaningful together with [`ServerBuilder::envelope`].
    pub fn governor_window(mut self, w: Duration) -> Self {
        self.config.governor_window = w;
        self
    }

    /// Governor decision-horizon length in windows (default 2,
    /// clamped to ≥ 1): each step judges the last `h` windows of
    /// energy against `h ×` the per-window target, and at most one
    /// frontier step happens per horizon.
    pub fn governor_hysteresis(mut self, h: u32) -> Self {
        self.config.governor_hysteresis = h.max(1);
        self
    }

    /// Register a named menu for fleet serving. Repeatable — each call
    /// adds one model; start them together with
    /// [`ServerBuilder::serve_fleet`]. The menu must be pool-shareable
    /// ([`Menu::shared`] or a [`Menu::from_artifact`] menu, whose model
    /// fingerprint is verified when the fleet starts); [`Menu::local`]
    /// engines are `!Send` and are rejected at `serve_fleet`.
    pub fn register(mut self, name: impl Into<String>, menu: Menu) -> Self {
        self.registrations.push((name.into(), menu));
        self
    }

    /// Start one server over every registered menu: N models, each with
    /// its own compiled frontier and budget cell, behind **one** shared
    /// worker pool and bounded queue. Requests pick their model with
    /// [`InferRequest::model`] (optional when exactly one model is
    /// registered) and batches stay point-coherent per model. With
    /// [`ServerBuilder::envelope`] set, each model runs its own
    /// [`Governor`] and the global envelope is split across models by
    /// observed demand — a hot model degrades along its own frontier
    /// before starving a cold one (see [`super::registry`]).
    ///
    /// [`InferRequest::model`]: super::request::InferRequest::model
    pub fn serve_fleet(self) -> Result<Server> {
        let cfg = self.config;
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(RequestQueue::new(cfg.queue_depth, metrics.clone()));
        let registry = Arc::new(ModelRegistry::build(&cfg, self.registrations, Instant::now())?);
        // the fleet's "global" cell mirrors the last fleet-wide
        // set_budget for reporting; selection reads the per-model cells
        let budget_bits = Arc::new(AtomicU64::new(cfg.budget_gflips.to_bits()));
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for _ in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let registry = registry.clone();
            let metrics = metrics.clone();
            workers.push(std::thread::spawn(move || {
                fleet_worker(&queue, &registry, &metrics, cfg)
            }));
        }
        let client = Client {
            queue: queue.clone(),
            budget_bits,
            metrics,
            serving: Serving::Fleet(registry),
        };
        Ok(Server { client, queue, workers })
    }

    /// Start the server over `menu`. Blocks until the menu is built
    /// and validated (engine factories run first), so a returned
    /// `Server` is ready to serve.
    ///
    /// Single-model only: menus added with [`ServerBuilder::register`]
    /// are served by [`ServerBuilder::serve_fleet`] instead, and mixing
    /// the two is rejected.
    pub fn serve(self, menu: Menu) -> Result<Server> {
        anyhow::ensure!(
            self.registrations.is_empty(),
            "this builder has {} registered menu(s) — serve them with serve_fleet(), or drop \
             the register() calls to serve a single menu",
            self.registrations.len()
        );
        let cfg = self.config;
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(RequestQueue::new(cfg.queue_depth, metrics.clone()));
        let budget_bits = Arc::new(AtomicU64::new(cfg.budget_gflips.to_bits()));
        // deferred shared menus build their engines here, with the
        // configured max batch (they are just a Shared menu afterwards)
        let menu = match menu {
            Menu::SharedDeferred(build) => Menu::Shared(build(cfg.max_batch)?),
            other => other,
        };
        match menu {
            Menu::Shared(points) => {
                let sample_len = validate_menu(points.iter().map(|p| p.engine.sample_len()))?;
                let policy = Arc::new(PowerPolicy::new(points)?);
                let governor = build_governor(&cfg, policy.menu(), &budget_bits)?;
                let mut workers = Vec::with_capacity(cfg.workers);
                for _ in 0..cfg.workers.max(1) {
                    let queue = queue.clone();
                    let policy = policy.clone();
                    let metrics = metrics.clone();
                    let budget_bits = budget_bits.clone();
                    let governor = governor.clone();
                    workers.push(std::thread::spawn(move || {
                        pool_worker(&queue, &policy, &metrics, &budget_bits, &governor, cfg)
                    }));
                }
                let client = Client {
                    queue: queue.clone(),
                    budget_bits,
                    metrics,
                    serving: Serving::Single { sample_len, governor },
                };
                Ok(Server { client, queue, workers })
            }
            Menu::Local(factory) => {
                let (ready_tx, ready_rx) =
                    mpsc::channel::<Result<(usize, Option<Arc<Governor>>)>>();
                let wq = queue.clone();
                let wm = metrics.clone();
                let wb = budget_bits.clone();
                let worker = std::thread::spawn(move || {
                    // engines (and hence the menu the governor needs)
                    // can only be built on this thread — they may be
                    // `!Send`; the governor itself is shareable and is
                    // handed back through the ready channel
                    let startup = build_local(factory)
                        .and_then(|(policy, sample_len)| {
                            let governor = build_governor(&cfg, policy.menu(), &wb)?;
                            Ok((policy, sample_len, governor))
                        });
                    let mut state = match startup {
                        Ok((policy, sample_len, governor)) => {
                            let _ = ready_tx.send(Ok((sample_len, governor.clone())));
                            (policy, governor)
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    local_worker(&wq, &mut state.0, &wm, &wb, &state.1, cfg);
                });
                let (sample_len, governor) = ready_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("server worker died during startup"))??;
                let client = Client {
                    queue: queue.clone(),
                    budget_bits,
                    metrics,
                    serving: Serving::Single { sample_len, governor },
                };
                Ok(Server { client, queue, workers: vec![worker] })
            }
            Menu::SharedDeferred(_) => unreachable!("resolved to Menu::Shared above"),
        }
    }
}

/// Build the closed-loop governor when an envelope is configured
/// (`None` keeps the open-loop path untouched). `menu` is the
/// policy's `(name, cost)` listing, cheapest first, so the point
/// indices workers report to [`Governor::observe`] line up with the
/// policy's selection indices.
fn build_governor(
    cfg: &ServerConfig,
    menu: Vec<(String, f64)>,
    budget_bits: &Arc<AtomicU64>,
) -> Result<Option<Arc<Governor>>> {
    let Some(envelope) = cfg.envelope else {
        return Ok(None);
    };
    let gc = GovernorConfig {
        envelope,
        window: cfg.governor_window,
        hysteresis: cfg.governor_hysteresis,
        ledger_windows: GovernorConfig::DEFAULT_LEDGER_WINDOWS,
    };
    Ok(Some(Arc::new(Governor::new(
        gc,
        menu,
        budget_bits.clone(),
        Instant::now(),
    )?)))
}

/// Non-empty menu with one agreed sample length.
fn validate_menu(sample_lens: impl IntoIterator<Item = usize>) -> Result<usize> {
    let mut lens = sample_lens.into_iter();
    let first = lens.next().ok_or_else(|| anyhow::anyhow!("empty operating-point menu"))?;
    for l in lens {
        anyhow::ensure!(l == first, "menu sample lengths disagree: {l} vs {first}");
    }
    Ok(first)
}

fn build_local(
    factory: Box<dyn FnOnce() -> Result<Vec<EnginePoint>> + Send>,
) -> Result<(PowerPolicy<EnginePoint>, usize)> {
    let points = factory()?;
    let sample_len = validate_menu(points.iter().map(|p| p.engine.sample_len()))?;
    Ok((PowerPolicy::new(points)?, sample_len))
}

/// QoS classifier: pinned point by name, otherwise the best point
/// under `min(global budget, request cap)`.
fn classify_for<'a, P: Costed>(
    policy: &'a PowerPolicy<P>,
    budget_bits: &'a AtomicU64,
) -> impl FnMut(&Pending) -> Result<usize, ServeError> + 'a {
    move |p: &Pending| {
        if let Some(pin) = &p.pin {
            return policy
                .index_of(pin)
                .ok_or_else(|| ServeError::UnknownPoint(pin.clone()));
        }
        let global = f64::from_bits(budget_bits.load(Ordering::Relaxed));
        // reject a NaN global budget before the min: f64::min ignores
        // NaN operands, so a finite per-request cap would otherwise
        // mask it and identical servers would treat capped and
        // cap-less requests inconsistently
        if global.is_nan() {
            return Err(ServeError::BadBudget);
        }
        let budget = p.max_gflips.map_or(global, |cap| global.min(cap));
        policy.select(budget)
    }
}

/// Stops the queue when a worker unwinds (a panicking engine must not
/// leave queued tickets hanging and the client accepting doomed
/// requests); a normal worker exit only re-stops an already-stopped
/// queue.
struct StopQueueOnDrop<'a>(&'a RequestQueue);

impl Drop for StopQueueOnDrop<'_> {
    fn drop(&mut self) {
        self.0.stop();
    }
}

/// Pool worker: collect a point-coherent batch, execute it on the
/// shared engine with this worker's scratch.
fn pool_worker(
    queue: &RequestQueue,
    policy: &PowerPolicy<SharedPoint>,
    metrics: &Metrics,
    budget_bits: &AtomicU64,
    governor: &Option<Arc<Governor>>,
    cfg: ServerConfig,
) {
    let _guard = StopQueueOnDrop(queue);
    let mut scratch = Scratch::new();
    loop {
        let collected = {
            let mut classify = classify_for(policy, budget_bits);
            queue.collect(cfg.max_batch, cfg.max_wait, &mut classify)
        };
        let Some((batch, idx)) = collected else { break };
        let point = policy.point(idx);
        let eng = point.engine.as_ref();
        // bracket execution so the governor can tell "worker parked"
        // (idle, may climb) from "batch running" (not idle)
        let t_batch = Instant::now();
        if let Some(g) = governor {
            g.batch_started(t_batch);
        }
        respond_batch(
            None,
            &point.name,
            point.giga_flips_per_sample,
            eng.sample_len(),
            eng.max_batch(),
            batch,
            metrics,
            |n, gf, metered| {
                if let Some(g) = governor {
                    g.observe(Instant::now(), idx, n, gf, metered);
                }
            },
            |x, n| eng.infer_batch_metered(x, n, &mut scratch),
        );
        if let Some(g) = governor {
            g.batch_finished(t_batch);
        }
    }
}

/// Single worker owning a menu of boxed (possibly `!Send`) engines.
fn local_worker(
    queue: &RequestQueue,
    policy: &mut PowerPolicy<EnginePoint>,
    metrics: &Metrics,
    budget_bits: &AtomicU64,
    governor: &Option<Arc<Governor>>,
    cfg: ServerConfig,
) {
    let _guard = StopQueueOnDrop(queue);
    loop {
        let collected = {
            let mut classify = classify_for(&*policy, budget_bits);
            queue.collect(cfg.max_batch, cfg.max_wait, &mut classify)
        };
        let Some((batch, idx)) = collected else { break };
        let (name, gf) = {
            let p = policy.point(idx);
            (p.name.clone(), p.giga_flips_per_sample)
        };
        let eng = policy.point_mut(idx).engine.as_mut();
        let (sample_len, max_b) = (eng.sample_len(), eng.max_batch());
        let t_batch = Instant::now();
        if let Some(g) = governor {
            g.batch_started(t_batch);
        }
        respond_batch(
            None,
            &name,
            gf,
            sample_len,
            max_b,
            batch,
            metrics,
            |n, gf_obs, metered| {
                if let Some(g) = governor {
                    g.observe(Instant::now(), idx, n, gf_obs, metered);
                }
            },
            |x, n| eng.infer_metered(x, n),
        );
        if let Some(g) = governor {
            g.batch_finished(t_batch);
        }
    }
}

/// Fleet worker: like [`pool_worker`], but the classifier routes into
/// the registry's global point index space, so each collected batch
/// resolves to one `(model, point)` pair — executed on that model's
/// engine, metered into that model's governor and the fleet arbiter's
/// demand window.
fn fleet_worker(
    queue: &RequestQueue,
    registry: &Arc<ModelRegistry>,
    metrics: &Metrics,
    cfg: ServerConfig,
) {
    let _guard = StopQueueOnDrop(queue);
    let mut scratch = Scratch::new();
    loop {
        let collected = {
            let mut classify = |p: &Pending| registry.classify(p);
            queue.collect(cfg.max_batch, cfg.max_wait, &mut classify)
        };
        let Some((batch, global_idx)) = collected else { break };
        let (mi, pi) = registry.locate(global_idx);
        let model = registry.model(mi);
        let point = model.policy.point(pi);
        let eng = point.engine.as_ref();
        let t_batch = Instant::now();
        if let Some(g) = &model.governor {
            g.batch_started(t_batch);
        }
        respond_batch(
            Some(&model.name),
            &point.name,
            point.giga_flips_per_sample,
            eng.sample_len(),
            eng.max_batch(),
            batch,
            metrics,
            |n, gf, metered| registry.note_batch(Instant::now(), mi, pi, n, gf, metered),
            |x, n| eng.infer_batch_metered(x, n, &mut scratch),
        );
        if let Some(g) = &model.governor {
            g.batch_finished(t_batch);
        }
    }
}

/// What a [`Client`] fronts: one menu, or a registered fleet.
#[derive(Clone)]
enum Serving {
    /// Single-model server (`serve`): one sample length, at most one
    /// governor.
    Single { sample_len: usize, governor: Option<Arc<Governor>> },
    /// Fleet server (`serve_fleet`): models resolved by name.
    Fleet(Arc<ModelRegistry>),
}

/// Client handle: submit QoS-tagged requests, change the global
/// budget, read metrics. Cheap to clone; every clone feeds the same
/// server.
#[derive(Clone)]
pub struct Client {
    queue: Arc<RequestQueue>,
    budget_bits: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
    serving: Serving,
}

impl Client {
    /// Submit one request; returns the [`Ticket`] its result arrives
    /// on. Sheds immediately with [`ServeError::QueueFull`] when the
    /// bounded queue is at depth, and rejects inputs of the wrong
    /// length with [`ServeError::BadInput`]. On a fleet server the
    /// request's model name is resolved here (typed
    /// [`ServeError::UnknownModel`] / [`ServeError::ModelRequired`]
    /// rejections), so the hot path works on indices.
    pub fn submit(&self, req: InferRequest) -> Result<Ticket, ServeError> {
        let (model_idx, expected_len) = match &self.serving {
            Serving::Single { sample_len, .. } => {
                if let Some(name) = req.model {
                    // a single-model server has no registry to resolve
                    // names against — reject rather than silently serve
                    // a different network than the caller asked for
                    return Err(ServeError::UnknownModel(name));
                }
                (0, *sample_len)
            }
            Serving::Fleet(reg) => {
                let idx = match &req.model {
                    Some(name) => reg
                        .resolve(name)
                        .ok_or_else(|| ServeError::UnknownModel(name.clone()))?,
                    // a fleet of one routes unnamed requests to it, so
                    // single-menu CLI/workflows work unchanged; with
                    // several models there is no safe default
                    None if reg.n_models() == 1 => 0,
                    None => return Err(ServeError::ModelRequired),
                };
                (idx, reg.model(idx).sample_len)
            }
        };
        if req.input.len() != expected_len {
            return Err(ServeError::BadInput { expected: expected_len, got: req.input.len() });
        }
        // A NaN cap would vanish inside `f64::min` at classification
        // time (min ignores NaN operands) — reject it at admission.
        if req.max_gflips.is_some_and(f64::is_nan) {
            return Err(ServeError::BadBudget);
        }
        let (tx, rx) = mpsc::channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let now = Instant::now();
        self.queue.push(Pending {
            input: req.input,
            model: model_idx,
            submitted: now,
            deadline: req.deadline.map(|d| now + d),
            priority: req.priority,
            max_gflips: req.max_gflips,
            pin: req.pin,
            tag: req.tag,
            cancelled: cancelled.clone(),
            resp: tx,
        })?;
        Ok(Ticket { rx, cancelled, done: false })
    }

    /// Blocking convenience: submit with default QoS and wait.
    pub fn infer(&self, input: Vec<f32>) -> Result<Response, ServeError> {
        self.submit(InferRequest::new(input))?.wait()
    }

    /// Change the global per-sample energy budget at runtime — the
    /// paper's "traverse the power-accuracy trade-off at deployment
    /// time". Per-request `max_gflips` caps are applied *on top* of
    /// this (the scheduler selects under the minimum of the two). On a
    /// fleet server this moves **every** model's budget cell together
    /// (the fleet-wide traversal); [`Client::set_model_budget`] moves
    /// one model alone.
    ///
    /// When the server runs a closed-loop [`Governor`]
    /// ([`ServerBuilder::envelope`]), the governor starts each
    /// decision window from the point this cell selects — a manual
    /// budget is honored until load makes the governor step, at which
    /// point it rewrites the cell with a frontier point's exact cost.
    pub fn set_budget(&self, gflips: f64) {
        self.budget_bits.store(gflips.to_bits(), Ordering::Relaxed);
        if let Serving::Fleet(reg) = &self.serving {
            for i in 0..reg.n_models() {
                reg.model(i).budget_bits.store(gflips.to_bits(), Ordering::Relaxed);
            }
        }
    }

    /// Change one registered model's budget cell (fleet servers);
    /// returns `false` when no model by that name is registered (or on
    /// a single-model server, which has no named models).
    pub fn set_model_budget(&self, model: &str, gflips: f64) -> bool {
        let Serving::Fleet(reg) = &self.serving else {
            return false;
        };
        match reg.resolve(model) {
            Some(i) => {
                reg.model(i).budget_bits.store(gflips.to_bits(), Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// One registered model's current budget (Gflips/sample); `None`
    /// when unknown or on a single-model server.
    pub fn model_budget(&self, model: &str) -> Option<f64> {
        let Serving::Fleet(reg) = &self.serving else {
            return None;
        };
        reg.resolve(model)
            .map(|i| f64::from_bits(reg.model(i).budget_bits.load(Ordering::Relaxed)))
    }

    /// Re-target this server's closed-loop [`Governor`] to a new
    /// envelope rate (Gflips/sec) without rebuilding anything — the
    /// shard router ([`crate::net::ShardRouter`]) uses this to move a
    /// shard's slice of the cluster envelope as demand shifts between
    /// shards, exactly the way the fleet arbiter re-targets per-model
    /// governors. Returns `false` (no-op) when no single-model
    /// governor runs: open-loop servers have no governor, and on a
    /// fleet server the per-model envelopes are owned by the
    /// registry's arbiter — writing them from outside would fight it.
    pub fn set_envelope_rate(&self, gflips_per_sec: f64) -> bool {
        match &self.serving {
            Serving::Single { governor: Some(g), .. } => {
                g.set_envelope_rate(gflips_per_sec);
                true
            }
            _ => false,
        }
    }

    /// Snapshot of the closed-loop energy governor; `None` on an
    /// open-loop server (no [`ServerBuilder::envelope`] configured).
    /// On a fleet server each model has its *own* governor: a fleet of
    /// exactly one model answers with it (so single-menu workflows are
    /// unchanged), larger fleets answer `None` — use
    /// [`Client::model_governor`] / [`Client::fleet`] instead.
    pub fn governor(&self) -> Option<GovernorSnapshot> {
        match &self.serving {
            Serving::Single { governor, .. } => governor.as_ref().map(|g| g.snapshot()),
            Serving::Fleet(reg) if reg.n_models() == 1 => {
                reg.model(0).governor.as_ref().map(|g| g.snapshot())
            }
            Serving::Fleet(_) => None,
        }
    }

    /// One registered model's governor snapshot; `None` when unknown,
    /// open-loop, or on a single-model server (use [`Client::governor`]
    /// there).
    pub fn model_governor(&self, model: &str) -> Option<GovernorSnapshot> {
        let Serving::Fleet(reg) = &self.serving else {
            return None;
        };
        reg.resolve(model)
            .and_then(|i| reg.model(i).governor.as_ref().map(|g| g.snapshot()))
    }

    /// Registered model names, in registration order (empty on a
    /// single-model server).
    pub fn models(&self) -> Vec<String> {
        match &self.serving {
            Serving::Single { .. } => Vec::new(),
            Serving::Fleet(reg) => reg.model_names(),
        }
    }

    /// Whole-fleet snapshot — per-model budgets, demand estimates,
    /// envelope shares and governors; `None` on a single-model server.
    pub fn fleet(&self) -> Option<FleetSnapshot> {
        match &self.serving {
            Serving::Single { .. } => None,
            Serving::Fleet(reg) => Some(reg.snapshot()),
        }
    }

    /// The last fleet-wide/global budget written (Gflips/sample). On a
    /// fleet server individual model cells may have diverged via
    /// [`Client::set_model_budget`] or their governors — read those
    /// with [`Client::model_budget`].
    pub fn budget(&self) -> f64 {
        match &self.serving {
            Serving::Fleet(reg) if reg.n_models() == 1 => {
                // fleet-of-one: report the one real cell, which the
                // model's governor may be rewriting
                f64::from_bits(reg.model(0).budget_bits.load(Ordering::Relaxed))
            }
            _ => f64::from_bits(self.budget_bits.load(Ordering::Relaxed)),
        }
    }

    /// Point-in-time serving metrics (latency, energy, rejections).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Flattened per-sample input length the menu expects. On a fleet
    /// server models may disagree — this answers for the *first*
    /// registered model; use [`Client::sample_len_for`] per model.
    pub fn sample_len(&self) -> usize {
        match &self.serving {
            Serving::Single { sample_len, .. } => *sample_len,
            Serving::Fleet(reg) => reg.model(0).sample_len,
        }
    }

    /// Per-sample input length of one registered model; `None` when
    /// unknown or on a single-model server.
    pub fn sample_len_for(&self, model: &str) -> Option<usize> {
        let Serving::Fleet(reg) = &self.serving else {
            return None;
        };
        reg.resolve(model).map(|i| reg.model(i).sample_len)
    }

    /// Admission-control bound.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }
}

/// The server: one or more worker threads behind a [`Client`]. Built
/// via [`ServerBuilder`] (see the module docs for the two menu kinds).
pub struct Server {
    client: Client,
    queue: Arc<RequestQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Entry point: `Server::builder().workers(4)...serve(menu)`.
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// A handle feeding this server; cheap to clone.
    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Number of worker threads.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Stop accepting requests, drain what was admitted, join all
    /// workers. Clients then observe [`ServeError::ServerStopped`].
    pub fn shutdown(mut self) {
        self.queue.stop();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // a server dropped without `shutdown` still releases its
        // workers (they exit after draining; not joined here)
        self.queue.stop();
    }
}

/// Respond to one collected batch, splitting it across engine calls of
/// at most `max_b` samples. `infer` runs one sub-batch and reports the
/// energy it metered (`None` for meter-less backends); `on_energy` is
/// told, per executed chunk, `(samples, Gflips observed, metered?)` —
/// the governor's feed — *before* responses go out, so a client that
/// has its response never races a stale governor. `model` is the
/// registry name serving the batch (`None` on a single-model server):
/// it qualifies the metrics key — two models' same-named points must
/// not alias — and is echoed on every [`Response`].
#[allow(clippy::too_many_arguments)]
fn respond_batch<F>(
    model: Option<&str>,
    name: &str,
    gf_per_sample: f64,
    sample_len: usize,
    max_b: usize,
    batch: Vec<Pending>,
    metrics: &Metrics,
    mut on_energy: impl FnMut(u64, f64, bool),
    mut infer: F,
) where
    F: FnMut(&[f32], usize) -> Result<(Vec<f32>, Option<f64>)>,
{
    // last-moment check: skip requests whose ticket was dropped while
    // the batch was being assembled. Deadlines need no re-check here —
    // they gate dequeueing, and the collect fill-wait is capped by the
    // earliest deadline in the batch, so execution starts in time.
    let mut live = Vec::with_capacity(batch.len());
    for r in batch {
        if r.cancelled.load(Ordering::Relaxed) {
            metrics.record_cancelled();
        } else {
            live.push(r);
        }
    }
    let batch = live;
    let max_b = max_b.max(1);
    let mut start = 0;
    while start < batch.len() {
        let n = (batch.len() - start).min(max_b);
        let chunk = &batch[start..start + n];
        let mut flat = Vec::with_capacity(n * sample_len);
        for r in chunk {
            flat.extend_from_slice(&r.input);
        }
        match infer(&flat, n) {
            Ok((out, measured)) => {
                let ol = out.len() / n;
                let lats: Vec<(f64, Priority)> = chunk
                    .iter()
                    .map(|r| (r.submitted.elapsed().as_secs_f64() * 1e6, r.priority))
                    .collect();
                let batch_gf = if gf_per_sample.is_finite() {
                    gf_per_sample * n as f64
                } else {
                    0.0
                };
                // governor and metrics both update *before* responding
                // so a client that has its response always observes
                // them (and the governor's decision) as already made.
                // An unmetered infinite-cost point (fp32 on PJRT) is
                // reported as infinite energy: its modeled cost is
                // unbounded, so any load on it must breach any finite
                // envelope — charging the metrics convention of 0.0
                // would leave the governor blind at the most expensive
                // point.
                let observed = measured.unwrap_or(if gf_per_sample.is_finite() {
                    batch_gf
                } else {
                    f64::INFINITY
                });
                on_energy(n as u64, observed, measured.is_some());
                metrics.record_batch(model, name, &lats, batch_gf, measured);
                let measured_each = measured.map(|m| m / n as f64);
                for (i, r) in chunk.iter().enumerate() {
                    let _ = r.resp.send(Ok(Response {
                        output: out[i * ol..(i + 1) * ol].to_vec(),
                        model: model.map(str::to_string),
                        point: name.to_string(),
                        latency: Duration::from_secs_f64(lats[i].0 * 1e-6),
                        giga_flips: if gf_per_sample.is_finite() { gf_per_sample } else { 0.0 },
                        measured_gflips: measured_each,
                        tag: r.tag.clone(),
                    }));
                }
            }
            Err(e) => {
                metrics.record_engine_failure();
                let msg = format!("{e:#}");
                eprintln!("serve error on {name}: {msg}");
                for r in chunk {
                    let _ = r.resp.send(Err(ServeError::Engine(msg.clone())));
                }
            }
        }
        start += n;
    }
}

/// Mock engines for unit tests.
#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
pub(crate) mod tests_support {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Echo-sum engine: out[j] = sum(input) + j.
    pub struct MockEngine {
        pub max_b: usize,
        pub in_len: usize,
        pub out_len: usize,
    }

    impl MockEngine {
        pub fn new(max_b: usize, in_len: usize, out_len: usize) -> Self {
            MockEngine { max_b, in_len, out_len }
        }

        fn compute(&self, x: &[f32], n: usize) -> Vec<f32> {
            let mut out = Vec::with_capacity(n * self.out_len);
            for i in 0..n {
                let s: f32 = x[i * self.in_len..(i + 1) * self.in_len].iter().sum();
                for j in 0..self.out_len {
                    out.push(s + j as f32);
                }
            }
            out
        }
    }

    impl Engine for MockEngine {
        fn max_batch(&self) -> usize {
            self.max_b
        }
        fn sample_len(&self) -> usize {
            self.in_len
        }
        fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>> {
            Ok(self.compute(x, n))
        }
    }

    impl BatchEngine for MockEngine {
        fn max_batch(&self) -> usize {
            self.max_b
        }
        fn sample_len(&self) -> usize {
            self.in_len
        }
        fn infer_batch(&self, x: &[f32], n: usize, _scratch: &mut Scratch) -> Result<Vec<f32>> {
            Ok(self.compute(x, n))
        }
    }

    /// Shared observability for [`GateEngine`]s.
    #[derive(Clone, Default)]
    pub struct Gate {
        /// Engines block in `infer` until this is set.
        pub release: Arc<AtomicBool>,
        /// Number of engine calls entered (incl. currently blocked).
        pub entered: Arc<AtomicUsize>,
        /// First element of every sample executed, in service order.
        pub served: Arc<Mutex<Vec<f32>>>,
    }

    impl Gate {
        pub fn new() -> Gate {
            Gate::default()
        }

        pub fn open(&self) {
            self.release.store(true, Ordering::SeqCst);
        }

        /// Spin until `n` engine calls have been entered.
        pub fn wait_entered(&self, n: usize) {
            let t0 = Instant::now();
            while self.entered.load(Ordering::SeqCst) < n {
                assert!(t0.elapsed() < Duration::from_secs(5), "gate wait timed out");
                std::thread::sleep(Duration::from_micros(200));
            }
        }

        pub fn served(&self) -> Vec<f32> {
            self.served.lock().unwrap().clone()
        }
    }

    /// MockEngine that blocks inside `infer` until its gate opens —
    /// for stalled-worker tests (queue-full shedding, deadline expiry,
    /// cancellation, priority draining).
    pub struct GateEngine {
        pub inner: MockEngine,
        pub gate: Gate,
    }

    impl GateEngine {
        pub fn new(max_b: usize, in_len: usize, out_len: usize, gate: Gate) -> Self {
            GateEngine { inner: MockEngine::new(max_b, in_len, out_len), gate }
        }

        fn run(&self, x: &[f32], n: usize) -> Vec<f32> {
            self.gate.entered.fetch_add(1, Ordering::SeqCst);
            let t0 = Instant::now();
            while !self.gate.release.load(Ordering::SeqCst) {
                assert!(t0.elapsed() < Duration::from_secs(5), "gate never opened");
                std::thread::sleep(Duration::from_micros(200));
            }
            let mut served = self.gate.served.lock().unwrap();
            for i in 0..n {
                served.push(x[i * self.inner.in_len]);
            }
            drop(served);
            self.inner.compute(x, n)
        }
    }

    impl Engine for GateEngine {
        fn max_batch(&self) -> usize {
            self.inner.max_b
        }
        fn sample_len(&self) -> usize {
            self.inner.in_len
        }
        fn infer(&mut self, x: &[f32], n: usize) -> Result<Vec<f32>> {
            Ok(self.run(x, n))
        }
    }

    impl BatchEngine for GateEngine {
        fn max_batch(&self) -> usize {
            self.inner.max_b
        }
        fn sample_len(&self) -> usize {
            self.inner.in_len
        }
        fn infer_batch(&self, x: &[f32], n: usize, _scratch: &mut Scratch) -> Result<Vec<f32>> {
            Ok(self.run(x, n))
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::tests_support::{Gate, GateEngine, MockEngine};
    use super::*;

    #[test]
    fn plan_engine_meter_pool_recovers_from_poison() {
        use crate::nn::{Model, QuantConfig};
        use crate::quant::ActQuantMethod;
        let mut model = Model::reference_cnn(7);
        let x = crate::nn::Tensor::zeros(vec![2, 1, 16, 16]);
        model.record_act_stats(&x).unwrap();
        let plan = Arc::new(
            ExecutionPlan::compile(
                &model,
                QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats),
                None,
            )
            .unwrap(),
        );
        let engine = PlanEngine::new(plan, 4);
        let mut scratch = Scratch::new();
        let input = vec![0.0f32; engine.sample_len()];
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _pool = engine.meters.lock().unwrap();
            panic!("poison the meter pool");
        }));
        assert!(engine.meters.lock().is_err(), "meter pool must be poisoned");
        // inference recovers the pool instead of panicking the worker
        let (out, measured) = engine.infer_batch_metered(&input, 1, &mut scratch).unwrap();
        assert_eq!(out.len(), 10);
        assert!(measured.unwrap() > 0.0, "the recovered meter still meters");
    }

    fn points() -> Vec<EnginePoint> {
        vec![
            EnginePoint {
                name: "cheap".into(),
                giga_flips_per_sample: 0.1,
                engine: Box::new(MockEngine::new(4, 3, 2)),
            },
            EnginePoint {
                name: "rich".into(),
                giga_flips_per_sample: 0.9,
                engine: Box::new(MockEngine::new(4, 3, 2)),
            },
        ]
    }

    fn shared_points() -> Vec<SharedPoint> {
        vec![
            SharedPoint {
                measured_gflips_per_sample: None,
                name: "cheap".into(),
                giga_flips_per_sample: 0.1,
                engine: Arc::new(MockEngine::new(4, 3, 2)),
            },
            SharedPoint {
                measured_gflips_per_sample: None,
                name: "rich".into(),
                giga_flips_per_sample: 0.9,
                engine: Arc::new(MockEngine::new(4, 3, 2)),
            },
        ]
    }

    /// Both gated points share one `Gate`, so a single worker can be
    /// stalled deterministically while requests pile up behind it.
    fn gated_points(gate: &Gate) -> Vec<SharedPoint> {
        vec![
            SharedPoint {
                measured_gflips_per_sample: None,
                name: "cheap".into(),
                giga_flips_per_sample: 0.1,
                engine: Arc::new(GateEngine::new(4, 3, 2, gate.clone())),
            },
            SharedPoint {
                measured_gflips_per_sample: None,
                name: "rich".into(),
                giga_flips_per_sample: 0.9,
                engine: Arc::new(GateEngine::new(4, 3, 2, gate.clone())),
            },
        ]
    }

    #[test]
    fn serves_and_responds_local() {
        let srv = ServerBuilder::new()
            .budget_gflips(1.0)
            .serve(Menu::local(|| Ok(points())))
            .unwrap();
        let c = srv.client();
        assert_eq!(c.sample_len(), 3);
        let r = c.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(r.output, vec![6.0, 7.0]);
        assert_eq!(r.point, "rich");
        assert_eq!(r.tag, None);
        // open-loop server: no governor, and mock engines meter nothing
        assert!(c.governor().is_none());
        assert_eq!(r.measured_gflips, None);
        srv.shutdown();
    }

    #[test]
    fn envelope_governor_degrades_under_load_and_recovers_when_idle() {
        // cheap = 0.1, rich = 0.9 GF/sample (modeled; mocks meter
        // nothing, so the governor runs on the modeled fallback).
        // Envelope 10 GF/s over 5 ms windows = 0.05 GF/window: a
        // single rich request breaches, so sustained load must walk
        // the served point down; an idle gap must climb back.
        let srv = ServerBuilder::new()
            .workers(1)
            .max_batch(1)
            .max_wait(Duration::from_micros(100))
            .envelope(EnergyEnvelope::gflips_per_sec(10.0))
            .governor_window(Duration::from_millis(5))
            .governor_hysteresis(1)
            .serve(Menu::shared(shared_points()))
            .unwrap();
        let c = srv.client();
        // the governor normalized the (infinite) default budget to the
        // most accurate point's exact cost
        assert_eq!(c.budget(), 0.9);
        assert!(c.governor().is_some());
        // sustained load: the served point must degrade to "cheap"
        let t0 = Instant::now();
        let mut degraded = false;
        while t0.elapsed() < Duration::from_secs(10) {
            if c.infer(vec![0.0; 3]).unwrap().point == "cheap" {
                degraded = true;
                break;
            }
        }
        assert!(degraded, "governor never stepped down under sustained load");
        // idle gap, then two probes: the first closes the idle windows
        // (climbing back), the second is served at the top again.
        // timing-sensitive: the gap must cover >= hysteresis * window
        // per climb step even on a loaded CI box, hence the slack
        // (deterministic coverage of the same walk lives in the
        // injected-clock governor tests and tests/scenarios.rs)
        std::thread::sleep(Duration::from_millis(100));
        let _ = c.infer(vec![0.0; 3]).unwrap();
        let r = c.infer(vec![0.0; 3]).unwrap();
        assert_eq!(r.point, "rich", "idle period must climb back to the accurate point");
        let g = c.governor().unwrap();
        assert!(g.switches >= 2, "expected at least one down + one up step, got {}", g.switches);
        assert!(g.windows >= 2);
        srv.shutdown();
    }

    #[test]
    fn bad_envelope_is_startup_error() {
        for bad in [f64::NAN, 0.0, -3.0, f64::INFINITY] {
            let e = ServerBuilder::new()
                .envelope(EnergyEnvelope::gflips_per_sec(bad))
                .serve(Menu::shared(shared_points()))
                .unwrap_err();
            assert!(e.to_string().contains("envelope"), "{e}");
        }
    }

    #[test]
    fn budget_traversal_switches_point() {
        let srv = ServerBuilder::new()
            .budget_gflips(1.0)
            .serve(Menu::local(|| Ok(points())))
            .unwrap();
        let c = srv.client();
        assert_eq!(c.infer(vec![0.0; 3]).unwrap().point, "rich");
        c.set_budget(0.2);
        assert_eq!(c.infer(vec![0.0; 3]).unwrap().point, "cheap");
        c.set_budget(5.0);
        assert_eq!(c.infer(vec![0.0; 3]).unwrap().point, "rich");
        let m = c.metrics();
        assert_eq!(m.requests, 3);
        srv.shutdown();
    }

    #[test]
    fn per_request_cap_beats_global_budget() {
        let srv = ServerBuilder::new()
            .budget_gflips(1.0)
            .serve(Menu::local(|| Ok(points())))
            .unwrap();
        let c = srv.client();
        let r = c
            .submit(InferRequest::new(vec![0.0; 3]).max_gflips(0.2))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.point, "cheap");
        // no cap: global budget alone
        assert_eq!(c.infer(vec![0.0; 3]).unwrap().point, "rich");
        srv.shutdown();
    }

    #[test]
    fn pinned_point_bypasses_policy_and_unknown_pin_is_typed() {
        let srv = ServerBuilder::new()
            .budget_gflips(1.0)
            .serve(Menu::local(|| Ok(points())))
            .unwrap();
        let c = srv.client();
        let r = c
            .submit(InferRequest::new(vec![0.0; 3]).pin_point("cheap"))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.point, "cheap");
        let e = c
            .submit(InferRequest::new(vec![0.0; 3]).pin_point("nope"))
            .unwrap()
            .wait()
            .unwrap_err();
        assert_eq!(e, ServeError::UnknownPoint("nope".into()));
        srv.shutdown();
    }

    #[test]
    fn tag_echoed_on_response() {
        let srv = ServerBuilder::new().serve(Menu::local(|| Ok(points()))).unwrap();
        let c = srv.client();
        let r = c
            .submit(InferRequest::new(vec![0.0; 3]).tag("trace-7"))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.tag.as_deref(), Some("trace-7"));
        srv.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let srv = ServerBuilder::new().serve(Menu::local(|| Ok(points()))).unwrap();
        let c = srv.client();
        let mut joins = Vec::new();
        for t in 0..8 {
            let c = c.clone();
            joins.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25 {
                    let v = (t * 100 + i) as f32;
                    let r = c.infer(vec![v, 0.0, 0.0]).unwrap();
                    assert_eq!(r.output[0], v);
                    ok += 1;
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 200);
        let m = c.metrics();
        assert_eq!(m.requests, 200);
        assert!(m.batches <= 200);
        srv.shutdown();
    }

    #[test]
    fn rejects_bad_input_length_typed() {
        let srv = ServerBuilder::new().serve(Menu::local(|| Ok(points()))).unwrap();
        let c = srv.client();
        let e = c.submit(InferRequest::new(vec![1.0])).unwrap_err();
        assert_eq!(e, ServeError::BadInput { expected: 3, got: 1 });
        srv.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_server_stopped() {
        let srv = ServerBuilder::new().serve(Menu::shared(shared_points())).unwrap();
        let c = srv.client();
        let _ = c.infer(vec![0.0; 3]).unwrap();
        srv.shutdown();
        assert_eq!(
            c.submit(InferRequest::new(vec![0.0; 3])).unwrap_err(),
            ServeError::ServerStopped
        );
    }

    #[test]
    fn oversized_batches_split_across_engine_calls() {
        // engine max_batch = 4, server max_batch = 16: a burst of 10
        // must still produce 10 correct responses.
        let srv = ServerBuilder::new()
            .max_batch(16)
            .max_wait(Duration::from_millis(30))
            .budget_gflips(1.0)
            .serve(Menu::local(|| Ok(points())))
            .unwrap();
        let c = srv.client();
        let tickets: Vec<_> = (0..10)
            .map(|i| c.submit(InferRequest::new(vec![i as f32, 0.0, 0.0])).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().output[0], i as f32);
        }
        srv.shutdown();
    }

    #[test]
    fn empty_menu_is_startup_error() {
        assert!(ServerBuilder::new().serve(Menu::shared(Vec::new())).is_err());
        assert!(ServerBuilder::new().serve(Menu::local(|| Ok(Vec::new()))).is_err());
    }

    #[test]
    fn nan_cost_menu_is_startup_error() {
        let bad = vec![SharedPoint {
            measured_gflips_per_sample: None,
            name: "nan".into(),
            giga_flips_per_sample: f64::NAN,
            engine: Arc::new(MockEngine::new(4, 3, 2)),
        }];
        let e = ServerBuilder::new().serve(Menu::shared(bad)).unwrap_err();
        assert!(e.to_string().contains("NaN"), "{e}");
    }

    #[test]
    fn nan_budgets_rejected_not_silently_served() {
        let srv = ServerBuilder::new()
            .budget_gflips(1.0)
            .serve(Menu::local(|| Ok(points())))
            .unwrap();
        let c = srv.client();
        // NaN per-request cap: rejected at admission
        let e = c
            .submit(InferRequest::new(vec![0.0; 3]).max_gflips(f64::NAN))
            .unwrap_err();
        assert_eq!(e, ServeError::BadBudget);
        // NaN global budget: typed rejection at scheduling (the seed
        // silently served the cheapest point)
        c.set_budget(f64::NAN);
        let e = c.infer(vec![0.0; 3]).unwrap_err();
        assert_eq!(e, ServeError::BadBudget);
        // ... and a finite per-request cap must not mask it (f64::min
        // would swallow the NaN operand)
        let e = c
            .submit(InferRequest::new(vec![0.0; 3]).max_gflips(0.5))
            .unwrap()
            .wait()
            .unwrap_err();
        assert_eq!(e, ServeError::BadBudget);
        // recovery: a sane budget serves again
        c.set_budget(1.0);
        assert_eq!(c.infer(vec![0.0; 3]).unwrap().point, "rich");
        srv.shutdown();
    }

    #[test]
    fn pool_serves_and_responds() {
        let srv = ServerBuilder::new()
            .workers(4)
            .budget_gflips(1.0)
            .serve(Menu::shared(shared_points()))
            .unwrap();
        assert_eq!(srv.n_workers(), 4);
        let c = srv.client();
        let r = c.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(r.output, vec![6.0, 7.0]);
        assert_eq!(r.point, "rich");
        srv.shutdown();
    }

    #[test]
    fn pool_budget_traversal_switches_point() {
        let srv = ServerBuilder::new()
            .workers(3)
            .budget_gflips(1.0)
            .serve(Menu::shared(shared_points()))
            .unwrap();
        let c = srv.client();
        assert_eq!(c.infer(vec![0.0; 3]).unwrap().point, "rich");
        c.set_budget(0.2);
        assert_eq!(c.infer(vec![0.0; 3]).unwrap().point, "cheap");
        c.set_budget(5.0);
        assert_eq!(c.infer(vec![0.0; 3]).unwrap().point, "rich");
        srv.shutdown();
    }

    #[test]
    fn pool_concurrent_clients_all_served() {
        let srv = ServerBuilder::new()
            .workers(4)
            .serve(Menu::shared(shared_points()))
            .unwrap();
        let c = srv.client();
        let mut joins = Vec::new();
        for t in 0..8 {
            let c = c.clone();
            joins.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25 {
                    let v = (t * 100 + i) as f32;
                    let r = c.infer(vec![v, 0.0, 0.0]).unwrap();
                    assert_eq!(r.output[0], v);
                    ok += 1;
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 200);
        assert_eq!(c.metrics().requests, 200);
        srv.shutdown();
    }

    #[test]
    fn pool_shutdown_stops_every_worker() {
        let srv = ServerBuilder::new()
            .workers(5)
            .serve(Menu::shared(shared_points()))
            .unwrap();
        let c = srv.client();
        let _ = c.infer(vec![0.0; 3]).unwrap();
        srv.shutdown(); // joins all 5 workers; hangs here if one is lost
        assert!(c.submit(InferRequest::new(vec![0.0; 3])).is_err());
    }

    // --- the new failure surface, under a deterministically stalled worker ---

    #[test]
    fn queue_full_sheds_under_stalled_worker() {
        let gate = Gate::new();
        let srv = ServerBuilder::new()
            .workers(1)
            .queue_depth(2)
            .max_batch(1)
            .max_wait(Duration::from_micros(100))
            .budget_gflips(1.0)
            .serve(Menu::shared(gated_points(&gate)))
            .unwrap();
        let c = srv.client();
        let t1 = c.submit(InferRequest::new(vec![1.0, 0.0, 0.0])).unwrap();
        gate.wait_entered(1); // worker now blocked inside the engine
        let t2 = c.submit(InferRequest::new(vec![2.0, 0.0, 0.0])).unwrap();
        let t3 = c.submit(InferRequest::new(vec![3.0, 0.0, 0.0])).unwrap();
        let e = c.submit(InferRequest::new(vec![4.0, 0.0, 0.0])).unwrap_err();
        assert_eq!(e, ServeError::QueueFull { depth: 2 });
        gate.open();
        for t in [t1, t2, t3] {
            t.wait().unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.shed, 1);
        assert_eq!(m.requests, 3);
        srv.shutdown();
    }

    #[test]
    fn expired_request_rejected_without_execution() {
        let gate = Gate::new();
        let srv = ServerBuilder::new()
            .workers(1)
            .max_batch(1)
            .max_wait(Duration::from_micros(100))
            .budget_gflips(1.0)
            .serve(Menu::shared(gated_points(&gate)))
            .unwrap();
        let c = srv.client();
        let t1 = c.submit(InferRequest::new(vec![1.0, 0.0, 0.0])).unwrap();
        gate.wait_entered(1);
        // an already-elapsed deadline expires deterministically the
        // moment the scheduler reaches the queued request — no sleep,
        // no race against the wall clock
        let t2 = c
            .submit(InferRequest::new(vec![2.0, 0.0, 0.0]).deadline(Duration::ZERO))
            .unwrap();
        gate.open();
        t1.wait().unwrap();
        assert_eq!(t2.wait().unwrap_err(), ServeError::DeadlineExceeded);
        // the expired request never reached an engine
        assert!(!gate.served().contains(&2.0));
        assert_eq!(c.metrics().expired, 1);
        srv.shutdown();
    }

    #[test]
    fn dropped_ticket_cancels_queued_request() {
        let gate = Gate::new();
        let srv = ServerBuilder::new()
            .workers(1)
            .max_batch(1)
            .max_wait(Duration::from_micros(100))
            .budget_gflips(1.0)
            .serve(Menu::shared(gated_points(&gate)))
            .unwrap();
        let c = srv.client();
        let t1 = c.submit(InferRequest::new(vec![1.0, 0.0, 0.0])).unwrap();
        gate.wait_entered(1);
        let t2 = c.submit(InferRequest::new(vec![2.0, 0.0, 0.0])).unwrap();
        drop(t2); // cancel while still queued
        gate.open();
        t1.wait().unwrap();
        // a later request still flows; the cancelled one never executed
        let r3 = c.infer(vec![3.0, 0.0, 0.0]).unwrap();
        assert_eq!(r3.output[0], 3.0);
        assert_eq!(gate.served(), vec![1.0, 3.0]);
        assert_eq!(c.metrics().cancelled, 1);
        srv.shutdown();
    }

    #[test]
    fn mixed_queue_splits_by_per_request_cap() {
        // global budget allows "rich"; a capped request queued in the
        // same window must be served by "cheap" instead, in its own
        // point-coherent batch.
        let gate = Gate::new();
        let srv = ServerBuilder::new()
            .workers(1)
            .max_batch(8)
            .max_wait(Duration::from_micros(100))
            .budget_gflips(1.0)
            .serve(Menu::shared(gated_points(&gate)))
            .unwrap();
        let c = srv.client();
        let t1 = c.submit(InferRequest::new(vec![1.0, 0.0, 0.0])).unwrap();
        gate.wait_entered(1);
        let capped = c
            .submit(InferRequest::new(vec![2.0, 0.0, 0.0]).max_gflips(0.2))
            .unwrap();
        let uncapped = c.submit(InferRequest::new(vec![3.0, 0.0, 0.0])).unwrap();
        gate.open();
        assert_eq!(t1.wait().unwrap().point, "rich");
        assert_eq!(capped.wait().unwrap().point, "cheap");
        assert_eq!(uncapped.wait().unwrap().point, "rich");
        srv.shutdown();
    }

    #[test]
    fn higher_priority_drains_first() {
        let gate = Gate::new();
        let srv = ServerBuilder::new()
            .workers(1)
            .max_batch(1)
            .max_wait(Duration::from_micros(100))
            .budget_gflips(1.0)
            .serve(Menu::shared(gated_points(&gate)))
            .unwrap();
        let c = srv.client();
        let t1 = c.submit(InferRequest::new(vec![1.0, 0.0, 0.0])).unwrap();
        gate.wait_entered(1);
        let low = c
            .submit(InferRequest::new(vec![10.0, 0.0, 0.0]).priority(Priority::BestEffort))
            .unwrap();
        let hi = c
            .submit(InferRequest::new(vec![20.0, 0.0, 0.0]).priority(Priority::Hi))
            .unwrap();
        gate.open();
        t1.wait().unwrap();
        hi.wait().unwrap();
        low.wait().unwrap();
        // Hi was submitted after BestEffort but executed first
        assert_eq!(gate.served(), vec![1.0, 20.0, 10.0]);
        srv.shutdown();
    }

    // --- fleet serving (ServerBuilder::register + serve_fleet) ---

    /// Two registered models with *identical point names* but distinct
    /// costs and sample lengths, so aliasing anywhere shows up fast.
    fn fleet_regs() -> Vec<(String, Menu)> {
        let menu_a = Menu::shared(vec![
            SharedPoint {
                measured_gflips_per_sample: None,
                name: "cheap".into(),
                giga_flips_per_sample: 0.1,
                engine: Arc::new(MockEngine::new(4, 3, 2)),
            },
            SharedPoint {
                measured_gflips_per_sample: None,
                name: "rich".into(),
                giga_flips_per_sample: 0.9,
                engine: Arc::new(MockEngine::new(4, 3, 2)),
            },
        ]);
        let menu_b = Menu::shared(vec![
            SharedPoint {
                measured_gflips_per_sample: None,
                name: "cheap".into(),
                giga_flips_per_sample: 0.2,
                engine: Arc::new(MockEngine::new(4, 5, 3)),
            },
            SharedPoint {
                measured_gflips_per_sample: None,
                name: "rich".into(),
                giga_flips_per_sample: 2.0,
                engine: Arc::new(MockEngine::new(4, 5, 3)),
            },
        ]);
        vec![("a".to_string(), menu_a), ("b".to_string(), menu_b)]
    }

    fn fleet_builder() -> ServerBuilder {
        let mut b = ServerBuilder::new().workers(2).budget_gflips(5.0);
        for (name, menu) in fleet_regs() {
            b = b.register(name, menu);
        }
        b
    }

    #[test]
    fn fleet_routes_by_model_and_checks_per_model_input_len() {
        let srv = fleet_builder().serve_fleet().unwrap();
        let c = srv.client();
        assert_eq!(c.models(), vec!["a", "b"]);
        assert_eq!(c.sample_len_for("a"), Some(3));
        assert_eq!(c.sample_len_for("b"), Some(5));
        let ra = c
            .submit(InferRequest::new(vec![1.0, 2.0, 3.0]).model("a"))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(ra.output, vec![6.0, 7.0]);
        assert_eq!(ra.model.as_deref(), Some("a"));
        assert_eq!(ra.point, "rich");
        let rb = c
            .submit(InferRequest::new(vec![1.0; 5]).model("b"))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(rb.output, vec![5.0, 6.0, 7.0]);
        assert_eq!(rb.model.as_deref(), Some("b"));
        assert_eq!(rb.point, "rich");
        // typed routing failures
        assert_eq!(
            c.submit(InferRequest::new(vec![0.0; 3]).model("nope")).unwrap_err(),
            ServeError::UnknownModel("nope".into())
        );
        assert_eq!(
            c.submit(InferRequest::new(vec![0.0; 3])).unwrap_err(),
            ServeError::ModelRequired
        );
        // input length is checked against the *request's* model
        assert_eq!(
            c.submit(InferRequest::new(vec![0.0; 3]).model("b")).unwrap_err(),
            ServeError::BadInput { expected: 5, got: 3 }
        );
        srv.shutdown();
    }

    #[test]
    fn fleet_metrics_key_by_model_so_same_point_names_cannot_alias() {
        // The registry-mode aliasing bugfix: both menus name their
        // points "cheap"/"rich"; per-point counters must stay separate.
        let srv = fleet_builder().serve_fleet().unwrap();
        let c = srv.client();
        for _ in 0..2 {
            c.submit(InferRequest::new(vec![0.0; 3]).model("a")).unwrap().wait().unwrap();
        }
        c.submit(InferRequest::new(vec![0.0; 5]).model("b")).unwrap().wait().unwrap();
        let m = c.metrics();
        let per: std::collections::BTreeMap<_, _> = m.per_point.iter().cloned().collect();
        assert_eq!(per.get("a:rich"), Some(&2));
        assert_eq!(per.get("b:rich"), Some(&1));
        assert!(
            !per.contains_key("rich"),
            "fleet metrics must be model-qualified, got {per:?}"
        );
        srv.shutdown();
    }

    #[test]
    fn fleet_per_model_budget_traversal_and_pins() {
        let srv = fleet_builder().serve_fleet().unwrap();
        let c = srv.client();
        // fleet-wide traversal moves both models
        c.set_budget(0.15);
        let ra = c.submit(InferRequest::new(vec![0.0; 3]).model("a")).unwrap().wait().unwrap();
        let rb = c.submit(InferRequest::new(vec![0.0; 5]).model("b")).unwrap().wait().unwrap();
        assert_eq!(ra.point, "cheap");
        assert_eq!(rb.point, "cheap"); // 0.15 < 0.2 -> falls back to cheapest
        // per-model budget moves one model only
        assert!(c.set_model_budget("b", 5.0));
        assert!(!c.set_model_budget("nope", 5.0));
        assert_eq!(c.model_budget("b"), Some(5.0));
        assert_eq!(c.model_budget("a"), Some(0.15));
        let ra = c.submit(InferRequest::new(vec![0.0; 3]).model("a")).unwrap().wait().unwrap();
        let rb = c.submit(InferRequest::new(vec![0.0; 5]).model("b")).unwrap().wait().unwrap();
        assert_eq!(ra.point, "cheap", "model a's budget untouched");
        assert_eq!(rb.point, "rich", "model b's budget raised alone");
        // pins resolve against the request's model
        let r = c
            .submit(InferRequest::new(vec![0.0; 3]).model("a").pin_point("rich"))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!((r.model.as_deref(), r.point.as_str()), (Some("a"), "rich"));
        srv.shutdown();
    }

    #[test]
    fn fleet_of_one_serves_unnamed_requests() {
        let (name, menu) = fleet_regs().remove(0);
        let srv = ServerBuilder::new().register(name, menu).serve_fleet().unwrap();
        let c = srv.client();
        let r = c.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(r.output, vec![6.0, 7.0]);
        assert_eq!(r.model.as_deref(), Some("a"));
        srv.shutdown();
    }

    #[test]
    fn single_model_server_rejects_model_field_and_mixed_builders() {
        let srv = ServerBuilder::new().serve(Menu::shared(shared_points())).unwrap();
        let c = srv.client();
        assert_eq!(
            c.submit(InferRequest::new(vec![0.0; 3]).model("a")).unwrap_err(),
            ServeError::UnknownModel("a".into())
        );
        // fleet-only accessors answer None/empty on a single-model server
        assert!(c.models().is_empty());
        assert!(c.fleet().is_none());
        assert_eq!(c.model_budget("a"), None);
        assert!(c.model_governor("a").is_none());
        srv.shutdown();
        // register + serve(menu) is a typed startup error
        let (name, menu) = fleet_regs().remove(0);
        let e = ServerBuilder::new()
            .register(name, menu)
            .serve(Menu::shared(shared_points()))
            .unwrap_err();
        assert!(e.to_string().contains("serve_fleet"), "{e}");
        // serve_fleet without registrations is a typed startup error
        assert!(ServerBuilder::new().serve_fleet().is_err());
    }

    #[test]
    fn fleet_envelope_starves_hot_model_before_cold_one() {
        // Model "hot" floods; model "cold" trickles. One shared
        // envelope: hot must walk ITS frontier down while cold keeps
        // serving its most accurate point.
        let menu = |cheap: f64, rich: f64, in_len: usize| {
            Menu::shared(vec![
                SharedPoint {
                    measured_gflips_per_sample: None,
                    name: "cheap".into(),
                    giga_flips_per_sample: cheap,
                    engine: Arc::new(MockEngine::new(8, in_len, 2)),
                },
                SharedPoint {
                    measured_gflips_per_sample: None,
                    name: "rich".into(),
                    giga_flips_per_sample: rich,
                    engine: Arc::new(MockEngine::new(8, in_len, 2)),
                },
            ])
        };
        let srv = ServerBuilder::new()
            .workers(2)
            .max_batch(4)
            .max_wait(Duration::from_micros(100))
            .envelope(EnergyEnvelope::gflips_per_sec(50.0))
            .governor_window(Duration::from_millis(5))
            .governor_hysteresis(1)
            // cold's whole frontier is ~4 orders cheaper than hot's
            // rich point, so even an aggressive probe rate keeps cold's
            // demand-need far inside the envelope while hot blows it
            .register("hot", menu(0.1, 10.0, 3))
            .register("cold", menu(0.0001, 0.001, 3))
            .serve_fleet()
            .unwrap();
        let c = srv.client();
        // flood "hot" from this thread until it degrades (the envelope
        // cannot sustain 10 GF/sample at any realistic rate); "cold"
        // stays idle throughout — its governor must not move
        let t0 = Instant::now();
        let mut hot_degraded = false;
        while t0.elapsed() < Duration::from_secs(20) {
            let rh = c
                .submit(InferRequest::new(vec![0.0; 3]).model("hot"))
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(rh.model.as_deref(), Some("hot"));
            if rh.point == "cheap" {
                hot_degraded = true;
                break;
            }
        }
        assert!(hot_degraded, "hot model never degraded under flood");
        // cold requests — paced no tighter than the governor window,
        // so a window can never hold more load than the share floor
        // covers — keep being served at cold's most accurate point
        let mut cold_points = Vec::new();
        for _ in 0..3 {
            let rc = c
                .submit(InferRequest::new(vec![0.0; 3]).model("cold"))
                .unwrap()
                .wait()
                .unwrap();
            cold_points.push(rc.point);
            // timing-sensitive: the pacing sleep must be >= the
            // governor window for the share-floor argument to hold
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(
            cold_points.iter().all(|p| p == "rich"),
            "cold model must keep its most accurate point, got {cold_points:?}"
        );
        let gh = c.model_governor("hot").expect("hot governor");
        let gc = c.model_governor("cold").expect("cold governor");
        assert!(gh.switches >= 1, "hot governor must have stepped");
        assert_eq!(gc.level, 1, "cold governor must still sit at its top point");
        let fleet = c.fleet().expect("fleet snapshot");
        assert_eq!(fleet.models.len(), 2);
        assert!(fleet.report().contains("model hot"));
        srv.shutdown();
    }

    #[test]
    fn ticket_wait_timeout_and_try_get() {
        let gate = Gate::new();
        let srv = ServerBuilder::new()
            .workers(1)
            .max_batch(1)
            .max_wait(Duration::from_micros(100))
            .serve(Menu::shared(gated_points(&gate)))
            .unwrap();
        let c = srv.client();
        let mut t = c.submit(InferRequest::new(vec![1.0, 0.0, 0.0])).unwrap();
        assert!(t.try_get().is_none());
        assert!(t.wait_timeout(Duration::from_millis(5)).is_none());
        gate.open();
        let r = loop {
            if let Some(r) = t.wait_timeout(Duration::from_millis(50)) {
                break r;
            }
        };
        assert_eq!(r.unwrap().output[0], 1.0);
        srv.shutdown();
    }
}
