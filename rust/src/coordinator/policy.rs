//! Budget → operating-point selection.
//!
//! Operating points are ordered by energy per sample; on the PANN menu
//! accuracy is monotone in energy (Fig. 1 / Table 2), so the policy
//! picks the most expensive point that fits the budget. fp32 is
//! modeled as unbounded cost: it is chosen only when the budget is
//! infinite (no power cap).
//!
//! The policy is generic over the point representation: the
//! single-worker server selects among [`EnginePoint`]s (boxed, possibly
//! `!Send` engines such as PJRT executables), the worker pool among
//! [`super::server::SharedPoint`]s (`Arc`-shared plan-backed engines).
//!
//! A fleet server runs one `PowerPolicy` **per registered model**,
//! each over that model's own frontier and budget cell; the
//! cross-model arbitration (who gets how much of a shared energy
//! envelope) lives in [`super::registry`], which lifts these per-model
//! selections into one global point index space.

// Request-handling surface: panics are banned (see clippy.toml);
// fail with a typed `ServeError` instead.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use super::request::ServeError;
use super::server::Engine;

/// Anything with a name and an energy cost the policy can rank.
pub trait Costed {
    fn point_name(&self) -> &str;
    /// Energy per sample in Giga bit flips; `f64::INFINITY` for fp32.
    fn cost_gflips(&self) -> f64;
    /// Serving-side *measured* energy per sample, when a calibration
    /// pass recorded one (`pann-menu/v2`'s
    /// `measured_gflips_per_sample`). Used only to break ties between
    /// points with equal modeled cost — the frontier's Pareto
    /// invariant is stated over the modeled cost, so the primary
    /// ranking must stay on [`Costed::cost_gflips`]. Defaults to
    /// `None` (rank by modeled cost alone).
    fn measured_gflips(&self) -> Option<f64> {
        None
    }
}

/// One selectable operating point owning a boxed engine.
pub struct EnginePoint {
    /// Point name (unique within its menu; pinnable).
    pub name: String,
    /// Energy per sample in Giga bit flips; `f64::INFINITY` for fp32.
    pub giga_flips_per_sample: f64,
    /// The (possibly `!Send`) engine executing this point.
    pub engine: Box<dyn Engine>,
}

impl Costed for EnginePoint {
    fn point_name(&self) -> &str {
        &self.name
    }
    fn cost_gflips(&self) -> f64 {
        self.giga_flips_per_sample
    }
}

/// Index of the most expensive cost `<= budget` in an ascending cost
/// list, falling back to the cheapest (index 0) when nothing fits —
/// the one budget→point rule, shared by [`PowerPolicy::select`] and
/// the governor's level resync ([`super::governor::Governor`]) so the
/// two can never drift apart.
pub(crate) fn best_fitting_index(costs: impl IntoIterator<Item = f64>, budget: f64) -> usize {
    let mut best = 0;
    for (i, c) in costs.into_iter().enumerate() {
        if c <= budget {
            best = i;
        }
    }
    best
}

/// The selection policy over a menu of points.
pub struct PowerPolicy<P: Costed = EnginePoint> {
    /// Sorted ascending by energy.
    points: Vec<P>,
}

impl<P: Costed> PowerPolicy<P> {
    /// Build from an unsorted menu.
    ///
    /// Rejects an empty menu and any point whose cost is NaN with
    /// [`ServeError::BadMenu`] — a NaN cost is unrankable and used to
    /// panic deep inside the sort (`partial_cmp().unwrap()`) after the
    /// server had already accepted the menu.
    pub fn new(mut points: Vec<P>) -> Result<Self, ServeError> {
        if points.is_empty() {
            return Err(ServeError::BadMenu("empty operating-point menu".into()));
        }
        if let Some(bad) = points.iter().find(|p| p.cost_gflips().is_nan()) {
            return Err(ServeError::BadMenu(format!(
                "point '{}' has a NaN energy cost",
                bad.point_name()
            )));
        }
        // Primary order: modeled cost (the Pareto invariant's axis).
        // Tie-break: among equal modeled costs, prefer the point whose
        // *measured* cost is lower — `best_fitting_index` picks the
        // highest-indexed fitting point, so the preferred point of an
        // equal-cost group must sort last (descending measured cost).
        // An unmeasured or NaN-measured point falls back to its
        // modeled cost, leaving fully-uncalibrated menus ordered
        // exactly as before.
        let effective = |p: &P| {
            p.measured_gflips()
                .filter(|m| !m.is_nan())
                .unwrap_or_else(|| p.cost_gflips())
        };
        points.sort_by(|a, b| {
            a.cost_gflips()
                .total_cmp(&b.cost_gflips())
                .then_with(|| effective(b).total_cmp(&effective(a)))
        });
        Ok(PowerPolicy { points })
    }

    /// Number of points on the menu.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// Whether the menu is empty (never true: construction rejects it).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Index of the best point under `budget_gflips` per sample.
    /// Falls back to the cheapest point when nothing fits. A NaN
    /// budget is rejected explicitly ([`ServeError::BadBudget`])
    /// rather than comparing false everywhere and silently serving
    /// the cheapest point.
    pub fn select(&self, budget_gflips: f64) -> Result<usize, ServeError> {
        if budget_gflips.is_nan() {
            return Err(ServeError::BadBudget);
        }
        Ok(best_fitting_index(
            self.points.iter().map(|p| p.cost_gflips()),
            budget_gflips,
        ))
    }

    /// Index of the point named `name` (for pinned requests).
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.points.iter().position(|p| p.point_name() == name)
    }

    /// The point at a selection index (ascending-cost order).
    pub fn point(&self, idx: usize) -> &P {
        &self.points[idx]
    }

    /// Mutable access to a point (the single-worker server owns its
    /// engines through the policy).
    pub fn point_mut(&mut self, idx: usize) -> &mut P {
        &mut self.points[idx]
    }

    /// Names + energies, cheapest first (for reports).
    pub fn menu(&self) -> Vec<(String, f64)> {
        self.points
            .iter()
            .map(|p| (p.point_name().to_string(), p.cost_gflips()))
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;
    use crate::coordinator::server::tests_support::MockEngine;

    fn point(name: &str, gf: f64) -> EnginePoint {
        EnginePoint {
            name: name.into(),
            giga_flips_per_sample: gf,
            engine: Box::new(MockEngine::new(4, 4, 2)),
        }
    }

    fn menu() -> PowerPolicy {
        PowerPolicy::new(vec![
            point("p8", 0.8),
            point("p2", 0.1),
            point("fp32", f64::INFINITY),
            point("p4", 0.3),
        ])
        .unwrap()
    }

    #[test]
    fn selects_best_under_budget() {
        let p = menu();
        assert_eq!(p.point(p.select(0.05).unwrap()).name, "p2"); // nothing fits -> cheapest
        assert_eq!(p.point(p.select(0.1).unwrap()).name, "p2");
        assert_eq!(p.point(p.select(0.5).unwrap()).name, "p4");
        assert_eq!(p.point(p.select(2.0).unwrap()).name, "p8");
        assert_eq!(p.point(p.select(f64::INFINITY).unwrap()).name, "fp32");
    }

    struct Calibrated {
        name: &'static str,
        cost: f64,
        measured: Option<f64>,
    }

    impl Costed for Calibrated {
        fn point_name(&self) -> &str {
            self.name
        }
        fn cost_gflips(&self) -> f64 {
            self.cost
        }
        fn measured_gflips(&self) -> Option<f64> {
            self.measured
        }
    }

    #[test]
    fn measured_cost_breaks_ties_between_equal_modeled_points() {
        // Two points at the same modeled cost, one measured cheaper:
        // a fitting budget must never pick the measured-dominated one.
        let p = PowerPolicy::new(vec![
            Calibrated { name: "measured-heavy", cost: 0.3, measured: Some(0.42) },
            Calibrated { name: "measured-light", cost: 0.3, measured: Some(0.28) },
            Calibrated { name: "cheap", cost: 0.1, measured: None },
        ])
        .unwrap();
        assert_eq!(p.point(p.select(0.5).unwrap()).name, "measured-light");
        // the tie-break stays *behind* the modeled-cost ranking: a
        // cheaper modeled point still outranks any measured ordering
        assert_eq!(p.point(p.select(0.2).unwrap()).name, "cheap");
        // NaN measurements are ignored, not sorted
        let p = PowerPolicy::new(vec![
            Calibrated { name: "nan-measured", cost: 0.3, measured: Some(f64::NAN) },
            Calibrated { name: "measured", cost: 0.3, measured: Some(0.25) },
        ])
        .unwrap();
        assert_eq!(p.point(p.select(1.0).unwrap()).name, "measured");
    }

    #[test]
    fn nan_cost_rejected_at_construction() {
        let e = PowerPolicy::new(vec![point("ok", 0.2), point("broken", f64::NAN)]).unwrap_err();
        match e {
            ServeError::BadMenu(msg) => assert!(msg.contains("broken"), "{msg}"),
            other => panic!("expected BadMenu, got {other:?}"),
        }
        let e = PowerPolicy::<EnginePoint>::new(Vec::new()).unwrap_err();
        assert!(matches!(e, ServeError::BadMenu(_)));
    }

    #[test]
    fn nan_budget_rejected_at_selection() {
        let p = menu();
        assert_eq!(p.select(f64::NAN).unwrap_err(), ServeError::BadBudget);
        // non-NaN budgets still select (the rejection is NaN-specific)
        assert_eq!(p.point(p.select(0.3).unwrap()).name, "p4");
    }

    #[test]
    fn index_of_finds_sorted_position() {
        let p = menu();
        assert_eq!(p.index_of("p2"), Some(0));
        assert_eq!(p.index_of("fp32"), Some(3));
        assert_eq!(p.index_of("nope"), None);
    }

    #[test]
    fn menu_sorted() {
        let p = menu();
        let m = p.menu();
        assert_eq!(m[0].0, "p2");
        assert_eq!(m[3].0, "fp32");
    }
}
