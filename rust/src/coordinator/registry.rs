//! Multi-model fleet serving: a registry of named menus behind one
//! worker pool, under one energy envelope.
//!
//! PRs 1–4 built deployment-time traversal of the power–accuracy
//! frontier for exactly one model per server. Real end-device and
//! edge-server deployments run *several* networks at once under a
//! single power budget — the setting the minimum-energy-network line
//! of work targets (Moons et al., *Minimum Energy Quantized Neural
//! Networks*; Goel et al., *A Survey of Methods for Low-Power Deep
//! Learning*). The [`ModelRegistry`] closes that gap:
//!
//! - [`super::server::ServerBuilder::register`] collects named
//!   [`Menu`]s; [`ServerBuilder::serve_fleet`] compiles each into its
//!   own [`PowerPolicy`] frontier (menu artifacts are
//!   fingerprint-verified exactly as in single-model serving) and
//!   serves all of them from **one shared worker pool**.
//! - Every registered model's points occupy a disjoint range of one
//!   *global point index space* (model `i`'s local point `p` lives at
//!   `offset[i] + p`). The classifier resolves a request to a global
//!   index, so `RequestQueue` batches stay point-coherent **per
//!   model** with no queue changes at all.
//! - Each model keeps its own budget cell: open-loop,
//!   [`super::server::Client::set_budget`] moves every model together
//!   and [`super::server::Client::set_model_budget`] moves one.
//! - Closed-loop, the global [`EnergyEnvelope`] is **arbitrated**: each
//!   model gets its own [`Governor`] over its own frontier, and the
//!   fleet arbiter re-splits the physical rate across models by the
//!   demand observed in a sliding window — max-min fairness
//!   ([`fair_shares`]): light ("cold") models are allocated what their
//!   traffic actually needs (with headroom) and keep their most
//!   accurate point, while a flooding ("hot") model gets only the
//!   residual and walks *its own* frontier down. A hot model degrades
//!   along its frontier before it can starve a cold one.
//!
//! Like the [`Governor`], the arbiter never reads the wall clock: all
//! demand accounting happens as batches are reported against the
//! caller's [`Instant`], so unit tests drive it with synthetic time.
//!
//! [`Menu`]: super::server::Menu
//! [`ServerBuilder::serve_fleet`]: super::server::ServerBuilder::serve_fleet

use super::batcher::Pending;
use super::governor::{EnergyEnvelope, Governor, GovernorConfig, GovernorSnapshot};
use super::policy::PowerPolicy;
use super::request::ServeError;
use super::server::{Menu, ServerConfig, SharedPoint};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Demand headroom multiplier of the fleet arbiter: a model's envelope
/// "need" is `observed samples/sec × top-point Gflips/sample ×` this
/// factor. The slack keeps a satisfied model comfortably inside its
/// share when its traffic is bursty or still ramping in the EWMA —
/// without it a cold model whose allocation exactly equals its average
/// draw would graze its governor threshold on every burst (or on every
/// speed-up of the flooding neighbor it interleaves with) and flap
/// down the frontier. 4× absorbs a doubled burst on top of a
/// half-converged demand estimate.
pub const DEMAND_HEADROOM: f64 = 4.0;

/// Fraction of the envelope reserved as a per-model share floor
/// (`total × this / n` each): a model that was idle through a demand
/// window is never allocated literally nothing, so traffic waking it
/// up is served (the governor climbed to the top during the idle
/// spell) without instantly breaching a zero target — the arbiter
/// grants its true need at the next window close.
pub const MIN_SHARE_FRAC: f64 = 0.02;

/// EWMA blend factor for the windowed demand estimate (weight of the
/// newest window; the remainder stays on history). One half makes the
/// estimate settle within a few windows while still smoothing
/// single-window spikes. The very first closed window *primes* the
/// estimate instead of blending against the zero it was initialized
/// with — halving every model's opening demand would under-allocate
/// exactly when no history justifies it.
const DEMAND_EWMA_ALPHA: f64 = 0.5;

/// One registered model: its compiled frontier, its budget cell, and
/// (closed-loop only) its governor.
pub(crate) struct FleetModel {
    /// Registration name ([`super::server::ServerBuilder::register`]).
    pub name: String,
    /// This model's own frontier, cheapest point first.
    pub policy: PowerPolicy<SharedPoint>,
    /// Flattened per-sample input length of this model's menu.
    pub sample_len: usize,
    /// This model's served-budget cell (same role as the single-model
    /// server's one global cell).
    pub budget_bits: Arc<AtomicU64>,
    /// Closed-loop governor over this model's frontier, defending the
    /// arbiter-assigned share of the global envelope. `None` open-loop.
    pub governor: Option<Arc<Governor>>,
}

impl FleetModel {
    /// Modeled cost of this model's most accurate point (the arbiter's
    /// per-sample price for "full accuracy").
    fn top_cost(&self) -> f64 {
        self.policy.point(self.policy.len() - 1).giga_flips_per_sample
    }
}

/// The fleet: N named models compiled to frontiers, served from one
/// pool. Built by [`super::server::ServerBuilder::serve_fleet`];
/// observed through [`super::server::Client::fleet`].
pub struct ModelRegistry {
    models: Vec<FleetModel>,
    /// `models[i]`'s points occupy global indices
    /// `offsets[i] .. offsets[i] + models[i].policy.len()`.
    offsets: Vec<usize>,
    arbiter: Option<FleetArbiter>,
}

impl ModelRegistry {
    /// Compile `registrations` into a fleet under `cfg`. Menus must be
    /// pool-servable ([`Menu::shared`] / deferred artifact menus —
    /// engine construction verifies artifact fingerprints here);
    /// [`Menu::local`] factories build `!Send` engines that cannot be
    /// shared by a pool and are rejected. Names must be unique.
    ///
    /// [`Menu::shared`]: super::server::Menu::shared
    /// [`Menu::local`]: super::server::Menu::local
    pub(crate) fn build(
        cfg: &ServerConfig,
        registrations: Vec<(String, Menu)>,
        now: Instant,
    ) -> Result<ModelRegistry> {
        anyhow::ensure!(
            !registrations.is_empty(),
            "no models registered — call ServerBuilder::register(name, menu) before serve_fleet()"
        );
        let n = registrations.len();
        let mut models: Vec<FleetModel> = Vec::with_capacity(n);
        let mut offsets = Vec::with_capacity(n);
        let mut next_offset = 0usize;
        for (name, menu) in registrations {
            anyhow::ensure!(
                models.iter().all(|m| m.name != name),
                "model '{name}' registered twice"
            );
            let points = match menu {
                Menu::Shared(points) => points,
                Menu::SharedDeferred(build) => build(cfg.max_batch)?,
                Menu::Local(_) => anyhow::bail!(
                    "model '{name}': fleet serving needs a pool-shareable menu \
                     (Menu::shared or a menu artifact); Menu::local engines are !Send"
                ),
            };
            let sample_len = {
                let mut lens = points.iter().map(|p| p.engine.sample_len());
                let first = lens
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("model '{name}': empty operating-point menu"))?;
                for l in lens {
                    anyhow::ensure!(
                        l == first,
                        "model '{name}': menu sample lengths disagree: {l} vs {first}"
                    );
                }
                first
            };
            let policy = PowerPolicy::new(points)
                .map_err(|e| anyhow::anyhow!("model '{name}': {e}"))?;
            let budget_bits = Arc::new(AtomicU64::new(cfg.budget_gflips.to_bits()));
            let governor = match cfg.envelope {
                None => None,
                Some(envelope) => {
                    // every model starts with an equal share; the
                    // arbiter re-splits by demand from the first
                    // closed window onward
                    let gc = GovernorConfig {
                        envelope: EnergyEnvelope::gflips_per_sec(envelope.rate() / n as f64),
                        window: cfg.governor_window,
                        hysteresis: cfg.governor_hysteresis,
                        ledger_windows: GovernorConfig::DEFAULT_LEDGER_WINDOWS,
                    };
                    Some(Arc::new(
                        Governor::new(gc, policy.menu(), budget_bits.clone(), now)
                            .map_err(|e| anyhow::anyhow!("model '{name}': {e}"))?,
                    ))
                }
            };
            offsets.push(next_offset);
            next_offset += policy.len();
            models.push(FleetModel { name, policy, sample_len, budget_bits, governor });
        }
        let arbiter = cfg.envelope.map(|envelope| {
            // demand is reassessed once per governor decision horizon,
            // so a model's share is stable across each step decision
            let window = cfg
                .governor_window
                .saturating_mul(cfg.governor_hysteresis.max(1));
            FleetArbiter::new(envelope.rate(), window, n, now)
        });
        Ok(ModelRegistry { models, offsets, arbiter })
    }

    /// Number of registered models.
    pub fn n_models(&self) -> usize {
        self.models.len()
    }

    /// Registration names, in registration order.
    pub fn model_names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }

    /// Registry index of the named model.
    pub(crate) fn resolve(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m.name == name)
    }

    pub(crate) fn model(&self, idx: usize) -> &FleetModel {
        &self.models[idx]
    }

    /// Map a global point index back to `(model index, local point)`.
    pub(crate) fn locate(&self, global: usize) -> (usize, usize) {
        // offsets is ascending; find the last offset <= global
        let mi = match self.offsets.binary_search(&global) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        (mi, global - self.offsets[mi])
    }

    /// The fleet classifier: pinned point by name on the request's
    /// model, otherwise that model's best point under `min(its budget
    /// cell, request cap)` — the single-model rule, applied per model,
    /// then lifted into the global index space so batches stay
    /// point-coherent per model.
    pub(crate) fn classify(&self, p: &Pending) -> Result<usize, ServeError> {
        let m = &self.models[p.model];
        let offset = self.offsets[p.model];
        if let Some(pin) = &p.pin {
            return m
                .policy
                .index_of(pin)
                .map(|i| offset + i)
                .ok_or_else(|| ServeError::UnknownPoint(pin.clone()));
        }
        let global = f64::from_bits(m.budget_bits.load(Ordering::Relaxed));
        if global.is_nan() {
            return Err(ServeError::BadBudget);
        }
        let budget = p.max_gflips.map_or(global, |cap| global.min(cap));
        m.policy.select(budget).map(|i| offset + i)
    }

    /// Report one executed chunk of `samples` samples on `model`'s
    /// local point `point` for `gflips` energy (`metered` as in
    /// [`Governor::observe`]): feeds the model's governor *and* the
    /// fleet arbiter's demand window. No-op wiring open-loop (no
    /// governors, no arbiter — demand splitting has nothing to split).
    pub(crate) fn note_batch(
        &self,
        now: Instant,
        model: usize,
        point: usize,
        samples: u64,
        gflips: f64,
        metered: bool,
    ) {
        if let Some(g) = &self.models[model].governor {
            g.observe(now, point, samples, gflips, metered);
        }
        if let Some(arb) = &self.arbiter {
            arb.observe(now, model, samples, &self.models);
        }
    }

    /// Point-in-time view of every registered model.
    pub fn snapshot(&self) -> FleetSnapshot {
        let arb = self.arbiter.as_ref().map(|a| a.snapshot());
        FleetSnapshot {
            models: self
                .models
                .iter()
                .enumerate()
                .map(|(i, m)| ModelFleetStatus {
                    name: m.name.clone(),
                    points: m.policy.len(),
                    sample_len: m.sample_len,
                    budget_gflips: f64::from_bits(m.budget_bits.load(Ordering::Relaxed)),
                    demand_rate: arb.as_ref().map(|a| a.demand_rate[i]),
                    envelope_share: arb.as_ref().map(|a| a.shares[i]),
                    governor: m.governor.as_ref().map(|g| g.snapshot()),
                })
                .collect(),
        }
    }
}

/// Max-min fair ("water-filling") split of `total` across `needs`:
/// walking the needs smallest first, each claimant gets
/// `min(need, remaining / claimants left)`; whatever is left over once
/// every need is met is spread equally. This is the allocation rule
/// that makes a hot model degrade before a cold one starves: a small
/// need is satisfied in full no matter how large the other demands
/// grow, while over-subscribed claimants split the residual equally.
/// (A zero-need claimant gets zero here when others are
/// over-subscribed; the fleet arbiter guards against that with a
/// [`MIN_SHARE_FRAC`] floor taken off the top.)
///
/// Infinite needs (a frontier topped by an unbounded-cost fp32 point)
/// simply claim their full equal share; NaN needs are treated as zero.
pub fn fair_shares(total: f64, needs: &[f64]) -> Vec<f64> {
    let n = needs.len();
    if n == 0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| needs[a].total_cmp(&needs[b]));
    let mut shares = vec![0.0f64; n];
    let mut remaining = total.max(0.0);
    for (k, &i) in order.iter().enumerate() {
        let fair = remaining / (n - k) as f64;
        let need = if needs[i].is_nan() { 0.0 } else { needs[i].max(0.0) };
        let s = need.min(fair);
        shares[i] = s;
        remaining -= s;
    }
    if remaining > 0.0 {
        let bonus = remaining / n as f64;
        for s in &mut shares {
            *s += bonus;
        }
    }
    shares
}

/// Demand-weighted splitter of the global [`EnergyEnvelope`] across the
/// fleet. Accumulates per-model sample counts; at each window boundary
/// it folds them into an EWMA demand rate, prices each model's "need"
/// (`rate × top cost × [`DEMAND_HEADROOM`]`), and re-targets every
/// model's [`Governor`] with its [`fair_shares`] allocation.
struct FleetArbiter {
    total_rate: f64,
    window: Duration,
    state: Mutex<ArbState>,
}

struct ArbState {
    window_start: Instant,
    /// Samples served per model since `window_start`.
    counts: Vec<u64>,
    /// EWMA samples/sec per model.
    demand_rate: Vec<f64>,
    /// Whether a first window has primed `demand_rate`.
    primed: bool,
    /// Current envelope share per model, Gflips/sec.
    shares: Vec<f64>,
}

/// Arbiter view used by [`FleetSnapshot`].
struct ArbSnapshot {
    demand_rate: Vec<f64>,
    shares: Vec<f64>,
}

impl FleetArbiter {
    fn new(total_rate: f64, window: Duration, n: usize, now: Instant) -> FleetArbiter {
        FleetArbiter {
            total_rate,
            window: if window.is_zero() { Duration::from_millis(1) } else { window },
            state: Mutex::new(ArbState {
                window_start: now,
                counts: vec![0; n],
                demand_rate: vec![0.0; n],
                primed: false,
                // matches the equal initial split of the governors
                shares: vec![total_rate / n as f64; n],
            }),
        }
    }

    /// Land `samples` of demand on `model`; close the demand window and
    /// re-split the envelope if `now` has passed its end. Like the
    /// governor, this takes the caller's `now` — no wall clock.
    fn observe(&self, now: Instant, model: usize, samples: u64, models: &[FleetModel]) {
        let mut s = self.state.lock().expect("fleet arbiter poisoned");
        s.counts[model] += samples;
        let Some(elapsed) = now.checked_duration_since(s.window_start) else {
            return;
        };
        if elapsed < self.window {
            return;
        }
        // One re-split per boundary crossing, over the actual elapsed
        // span (a long quiet gap is one long window of near-zero rate,
        // not thousands of empty ones — bounded work by construction).
        let secs = elapsed.as_secs_f64().max(1e-9);
        for i in 0..s.counts.len() {
            let inst = s.counts[i] as f64 / secs;
            s.demand_rate[i] = if s.primed {
                (1.0 - DEMAND_EWMA_ALPHA) * s.demand_rate[i] + DEMAND_EWMA_ALPHA * inst
            } else {
                inst
            };
            s.counts[i] = 0;
        }
        s.primed = true;
        s.window_start = now;
        let needs: Vec<f64> = s
            .demand_rate
            .iter()
            .zip(models)
            .map(|(&rate, m)| rate * m.top_cost() * DEMAND_HEADROOM)
            .collect();
        // per-model floor off the top, max-min fairness on the rest
        let n = models.len() as f64;
        let floor = self.total_rate * MIN_SHARE_FRAC / n;
        let mut shares = fair_shares(self.total_rate - floor * n, &needs);
        for sh in &mut shares {
            *sh += floor;
        }
        s.shares = shares;
        for (m, &share) in models.iter().zip(&s.shares) {
            if let Some(g) = &m.governor {
                g.set_envelope_rate(share);
            }
        }
    }

    fn snapshot(&self) -> ArbSnapshot {
        let s = self.state.lock().expect("fleet arbiter poisoned");
        ArbSnapshot { demand_rate: s.demand_rate.clone(), shares: s.shares.clone() }
    }
}

/// Point-in-time view of the whole fleet
/// ([`super::server::Client::fleet`]).
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    /// One status per registered model, in registration order.
    pub models: Vec<ModelFleetStatus>,
}

/// One model's slice of a [`FleetSnapshot`].
#[derive(Clone, Debug)]
pub struct ModelFleetStatus {
    /// Registration name.
    pub name: String,
    /// Frontier points on this model's menu.
    pub points: usize,
    /// Flattened per-sample input length this model expects.
    pub sample_len: usize,
    /// This model's current served budget (Gflips/sample).
    pub budget_gflips: f64,
    /// Arbiter's EWMA demand estimate, samples/sec (`None` open-loop).
    pub demand_rate: Option<f64>,
    /// This model's current share of the global envelope, Gflips/sec
    /// (`None` open-loop).
    pub envelope_share: Option<f64>,
    /// This model's governor view (`None` open-loop).
    pub governor: Option<GovernorSnapshot>,
}

impl FleetSnapshot {
    /// Human-readable multi-line report (CLI / bench output).
    pub fn report(&self) -> String {
        let mut s = String::new();
        for m in &self.models {
            s.push_str(&format!(
                "model {}: {} frontier points, budget {:.6} GF/sample",
                m.name, m.points, m.budget_gflips
            ));
            if let (Some(d), Some(sh)) = (m.demand_rate, m.envelope_share) {
                s.push_str(&format!(
                    ", demand {d:.1} samples/s, envelope share {sh:.4} GF/s"
                ));
            }
            s.push('\n');
            if let Some(g) = &m.governor {
                for line in g.report().lines() {
                    s.push_str(&format!("  {line}\n"));
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::super::server::tests_support::MockEngine;
    use super::*;
    use std::sync::mpsc;

    fn shared(name: &str, gf: f64, in_len: usize) -> SharedPoint {
        SharedPoint {
            name: name.into(),
            giga_flips_per_sample: gf,
            engine: Arc::new(MockEngine::new(4, in_len, 2)),
        }
    }

    fn cfg(envelope: Option<f64>) -> ServerConfig {
        ServerConfig {
            envelope: envelope.map(EnergyEnvelope::gflips_per_sec),
            governor_window: Duration::from_millis(10),
            governor_hysteresis: 1,
            ..ServerConfig::default()
        }
    }

    fn two_model_regs() -> Vec<(String, Menu)> {
        vec![
            (
                "a".to_string(),
                Menu::shared(vec![shared("cheap", 0.1, 3), shared("rich", 1.0, 3)]),
            ),
            (
                "b".to_string(),
                Menu::shared(vec![shared("cheap", 0.2, 5), shared("rich", 2.0, 5)]),
            ),
        ]
    }

    // the receiver is dropped: these Pendings are only classified,
    // never responded to
    fn pending(model: usize, cap: Option<f64>, pin: Option<&str>) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            input: vec![0.0; 3],
            model,
            submitted: Instant::now(),
            deadline: None,
            priority: super::super::request::Priority::Normal,
            max_gflips: cap,
            pin: pin.map(str::to_string),
            tag: None,
            cancelled: Arc::new(std::sync::atomic::AtomicBool::new(false)),
            resp: tx,
        }
    }

    #[test]
    fn fair_shares_satisfies_small_needs_first() {
        // cold needs 1, hot needs 100, total 10: cold gets its 1 in
        // full, hot gets the residual 9.
        let s = fair_shares(10.0, &[100.0, 1.0]);
        assert!((s[1] - 1.0).abs() < 1e-12);
        assert!((s[0] - 9.0).abs() < 1e-12);
        // oversubscribed on both sides: equal split
        let s = fair_shares(10.0, &[100.0, 80.0]);
        assert!((s[0] - 5.0).abs() < 1e-12 && (s[1] - 5.0).abs() < 1e-12);
        // under-subscribed: leftover spread equally, shares stay > need
        let s = fair_shares(10.0, &[1.0, 2.0]);
        assert!((s[0] - (1.0 + 3.5)).abs() < 1e-12);
        assert!((s[1] - (2.0 + 3.5)).abs() < 1e-12);
        assert!(((s[0] + s[1]) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn fair_shares_handles_zero_inf_nan_and_empty() {
        assert!(fair_shares(10.0, &[]).is_empty());
        // zero-demand model still ends strictly positive via the
        // leftover spread when headroom exists
        let s = fair_shares(10.0, &[0.0, 1.0]);
        assert!(s[0] > 0.0);
        // an infinite need (fp32-topped frontier) takes its equal
        // share, not everything
        let s = fair_shares(10.0, &[f64::INFINITY, 1.0]);
        assert!((s[1] - 1.0).abs() < 1e-12);
        assert!((s[0] - 9.0).abs() < 1e-12);
        let s = fair_shares(10.0, &[f64::NAN, 4.0]);
        assert!(s[0].is_finite() && s[1].is_finite());
        // never over-allocates
        let s = fair_shares(5.0, &[100.0, 100.0, 100.0]);
        let sum: f64 = s.iter().sum();
        assert!((sum - 5.0).abs() < 1e-9);
    }

    #[test]
    fn registry_rejects_duplicates_local_menus_and_empty() {
        let c = cfg(None);
        let e = ModelRegistry::build(&c, Vec::new(), Instant::now()).unwrap_err();
        assert!(e.to_string().contains("no models registered"), "{e}");
        let dup = vec![
            ("a".to_string(), Menu::shared(vec![shared("p", 0.1, 3)])),
            ("a".to_string(), Menu::shared(vec![shared("p", 0.1, 3)])),
        ];
        let e = ModelRegistry::build(&c, dup, Instant::now()).unwrap_err();
        assert!(e.to_string().contains("registered twice"), "{e}");
        let local = vec![("a".to_string(), Menu::local(|| Ok(Vec::new())))];
        let e = ModelRegistry::build(&c, local, Instant::now()).unwrap_err();
        assert!(e.to_string().contains("!Send"), "{e}");
        let empty = vec![("a".to_string(), Menu::shared(Vec::new()))];
        assert!(ModelRegistry::build(&c, empty, Instant::now()).is_err());
    }

    #[test]
    fn classify_routes_into_disjoint_global_ranges() {
        let reg = ModelRegistry::build(&cfg(None), two_model_regs(), Instant::now()).unwrap();
        assert_eq!(reg.n_models(), 2);
        assert_eq!(reg.model_names(), vec!["a", "b"]);
        // model 0's points at 0..2, model 1's at 2..4
        // (default budget = inf -> each model's richest point)
        let g = reg.classify(&pending(0, None, None)).unwrap();
        assert_eq!(reg.locate(g), (0, 1));
        let g = reg.classify(&pending(1, None, None)).unwrap();
        assert_eq!(reg.locate(g), (1, 1));
        // per-request caps select within the request's own frontier
        let g = reg.classify(&pending(1, Some(0.5), None)).unwrap();
        assert_eq!(reg.locate(g), (1, 0));
        // pins resolve against the request's model — both menus name a
        // point "cheap", and they must not collide
        let ga = reg.classify(&pending(0, None, Some("cheap"))).unwrap();
        let gb = reg.classify(&pending(1, None, Some("cheap"))).unwrap();
        assert_ne!(ga, gb);
        assert_eq!(reg.locate(ga), (0, 0));
        assert_eq!(reg.locate(gb), (1, 0));
        let e = reg.classify(&pending(0, None, Some("nope"))).unwrap_err();
        assert_eq!(e, ServeError::UnknownPoint("nope".into()));
        // per-model sample lengths survive
        assert_eq!(reg.model(0).sample_len, 3);
        assert_eq!(reg.model(1).sample_len, 5);
    }

    #[test]
    fn per_model_budgets_are_independent() {
        let reg = ModelRegistry::build(&cfg(None), two_model_regs(), Instant::now()).unwrap();
        reg.model(0).budget_bits.store(0.1f64.to_bits(), Ordering::Relaxed);
        let g = reg.classify(&pending(0, None, None)).unwrap();
        assert_eq!(reg.locate(g), (0, 0), "model a capped to its cheap point");
        let g = reg.classify(&pending(1, None, None)).unwrap();
        assert_eq!(reg.locate(g), (1, 1), "model b untouched");
        // NaN budget on one model rejects only that model's requests
        reg.model(0).budget_bits.store(f64::NAN.to_bits(), Ordering::Relaxed);
        assert_eq!(
            reg.classify(&pending(0, None, None)).unwrap_err(),
            ServeError::BadBudget
        );
        assert!(reg.classify(&pending(1, None, None)).is_ok());
    }

    #[test]
    fn arbiter_equal_split_when_both_models_oversubscribe() {
        // Both models flood past any fair share: max-min collapses to
        // an equal split — the hot-in-samples model cannot push the
        // other below half the envelope, and shares always sum to it.
        let t0 = Instant::now();
        let c = cfg(Some(10.0));
        let reg = ModelRegistry::build(&c, two_model_regs(), t0).unwrap();
        // initial split is equal
        let snap = reg.snapshot();
        assert_eq!(snap.models.len(), 2);
        for m in &snap.models {
            assert!((m.envelope_share.unwrap() - 5.0).abs() < 1e-12);
        }
        // skewed flood: 1000 samples/s on a, 100/s on b, both of
        // whose needs exceed the 10 GF/s envelope
        let w = Duration::from_millis(10);
        reg.note_batch(t0 + w / 2, 0, 1, 10, 10.0, false);
        reg.note_batch(t0 + w, 0, 1, 0, 0.0, false);
        reg.note_batch(t0 + w + Duration::from_micros(1), 1, 1, 1, 2.0, false);
        reg.note_batch(t0 + w * 2, 0, 1, 10, 10.0, false);
        reg.note_batch(t0 + w * 2 + Duration::from_micros(1), 1, 1, 1, 2.0, false);
        reg.note_batch(t0 + w * 3, 0, 1, 10, 10.0, false);
        let snap = reg.snapshot();
        let a = &snap.models[0];
        let b = &snap.models[1];
        let share_sum = a.envelope_share.unwrap() + b.envelope_share.unwrap();
        assert!((share_sum - 10.0).abs() < 1e-9, "shares must sum to the envelope");
        assert!(a.demand_rate.unwrap() > b.demand_rate.unwrap());
        assert!(b.envelope_share.unwrap() >= 5.0 - 1e-9, "cold model keeps >= fair share");
    }

    #[test]
    fn arbiter_grants_cold_model_its_need_in_full() {
        // a floods (1000 samples/s at top cost 1.0); b trickles at
        // 1 sample/s with top cost 2.0, so b's steady need is
        // 1 × 2.0 × DEMAND_HEADROOM = 8 GF/s — inside the 20 GF/s
        // envelope's fair half. Max-min must satisfy b in full (plus
        // the floor) and hand a only the residual, however hard a
        // floods.
        let t0 = Instant::now();
        let c = ServerConfig {
            governor_window: Duration::from_secs(1),
            ..cfg(Some(20.0))
        };
        let reg = ModelRegistry::build(&c, two_model_regs(), t0).unwrap();
        let w = Duration::from_secs(1);
        let mut now = t0;
        for k in 1..=4u32 {
            // during each 1s window: a lands 1000 samples, b lands 1
            reg.note_batch(now + w / 2, 0, 1, 1000, 1000.0, false);
            reg.note_batch(now + w / 2, 1, 1, 1, 2.0, false);
            now = t0 + w * k;
            reg.note_batch(now, 0, 1, 0, 0.0, false);
        }
        let snap = reg.snapshot();
        let a = &snap.models[0];
        let b = &snap.models[1];
        let b_share = b.envelope_share.unwrap();
        let a_share = a.envelope_share.unwrap();
        assert!(
            (7.0..=9.0).contains(&b_share),
            "cold model must get ~its 8 GF/s need, got {b_share}"
        );
        assert!(a_share > b_share, "hot model takes the larger residual, got {a_share}");
        assert!((a_share + b_share - 20.0).abs() < 1e-9);
    }

    #[test]
    fn arbiter_share_floor_protects_a_model_idle_through_priming() {
        // Model b is completely idle while a floods through the first
        // demand windows: pure max-min would hand b literally nothing,
        // and its first request after the idle spell would breach a
        // zero target. The MIN_SHARE_FRAC floor keeps every share
        // strictly positive.
        let t0 = Instant::now();
        let reg = ModelRegistry::build(&cfg(Some(10.0)), two_model_regs(), t0).unwrap();
        let w = Duration::from_millis(10);
        reg.note_batch(t0 + w / 2, 0, 1, 100, 100.0, false);
        reg.note_batch(t0 + w, 0, 1, 0, 0.0, false); // close: b idle
        let snap = reg.snapshot();
        let b_share = snap.models[1].envelope_share.unwrap();
        let floor = 10.0 * MIN_SHARE_FRAC / 2.0;
        assert!(
            (b_share - floor).abs() < 1e-12,
            "idle model must keep the floor share, got {b_share}"
        );
        assert!(snap.models[0].envelope_share.unwrap() > b_share);
    }
}
