//! Multi-model fleet serving: a registry of named menus behind one
//! worker pool, under one energy envelope.
//!
//! PRs 1–4 built deployment-time traversal of the power–accuracy
//! frontier for exactly one model per server. Real end-device and
//! edge-server deployments run *several* networks at once under a
//! single power budget — the setting the minimum-energy-network line
//! of work targets (Moons et al., *Minimum Energy Quantized Neural
//! Networks*; Goel et al., *A Survey of Methods for Low-Power Deep
//! Learning*). The [`ModelRegistry`] closes that gap:
//!
//! - [`super::server::ServerBuilder::register`] collects named
//!   [`Menu`]s; [`ServerBuilder::serve_fleet`] compiles each into its
//!   own [`PowerPolicy`] frontier (menu artifacts are
//!   fingerprint-verified exactly as in single-model serving) and
//!   serves all of them from **one shared worker pool**.
//! - Every registered model's points occupy a disjoint range of one
//!   *global point index space* (model `i`'s local point `p` lives at
//!   `offset[i] + p`). The classifier resolves a request to a global
//!   index, so `RequestQueue` batches stay point-coherent **per
//!   model** with no queue changes at all.
//! - Each model keeps its own budget cell: open-loop,
//!   [`super::server::Client::set_budget`] moves every model together
//!   and [`super::server::Client::set_model_budget`] moves one.
//! - Closed-loop, the global [`EnergyEnvelope`] is **arbitrated**: each
//!   model gets its own [`Governor`] over its own frontier, and the
//!   fleet arbiter re-splits the physical rate across models by the
//!   demand observed in a sliding window — max-min fairness
//!   ([`fair_shares`]): light ("cold") models are allocated what their
//!   traffic actually needs (with headroom) and keep their most
//!   accurate point, while a flooding ("hot") model gets only the
//!   residual and walks *its own* frontier down. A hot model degrades
//!   along its frontier before it can starve a cold one.
//!
//! Like the [`Governor`], the arbiter never reads the wall clock: all
//! demand accounting happens as batches are reported against the
//! caller's [`Instant`], so unit tests drive it with synthetic time.
//!
//! [`Menu`]: super::server::Menu
//! [`ServerBuilder::serve_fleet`]: super::server::ServerBuilder::serve_fleet

// Request-handling surface: panics are banned (see clippy.toml);
// fail with a typed `ServeError` instead.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use super::arbiter::{EnvelopeSplitter, SplitterSnapshot};
use super::batcher::Pending;
use super::governor::{EnergyEnvelope, Governor, GovernorConfig, GovernorSnapshot};
use super::policy::PowerPolicy;
use super::request::ServeError;
use super::server::{Menu, ServerConfig, SharedPoint};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

// The water-filling split itself lives in `arbiter` now (PR 7 shares
// it with the shard router); these re-exports keep the original fleet
// API paths working.
pub use super::arbiter::{fair_shares, DEMAND_HEADROOM, MIN_SHARE_FRAC};

/// One registered model: its compiled frontier, its budget cell, and
/// (closed-loop only) its governor.
pub(crate) struct FleetModel {
    /// Registration name ([`super::server::ServerBuilder::register`]).
    pub name: String,
    /// This model's own frontier, cheapest point first.
    pub policy: PowerPolicy<SharedPoint>,
    /// Flattened per-sample input length of this model's menu.
    pub sample_len: usize,
    /// This model's served-budget cell (same role as the single-model
    /// server's one global cell).
    pub budget_bits: Arc<AtomicU64>,
    /// Closed-loop governor over this model's frontier, defending the
    /// arbiter-assigned share of the global envelope. `None` open-loop.
    pub governor: Option<Arc<Governor>>,
}

impl FleetModel {
    /// Modeled cost of this model's most accurate point (the arbiter's
    /// per-sample price for "full accuracy").
    fn top_cost(&self) -> f64 {
        self.policy.point(self.policy.len() - 1).giga_flips_per_sample
    }
}

/// The fleet: N named models compiled to frontiers, served from one
/// pool. Built by [`super::server::ServerBuilder::serve_fleet`];
/// observed through [`super::server::Client::fleet`].
pub struct ModelRegistry {
    models: Vec<FleetModel>,
    /// `models[i]`'s points occupy global indices
    /// `offsets[i] .. offsets[i] + models[i].policy.len()`.
    offsets: Vec<usize>,
    arbiter: Option<FleetArbiter>,
}

impl ModelRegistry {
    /// Compile `registrations` into a fleet under `cfg`. Menus must be
    /// pool-servable ([`Menu::shared`] / deferred artifact menus —
    /// engine construction verifies artifact fingerprints here);
    /// [`Menu::local`] factories build `!Send` engines that cannot be
    /// shared by a pool and are rejected. Names must be unique.
    ///
    /// [`Menu::shared`]: super::server::Menu::shared
    /// [`Menu::local`]: super::server::Menu::local
    pub(crate) fn build(
        cfg: &ServerConfig,
        registrations: Vec<(String, Menu)>,
        now: Instant,
    ) -> Result<ModelRegistry> {
        anyhow::ensure!(
            !registrations.is_empty(),
            "no models registered — call ServerBuilder::register(name, menu) before serve_fleet()"
        );
        let n = registrations.len();
        let mut models: Vec<FleetModel> = Vec::with_capacity(n);
        let mut offsets = Vec::with_capacity(n);
        let mut next_offset = 0usize;
        for (name, menu) in registrations {
            anyhow::ensure!(
                models.iter().all(|m| m.name != name),
                "model '{name}' registered twice"
            );
            let points = match menu {
                Menu::Shared(points) => points,
                Menu::SharedDeferred(build) => build(cfg.max_batch)?,
                Menu::Local(_) => anyhow::bail!(
                    "model '{name}': fleet serving needs a pool-shareable menu \
                     (Menu::shared or a menu artifact); Menu::local engines are !Send"
                ),
            };
            let sample_len = {
                let mut lens = points.iter().map(|p| p.engine.sample_len());
                let first = lens
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("model '{name}': empty operating-point menu"))?;
                for l in lens {
                    anyhow::ensure!(
                        l == first,
                        "model '{name}': menu sample lengths disagree: {l} vs {first}"
                    );
                }
                first
            };
            let policy = PowerPolicy::new(points)
                .map_err(|e| anyhow::anyhow!("model '{name}': {e}"))?;
            let budget_bits = Arc::new(AtomicU64::new(cfg.budget_gflips.to_bits()));
            let governor = match cfg.envelope {
                None => None,
                Some(envelope) => {
                    // every model starts with an equal share; the
                    // arbiter re-splits by demand from the first
                    // closed window onward
                    let gc = GovernorConfig {
                        envelope: EnergyEnvelope::gflips_per_sec(envelope.rate() / n as f64),
                        window: cfg.governor_window,
                        hysteresis: cfg.governor_hysteresis,
                        ledger_windows: GovernorConfig::DEFAULT_LEDGER_WINDOWS,
                    };
                    Some(Arc::new(
                        Governor::new(gc, policy.menu(), budget_bits.clone(), now)
                            .map_err(|e| anyhow::anyhow!("model '{name}': {e}"))?,
                    ))
                }
            };
            offsets.push(next_offset);
            next_offset += policy.len();
            models.push(FleetModel { name, policy, sample_len, budget_bits, governor });
        }
        let arbiter = cfg.envelope.map(|envelope| {
            // demand is reassessed once per governor decision horizon,
            // so a model's share is stable across each step decision
            let window = cfg
                .governor_window
                .saturating_mul(cfg.governor_hysteresis.max(1));
            FleetArbiter::new(envelope.rate(), window, n, now)
        });
        Ok(ModelRegistry { models, offsets, arbiter })
    }

    /// Number of registered models.
    pub fn n_models(&self) -> usize {
        self.models.len()
    }

    /// Registration names, in registration order.
    pub fn model_names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }

    /// Registry index of the named model.
    pub(crate) fn resolve(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m.name == name)
    }

    pub(crate) fn model(&self, idx: usize) -> &FleetModel {
        &self.models[idx]
    }

    /// Map a global point index back to `(model index, local point)`.
    pub(crate) fn locate(&self, global: usize) -> (usize, usize) {
        // offsets is ascending; find the last offset <= global
        let mi = match self.offsets.binary_search(&global) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        (mi, global - self.offsets[mi])
    }

    /// The fleet classifier: pinned point by name on the request's
    /// model, otherwise that model's best point under `min(its budget
    /// cell, request cap)` — the single-model rule, applied per model,
    /// then lifted into the global index space so batches stay
    /// point-coherent per model.
    pub(crate) fn classify(&self, p: &Pending) -> Result<usize, ServeError> {
        let m = &self.models[p.model];
        let offset = self.offsets[p.model];
        if let Some(pin) = &p.pin {
            return m
                .policy
                .index_of(pin)
                .map(|i| offset + i)
                .ok_or_else(|| ServeError::UnknownPoint(pin.clone()));
        }
        let global = f64::from_bits(m.budget_bits.load(Ordering::Relaxed));
        if global.is_nan() {
            return Err(ServeError::BadBudget);
        }
        let budget = p.max_gflips.map_or(global, |cap| global.min(cap));
        m.policy.select(budget).map(|i| offset + i)
    }

    /// Report one executed chunk of `samples` samples on `model`'s
    /// local point `point` for `gflips` energy (`metered` as in
    /// [`Governor::observe`]): feeds the model's governor *and* the
    /// fleet arbiter's demand window. No-op wiring open-loop (no
    /// governors, no arbiter — demand splitting has nothing to split).
    pub(crate) fn note_batch(
        &self,
        now: Instant,
        model: usize,
        point: usize,
        samples: u64,
        gflips: f64,
        metered: bool,
    ) {
        if let Some(g) = &self.models[model].governor {
            g.observe(now, point, samples, gflips, metered);
        }
        if let Some(arb) = &self.arbiter {
            arb.observe(now, model, samples, &self.models);
        }
    }

    /// Point-in-time view of every registered model.
    pub fn snapshot(&self) -> FleetSnapshot {
        let arb = self.arbiter.as_ref().map(|a| a.snapshot());
        FleetSnapshot {
            models: self
                .models
                .iter()
                .enumerate()
                .map(|(i, m)| ModelFleetStatus {
                    name: m.name.clone(),
                    points: m.policy.len(),
                    sample_len: m.sample_len,
                    budget_gflips: f64::from_bits(m.budget_bits.load(Ordering::Relaxed)),
                    demand_rate: arb.as_ref().map(|a| a.demand_rate[i]),
                    envelope_share: arb.as_ref().map(|a| a.shares[i]),
                    governor: m.governor.as_ref().map(|g| g.snapshot()),
                })
                .collect(),
        }
    }
}

/// The fleet adapter over [`EnvelopeSplitter`]: prices every model's
/// demand by the top cost of *its own* frontier, and re-targets each
/// model's [`Governor`] whenever a window boundary answers fresh
/// shares.
struct FleetArbiter {
    splitter: EnvelopeSplitter,
}

impl FleetArbiter {
    fn new(total_rate: f64, window: std::time::Duration, n: usize, now: Instant) -> FleetArbiter {
        FleetArbiter { splitter: EnvelopeSplitter::new(total_rate, window, n, now) }
    }

    /// Land `samples` of demand on `model`; close the demand window and
    /// re-split the envelope if `now` has passed its end. Like the
    /// governor, this takes the caller's `now` — no wall clock.
    fn observe(&self, now: Instant, model: usize, samples: u64, models: &[FleetModel]) {
        let shares = self.splitter.observe(now, model, samples, |i| models[i].top_cost());
        if let Some(shares) = shares {
            for (m, &share) in models.iter().zip(&shares) {
                if let Some(g) = &m.governor {
                    g.set_envelope_rate(share);
                }
            }
        }
    }

    fn snapshot(&self) -> SplitterSnapshot {
        self.splitter.snapshot()
    }
}

/// Point-in-time view of the whole fleet
/// ([`super::server::Client::fleet`]).
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    /// One status per registered model, in registration order.
    pub models: Vec<ModelFleetStatus>,
}

/// One model's slice of a [`FleetSnapshot`].
#[derive(Clone, Debug)]
pub struct ModelFleetStatus {
    /// Registration name.
    pub name: String,
    /// Frontier points on this model's menu.
    pub points: usize,
    /// Flattened per-sample input length this model expects.
    pub sample_len: usize,
    /// This model's current served budget (Gflips/sample).
    pub budget_gflips: f64,
    /// Arbiter's EWMA demand estimate, samples/sec (`None` open-loop).
    pub demand_rate: Option<f64>,
    /// This model's current share of the global envelope, Gflips/sec
    /// (`None` open-loop).
    pub envelope_share: Option<f64>,
    /// This model's governor view (`None` open-loop).
    pub governor: Option<GovernorSnapshot>,
}

impl FleetSnapshot {
    /// Human-readable multi-line report (CLI / bench output).
    pub fn report(&self) -> String {
        let mut s = String::new();
        for m in &self.models {
            s.push_str(&format!(
                "model {}: {} frontier points, budget {:.6} GF/sample",
                m.name, m.points, m.budget_gflips
            ));
            if let (Some(d), Some(sh)) = (m.demand_rate, m.envelope_share) {
                s.push_str(&format!(
                    ", demand {d:.1} samples/s, envelope share {sh:.4} GF/s"
                ));
            }
            s.push('\n');
            if let Some(g) = &m.governor {
                for line in g.report().lines() {
                    s.push_str(&format!("  {line}\n"));
                }
            }
        }
        s
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::super::server::tests_support::MockEngine;
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    fn shared(name: &str, gf: f64, in_len: usize) -> SharedPoint {
        SharedPoint {
            measured_gflips_per_sample: None,
            name: name.into(),
            giga_flips_per_sample: gf,
            engine: Arc::new(MockEngine::new(4, in_len, 2)),
        }
    }

    fn cfg(envelope: Option<f64>) -> ServerConfig {
        ServerConfig {
            envelope: envelope.map(EnergyEnvelope::gflips_per_sec),
            governor_window: Duration::from_millis(10),
            governor_hysteresis: 1,
            ..ServerConfig::default()
        }
    }

    fn two_model_regs() -> Vec<(String, Menu)> {
        vec![
            (
                "a".to_string(),
                Menu::shared(vec![shared("cheap", 0.1, 3), shared("rich", 1.0, 3)]),
            ),
            (
                "b".to_string(),
                Menu::shared(vec![shared("cheap", 0.2, 5), shared("rich", 2.0, 5)]),
            ),
        ]
    }

    // the receiver is dropped: these Pendings are only classified,
    // never responded to
    fn pending(model: usize, cap: Option<f64>, pin: Option<&str>) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            input: vec![0.0; 3],
            model,
            submitted: Instant::now(),
            deadline: None,
            priority: super::super::request::Priority::Normal,
            max_gflips: cap,
            pin: pin.map(str::to_string),
            tag: None,
            cancelled: Arc::new(std::sync::atomic::AtomicBool::new(false)),
            resp: tx,
        }
    }

    // (the fair_shares / demand_shares unit and property tests live
    // with the extracted helper in `coordinator/arbiter.rs`)

    #[test]
    fn registry_rejects_duplicates_local_menus_and_empty() {
        let c = cfg(None);
        let e = ModelRegistry::build(&c, Vec::new(), Instant::now()).unwrap_err();
        assert!(e.to_string().contains("no models registered"), "{e}");
        let dup = vec![
            ("a".to_string(), Menu::shared(vec![shared("p", 0.1, 3)])),
            ("a".to_string(), Menu::shared(vec![shared("p", 0.1, 3)])),
        ];
        let e = ModelRegistry::build(&c, dup, Instant::now()).unwrap_err();
        assert!(e.to_string().contains("registered twice"), "{e}");
        let local = vec![("a".to_string(), Menu::local(|| Ok(Vec::new())))];
        let e = ModelRegistry::build(&c, local, Instant::now()).unwrap_err();
        assert!(e.to_string().contains("!Send"), "{e}");
        let empty = vec![("a".to_string(), Menu::shared(Vec::new()))];
        assert!(ModelRegistry::build(&c, empty, Instant::now()).is_err());
    }

    #[test]
    fn classify_routes_into_disjoint_global_ranges() {
        let reg = ModelRegistry::build(&cfg(None), two_model_regs(), Instant::now()).unwrap();
        assert_eq!(reg.n_models(), 2);
        assert_eq!(reg.model_names(), vec!["a", "b"]);
        // model 0's points at 0..2, model 1's at 2..4
        // (default budget = inf -> each model's richest point)
        let g = reg.classify(&pending(0, None, None)).unwrap();
        assert_eq!(reg.locate(g), (0, 1));
        let g = reg.classify(&pending(1, None, None)).unwrap();
        assert_eq!(reg.locate(g), (1, 1));
        // per-request caps select within the request's own frontier
        let g = reg.classify(&pending(1, Some(0.5), None)).unwrap();
        assert_eq!(reg.locate(g), (1, 0));
        // pins resolve against the request's model — both menus name a
        // point "cheap", and they must not collide
        let ga = reg.classify(&pending(0, None, Some("cheap"))).unwrap();
        let gb = reg.classify(&pending(1, None, Some("cheap"))).unwrap();
        assert_ne!(ga, gb);
        assert_eq!(reg.locate(ga), (0, 0));
        assert_eq!(reg.locate(gb), (1, 0));
        let e = reg.classify(&pending(0, None, Some("nope"))).unwrap_err();
        assert_eq!(e, ServeError::UnknownPoint("nope".into()));
        // per-model sample lengths survive
        assert_eq!(reg.model(0).sample_len, 3);
        assert_eq!(reg.model(1).sample_len, 5);
    }

    #[test]
    fn per_model_budgets_are_independent() {
        let reg = ModelRegistry::build(&cfg(None), two_model_regs(), Instant::now()).unwrap();
        reg.model(0).budget_bits.store(0.1f64.to_bits(), Ordering::Relaxed);
        let g = reg.classify(&pending(0, None, None)).unwrap();
        assert_eq!(reg.locate(g), (0, 0), "model a capped to its cheap point");
        let g = reg.classify(&pending(1, None, None)).unwrap();
        assert_eq!(reg.locate(g), (1, 1), "model b untouched");
        // NaN budget on one model rejects only that model's requests
        reg.model(0).budget_bits.store(f64::NAN.to_bits(), Ordering::Relaxed);
        assert_eq!(
            reg.classify(&pending(0, None, None)).unwrap_err(),
            ServeError::BadBudget
        );
        assert!(reg.classify(&pending(1, None, None)).is_ok());
    }

    #[test]
    fn arbiter_equal_split_when_both_models_oversubscribe() {
        // Both models flood past any fair share: max-min collapses to
        // an equal split — the hot-in-samples model cannot push the
        // other below half the envelope, and shares always sum to it.
        let t0 = Instant::now();
        let c = cfg(Some(10.0));
        let reg = ModelRegistry::build(&c, two_model_regs(), t0).unwrap();
        // initial split is equal
        let snap = reg.snapshot();
        assert_eq!(snap.models.len(), 2);
        for m in &snap.models {
            assert!((m.envelope_share.unwrap() - 5.0).abs() < 1e-12);
        }
        // skewed flood: 1000 samples/s on a, 100/s on b, both of
        // whose needs exceed the 10 GF/s envelope
        let w = Duration::from_millis(10);
        reg.note_batch(t0 + w / 2, 0, 1, 10, 10.0, false);
        reg.note_batch(t0 + w, 0, 1, 0, 0.0, false);
        reg.note_batch(t0 + w + Duration::from_micros(1), 1, 1, 1, 2.0, false);
        reg.note_batch(t0 + w * 2, 0, 1, 10, 10.0, false);
        reg.note_batch(t0 + w * 2 + Duration::from_micros(1), 1, 1, 1, 2.0, false);
        reg.note_batch(t0 + w * 3, 0, 1, 10, 10.0, false);
        let snap = reg.snapshot();
        let a = &snap.models[0];
        let b = &snap.models[1];
        let share_sum = a.envelope_share.unwrap() + b.envelope_share.unwrap();
        assert!((share_sum - 10.0).abs() < 1e-9, "shares must sum to the envelope");
        assert!(a.demand_rate.unwrap() > b.demand_rate.unwrap());
        assert!(b.envelope_share.unwrap() >= 5.0 - 1e-9, "cold model keeps >= fair share");
    }

    #[test]
    fn arbiter_grants_cold_model_its_need_in_full() {
        // a floods (1000 samples/s at top cost 1.0); b trickles at
        // 1 sample/s with top cost 2.0, so b's steady need is
        // 1 × 2.0 × DEMAND_HEADROOM = 8 GF/s — inside the 20 GF/s
        // envelope's fair half. Max-min must satisfy b in full (plus
        // the floor) and hand a only the residual, however hard a
        // floods.
        let t0 = Instant::now();
        let c = ServerConfig {
            governor_window: Duration::from_secs(1),
            ..cfg(Some(20.0))
        };
        let reg = ModelRegistry::build(&c, two_model_regs(), t0).unwrap();
        let w = Duration::from_secs(1);
        let mut now = t0;
        for k in 1..=4u32 {
            // during each 1s window: a lands 1000 samples, b lands 1
            reg.note_batch(now + w / 2, 0, 1, 1000, 1000.0, false);
            reg.note_batch(now + w / 2, 1, 1, 1, 2.0, false);
            now = t0 + w * k;
            reg.note_batch(now, 0, 1, 0, 0.0, false);
        }
        let snap = reg.snapshot();
        let a = &snap.models[0];
        let b = &snap.models[1];
        let b_share = b.envelope_share.unwrap();
        let a_share = a.envelope_share.unwrap();
        assert!(
            (7.0..=9.0).contains(&b_share),
            "cold model must get ~its 8 GF/s need, got {b_share}"
        );
        assert!(a_share > b_share, "hot model takes the larger residual, got {a_share}");
        assert!((a_share + b_share - 20.0).abs() < 1e-9);
    }

    #[test]
    fn arbiter_share_floor_protects_a_model_idle_through_priming() {
        // Model b is completely idle while a floods through the first
        // demand windows: pure max-min would hand b literally nothing,
        // and its first request after the idle spell would breach a
        // zero target. The MIN_SHARE_FRAC floor keeps every share
        // strictly positive.
        let t0 = Instant::now();
        let reg = ModelRegistry::build(&cfg(Some(10.0)), two_model_regs(), t0).unwrap();
        let w = Duration::from_millis(10);
        reg.note_batch(t0 + w / 2, 0, 1, 100, 100.0, false);
        reg.note_batch(t0 + w, 0, 1, 0, 0.0, false); // close: b idle
        let snap = reg.snapshot();
        let b_share = snap.models[1].envelope_share.unwrap();
        let floor = 10.0 * MIN_SHARE_FRAC / 2.0;
        assert!(
            (b_share - floor).abs() < 1e-12,
            "idle model must keep the floor share, got {b_share}"
        );
        assert!(snap.models[0].envelope_share.unwrap() > b_share);
    }
}
