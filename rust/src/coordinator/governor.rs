//! Closed-loop energy governor: feed *measured* flip energy back into
//! the operating point the server runs.
//!
//! The paper's deployment story (Sec. 6) traverses the power–accuracy
//! trade-off open-loop: somebody sets a budget, [`PowerPolicy`] picks
//! the matching frontier point. That leaves exactly the gap the
//! minimum-energy-network line of work keeps pointing at — modeled
//! energy and observed energy drift apart, and nothing pushes the
//! served point back when sustained load blows the power envelope.
//!
//! The [`Governor`] closes that loop:
//!
//! 1. Workers report every executed batch — sample count plus the
//!    energy it *actually* metered ([`crate::nn::PowerMeter`] totals,
//!    surfaced by the metered engine calls in [`super::server`]) — into
//!    a sliding window ledger.
//! 2. At each window boundary the windowed energy is compared against
//!    the [`EnergyEnvelope`] target (Gflips per second — the crate's
//!    platform-free joules proxy, paper footnote 2).
//! 3. Decisions use a rolling horizon of the last `hysteresis`
//!    windows, rate-limited to **one step per horizon**: when the
//!    horizon's energy exceeds `hysteresis × target` the served
//!    budget steps one frontier point down (cheaper, less accurate);
//!    when it fits *and the same load would also fit one point up*,
//!    it steps back up. An idle horizon always fits, so quiet periods
//!    climb back to the most accurate point; judging the horizon
//!    *sum* (a rate) rather than per-window streaks means sparse or
//!    bursty overload still degrades instead of slipping between
//!    windows. A single-point menu can never oscillate: there is
//!    nowhere to step.
//!
//! The governor writes the same atomic budget cell
//! [`super::server::Client::set_budget`] writes, so the rest of the
//! stack (classification, per-request caps, pinning) is untouched.
//! With an envelope configured the governor co-owns that cell: at
//! every window close it re-derives its frontier level from whatever
//! the cell currently selects (so a manual `set_budget` is honored,
//! attributed correctly, and can never be mistaken for a higher
//! level), and whenever it *steps* it rewrites the cell with the new
//! point's exact cost. Without an envelope (`ServerBuilder` default)
//! the open-loop path is bit-identical to before.
//!
//! Determinism: the governor never reads the wall clock. Every
//! decision happens inside [`Governor::observe`], which takes the
//! current [`Instant`] as an argument — workers pass `Instant::now()`,
//! unit tests pass synthetic instants and drive the window grid by
//! hand. Workers additionally bracket execution with
//! [`Governor::batch_started`] / [`Governor::batch_finished`], so a
//! window that elapses *during* a long-running batch is not mistaken
//! for idle headroom. Size [`GovernorConfig::window`] at or above the
//! typical per-batch execution time: with much smaller windows a
//! completing batch's energy lands in a single window and reads as a
//! burst, which keeps the governor correct but conservative (it will
//! sit lower on the frontier than the true rate requires).
//!
//! [`PowerPolicy`]: super::policy::PowerPolicy

// Request-handling surface: panics are banned (see clippy.toml). The
// governor's state mutex recovers from poisoning via `into_inner`: the
// state is a monotone ledger (counters, rolling windows) that stays
// internally consistent even if a panicking worker abandoned it
// mid-update, and losing the governor entirely would freeze the served
// operating point for good.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Sustained-energy target the governor defends.
///
/// Expressed as a *rate* (Giga bit flips per second) rather than per
/// sample: per-sample budgets are what the open-loop [`PowerPolicy`]
/// already handles, while an envelope caps the total energy drawn per
/// unit time regardless of request rate — the joules-per-second proxy
/// of a thermal or battery limit, in the paper's platform-independent
/// flip units.
///
/// ```
/// use pann::coordinator::EnergyEnvelope;
/// let e = EnergyEnvelope::gflips_per_sec(50.0);
/// assert_eq!(e.rate(), 50.0);
/// ```
///
/// [`PowerPolicy`]: super::policy::PowerPolicy
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyEnvelope {
    gflips_per_sec: f64,
}

impl EnergyEnvelope {
    /// Envelope at `rate` Giga bit flips per second. Validated when
    /// the governor is built: the rate must be finite and positive.
    pub fn gflips_per_sec(rate: f64) -> EnergyEnvelope {
        EnergyEnvelope { gflips_per_sec: rate }
    }

    /// The target rate in Giga bit flips per second.
    pub fn rate(&self) -> f64 {
        self.gflips_per_sec
    }
}

/// Governor tuning knobs (see [`super::server::ServerBuilder::envelope`]).
#[derive(Clone, Copy, Debug)]
pub struct GovernorConfig {
    /// Sustained-energy target the governor defends.
    pub envelope: EnergyEnvelope,
    /// Ledger window length; decisions happen at window boundaries.
    pub window: Duration,
    /// Decision-horizon length in windows (≥ 1): each step judges the
    /// energy of the last `hysteresis` windows against
    /// `hysteresis × target`, and at most one step happens per
    /// horizon.
    pub hysteresis: u32,
    /// Closed windows kept for the per-point measured-cost ledger.
    pub ledger_windows: usize,
}

impl GovernorConfig {
    /// Default decision-window length (100 ms).
    pub const DEFAULT_WINDOW: Duration = Duration::from_millis(100);
    /// Default decision horizon, in windows.
    pub const DEFAULT_HYSTERESIS: u32 = 2;
    /// Default number of closed windows kept in the measured-cost ledger.
    pub const DEFAULT_LEDGER_WINDOWS: usize = 64;

    /// Defaults: 100 ms windows, hysteresis 2, 64-window ledger.
    pub fn new(envelope: EnergyEnvelope) -> GovernorConfig {
        GovernorConfig {
            envelope,
            window: Self::DEFAULT_WINDOW,
            hysteresis: Self::DEFAULT_HYSTERESIS,
            ledger_windows: Self::DEFAULT_LEDGER_WINDOWS,
        }
    }
}

/// Per-point metered totals of one closed window.
struct WindowRecord {
    /// `(metered samples, metered Gflips)` per frontier point.
    per_point: Vec<(u64, f64)>,
}

struct GovState {
    /// Index into `costs` currently served (ascending cost order).
    level: usize,
    /// Start of the currently accumulating window.
    window_start: Instant,
    /// Energy observed in the current window (metered when available,
    /// modeled otherwise), Giga bit flips.
    win_gflips: f64,
    win_samples: u64,
    /// Metered-only per-point accumulation of the current window.
    win_per_point: Vec<(u64, f64)>,
    /// Rolling `(samples, gflips)` of the last `hysteresis` closed
    /// windows — the decision horizon.
    recent: VecDeque<(u64, f64)>,
    /// Start instants of the batches currently executing (bracketed
    /// by [`Governor::batch_started`] / [`Governor::batch_finished`];
    /// at most one entry per worker). A window that ends after the
    /// *earliest* of these is covered by execution, not idle — the
    /// running batch's energy has not landed yet and the window must
    /// not be read as recovery headroom. Tracking each batch's own
    /// start (rather than one "busy since" anchor) matters under
    /// continuous load: back-to-back short batches keep the anchor
    /// recent, so long-past windows still read as observable and the
    /// governor can climb again without requiring a fully idle
    /// moment.
    in_flight_starts: Vec<Instant>,
    /// Closed windows since the last step (saturating): a new step
    /// needs a full horizon of fresh evidence.
    windows_since_step: u32,
    /// Frontier steps taken (up or down).
    switches: u64,
    /// Closed windows total.
    windows: u64,
    /// Closed windows spent at each level.
    residency: Vec<u64>,
    /// Metered per-point history, most recent window last.
    ledger: VecDeque<WindowRecord>,
    /// Σ |window energy − target| / target over windows that served
    /// at least one sample (envelope tracking error numerator).
    err_sum: f64,
    loaded_windows: u64,
}

impl GovState {
    fn empty(now: Instant) -> GovState {
        GovState {
            level: 0,
            window_start: now,
            win_gflips: 0.0,
            win_samples: 0,
            win_per_point: Vec::new(),
            recent: VecDeque::new(),
            in_flight_starts: Vec::new(),
            // saturated: the very first decision only waits for the
            // horizon to fill, not for an imaginary previous step
            windows_since_step: u32::MAX,
            switches: 0,
            windows: 0,
            residency: Vec::new(),
            ledger: VecDeque::new(),
            err_sum: 0.0,
            loaded_windows: 0,
        }
    }
}

/// The closed-loop governor. One per server (when an envelope is
/// configured); shared by all workers through an `Arc`.
pub struct Governor {
    cfg: GovernorConfig,
    /// Frontier point names, cheapest first (the [`PowerPolicy`]
    /// ordering, so worker point indices agree).
    ///
    /// [`PowerPolicy`]: super::policy::PowerPolicy
    names: Vec<String>,
    /// Energy cost per sample of each point, ascending.
    costs: Vec<f64>,
    /// Energy target per window (Giga bit flips), stored as `f64` bits
    /// so a fleet arbiter ([`super::registry`]) can re-split a shared
    /// envelope across models while windows are closing. On a
    /// single-model server nothing ever rewrites it, so the value is
    /// exactly the constructor's `envelope × window`.
    target_bits: AtomicU64,
    /// The served-budget cell shared with policy classification.
    budget_bits: Arc<AtomicU64>,
    state: Mutex<GovState>,
}

/// Point-in-time view of the governor for reports and benches.
#[derive(Clone, Debug)]
pub struct GovernorSnapshot {
    /// Current frontier level (index into `residency`, cheapest = 0).
    pub level: usize,
    /// Name of the currently served point.
    pub point: String,
    /// Frontier steps taken so far (up + down).
    pub switches: u64,
    /// Closed decision windows so far.
    pub windows: u64,
    /// Decision-window length.
    pub window: Duration,
    /// Envelope target per window, Giga bit flips.
    pub target_gflips_per_window: f64,
    /// Closed windows spent serving each point, cheapest first.
    pub residency: Vec<(String, u64)>,
    /// Measured Gflips/sample per point over the ledger (metered
    /// observations only; `None` where nothing was metered — e.g. a
    /// PJRT backend without a flip meter).
    pub measured_gflips_per_sample: Vec<(String, Option<f64>)>,
    /// Mean relative envelope tracking error over loaded windows
    /// (`|E_w − target| / target`); `None` before any loaded window.
    pub mean_tracking_error: Option<f64>,
}

impl GovernorSnapshot {
    /// Human-readable multi-line report (CLI / bench output).
    pub fn report(&self) -> String {
        let mut s = format!(
            "governor: point {} (level {}), {} switches over {} windows of {:?} \
             (target {:.4} GF/window)\n",
            self.point, self.level, self.switches, self.windows, self.window,
            self.target_gflips_per_window,
        );
        if let Some(e) = self.mean_tracking_error {
            s.push_str(&format!("  envelope tracking error (loaded windows): {:.1}%\n", e * 100.0));
        }
        for (i, (name, windows)) in self.residency.iter().enumerate() {
            let measured = match self.measured_gflips_per_sample[i].1 {
                Some(gf) => format!("{gf:.6} GF/sample measured"),
                None => "no metered samples".to_string(),
            };
            s.push_str(&format!("  point {name}: residency {windows} windows, {measured}\n"));
        }
        s
    }
}

impl Governor {
    /// Build a governor over `menu` (`(name, Gflips/sample)` pairs,
    /// **ascending cost** — the [`super::policy::PowerPolicy::menu`]
    /// order, so the point indices workers report match).
    ///
    /// The initial level is whatever point the budget cell currently
    /// selects (the builder's `budget_gflips`); the cell is then
    /// normalized to that point's exact cost so the governor and the
    /// policy agree from the first request.
    pub fn new(
        cfg: GovernorConfig,
        menu: Vec<(String, f64)>,
        budget_bits: Arc<AtomicU64>,
        now: Instant,
    ) -> anyhow::Result<Governor> {
        anyhow::ensure!(!menu.is_empty(), "governor needs a non-empty menu");
        let rate = cfg.envelope.rate();
        anyhow::ensure!(
            rate.is_finite() && rate > 0.0,
            "energy envelope must be a finite positive Gflips/sec rate, got {rate}"
        );
        anyhow::ensure!(!cfg.window.is_zero(), "governor window must be non-zero");
        let cfg = GovernorConfig {
            hysteresis: cfg.hysteresis.max(1),
            ledger_windows: cfg.ledger_windows.max(1),
            ..cfg
        };
        let (names, costs): (Vec<String>, Vec<f64>) = menu.into_iter().unzip();
        // strictly ascending: the budget cell is the only channel
        // between governor and policy, and two points with the same
        // cost cannot be told apart through it — a step between them
        // would immediately resync back (livelock), so duplicate-cost
        // menus are rejected up front
        anyhow::ensure!(
            costs.windows(2).all(|w| w[0] < w[1]),
            "governor menu costs must be strictly ascending (duplicate-cost points are \
             indistinguishable through the budget cell)"
        );
        let target_per_window = rate * cfg.window.as_secs_f64();
        let governor = Governor {
            cfg,
            names,
            costs,
            target_bits: AtomicU64::new(target_per_window.to_bits()),
            budget_bits,
            state: Mutex::new(GovState::empty(now)),
        };
        // start from the point the current budget already selects and
        // normalize the cell to that point's exact cost
        let budget = f64::from_bits(governor.budget_bits.load(Ordering::Relaxed));
        let level = governor.level_of(budget);
        governor
            .budget_bits
            .store(governor.costs[level].to_bits(), Ordering::Relaxed);
        let n = governor.costs.len();
        {
            let mut s = governor.state();
            s.level = level;
            s.win_per_point = vec![(0, 0.0); n];
            s.residency = vec![0; n];
        }
        Ok(governor)
    }

    /// Lock the governor state, recovering a poisoned guard (see the
    /// module-top note: the ledger stays consistent, and losing the
    /// governor would freeze the served point).
    fn state(&self) -> MutexGuard<'_, GovState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The frontier level `budget` selects — literally the
    /// [`super::policy::PowerPolicy::select`] rule (one shared
    /// implementation, so classification and governor attribution
    /// cannot drift apart).
    fn level_of(&self, budget: f64) -> usize {
        super::policy::best_fitting_index(self.costs.iter().copied(), budget)
    }

    /// Number of frontier points governed.
    pub fn n_points(&self) -> usize {
        self.costs.len()
    }

    /// The current energy target per window, Giga bit flips.
    fn target_per_window(&self) -> f64 {
        f64::from_bits(self.target_bits.load(Ordering::Relaxed))
    }

    /// Re-target the envelope this governor defends (Gflips/sec) —
    /// the fleet-arbitration hook ([`super::registry::ModelRegistry`]):
    /// when several models share one physical envelope, each model's
    /// governor defends its currently allocated *share*, and the
    /// arbiter moves the shares as observed demand shifts. Windows
    /// already closed keep the decisions they made; the new target
    /// applies from the next window close onward.
    ///
    /// Non-finite, NaN or non-positive rates are clamped to a tiny
    /// positive floor rather than rejected: a zero target would make
    /// every loaded window a breach *and* stop idle recovery-climb
    /// projections from ever fitting, wedging the model at the floor
    /// even after the demand that squeezed it out disappears.
    pub fn set_envelope_rate(&self, gflips_per_sec: f64) {
        let rate = if gflips_per_sec.is_finite() && gflips_per_sec > 0.0 {
            gflips_per_sec
        } else {
            f64::MIN_POSITIVE
        };
        let target = rate * self.cfg.window.as_secs_f64();
        self.target_bits.store(target.to_bits(), Ordering::Relaxed);
    }

    /// Report one executed chunk: `samples` samples served on frontier
    /// point `point` for `gflips` energy. `metered` says whether the
    /// energy came from an actual flip meter (feeds the per-point
    /// calibration ledger) or from the modeled per-sample cost (feeds
    /// the envelope only).
    ///
    /// All window-boundary decisions happen here, against the caller's
    /// `now` — no wall clock is read, which is what makes the governor
    /// unit-testable with synthetic instants. Elapsed windows since
    /// the last observation are closed first (idle windows count as
    /// under-envelope, so recovery happens on the first batch after a
    /// quiet period), then the observation lands in the now-current
    /// window.
    pub fn observe(&self, now: Instant, point: usize, samples: u64, gflips: f64, metered: bool) {
        let mut s = self.state();
        self.close_elapsed_windows(&mut s, now);
        s.win_gflips += gflips;
        s.win_samples += samples;
        if metered {
            if let Some(slot) = s.win_per_point.get_mut(point) {
                slot.0 += samples;
                slot.1 += gflips;
            }
        }
    }

    /// A worker is about to execute a batch (at `now`). Paired with
    /// [`Governor::batch_finished`]`(now)`, this lets the governor
    /// tell an idle gap (worker parked on the queue) from execution
    /// time (a batch running longer than a window): windows covered
    /// by a running batch have unlanded energy and must not be read
    /// as recovery headroom, or a slow engine would make the governor
    /// climb mid-batch and step back down on completion — a thrash
    /// loop.
    pub fn batch_started(&self, now: Instant) {
        let mut s = self.state();
        s.in_flight_starts.push(now);
    }

    /// The batch bracketed by [`Governor::batch_started`]`(started)`
    /// completed (its chunks already reported through
    /// [`Governor::observe`]). Pass the same instant given to
    /// `batch_started`, so the busy anchor tracks the earliest batch
    /// that is *still* running.
    pub fn batch_finished(&self, started: Instant) {
        let mut s = self.state();
        if let Some(i) = s.in_flight_starts.iter().position(|&b| b == started) {
            s.in_flight_starts.swap_remove(i);
        }
    }

    /// Current view (also closes nothing: decisions stay tied to
    /// observations, so a snapshot never mutates the schedule).
    pub fn snapshot(&self) -> GovernorSnapshot {
        let s = self.state();
        let measured = self
            .names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let (mut n, mut gf) = s.win_per_point[i];
                for w in &s.ledger {
                    n += w.per_point[i].0;
                    gf += w.per_point[i].1;
                }
                (name.clone(), if n > 0 { Some(gf / n as f64) } else { None })
            })
            .collect();
        GovernorSnapshot {
            level: s.level,
            point: self.names[s.level].clone(),
            switches: s.switches,
            windows: s.windows,
            window: self.cfg.window,
            target_gflips_per_window: self.target_per_window(),
            residency: self
                .names
                .iter()
                .cloned()
                .zip(s.residency.iter().copied())
                .collect(),
            measured_gflips_per_sample: measured,
            mean_tracking_error: if s.loaded_windows > 0 {
                Some(s.err_sum / s.loaded_windows as f64)
            } else {
                None
            },
        }
    }

    /// Close every window boundary `now` has passed, deciding at each.
    fn close_elapsed_windows(&self, s: &mut GovState, now: Instant) {
        let window = self.cfg.window;
        // After enough consecutive identical (empty) windows the state
        // is a fixed point — level at the top, counters saturated — so
        // a long idle gap does not need one iteration per window: jump
        // the grid so that at most `cap` windows remain to close. The
        // new start is recomputed from `now` (sub-window remainder
        // preserved) rather than advanced by a window count, so the
        // bound holds for arbitrarily long gaps.
        let cap = (self.cfg.hysteresis as u128)
            .saturating_mul(self.costs.len() as u128)
            .saturating_add(self.cfg.ledger_windows as u128)
            .saturating_mul(2)
            .min(4096);
        if let Some(elapsed) = now.checked_duration_since(s.window_start) {
            let win_nanos = window.as_nanos().max(1);
            let k = elapsed.as_nanos() / win_nanos;
            if k > cap {
                let rem = (elapsed.as_nanos() % win_nanos) as u64;
                let keep = window * cap as u32 + Duration::from_nanos(rem);
                if let Some(start) = now.checked_sub(keep) {
                    // the skipped windows were all empty (energy only
                    // lands through observe, which closes first) —
                    // account the elapsed time to the level the budget
                    // cell selected throughout the gap (resync first:
                    // a manual set_budget during the idle gap changed
                    // which point would have served), so
                    // `windows`/residency keep describing wall time
                    // even though only `cap` windows get decided
                    s.level = self
                        .level_of(f64::from_bits(self.budget_bits.load(Ordering::Relaxed)));
                    let skipped = (k - cap) as u64;
                    s.windows += skipped;
                    s.residency[s.level] += skipped;
                    s.window_start = start;
                }
            }
        }
        while now
            .checked_duration_since(s.window_start)
            .is_some_and(|e| e >= window)
        {
            let window_end = s.window_start + window;
            self.close_one_window(s, window_end);
            s.window_start = window_end;
        }
    }

    fn close_one_window(&self, s: &mut GovState, window_end: Instant) {
        // A client may have written the budget cell manually since the
        // last decision ([`super::server::Client::set_budget`]): start
        // from the level that cell *actually* selects, so residency is
        // attributed to the point that served the window and a breach
        // step can only ever move the budget down from there — never
        // "step down" from a stale higher level onto a budget larger
        // than the manual one.
        s.level = self.level_of(f64::from_bits(self.budget_bits.load(Ordering::Relaxed)));
        let target = self.target_per_window();
        s.windows += 1;
        s.residency[s.level] += 1;
        // infinite observed energy (an unbounded-cost point served
        // without a meter) still counts as a breach below, but would
        // poison the mean tracking error — keep the error ledger
        // finite-only
        if s.win_samples > 0 && s.win_gflips.is_finite() {
            s.err_sum += (s.win_gflips - target).abs() / target;
            s.loaded_windows += 1;
        }
        // roll the metered per-point accumulation into the ledger
        let fresh = vec![(0, 0.0); self.costs.len()];
        let rec = WindowRecord { per_point: std::mem::replace(&mut s.win_per_point, fresh) };
        s.ledger.push_back(rec);
        while s.ledger.len() > self.cfg.ledger_windows {
            s.ledger.pop_front();
        }
        let win_gflips = s.win_gflips;
        let win_samples = s.win_samples;
        s.win_gflips = 0.0;
        s.win_samples = 0;
        // The decision works on a rolling horizon of the last
        // `hysteresis` windows, not on per-window streaks: a streak
        // counter would either reset on every empty window (sparse
        // overload never degrades) or treat gaps as recovery (bursty
        // overload thrashes up and down). Summing over the horizon
        // judges the *rate*, which is what the envelope is. Steps are
        // rate-limited to one per full horizon so each step's effect
        // is observed before the next decision.
        let h = self.cfg.hysteresis as usize;
        s.recent.push_back((win_samples, win_gflips));
        while s.recent.len() > h {
            s.recent.pop_front();
        }
        if s.recent.len() == h && s.windows_since_step >= self.cfg.hysteresis {
            let (sum_samples, sum_gf) = s
                .recent
                .iter()
                .fold((0u64, 0.0f64), |(a, b), &(x, y)| (a + x, b + y));
            let horizon_target = target * h as f64;
            if sum_gf > horizon_target {
                // over the envelope: degrade one frontier point
                if s.level > 0 {
                    s.level -= 1;
                    s.switches += 1;
                    s.windows_since_step = 0;
                    self.set_budget(s.level);
                }
            } else if s.level + 1 < self.costs.len() {
                // fits here — climb only if the same horizon's load
                // would also fit one point up. A truly idle horizon
                // always fits (quiet periods recover full accuracy),
                // but a window that a still-running batch overlaps is
                // not fully observed — its energy has not landed yet
                // (regardless of what other workers landed in it), so
                // treat the horizon as unknown and hold rather than
                // climb on incomplete evidence.
                let busy = s
                    .in_flight_starts
                    .iter()
                    .min()
                    .is_some_and(|&b| b < window_end);
                let projected = if busy {
                    f64::INFINITY
                } else if sum_samples > 0 {
                    sum_samples as f64 * self.costs[s.level + 1]
                } else {
                    0.0
                };
                if projected <= horizon_target {
                    s.level += 1;
                    s.switches += 1;
                    s.windows_since_step = 0;
                    self.set_budget(s.level);
                }
            }
        }
        s.windows_since_step = s.windows_since_step.saturating_add(1);
    }

    fn set_budget(&self, level: usize) {
        self.budget_bits
            .store(self.costs[level].to_bits(), Ordering::Relaxed);
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;

    const WIN: Duration = Duration::from_secs(1);

    fn gov(costs: &[f64], rate: f64, hysteresis: u32, t0: Instant) -> (Governor, Arc<AtomicU64>) {
        let budget = Arc::new(AtomicU64::new(f64::INFINITY.to_bits()));
        let menu = costs
            .iter()
            .enumerate()
            .map(|(i, &c)| (format!("p{i}"), c))
            .collect();
        let cfg = GovernorConfig {
            envelope: EnergyEnvelope::gflips_per_sec(rate),
            window: WIN,
            hysteresis,
            ledger_windows: 8,
        };
        let g = Governor::new(cfg, menu, budget.clone(), t0).unwrap();
        (g, budget)
    }

    fn budget_of(b: &AtomicU64) -> f64 {
        f64::from_bits(b.load(Ordering::Relaxed))
    }

    #[test]
    fn starts_at_point_selected_by_current_budget() {
        let t0 = Instant::now();
        let budget = Arc::new(AtomicU64::new(3.0f64.to_bits()));
        let menu = vec![("a".into(), 1.0), ("b".into(), 2.0), ("c".into(), 4.0)];
        let cfg = GovernorConfig::new(EnergyEnvelope::gflips_per_sec(1.0));
        let g = Governor::new(cfg, menu, budget.clone(), t0).unwrap();
        let snap = g.snapshot();
        assert_eq!(snap.level, 1);
        assert_eq!(snap.point, "b");
        // budget normalized to the selected point's exact cost
        assert_eq!(budget_of(&budget), 2.0);
    }

    #[test]
    fn rejects_bad_envelope_window_and_menu() {
        let t0 = Instant::now();
        let budget = Arc::new(AtomicU64::new(f64::INFINITY.to_bits()));
        let menu = || vec![("a".to_string(), 1.0)];
        for bad in [f64::NAN, 0.0, -1.0, f64::INFINITY] {
            let cfg = GovernorConfig::new(EnergyEnvelope::gflips_per_sec(bad));
            assert!(Governor::new(cfg, menu(), budget.clone(), t0).is_err(), "rate {bad}");
        }
        let mut cfg = GovernorConfig::new(EnergyEnvelope::gflips_per_sec(1.0));
        cfg.window = Duration::ZERO;
        assert!(Governor::new(cfg, menu(), budget.clone(), t0).is_err());
        let cfg = GovernorConfig::new(EnergyEnvelope::gflips_per_sec(1.0));
        assert!(Governor::new(cfg, Vec::new(), budget.clone(), t0).is_err());
        // unsorted menus are a construction error, not a silent misrank
        let unsorted = vec![("hi".to_string(), 2.0), ("lo".to_string(), 1.0)];
        assert!(Governor::new(cfg, unsorted, budget.clone(), t0).is_err());
        // duplicate costs are indistinguishable through the budget
        // cell: stepping between them would livelock, so reject
        let dup = vec![
            ("a".to_string(), 1.0),
            ("b".to_string(), 2.0),
            ("b2".to_string(), 2.0),
        ];
        assert!(Governor::new(cfg, dup, budget, t0).is_err());
    }

    #[test]
    fn sparse_overload_still_accumulates_breach_pressure() {
        // One 10 GF batch every other window is a sustained 5 GF/sec
        // against a 1 GF/sec envelope. A per-window streak counter
        // would reset on each empty window and never degrade; the
        // rolling horizon judges the rate and must step down.
        let t0 = Instant::now();
        let (g, budget) = gov(&[1.0, 4.0], 1.0, 2, t0);
        assert_eq!(g.snapshot().level, 1);
        g.observe(t0 + WIN / 2, 1, 1, 10.0, false); // w0 loaded breach
        // closes w0 (horizon not full yet) and the empty w1 — the
        // horizon [10, 0] sums to 10 > 2 -> step down
        g.observe(t0 + WIN * 5 / 2, 1, 1, 10.0, false); // w2 loaded breach
        // closes w2 (one horizon must pass before the next step)
        g.observe(t0 + WIN * 7 / 2, 1, 0, 0.0, false);
        let snap = g.snapshot();
        assert_eq!(snap.level, 0, "sparse overload must still degrade");
        assert_eq!(budget_of(&budget), 1.0);
        assert_eq!(snap.switches, 1);
    }

    #[test]
    fn breach_steps_down_exactly_one_point_per_hysteresis_window() {
        // target 1 GF/window, hysteresis 2: two over-windows per step.
        let t0 = Instant::now();
        let (g, budget) = gov(&[1.0, 2.0, 4.0], 1.0, 2, t0);
        assert_eq!(g.snapshot().level, 2);
        // window 0 over target (observation lands inside window 0)
        g.observe(t0 + WIN / 2, 2, 1, 4.0, false);
        // closing window 0: the 2-window horizon is not full yet
        g.observe(t0 + WIN * 3 / 2, 2, 1, 4.0, false);
        assert_eq!(g.snapshot().level, 2);
        // closing window 1: horizon [4, 4] = 8 > 2 -> exactly one step
        g.observe(t0 + WIN * 5 / 2, 1, 1, 4.0, false);
        let snap = g.snapshot();
        assert_eq!(snap.level, 1);
        assert_eq!(snap.switches, 1);
        assert_eq!(budget_of(&budget), 2.0);
        // one horizon later, still breaching -> one more step, to the
        // floor (one step per hysteresis horizon, never a jump)
        g.observe(t0 + WIN * 7 / 2, 1, 1, 4.0, false);
        g.observe(t0 + WIN * 9 / 2, 0, 1, 4.0, false);
        assert_eq!(g.snapshot().level, 0);
        assert_eq!(budget_of(&budget), 1.0);
        // sustained breach at the floor: stays, no oscillation
        g.observe(t0 + WIN * 11 / 2, 0, 1, 4.0, false);
        g.observe(t0 + WIN * 13 / 2, 0, 1, 4.0, false);
        g.observe(t0 + WIN * 15 / 2, 0, 1, 4.0, false);
        let snap = g.snapshot();
        assert_eq!(snap.level, 0);
        assert_eq!(snap.switches, 2);
    }

    #[test]
    fn recovery_steps_up_when_next_point_fits() {
        // generous target: 10 GF/window; light load at the cheap point
        // projects to 1 * 2.0 = 2.0 at the next point up -> fits.
        let t0 = Instant::now();
        let budget = Arc::new(AtomicU64::new(0.5f64.to_bits())); // selects cheapest
        let menu = vec![("a".into(), 1.0), ("b".into(), 2.0), ("c".into(), 4.0)];
        let cfg = GovernorConfig {
            envelope: EnergyEnvelope::gflips_per_sec(10.0),
            window: WIN,
            hysteresis: 2,
            ledger_windows: 8,
        };
        let g = Governor::new(cfg, menu, budget.clone(), t0).unwrap();
        assert_eq!(g.snapshot().level, 0);
        g.observe(t0 + WIN / 2, 0, 1, 1.0, false);
        g.observe(t0 + WIN * 3 / 2, 0, 1, 1.0, false); // closes w0 (horizon filling)
        g.observe(t0 + WIN * 5 / 2, 0, 1, 1.0, false); // closes w1: horizon fits above -> up
        let snap = g.snapshot();
        assert_eq!(snap.level, 1);
        assert_eq!(budget_of(&budget), 2.0);
        // heavy load: projecting it to the next point up (4.0 each,
        // 5 samples/window -> 40 GF per 20-GF horizon) would blow the
        // envelope -> the governor holds rather than climb
        g.observe(t0 + WIN * 7 / 2, 1, 5, 10.0, false);
        g.observe(t0 + WIN * 9 / 2, 1, 5, 10.0, false);
        g.observe(t0 + WIN * 11 / 2, 1, 5, 10.0, false);
        g.observe(t0 + WIN * 13 / 2, 1, 5, 10.0, false);
        assert_eq!(g.snapshot().level, 1);
    }

    #[test]
    fn idle_windows_climb_back_to_the_most_accurate_point() {
        let t0 = Instant::now();
        let budget = Arc::new(AtomicU64::new(1.0f64.to_bits()));
        let menu = vec![("a".into(), 1.0), ("b".into(), 2.0), ("c".into(), 4.0)];
        let cfg = GovernorConfig {
            envelope: EnergyEnvelope::gflips_per_sec(1.0),
            window: WIN,
            hysteresis: 2,
            ledger_windows: 8,
        };
        let g = Governor::new(cfg, menu, budget.clone(), t0).unwrap();
        assert_eq!(g.snapshot().level, 0);
        // one observation long after start: the elapsed idle windows
        // are closed first, stepping up every `hysteresis` windows
        g.observe(t0 + WIN * 20, 0, 1, 1.0, false);
        let snap = g.snapshot();
        assert_eq!(snap.level, 2, "idle catch-up must climb to the top");
        assert_eq!(snap.switches, 2);
        assert_eq!(budget_of(&budget), 4.0);
    }

    #[test]
    fn single_point_menu_never_oscillates() {
        let t0 = Instant::now();
        let (g, budget) = gov(&[1.0], 1.0, 1, t0);
        for k in 1..=10u32 {
            // alternate breach and idle windows
            let gf = if k % 2 == 0 { 5.0 } else { 0.0 };
            g.observe(t0 + WIN * k - WIN / 2, 0, (gf > 0.0) as u64, gf, false);
        }
        let snap = g.snapshot();
        assert_eq!(snap.level, 0);
        assert_eq!(snap.switches, 0);
        assert_eq!(budget_of(&budget), 1.0);
        assert!(snap.windows >= 9);
    }

    #[test]
    fn ledger_reports_measured_cost_per_point_metered_only() {
        let t0 = Instant::now();
        let (g, _b) = gov(&[1.0, 2.0], 100.0, 2, t0);
        // metered observations on point 0: 4 samples, 0.8 GF
        g.observe(t0 + WIN / 4, 0, 2, 0.4, true);
        g.observe(t0 + WIN / 2, 0, 2, 0.4, true);
        // modeled observation on point 1 must NOT enter the ledger
        g.observe(t0 + WIN * 3 / 4, 1, 5, 10.0, false);
        let snap = g.snapshot();
        let m: std::collections::BTreeMap<_, _> =
            snap.measured_gflips_per_sample.into_iter().collect();
        let p0 = m["p0"].expect("point 0 has metered samples");
        assert!((p0 - 0.2).abs() < 1e-12, "{p0}");
        assert_eq!(m["p1"], None);
    }

    #[test]
    fn residency_and_tracking_error_accumulate() {
        let t0 = Instant::now();
        let (g, _b) = gov(&[1.0, 2.0], 1.0, 10, t0); // high hysteresis: no steps
        // two loaded windows at |E - 1|/1 = 1.0 and 0.5
        g.observe(t0 + WIN / 2, 1, 1, 2.0, false);
        g.observe(t0 + WIN * 3 / 2, 1, 1, 1.5, false);
        g.observe(t0 + WIN * 5 / 2, 1, 0, 0.0, false); // close w1; w2 idle
        g.observe(t0 + WIN * 7 / 2, 1, 0, 0.0, false); // close w2 (idle, no err)
        let snap = g.snapshot();
        assert_eq!(snap.windows, 3);
        let err = snap.mean_tracking_error.unwrap();
        assert!((err - 0.75).abs() < 1e-12, "{err}");
        let resid: u64 = snap.residency.iter().map(|(_, w)| w).sum();
        assert_eq!(resid, 3);
        assert_eq!(snap.residency[1].1, 3, "all windows spent at the starting level");
    }

    #[test]
    fn manual_budget_override_resyncs_level_so_breach_never_raises_budget() {
        // A client writes the budget cell directly; the governor must
        // pick up the manually-selected level at the next window close
        // — a breach there must NOT "step down" from the stale high
        // level onto a budget far above the manual one.
        let t0 = Instant::now();
        let (g, budget) = gov(&[0.1, 2.0, 4.0], 1.0, 1, t0);
        assert_eq!(g.snapshot().level, 2); // governor starts at the top
        budget.store(0.1f64.to_bits(), Ordering::Relaxed); // manual override
        g.observe(t0 + WIN / 2, 0, 1, 5.0, false); // breach traffic at "cheap"
        g.observe(t0 + WIN * 3 / 2, 0, 1, 5.0, false); // closes the breach window
        let snap = g.snapshot();
        assert_eq!(snap.level, 0, "level must resync to the manual budget");
        assert_eq!(
            budget_of(&budget),
            0.1,
            "a breach at the floor must not raise the budget"
        );
        assert_eq!(snap.residency[0].1, 1, "window attributed to the served point");
        // idle recovery still works from the resynced level
        g.observe(t0 + WIN * 11 / 2, 0, 0, 0.0, false);
        assert_eq!(g.snapshot().level, 2);
        assert_eq!(budget_of(&budget), 4.0);
    }

    #[test]
    fn infinite_observed_energy_breaches_without_poisoning_tracking_error() {
        // An unbounded-cost point served without a meter reports
        // infinite energy (see respond_batch): that must count as a
        // breach — stepping the governor down — while the mean
        // tracking error stays finite.
        let t0 = Instant::now();
        let (g, budget) = gov(&[1.0, f64::INFINITY], 1.0, 1, t0);
        assert_eq!(g.snapshot().level, 1); // starts at the "fp32" top
        g.observe(t0 + WIN / 2, 1, 1, f64::INFINITY, false);
        g.observe(t0 + WIN * 3 / 2, 1, 1, 0.5, false); // closes the inf window
        let snap = g.snapshot();
        assert_eq!(snap.level, 0, "infinite energy must breach the envelope");
        assert_eq!(budget_of(&budget), 1.0);
        assert_eq!(snap.mean_tracking_error, None, "inf window must not enter the error ledger");
        // a later finite loaded window keeps the error ledger sane
        g.observe(t0 + WIN * 5 / 2, 0, 1, 0.5, false);
        let err = g.snapshot().mean_tracking_error.unwrap();
        assert!(err.is_finite());
    }

    #[test]
    fn busy_windows_do_not_count_as_idle_recovery() {
        // Windows that a still-running batch overlaps must not be read
        // as recovery headroom — neither when they close empty (the
        // slow single-engine case) nor when another worker lands a
        // light trickle in them (the mixed pool case) — or a slow
        // batch would make the governor climb mid-flight and step
        // back down on completion (thrash).
        let t0 = Instant::now();
        let budget = Arc::new(AtomicU64::new(1.0f64.to_bits())); // start cheap
        let menu = vec![("a".into(), 1.0), ("b".into(), 4.0)];
        let cfg = GovernorConfig {
            envelope: EnergyEnvelope::gflips_per_sec(10.0), // target 10 GF/window
            window: WIN,
            hysteresis: 1,
            ledger_windows: 8,
        };
        let g = Governor::new(cfg, menu, budget.clone(), t0).unwrap();
        assert_eq!(g.snapshot().level, 0);
        // a long batch starts immediately and is still running while
        // another worker's light trickle lands (1 sample projects to
        // 4 GF at the next point up — it would fit and climb if the
        // busy overlap were ignored)
        g.batch_started(t0);
        g.observe(t0 + WIN * 3 / 2, 0, 1, 0.5, false); // trickle, other worker
        g.observe(t0 + WIN * 9 / 2, 0, 1, 0.5, false); // closes busy windows
        let snap = g.snapshot();
        assert_eq!(snap.level, 0, "windows covered by a running batch must not climb");
        assert_eq!(snap.switches, 0);
        g.batch_finished(t0);
        // a batch in flight whose start is *recent* must not block
        // recovery: the earlier windows were genuinely idle (the busy
        // anchor follows the earliest still-running batch, so
        // back-to-back short batches never pin the governor down)
        let t_probe = t0 + WIN * 9;
        g.batch_started(t_probe);
        g.observe(t_probe, 0, 1, 0.5, false);
        g.batch_finished(t_probe);
        assert_eq!(g.snapshot().level, 1, "parked-worker idle must still recover");
        assert_eq!(budget_of(&budget), 4.0);
    }

    #[test]
    fn retargeted_envelope_applies_from_next_window_close() {
        // Fleet arbitration rewrites the defended rate mid-flight: a
        // load that fit the original envelope must breach after the
        // share is cut, and a widened share must let the same load
        // climb back. Invalid rates clamp to a positive floor instead
        // of wedging the governor.
        let t0 = Instant::now();
        let (g, budget) = gov(&[1.0, 4.0], 10.0, 1, t0); // 10 GF/window
        assert_eq!(g.snapshot().level, 1);
        // 1 sample × 4 GF per window fits the 10 GF target
        g.observe(t0 + WIN / 2, 1, 1, 4.0, false);
        g.observe(t0 + WIN * 3 / 2, 1, 1, 4.0, false);
        assert_eq!(g.snapshot().level, 1);
        // the arbiter cuts this model's share to 1 GF/s: the same load
        // now breaches and the governor must step down
        g.set_envelope_rate(1.0);
        assert_eq!(g.snapshot().target_gflips_per_window, 1.0);
        g.observe(t0 + WIN * 5 / 2, 1, 1, 4.0, false);
        g.observe(t0 + WIN * 7 / 2, 0, 1, 1.0, false);
        let snap = g.snapshot();
        assert_eq!(snap.level, 0, "cut share must degrade the served point");
        assert_eq!(budget_of(&budget), 1.0);
        // share restored: the cheap-point load projects to 4 GF at the
        // next point up, which fits 10 GF/window again -> climb
        g.set_envelope_rate(10.0);
        g.observe(t0 + WIN * 9 / 2, 0, 1, 1.0, false);
        g.observe(t0 + WIN * 11 / 2, 0, 1, 1.0, false);
        assert_eq!(g.snapshot().level, 1, "restored share must climb back");
        // invalid rates clamp, they do not poison the target
        g.set_envelope_rate(f64::NAN);
        assert!(g.snapshot().target_gflips_per_window > 0.0);
        g.set_envelope_rate(0.0);
        assert!(g.snapshot().target_gflips_per_window > 0.0);
    }

    #[test]
    fn poisoned_state_recovers_instead_of_cascading_panics() {
        let t0 = Instant::now();
        let (g, budget) = gov(&[1.0, 4.0], 1.0, 1, t0);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _s = g.state.lock().unwrap();
            panic!("poison the governor");
        }));
        assert!(g.state.lock().is_err(), "governor mutex must be poisoned");
        // every entry point recovers the guard and keeps governing:
        // a breach after the poison still steps the budget down
        g.batch_started(t0);
        g.batch_finished(t0);
        g.observe(t0 + WIN / 2, 1, 1, 9.0, false);
        g.observe(t0 + WIN * 3 / 2, 1, 1, 9.0, false);
        let snap = g.snapshot();
        assert_eq!(snap.level, 0, "governing must continue after poison recovery");
        assert_eq!(budget_of(&budget), 1.0);
    }

    #[test]
    fn long_idle_gap_is_bounded_and_converges() {
        let t0 = Instant::now();
        let (g, budget) = gov(&[1.0, 2.0, 4.0], 1.0, 1, t0);
        // drive down to the floor first
        g.observe(t0 + WIN / 2, 2, 1, 9.0, false);
        g.observe(t0 + WIN * 3 / 2, 2, 1, 9.0, false);
        g.observe(t0 + WIN * 5 / 2, 2, 1, 9.0, false);
        assert_eq!(g.snapshot().level, 0);
        // a week of idle must not spin one iteration per window, and
        // must still land at the top
        g.observe(t0 + WIN * 600_000, 0, 1, 0.1, false);
        let snap = g.snapshot();
        assert_eq!(snap.level, 2);
        assert_eq!(budget_of(&budget), 4.0);
    }
}
