//! Demand-weighted envelope arbitration, shared by the fleet and the
//! shard router.
//!
//! PR 5's `FleetArbiter` split one [`EnergyEnvelope`] across the
//! models of a fleet with max-min fair water-filling over observed
//! demand. The shard router ([`crate::net::ShardRouter`]) needs the
//! *same* split across the N shards of one logical model — so the
//! mechanism lives here, once, in three layers:
//!
//! - [`fair_shares`] — the pure water-filling rule: split a total
//!   across raw "needs", smallest first, leftover spread equally.
//! - [`demand_shares`] — price a [`Demand`] (an observed request rate
//!   at a per-sample energy cost) into a need with headroom, take a
//!   per-claimant floor off the top, then [`fair_shares`] the rest.
//! - [`EnvelopeSplitter`] — the stateful windowed form: accumulate
//!   per-claimant sample counts, fold them into an EWMA demand rate at
//!   each window boundary, and answer the re-split shares. Like the
//!   [`Governor`], it never reads the wall clock — every decision
//!   happens against the caller's [`Instant`], so unit tests drive it
//!   with synthetic time.
//!
//! The fleet arbiter (`registry.rs`) and the shard router are thin
//! adapters over [`EnvelopeSplitter`]: the fleet prices each model by
//! the top cost of *its own* frontier, the shard router prices every
//! shard by the one shared frontier's top cost.
//!
//! [`EnergyEnvelope`]: super::governor::EnergyEnvelope
//! [`Governor`]: super::governor::Governor

// Request-handling surface: panics are banned (see clippy.toml). The
// splitter's mutex recovers from poisoning via `into_inner` — the
// state is a demand ledger whose worst torn update miscounts one
// window, while losing arbitration would freeze every claimant's
// envelope share.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

/// Demand headroom multiplier: a claimant's envelope "need" is
/// `observed samples/sec × per-sample cost ×` this factor. The slack
/// keeps a satisfied claimant comfortably inside its share when its
/// traffic is bursty or still ramping in the EWMA — without it a cold
/// claimant whose allocation exactly equals its average draw would
/// graze its governor threshold on every burst (or on every speed-up
/// of the flooding neighbor it interleaves with) and flap down the
/// frontier. 4× absorbs a doubled burst on top of a half-converged
/// demand estimate.
pub const DEMAND_HEADROOM: f64 = 4.0;

/// Fraction of the envelope reserved as a per-claimant share floor
/// (`total × this / n` each): a claimant that was idle through a
/// demand window is never allocated literally nothing, so traffic
/// waking it up is served (its governor climbed to the top during the
/// idle spell) without instantly breaching a zero target — the
/// splitter grants its true need at the next window close.
pub const MIN_SHARE_FRAC: f64 = 0.02;

/// EWMA blend factor for the windowed demand estimate (weight of the
/// newest window; the remainder stays on history). One half makes the
/// estimate settle within a few windows while still smoothing
/// single-window spikes. The very first closed window *primes* the
/// estimate instead of blending against the zero it was initialized
/// with — halving every claimant's opening demand would under-allocate
/// exactly when no history justifies it.
const DEMAND_EWMA_ALPHA: f64 = 0.5;

/// One claimant's observed demand: a request rate at a per-sample
/// energy price. The product `rate × unit_cost` is the Gflips/sec the
/// claimant would draw serving its whole load on that point;
/// [`demand_shares`] multiplies in [`DEMAND_HEADROOM`] on top.
#[derive(Clone, Copy, Debug)]
pub struct Demand {
    /// Observed samples/sec.
    pub rate: f64,
    /// Energy price per sample, Giga bit flips (typically the cost of
    /// the claimant's most accurate frontier point — "what full
    /// accuracy would cost").
    pub unit_cost: f64,
}

/// Max-min fair ("water-filling") split of `total` across `needs`:
/// walking the needs smallest first, each claimant gets
/// `min(need, remaining / claimants left)`; whatever is left over once
/// every need is met is spread equally. This is the allocation rule
/// that makes a hot claimant degrade before a cold one starves: a
/// small need is satisfied in full no matter how large the other
/// demands grow, while over-subscribed claimants split the residual
/// equally. (A zero-need claimant gets zero here when others are
/// over-subscribed; [`demand_shares`] guards against that with a
/// [`MIN_SHARE_FRAC`] floor taken off the top.)
///
/// Infinite needs (a frontier topped by an unbounded-cost fp32 point)
/// simply claim their full equal share; NaN needs are treated as zero.
pub fn fair_shares(total: f64, needs: &[f64]) -> Vec<f64> {
    let n = needs.len();
    if n == 0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| needs[a].total_cmp(&needs[b]));
    let mut shares = vec![0.0f64; n];
    let mut remaining = total.max(0.0);
    for (k, &i) in order.iter().enumerate() {
        let fair = remaining / (n - k) as f64;
        let need = if needs[i].is_nan() { 0.0 } else { needs[i].max(0.0) };
        let s = need.min(fair);
        shares[i] = s;
        remaining -= s;
    }
    if remaining > 0.0 {
        let bonus = remaining / n as f64;
        for s in &mut shares {
            *s += bonus;
        }
    }
    shares
}

/// [`fair_shares`] over priced [`Demand`]s: each claimant's need is
/// `rate × unit_cost × headroom`, a floor of `total × floor_frac / n`
/// is taken off the top for every claimant, and the remainder is split
/// max-min fairly over the needs. Shares always sum to `max(total, 0)`
/// (`floor_frac` is clamped to `[0, 1]`).
pub fn demand_shares(total: f64, demands: &[Demand], headroom: f64, floor_frac: f64) -> Vec<f64> {
    let n = demands.len();
    if n == 0 {
        return Vec::new();
    }
    let total = total.max(0.0);
    let needs: Vec<f64> =
        demands.iter().map(|d| d.rate * d.unit_cost * headroom).collect();
    let floor = total * floor_frac.clamp(0.0, 1.0) / n as f64;
    let mut shares = fair_shares(total - floor * n as f64, &needs);
    for s in &mut shares {
        *s += floor;
    }
    shares
}

/// The stateful, windowed splitter of one [`EnergyEnvelope`] across
/// `n` claimants (fleet models or router shards).
///
/// Callers land per-claimant sample counts through
/// [`EnvelopeSplitter::observe`]; when the caller's `now` crosses a
/// window boundary the accumulated counts are folded into an EWMA
/// demand rate (the first closed window primes it), priced into needs,
/// and re-split — the fresh shares are returned exactly once per
/// boundary for the caller to apply (re-targeting governors is the
/// caller's business: this type knows nothing about what a claimant
/// *is*).
///
/// [`EnergyEnvelope`]: super::governor::EnergyEnvelope
pub struct EnvelopeSplitter {
    total_rate: f64,
    window: Duration,
    headroom: f64,
    floor_frac: f64,
    state: Mutex<SplitState>,
}

struct SplitState {
    window_start: Instant,
    /// Samples landed per claimant since `window_start`.
    counts: Vec<u64>,
    /// EWMA samples/sec per claimant.
    demand_rate: Vec<f64>,
    /// Whether a first window has primed `demand_rate`.
    primed: bool,
    /// Current share per claimant, Gflips/sec.
    shares: Vec<f64>,
}

/// Point-in-time view of an [`EnvelopeSplitter`].
#[derive(Clone, Debug)]
pub struct SplitterSnapshot {
    /// EWMA demand estimate per claimant, samples/sec.
    pub demand_rate: Vec<f64>,
    /// Current envelope share per claimant, Gflips/sec.
    pub shares: Vec<f64>,
}

impl EnvelopeSplitter {
    /// A splitter of `total_rate` Gflips/sec across `n` claimants,
    /// re-assessed once per `window`, with the default
    /// [`DEMAND_HEADROOM`] and [`MIN_SHARE_FRAC`] parameters. Every
    /// claimant starts on an equal share.
    pub fn new(total_rate: f64, window: Duration, n: usize, now: Instant) -> EnvelopeSplitter {
        EnvelopeSplitter {
            total_rate,
            window: if window.is_zero() { Duration::from_millis(1) } else { window },
            headroom: DEMAND_HEADROOM,
            floor_frac: MIN_SHARE_FRAC,
            state: Mutex::new(SplitState {
                window_start: now,
                counts: vec![0; n],
                demand_rate: vec![0.0; n],
                primed: false,
                shares: vec![total_rate / n.max(1) as f64; n],
            }),
        }
    }

    /// The envelope rate being split, Gflips/sec.
    pub fn total_rate(&self) -> f64 {
        self.total_rate
    }

    /// Land `samples` of demand on `claimant`; when `now` has passed
    /// the window's end, fold the counts into the EWMA, re-split, and
    /// return the fresh shares (in claimant order) for the caller to
    /// apply. `unit_cost(i)` prices claimant `i`'s demand (its
    /// most-accurate-point Gflips/sample). Returns `None` inside a
    /// window — one re-split per boundary crossing, over the actual
    /// elapsed span (a long quiet gap is one long window of near-zero
    /// rate, not thousands of empty ones — bounded work by
    /// construction). Like the governor, this takes the caller's
    /// `now`: no wall clock.
    pub fn observe(
        &self,
        now: Instant,
        claimant: usize,
        samples: u64,
        unit_cost: impl Fn(usize) -> f64,
    ) -> Option<Vec<f64>> {
        let mut s = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        s.counts[claimant] += samples;
        let elapsed = now.checked_duration_since(s.window_start)?;
        if elapsed < self.window {
            return None;
        }
        let secs = elapsed.as_secs_f64().max(1e-9);
        for i in 0..s.counts.len() {
            let inst = s.counts[i] as f64 / secs;
            s.demand_rate[i] = if s.primed {
                (1.0 - DEMAND_EWMA_ALPHA) * s.demand_rate[i] + DEMAND_EWMA_ALPHA * inst
            } else {
                inst
            };
            s.counts[i] = 0;
        }
        s.primed = true;
        s.window_start = now;
        let demands: Vec<Demand> = s
            .demand_rate
            .iter()
            .enumerate()
            .map(|(i, &rate)| Demand { rate, unit_cost: unit_cost(i) })
            .collect();
        let shares = demand_shares(self.total_rate, &demands, self.headroom, self.floor_frac);
        s.shares.clone_from(&shares);
        Some(shares)
    }

    /// Current demand estimates and shares.
    pub fn snapshot(&self) -> SplitterSnapshot {
        let s = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        SplitterSnapshot { demand_rate: s.demand_rate.clone(), shares: s.shares.clone() }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sum(v: &[f64]) -> f64 {
        v.iter().sum()
    }

    #[test]
    fn fair_shares_satisfies_small_needs_first() {
        // cold needs 1, hot needs 100, total 10: cold gets its 1 in
        // full, hot gets the residual 9.
        let s = fair_shares(10.0, &[100.0, 1.0]);
        assert!((s[1] - 1.0).abs() < 1e-12);
        assert!((s[0] - 9.0).abs() < 1e-12);
        // oversubscribed on both sides: equal split
        let s = fair_shares(10.0, &[100.0, 80.0]);
        assert!((s[0] - 5.0).abs() < 1e-12 && (s[1] - 5.0).abs() < 1e-12);
        // under-subscribed: leftover spread equally, shares stay > need
        let s = fair_shares(10.0, &[1.0, 2.0]);
        assert!((s[0] - (1.0 + 3.5)).abs() < 1e-12);
        assert!((s[1] - (2.0 + 3.5)).abs() < 1e-12);
        assert!((sum(&s) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn fair_shares_handles_zero_inf_nan_and_empty() {
        assert!(fair_shares(10.0, &[]).is_empty());
        // zero-demand claimant still ends strictly positive via the
        // leftover spread when headroom exists
        let s = fair_shares(10.0, &[0.0, 1.0]);
        assert!(s[0] > 0.0);
        // an infinite need (fp32-topped frontier) takes its equal
        // share, not everything
        let s = fair_shares(10.0, &[f64::INFINITY, 1.0]);
        assert!((s[1] - 1.0).abs() < 1e-12);
        assert!((s[0] - 9.0).abs() < 1e-12);
        let s = fair_shares(10.0, &[f64::NAN, 4.0]);
        assert!(s[0].is_finite() && s[1].is_finite());
        // never over-allocates
        let s = fair_shares(5.0, &[100.0, 100.0, 100.0]);
        assert!((sum(&s) - 5.0).abs() < 1e-9);
    }

    // --- the three extraction properties, over randomized cases ---

    fn random_demands(rng: &mut Rng, n: usize) -> Vec<Demand> {
        (0..n)
            .map(|_| Demand {
                rate: rng.f64() * 1000.0,
                unit_cost: 1e-4 + rng.f64() * 2.0,
            })
            .collect()
    }

    #[test]
    fn property_shares_sum_to_envelope() {
        let mut rng = Rng::new(0xA1B1);
        for _ in 0..200 {
            let n = 1 + rng.below(6);
            let total = rng.f64() * 50.0;
            let d = random_demands(&mut rng, n);
            let s = demand_shares(total, &d, DEMAND_HEADROOM, MIN_SHARE_FRAC);
            assert_eq!(s.len(), n);
            assert!(
                (sum(&s) - total).abs() < 1e-9 * total.max(1.0),
                "shares {s:?} must sum to {total}"
            );
        }
    }

    #[test]
    fn property_monotone_in_own_demand() {
        // Raising one claimant's demand never lowers its own share
        // (and never raises anyone else's).
        let mut rng = Rng::new(0xB2C2);
        for _ in 0..200 {
            let n = 2 + rng.below(5);
            let total = 1.0 + rng.f64() * 50.0;
            let d = random_demands(&mut rng, n);
            let i = rng.below(n);
            let mut d2 = d.clone();
            d2[i].rate += 1.0 + rng.f64() * 500.0;
            let s1 = demand_shares(total, &d, DEMAND_HEADROOM, MIN_SHARE_FRAC);
            let s2 = demand_shares(total, &d2, DEMAND_HEADROOM, MIN_SHARE_FRAC);
            assert!(
                s2[i] >= s1[i] - 1e-9,
                "claimant {i}'s share fell from {} to {} when its demand rose",
                s1[i],
                s2[i]
            );
            for j in 0..n {
                if j != i {
                    assert!(
                        s2[j] <= s1[j] + 1e-9,
                        "claimant {j}'s share rose when {i}'s demand did"
                    );
                }
            }
        }
    }

    #[test]
    fn property_floor_respected() {
        // Every claimant — even one with zero demand against flooding
        // neighbors — keeps at least the MIN_SHARE_FRAC floor.
        let mut rng = Rng::new(0xC3D3);
        for _ in 0..200 {
            let n = 2 + rng.below(5);
            let total = 1.0 + rng.f64() * 50.0;
            let mut d = random_demands(&mut rng, n);
            d[0].rate = 0.0; // one idle claimant
            let s = demand_shares(total, &d, DEMAND_HEADROOM, MIN_SHARE_FRAC);
            let floor = total * MIN_SHARE_FRAC / n as f64;
            for (i, &sh) in s.iter().enumerate() {
                assert!(
                    sh >= floor - 1e-12,
                    "claimant {i} got {sh}, below the {floor} floor"
                );
            }
        }
    }

    #[test]
    fn splitter_windows_prime_then_blend() {
        let t0 = Instant::now();
        let w = Duration::from_millis(10);
        let sp = EnvelopeSplitter::new(10.0, w, 2, t0);
        // initial: equal shares, no demand
        let snap = sp.snapshot();
        assert_eq!(snap.shares, vec![5.0, 5.0]);
        assert_eq!(snap.demand_rate, vec![0.0, 0.0]);
        // inside the window: no re-split
        assert!(sp.observe(t0 + w / 2, 0, 100, |_| 1.0).is_none());
        // boundary: primed with the instantaneous rate (10k samples/s)
        let shares = sp.observe(t0 + w, 0, 0, |_| 1.0).expect("boundary re-split");
        assert!((sum(&shares) - 10.0).abs() < 1e-9);
        let snap = sp.snapshot();
        assert!((snap.demand_rate[0] - 10_000.0).abs() < 1.0, "{:?}", snap.demand_rate);
        // the idle claimant keeps exactly the floor share
        let floor = 10.0 * MIN_SHARE_FRAC / 2.0;
        assert!((snap.shares[1] - floor).abs() < 1e-12);
        // next window idle: EWMA halves the estimate instead of zeroing
        let shares = sp.observe(t0 + w * 2, 0, 0, |_| 1.0).expect("second boundary");
        assert!((sum(&shares) - 10.0).abs() < 1e-9);
        assert!((sp.snapshot().demand_rate[0] - 5_000.0).abs() < 1.0);
    }

    #[test]
    fn poisoned_splitter_keeps_arbitrating() {
        let t0 = Instant::now();
        let w = Duration::from_millis(10);
        let sp = EnvelopeSplitter::new(10.0, w, 2, t0);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _s = sp.state.lock().unwrap();
            panic!("poison the splitter");
        }));
        assert!(sp.state.lock().is_err(), "splitter mutex must be poisoned");
        // observation and snapshot recover the guard and still re-split
        assert!(sp.observe(t0 + w / 2, 0, 100, |_| 1.0).is_none());
        let shares = sp.observe(t0 + w, 0, 0, |_| 1.0).expect("boundary re-split");
        assert!((sum(&shares) - 10.0).abs() < 1e-9);
        assert!(sp.snapshot().demand_rate[0] > 0.0);
    }

    #[test]
    fn splitter_ignores_time_running_backwards() {
        let t0 = Instant::now();
        let sp = EnvelopeSplitter::new(10.0, Duration::from_millis(10), 2, t0 + Duration::from_secs(1));
        // a `now` before the window start must not panic or re-split
        assert!(sp.observe(t0, 0, 5, |_| 1.0).is_none());
    }
}
