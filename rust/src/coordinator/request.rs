//! The public request model of the serving API.
//!
//! A caller describes one inference with an [`InferRequest`] — input
//! plus QoS: a relative `deadline`, a per-request energy cap
//! (`max_gflips`), a [`Priority`] class, an optional pinned operating
//! point and a trace tag. Submitting yields a [`Ticket`]; the server
//! answers through it with `Result<Response, ServeError>`. Dropping a
//! ticket before the result arrives cancels the request if it is
//! still queued — the scheduler skips it without executing.
//!
//! Failure is typed: [`ServeError`] is the entire error surface of the
//! request path (admission, scheduling, execution), replacing the
//! seed's anyhow strings + dropped-sender `RecvError`s.

// Request-handling surface: panics are banned (see clippy.toml); fail
// with a typed `ServeError` instead.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Typed failure surface of the serving API.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Admission control shed the request: the bounded queue already
    /// holds `depth` requests.
    QueueFull { depth: usize },
    /// The request's deadline had already passed when the scheduler
    /// reached it; it was rejected without being executed.
    DeadlineExceeded,
    /// Input length does not match the menu's per-sample length.
    BadInput { expected: usize, got: usize },
    /// The request pinned an operating point that is not on the menu.
    UnknownPoint(String),
    /// The server has been shut down (or its worker died).
    ServerStopped,
    /// The backend engine failed while executing the batch.
    Engine(String),
    /// The operating-point menu handed to the policy was unusable
    /// (empty, or a point whose energy cost is NaN and therefore
    /// unrankable).
    BadMenu(String),
    /// The effective energy budget (global budget or per-request
    /// `max_gflips` cap) was NaN — rejected explicitly instead of
    /// silently falling through every comparison to the cheapest
    /// point.
    BadBudget,
    /// The request named a model that is not in the server's registry
    /// (or named any model at all on a single-model server, which has
    /// no registry).
    UnknownModel(String),
    /// The server registers several models and the request did not say
    /// which one to run ([`InferRequest::model`]); with more than one
    /// registered model there is no safe default to route to.
    ModelRequired,
    /// Serving-internal invariant failure (e.g. a shared lock poisoned
    /// by a panicking worker). The request was not executed; the server
    /// may still serve others.
    Internal(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { depth } => write!(f, "queue full ({depth} pending)"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::BadInput { expected, got } => {
                write!(f, "bad input length: expected {expected}, got {got}")
            }
            ServeError::UnknownPoint(name) => write!(f, "unknown operating point '{name}'"),
            ServeError::ServerStopped => write!(f, "server stopped"),
            ServeError::Engine(msg) => write!(f, "engine failure: {msg}"),
            ServeError::BadMenu(msg) => write!(f, "bad operating-point menu: {msg}"),
            ServeError::BadBudget => write!(f, "NaN energy budget"),
            ServeError::UnknownModel(name) => write!(f, "unknown model '{name}'"),
            ServeError::ModelRequired => {
                write!(f, "multi-model server: the request must name a model")
            }
            ServeError::Internal(msg) => write!(f, "internal serving error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Scheduling class. Higher priorities drain first when groups of
/// requests compete for a worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive: drains before every other class.
    Hi,
    /// The default class.
    Normal,
    /// Drains only when the higher lanes are empty.
    BestEffort,
}

/// Number of priority classes (queue lanes).
pub(crate) const N_PRIORITIES: usize = 3;

impl Priority {
    /// Queue-lane index, highest priority first.
    pub(crate) fn lane(self) -> usize {
        match self {
            Priority::Hi => 0,
            Priority::Normal => 1,
            Priority::BestEffort => 2,
        }
    }

    /// All classes, highest first (for reports).
    pub const ALL: [Priority; N_PRIORITIES] =
        [Priority::Hi, Priority::Normal, Priority::BestEffort];

    /// Stable lower-case label (reports, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Hi => "hi",
            Priority::Normal => "normal",
            Priority::BestEffort => "best-effort",
        }
    }
}

/// One inference request: input + per-request QoS. Built fluently,
/// then handed to [`crate::coordinator::Client::submit`], which
/// returns the [`Ticket`] the result arrives on:
///
/// ```
/// use pann::coordinator::{InferRequest, Priority};
/// use std::time::Duration;
///
/// let req = InferRequest::new(vec![0.0; 256])
///     .deadline(Duration::from_millis(20)) // start-by, else DeadlineExceeded
///     .max_gflips(0.05)                    // per-request energy cap
///     .priority(Priority::Hi)              // drains before Normal/BestEffort
///     .tag("user-42");                     // echoed on the Response
/// # let _ = req;
/// ```
#[derive(Clone, Debug)]
pub struct InferRequest {
    pub(crate) input: Vec<f32>,
    pub(crate) model: Option<String>,
    pub(crate) deadline: Option<Duration>,
    pub(crate) max_gflips: Option<f64>,
    pub(crate) priority: Priority,
    pub(crate) pin: Option<String>,
    pub(crate) tag: Option<String>,
    pub(crate) affinity: Option<String>,
}

impl InferRequest {
    /// A request with default QoS (no deadline, no cap, [`Priority::Normal`]).
    pub fn new(input: Vec<f32>) -> InferRequest {
        InferRequest {
            input,
            model: None,
            deadline: None,
            max_gflips: None,
            priority: Priority::Normal,
            pin: None,
            tag: None,
            affinity: None,
        }
    }

    /// Route to the named registered model (fleet servers,
    /// [`crate::coordinator::ServerBuilder::register`]). Required when
    /// more than one model is registered ([`ServeError::ModelRequired`]
    /// otherwise); a fleet of exactly one model routes unnamed requests
    /// to it, and a single-model server rejects any named model with
    /// [`ServeError::UnknownModel`].
    pub fn model(mut self, name: impl Into<String>) -> Self {
        self.model = Some(name.into());
        self
    }

    /// Reject (unexecuted) if not *started* within `d` of submission.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Per-request energy cap in Giga bit flips per sample. The
    /// scheduler selects under `min(global budget, max_gflips)`.
    pub fn max_gflips(mut self, g: f64) -> Self {
        self.max_gflips = Some(g);
        self
    }

    /// Scheduling class (default [`Priority::Normal`]).
    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Bypass policy selection: serve on the named operating point.
    pub fn pin_point(mut self, name: impl Into<String>) -> Self {
        self.pin = Some(name.into());
        self
    }

    /// Opaque trace tag, echoed back on the [`Response`].
    pub fn tag(mut self, t: impl Into<String>) -> Self {
        self.tag = Some(t.into());
        self
    }

    /// Shard-routing affinity key ([`crate::net::ShardRouter`]):
    /// requests sharing a key consistently land on the same shard
    /// (rendezvous hashing), so per-shard state such as warmed caches
    /// stays hot. Without a key the router spreads requests
    /// round-robin. A plain single [`crate::coordinator::Client`]
    /// ignores it.
    pub fn affinity(mut self, key: impl Into<String>) -> Self {
        self.affinity = Some(key.into());
        self
    }
}

/// One served response.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// Flattened output logits of the sample.
    pub output: Vec<f32>,
    /// Registered model that served the request (`None` on a
    /// single-model server).
    pub model: Option<String>,
    /// Operating point that served the request.
    pub point: String,
    /// Submission-to-response wall time.
    pub latency: Duration,
    /// Energy charged to this request (Giga bit flips) under the
    /// *modeled* per-sample cost of the serving point.
    pub giga_flips: f64,
    /// This request's share of the energy the engine *actually
    /// metered* for its batch (Giga bit flips); `None` when the
    /// backend has no flip meter (e.g. PJRT executables).
    pub measured_gflips: Option<f64>,
    /// Trace tag from the request, if any.
    pub tag: Option<String>,
}

/// Handle for one in-flight request.
///
/// Dropping a `Ticket` whose result has not been taken cancels the
/// request if it is still queued: the scheduler discards it without
/// executing.
pub struct Ticket {
    pub(crate) rx: mpsc::Receiver<Result<Response, ServeError>>,
    pub(crate) cancelled: Arc<AtomicBool>,
    pub(crate) done: bool,
}

impl Ticket {
    /// Block until the result arrives.
    pub fn wait(mut self) -> Result<Response, ServeError> {
        self.done = true;
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::ServerStopped),
        }
    }

    /// Wait up to `d`; `None` on timeout (the ticket stays live — call
    /// again, or drop it to cancel a still-queued request).
    pub fn wait_timeout(&mut self, d: Duration) -> Option<Result<Response, ServeError>> {
        if self.done {
            return None;
        }
        match self.rx.recv_timeout(d) {
            Ok(r) => {
                self.done = true;
                Some(r)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.done = true;
                Some(Err(ServeError::ServerStopped))
            }
        }
    }

    /// Non-blocking poll; `None` while the request is still in flight
    /// (or after the result has already been taken).
    pub fn try_get(&mut self) -> Option<Result<Response, ServeError>> {
        if self.done {
            return None;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = true;
                Some(r)
            }
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.done = true;
                Some(Err(ServeError::ServerStopped))
            }
        }
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        if !self.done {
            self.cancelled.store(true, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_chaining() {
        let r = InferRequest::new(vec![1.0, 2.0]);
        assert_eq!(r.priority, Priority::Normal);
        assert!(r.deadline.is_none() && r.max_gflips.is_none() && r.pin.is_none());
        assert!(r.model.is_none() && r.affinity.is_none());
        let r = r
            .deadline(Duration::from_millis(5))
            .max_gflips(0.25)
            .priority(Priority::Hi)
            .pin_point("p8")
            .model("resnet")
            .tag("t")
            .affinity("user-42");
        assert_eq!(r.deadline, Some(Duration::from_millis(5)));
        assert_eq!(r.max_gflips, Some(0.25));
        assert_eq!(r.priority, Priority::Hi);
        assert_eq!(r.pin.as_deref(), Some("p8"));
        assert_eq!(r.model.as_deref(), Some("resnet"));
        assert_eq!(r.tag.as_deref(), Some("t"));
        assert_eq!(r.affinity.as_deref(), Some("user-42"));
    }

    #[test]
    fn ticket_drop_sets_cancel_flag() {
        let (_tx, rx) = mpsc::channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let t = Ticket { rx, cancelled: cancelled.clone(), done: false };
        drop(t);
        assert!(cancelled.load(Ordering::Relaxed));
    }

    #[test]
    fn ticket_result_taken_only_once() {
        let (tx, rx) = mpsc::channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let mut t = Ticket { rx, cancelled: cancelled.clone(), done: false };
        assert!(t.try_get().is_none());
        tx.send(Err(ServeError::DeadlineExceeded)).unwrap();
        assert_eq!(t.try_get(), Some(Err(ServeError::DeadlineExceeded)));
        assert!(t.try_get().is_none());
        assert!(t.wait_timeout(Duration::from_millis(1)).is_none());
        drop(t);
        // result was taken: dropping must NOT flag a cancellation
        assert!(!cancelled.load(Ordering::Relaxed));
    }

    #[test]
    fn wait_on_dropped_sender_is_server_stopped() {
        let (tx, rx) = mpsc::channel::<Result<Response, ServeError>>();
        drop(tx);
        let t = Ticket { rx, cancelled: Arc::new(AtomicBool::new(false)), done: false };
        assert_eq!(t.wait(), Err(ServeError::ServerStopped));
    }

    #[test]
    fn priority_lanes_ordered() {
        assert_eq!(Priority::Hi.lane(), 0);
        assert_eq!(Priority::Normal.lane(), 1);
        assert_eq!(Priority::BestEffort.lane(), 2);
        assert!(Priority::Hi < Priority::BestEffort);
    }
}
