//! Serving metrics: latency distribution, throughput, energy.

use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
struct Inner {
    latencies_us: Vec<f64>,
    batches: u64,
    requests: u64,
    giga_flips: f64,
    per_point: std::collections::BTreeMap<String, u64>,
}

/// Thread-safe metrics collector.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

/// A point-in-time snapshot for reports.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub throughput_rps: f64,
    pub total_giga_flips: f64,
    pub per_point: Vec<(String, u64)>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    /// Record one served batch.
    pub fn record_batch(&self, point: &str, n: usize, latencies_us: &[f64], giga_flips: f64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += n as u64;
        g.giga_flips += giga_flips;
        g.latencies_us.extend_from_slice(latencies_us);
        *g.per_point.entry(point.to_string()).or_insert(0) += n as u64;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = self.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(1.0);
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            mean_batch: if g.batches > 0 { g.requests as f64 / g.batches as f64 } else { 0.0 },
            p50_us: crate::util::stats::percentile(&g.latencies_us, 50.0),
            p99_us: crate::util::stats::percentile(&g.latencies_us, 99.0),
            throughput_rps: g.requests as f64 / elapsed.max(1e-9),
            total_giga_flips: g.giga_flips,
            per_point: g.per_point.iter().map(|(k, v)| (k.clone(), *v)).collect(),
        }
    }
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} batches={} (mean batch {:.2})\nlatency p50={:.0}µs p99={:.0}µs  throughput={:.0} req/s\nenergy={:.4} Gflips total ({:.5} Gflips/req)\n",
            self.requests,
            self.batches,
            self.mean_batch,
            self.p50_us,
            self.p99_us,
            self.throughput_rps,
            self.total_giga_flips,
            self.total_giga_flips / self.requests.max(1) as f64,
        );
        for (k, v) in &self.per_point {
            s.push_str(&format!("  point {k}: {v} requests\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.record_batch("p4", 3, &[100.0, 200.0, 300.0], 0.5);
        m.record_batch("p8", 1, &[400.0], 0.4);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch, 2.0);
        assert!((s.total_giga_flips - 0.9).abs() < 1e-12);
        assert_eq!(s.per_point.len(), 2);
        assert!(s.p99_us >= s.p50_us);
    }
}
