//! Serving metrics: latency distribution (overall and per priority
//! class), throughput, energy — modeled *and* measured — and the
//! admission-control counters (shed / deadline-expired / cancelled).
//!
//! Latency percentiles are computed over a **bounded ring buffer** of
//! the most recent [`LATENCY_WINDOW`] samples per distribution (one
//! overall, one per priority lane). The seed pushed every latency into
//! an unbounded `Vec`, so a long-lived server leaked memory linearly
//! with traffic; the ring caps memory at a constant while keeping the
//! percentiles meaningful (they describe the recent window, which is
//! what an operator watches anyway). Counters (`requests`, energy
//! totals, rejections) remain exact over the server's lifetime.
//!
//! Energy is tracked twice: the *modeled* cost (menu Gflips/sample ×
//! samples — what the policy budgeted) and the *measured* cost (the
//! engine's [`crate::nn::PowerMeter`] totals, when the backend meters
//! flips). Their difference — `measured_minus_modeled_gflips`,
//! accumulated only over batches that had a meter — is the
//! modeled-vs-observed gap the closed-loop
//! [`super::governor::Governor`] exists to absorb. `point_switches`
//! counts how often consecutive batches were served by different
//! operating points (budget traversal and governor activity alike).

// Request-handling surface: panics are banned (see clippy.toml). The
// metrics mutex recovers from poisoning via `into_inner`: counters are
// monotone and a torn update at worst miscounts one batch — losing all
// observability (or cascading the panic into every reporting thread)
// is strictly worse.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use super::request::{Priority, N_PRIORITIES};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

/// Latency samples held per distribution (overall + per lane).
pub const LATENCY_WINDOW: usize = 4096;

/// Fixed-capacity ring of the most recent latency samples, plus the
/// exact all-time count.
#[derive(Default)]
struct LatencyRing {
    buf: Vec<f64>,
    /// Next write slot once the buffer is full.
    next: usize,
    /// All-time samples pushed (not capped).
    total: u64,
}

impl LatencyRing {
    fn push(&mut self, v: f64) {
        if self.buf.len() < LATENCY_WINDOW {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % LATENCY_WINDOW;
        self.total += 1;
    }

    /// The retained window, unordered (percentile sorts its own copy).
    fn samples(&self) -> &[f64] {
        &self.buf
    }
}

/// Modeled and measured energy served by one operating point.
#[derive(Default, Clone)]
struct PointStat {
    requests: u64,
    /// Metered samples / Gflips (absent for meter-less backends).
    measured_samples: u64,
    measured_gflips: f64,
}

#[derive(Default)]
struct Inner {
    latencies_us: LatencyRing,
    /// Latencies split by priority class (lane order).
    lane_latencies_us: [LatencyRing; N_PRIORITIES],
    batches: u64,
    requests: u64,
    /// Modeled energy total (menu cost × samples).
    giga_flips: f64,
    /// Measured energy total over metered batches.
    measured_giga_flips: f64,
    /// Modeled energy of exactly those batches that were metered —
    /// the apples-to-apples base for the measured-vs-modeled delta.
    modeled_when_measured: f64,
    per_point: std::collections::BTreeMap<String, PointStat>,
    /// Times consecutive batches *of the same model* were served by
    /// different points.
    point_switches: u64,
    /// Last point served per model (keyed by model name, `""` on a
    /// single-model server): the switch edge detector must be
    /// per-model, or interleaved fleet traffic would read as a switch
    /// on every batch even with every model pinned to one point.
    last_point: std::collections::BTreeMap<String, String>,
    /// Requests shed at admission (`QueueFull`).
    shed: u64,
    /// Requests rejected unexecuted (`DeadlineExceeded`).
    expired: u64,
    /// Requests rejected unexecuted for a non-deadline reason
    /// (e.g. `UnknownPoint`).
    unservable: u64,
    /// Requests discarded because the client dropped the ticket.
    cancelled: u64,
    /// Batches whose engine call failed (`ServeError::Engine`).
    engine_failures: u64,
}

/// Thread-safe metrics collector.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

/// Latency summary of one priority class.
#[derive(Clone, Debug)]
pub struct PriorityLatency {
    /// The priority class this row describes.
    pub priority: Priority,
    /// All-time requests served in this class.
    pub requests: u64,
    /// Median latency over the retained window, microseconds.
    pub p50_us: f64,
    /// 99th-percentile latency over the retained window, microseconds.
    pub p99_us: f64,
}

/// A point-in-time snapshot for reports.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// All-time requests served.
    pub requests: u64,
    /// All-time batches executed.
    pub batches: u64,
    /// Mean requests per batch (batching efficiency).
    pub mean_batch: f64,
    /// Percentiles over the retained window of recent samples
    /// ([`LATENCY_WINDOW`]), not the full history.
    pub p50_us: f64,
    /// 99th-percentile latency over the retained window, microseconds.
    pub p99_us: f64,
    /// Requests per second over the server's lifetime.
    pub throughput_rps: f64,
    /// Modeled energy total (menu Gflips/sample × samples).
    pub total_giga_flips: f64,
    /// Measured energy total (engine flip meters; metered batches).
    pub measured_giga_flips: f64,
    /// Measured − modeled, over metered batches only — positive when
    /// the menu's compiled costs undershoot reality.
    pub measured_minus_modeled_gflips: f64,
    /// Requests served per operating point (residency). On a fleet
    /// server the keys are `model:point` (each registered model keeps
    /// its own counters even when point names collide); on a
    /// single-model server they are the bare point names. Index-parallel
    /// with `per_point_measured`: both are produced by one iteration
    /// over the same per-point table and must stay that way (the
    /// report pairs them by index).
    pub per_point: Vec<(String, u64)>,
    /// Measured Gflips/sample per point, `None` where nothing was
    /// metered — the serving-side calibration the `pann-menu/v2`
    /// artifact field stores. Same order as `per_point`.
    pub per_point_measured: Vec<(String, Option<f64>)>,
    /// Times consecutive batches *of the same model* (in completion
    /// order) changed operating point — fleet traffic interleaving
    /// across models does not count. On a multi-worker pool, in-flight
    /// batches from different workers can interleave across one budget
    /// change, so this may exceed the number of budget traversals —
    /// [`crate::coordinator::GovernorSnapshot::switches`] counts
    /// actual governor steps instead.
    pub point_switches: u64,
    /// Per-priority latency, highest class first.
    pub per_priority: Vec<PriorityLatency>,
    /// Requests shed at admission (`QueueFull`).
    pub shed: u64,
    /// Requests rejected unexecuted past their deadline.
    pub expired: u64,
    /// Requests rejected unexecuted for a non-deadline reason.
    pub unservable: u64,
    /// Requests discarded because the client dropped the ticket.
    pub cancelled: u64,
    /// Batches whose engine call failed.
    pub engine_failures: u64,
}

impl Metrics {
    /// Fresh collector; the throughput clock starts now.
    pub fn new() -> Self {
        Metrics { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    /// Lock the counters, recovering a poisoned guard (see the
    /// module-top note).
    fn guard(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record one served batch: per-request `(latency µs, priority)`,
    /// the batch's *modeled* energy, and the energy the engine
    /// actually metered (`None` for meter-less backends).
    ///
    /// `model` is the registry name the batch was served for, `None`
    /// on a single-model server. The per-point table is keyed by
    /// `(model, point)` — two registered models whose compiled menus
    /// happen to share a point name (`compile_menu` names points
    /// `pt00-…` for every model) must not alias each other's residency
    /// or calibration counters, and a single-model server keeps its
    /// bare point-name keys exactly as before.
    pub fn record_batch(
        &self,
        model: Option<&str>,
        point: &str,
        lats: &[(f64, Priority)],
        giga_flips: f64,
        measured_giga_flips: Option<f64>,
    ) {
        let key = match model {
            Some(m) => format!("{m}:{point}"),
            None => point.to_string(),
        };
        let mut g = self.guard();
        g.batches += 1;
        g.requests += lats.len() as u64;
        g.giga_flips += giga_flips;
        for &(us, prio) in lats {
            g.latencies_us.push(us);
            g.lane_latencies_us[prio.lane()].push(us);
        }
        // per-model edge detection: only a genuine within-model point
        // change counts as a switch (interleaved fleet batches from
        // different models are not traversal activity)
        let inner = &mut *g;
        match inner.last_point.get_mut(model.unwrap_or("")) {
            Some(last) if last.as_str() == point => {}
            Some(last) => {
                inner.point_switches += 1;
                last.clear();
                last.push_str(point);
            }
            None => {
                inner
                    .last_point
                    .insert(model.unwrap_or("").to_string(), point.to_string());
            }
        }
        let stat = g.per_point.entry(key).or_default();
        stat.requests += lats.len() as u64;
        if let Some(m) = measured_giga_flips {
            stat.measured_samples += lats.len() as u64;
            stat.measured_gflips += m;
            g.measured_giga_flips += m;
            g.modeled_when_measured += giga_flips;
        }
    }

    /// One request shed at admission (queue full).
    pub fn record_shed(&self) {
        self.guard().shed += 1;
    }

    /// One request rejected unexecuted because its deadline passed.
    pub fn record_expired(&self) {
        self.guard().expired += 1;
    }

    /// One request rejected unexecuted for a non-deadline reason
    /// (e.g. an unknown pinned point).
    pub fn record_unservable(&self) {
        self.guard().unservable += 1;
    }

    /// One request discarded because its ticket was dropped.
    pub fn record_cancelled(&self) {
        self.guard().cancelled += 1;
    }

    /// One failed engine call (all requests of the batch got
    /// `ServeError::Engine`).
    pub fn record_engine_failure(&self) {
        self.guard().engine_failures += 1;
    }

    /// Point-in-time snapshot of every counter and distribution.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.guard();
        let elapsed = self.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(1.0);
        let per_priority = Priority::ALL
            .iter()
            .map(|&p| {
                let lane = &g.lane_latencies_us[p.lane()];
                PriorityLatency {
                    priority: p,
                    requests: lane.total,
                    p50_us: crate::util::stats::percentile(lane.samples(), 50.0),
                    p99_us: crate::util::stats::percentile(lane.samples(), 99.0),
                }
            })
            .collect();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            mean_batch: if g.batches > 0 { g.requests as f64 / g.batches as f64 } else { 0.0 },
            p50_us: crate::util::stats::percentile(g.latencies_us.samples(), 50.0),
            p99_us: crate::util::stats::percentile(g.latencies_us.samples(), 99.0),
            throughput_rps: g.requests as f64 / elapsed.max(1e-9),
            total_giga_flips: g.giga_flips,
            measured_giga_flips: g.measured_giga_flips,
            measured_minus_modeled_gflips: g.measured_giga_flips - g.modeled_when_measured,
            per_point: g.per_point.iter().map(|(k, v)| (k.clone(), v.requests)).collect(),
            per_point_measured: g
                .per_point
                .iter()
                .map(|(k, v)| {
                    let m = if v.measured_samples > 0 {
                        Some(v.measured_gflips / v.measured_samples as f64)
                    } else {
                        None
                    };
                    (k.clone(), m)
                })
                .collect(),
            point_switches: g.point_switches,
            per_priority,
            shed: g.shed,
            expired: g.expired,
            unservable: g.unservable,
            cancelled: g.cancelled,
            engine_failures: g.engine_failures,
        }
    }

    /// Latency samples currently held (overall ring) — bounded by
    /// [`LATENCY_WINDOW`] no matter how many requests were served.
    #[cfg(test)]
    fn held_latency_samples(&self) -> usize {
        self.guard().latencies_us.buf.len()
    }
}

impl MetricsSnapshot {
    /// Human-readable multi-line report (CLI / bench output).
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} batches={} (mean batch {:.2})\nlatency p50={:.0}µs p99={:.0}µs  throughput={:.0} req/s\nenergy={:.4} Gflips total ({:.5} Gflips/req)\n",
            self.requests,
            self.batches,
            self.mean_batch,
            self.p50_us,
            self.p99_us,
            self.throughput_rps,
            self.total_giga_flips,
            self.total_giga_flips / self.requests.max(1) as f64,
        );
        if self.measured_giga_flips > 0.0 {
            s.push_str(&format!(
                "measured energy={:.4} Gflips (measured − modeled: {:+.4})\n",
                self.measured_giga_flips, self.measured_minus_modeled_gflips
            ));
        }
        if self.point_switches > 0 {
            s.push_str(&format!("operating-point switches: {}\n", self.point_switches));
        }
        if self.shed + self.expired + self.unservable + self.cancelled + self.engine_failures > 0 {
            s.push_str(&format!(
                "rejected: {} shed (queue full), {} past deadline, {} unservable, {} cancelled, {} engine failures\n",
                self.shed, self.expired, self.unservable, self.cancelled, self.engine_failures
            ));
        }
        for pl in &self.per_priority {
            if pl.requests > 0 {
                s.push_str(&format!(
                    "  class {:<12} {} requests  p50={:.0}µs p99={:.0}µs\n",
                    pl.priority.name(),
                    pl.requests,
                    pl.p50_us,
                    pl.p99_us
                ));
            }
        }
        for (i, (k, v)) in self.per_point.iter().enumerate() {
            let measured = match self.per_point_measured.get(i).and_then(|(_, m)| *m) {
                Some(gf) => format!(" ({gf:.6} GF/sample measured)"),
                None => String::new(),
            };
            s.push_str(&format!("  point {k}: {v} requests{measured}\n"));
        }
        s
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.record_batch(
            None,
            "p4",
            &[
                (100.0, Priority::Hi),
                (200.0, Priority::Normal),
                (300.0, Priority::Normal),
            ],
            0.5,
            None,
        );
        m.record_batch(None, "p8", &[(400.0, Priority::BestEffort)], 0.4, None);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch, 2.0);
        assert!((s.total_giga_flips - 0.9).abs() < 1e-12);
        assert_eq!(s.per_point.len(), 2);
        assert!(s.p99_us >= s.p50_us);
        assert_eq!(s.per_priority.len(), 3);
        assert_eq!(s.per_priority[0].requests, 1); // Hi
        assert_eq!(s.per_priority[1].requests, 2); // Normal
        assert_eq!(s.per_priority[2].requests, 1); // BestEffort
        assert_eq!(s.per_priority[0].p50_us, 100.0);
        // two points, two batches -> one switch
        assert_eq!(s.point_switches, 1);
    }

    #[test]
    fn rejection_counters() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_expired();
        m.record_unservable();
        m.record_cancelled();
        m.record_engine_failure();
        let s = m.snapshot();
        assert_eq!(
            (s.shed, s.expired, s.unservable, s.cancelled, s.engine_failures),
            (2, 1, 1, 1, 1)
        );
        assert!(s.report().contains("2 shed"));
    }

    #[test]
    fn latency_memory_bounded_under_sustained_load() {
        // the seed grew an unbounded Vec per latency sample; the ring
        // must hold at most LATENCY_WINDOW samples no matter the load
        let m = Metrics::new();
        let n = LATENCY_WINDOW as u64 * 8;
        for i in 0..n {
            m.record_batch(None, "p", &[(i as f64, Priority::Normal)], 0.01, None);
        }
        assert_eq!(m.held_latency_samples(), LATENCY_WINDOW);
        let s = m.snapshot();
        // exact counters survive the capping
        assert_eq!(s.requests, n);
        assert_eq!(s.per_priority[1].requests, n);
        // percentiles describe the *recent* window: the oldest
        // retained sample is n - LATENCY_WINDOW
        assert!(s.p50_us >= (n - LATENCY_WINDOW as u64) as f64);
        assert!(s.p99_us >= s.p50_us);
    }

    #[test]
    fn measured_vs_modeled_delta_and_per_point_calibration() {
        let m = Metrics::new();
        // metered batch: modeled 0.5, measured 0.6 -> delta +0.1
        let two = [(100.0, Priority::Normal), (110.0, Priority::Normal)];
        m.record_batch(None, "p4", &two, 0.5, Some(0.6));
        // meter-less batch: counts toward modeled total only
        m.record_batch(None, "p4", &[(120.0, Priority::Normal)], 0.25, None);
        let s = m.snapshot();
        assert!((s.total_giga_flips - 0.75).abs() < 1e-12);
        assert!((s.measured_giga_flips - 0.6).abs() < 1e-12);
        assert!((s.measured_minus_modeled_gflips - 0.1).abs() < 1e-12);
        // per-point calibration: 0.6 GF over 2 metered samples
        assert_eq!(s.per_point_measured.len(), 1);
        let (name, measured) = &s.per_point_measured[0];
        assert_eq!(name, "p4");
        assert!((measured.unwrap() - 0.3).abs() < 1e-12);
        assert!(s.report().contains("measured energy"));
    }

    #[test]
    fn switch_counter_tracks_point_changes_only() {
        let m = Metrics::new();
        let lat = [(1.0, Priority::Normal)];
        m.record_batch(None, "a", &lat, 0.1, None);
        m.record_batch(None, "a", &lat, 0.1, None); // same point: no switch
        m.record_batch(None, "b", &lat, 0.2, None); // a -> b
        m.record_batch(None, "a", &lat, 0.1, None); // b -> a
        assert_eq!(m.snapshot().point_switches, 2);
    }

    #[test]
    fn poisoned_metrics_keep_counting() {
        let m = Metrics::new();
        m.record_shed();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.inner.lock().unwrap();
            panic!("poison the metrics");
        }));
        assert!(m.inner.lock().is_err(), "metrics mutex must be poisoned");
        // counting and snapshots recover the guard instead of panicking
        m.record_shed();
        m.record_batch(None, "p", &[(1.0, Priority::Normal)], 0.1, None);
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.requests, 1);
    }

    #[test]
    fn fleet_switch_counter_is_per_model() {
        // interleaved fleet traffic with every model pinned to one
        // point must count ZERO switches — model interleaving is not
        // frontier traversal
        let m = Metrics::new();
        let lat = [(1.0, Priority::Normal)];
        for _ in 0..3 {
            m.record_batch(Some("hot"), "p", &lat, 0.1, None);
            m.record_batch(Some("cold"), "p", &lat, 0.1, None);
        }
        assert_eq!(m.snapshot().point_switches, 0);
        // a genuine within-model change still counts, once
        m.record_batch(Some("hot"), "q", &lat, 0.1, None);
        m.record_batch(Some("cold"), "p", &lat, 0.1, None);
        assert_eq!(m.snapshot().point_switches, 1);
        // ...and the per-point residency table stays model-qualified
        let s = m.snapshot();
        let keys: Vec<&str> = s.per_point.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["cold:p", "hot:p", "hot:q"]);
    }

    #[test]
    fn ring_exactly_at_window_keeps_every_sample() {
        // the boundary case: the LATENCY_WINDOW-th sample must still
        // land in the unwrapped buffer, and the percentiles must be
        // computed over all of it
        let m = Metrics::new();
        for i in 0..LATENCY_WINDOW {
            m.record_batch(None, "p", &[(i as f64, Priority::Normal)], 0.0, None);
        }
        assert_eq!(m.held_latency_samples(), LATENCY_WINDOW);
        let s = m.snapshot();
        // sorted samples are 0..=4095: rank(p50) = 0.5 * 4095
        assert!((s.p50_us - 2047.5).abs() < 1e-9, "p50 {}", s.p50_us);
        // rank(p99) = 0.99 * 4095 = 4054.05, interpolated
        assert!((s.p99_us - 4054.05).abs() < 1e-6, "p99 {}", s.p99_us);
    }

    #[test]
    fn ring_evicts_exactly_the_oldest_at_window_plus_one() {
        // one past the boundary: sample 0 (and only sample 0) must
        // leave the window, shifting both percentiles up by exactly 1
        let m = Metrics::new();
        for i in 0..=LATENCY_WINDOW {
            m.record_batch(None, "p", &[(i as f64, Priority::Normal)], 0.0, None);
        }
        assert_eq!(m.held_latency_samples(), LATENCY_WINDOW, "capacity must not grow");
        let s = m.snapshot();
        assert_eq!(s.requests, LATENCY_WINDOW as u64 + 1, "exact counters keep counting");
        // retained samples are 1..=4096
        assert!((s.p50_us - 2048.5).abs() < 1e-9, "p50 {}", s.p50_us);
        assert!((s.p99_us - 4055.05).abs() < 1e-6, "p99 {}", s.p99_us);
    }

    #[test]
    fn percentiles_on_tiny_windows_interpolate_exactly() {
        // 1 sample: p50 == p99 == the sample
        let m = Metrics::new();
        m.record_batch(None, "p", &[(42.0, Priority::Normal)], 0.0, None);
        let s = m.snapshot();
        assert_eq!(s.p50_us, 42.0);
        assert_eq!(s.p99_us, 42.0);

        // 2 samples a=100, b=300: p50 = midpoint, p99 = a + 0.99(b-a)
        let m = Metrics::new();
        for v in [100.0, 300.0] {
            m.record_batch(None, "p", &[(v, Priority::Normal)], 0.0, None);
        }
        let s = m.snapshot();
        assert!((s.p50_us - 200.0).abs() < 1e-9, "p50 {}", s.p50_us);
        assert!((s.p99_us - 298.0).abs() < 1e-6, "p99 {}", s.p99_us);

        // 3 samples: p50 is the middle one, p99 interpolates the top
        let m = Metrics::new();
        for v in [30.0, 10.0, 20.0] {
            m.record_batch(None, "p", &[(v, Priority::Normal)], 0.0, None);
        }
        let s = m.snapshot();
        assert!((s.p50_us - 20.0).abs() < 1e-9, "p50 {}", s.p50_us);
        // rank = 0.99 * 2 = 1.98: 0.02 * 20 + 0.98 * 30
        assert!((s.p99_us - 29.8).abs() < 1e-6, "p99 {}", s.p99_us);
    }
}
