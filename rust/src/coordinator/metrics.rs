//! Serving metrics: latency distribution (overall and per priority
//! class), throughput, energy, and the admission-control counters
//! (shed / deadline-expired / cancelled).

use super::request::{Priority, N_PRIORITIES};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Default)]
struct Inner {
    latencies_us: Vec<f64>,
    /// Latencies split by priority class (lane order).
    lane_latencies_us: [Vec<f64>; N_PRIORITIES],
    batches: u64,
    requests: u64,
    giga_flips: f64,
    per_point: std::collections::BTreeMap<String, u64>,
    /// Requests shed at admission (`QueueFull`).
    shed: u64,
    /// Requests rejected unexecuted (`DeadlineExceeded`).
    expired: u64,
    /// Requests rejected unexecuted for a non-deadline reason
    /// (e.g. `UnknownPoint`).
    unservable: u64,
    /// Requests discarded because the client dropped the ticket.
    cancelled: u64,
    /// Batches whose engine call failed (`ServeError::Engine`).
    engine_failures: u64,
}

/// Thread-safe metrics collector.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Option<Instant>,
}

/// Latency summary of one priority class.
#[derive(Clone, Debug)]
pub struct PriorityLatency {
    pub priority: Priority,
    pub requests: u64,
    pub p50_us: f64,
    pub p99_us: f64,
}

/// A point-in-time snapshot for reports.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub throughput_rps: f64,
    pub total_giga_flips: f64,
    pub per_point: Vec<(String, u64)>,
    /// Per-priority latency, highest class first.
    pub per_priority: Vec<PriorityLatency>,
    pub shed: u64,
    pub expired: u64,
    pub unservable: u64,
    pub cancelled: u64,
    pub engine_failures: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics { inner: Mutex::new(Inner::default()), started: Some(Instant::now()) }
    }

    /// Record one served batch: per-request `(latency µs, priority)`
    /// plus the batch's total energy.
    pub fn record_batch(&self, point: &str, lats: &[(f64, Priority)], giga_flips: f64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += lats.len() as u64;
        g.giga_flips += giga_flips;
        for &(us, prio) in lats {
            g.latencies_us.push(us);
            g.lane_latencies_us[prio.lane()].push(us);
        }
        *g.per_point.entry(point.to_string()).or_insert(0) += lats.len() as u64;
    }

    /// One request shed at admission (queue full).
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// One request rejected unexecuted because its deadline passed.
    pub fn record_expired(&self) {
        self.inner.lock().unwrap().expired += 1;
    }

    /// One request rejected unexecuted for a non-deadline reason
    /// (e.g. an unknown pinned point).
    pub fn record_unservable(&self) {
        self.inner.lock().unwrap().unservable += 1;
    }

    /// One request discarded because its ticket was dropped.
    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    /// One failed engine call (all requests of the batch got
    /// `ServeError::Engine`).
    pub fn record_engine_failure(&self) {
        self.inner.lock().unwrap().engine_failures += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = self.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(1.0);
        let per_priority = Priority::ALL
            .iter()
            .map(|&p| {
                let lat = &g.lane_latencies_us[p.lane()];
                PriorityLatency {
                    priority: p,
                    requests: lat.len() as u64,
                    p50_us: crate::util::stats::percentile(lat, 50.0),
                    p99_us: crate::util::stats::percentile(lat, 99.0),
                }
            })
            .collect();
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            mean_batch: if g.batches > 0 { g.requests as f64 / g.batches as f64 } else { 0.0 },
            p50_us: crate::util::stats::percentile(&g.latencies_us, 50.0),
            p99_us: crate::util::stats::percentile(&g.latencies_us, 99.0),
            throughput_rps: g.requests as f64 / elapsed.max(1e-9),
            total_giga_flips: g.giga_flips,
            per_point: g.per_point.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            per_priority,
            shed: g.shed,
            expired: g.expired,
            unservable: g.unservable,
            cancelled: g.cancelled,
            engine_failures: g.engine_failures,
        }
    }
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} batches={} (mean batch {:.2})\nlatency p50={:.0}µs p99={:.0}µs  throughput={:.0} req/s\nenergy={:.4} Gflips total ({:.5} Gflips/req)\n",
            self.requests,
            self.batches,
            self.mean_batch,
            self.p50_us,
            self.p99_us,
            self.throughput_rps,
            self.total_giga_flips,
            self.total_giga_flips / self.requests.max(1) as f64,
        );
        if self.shed + self.expired + self.unservable + self.cancelled + self.engine_failures > 0 {
            s.push_str(&format!(
                "rejected: {} shed (queue full), {} past deadline, {} unservable, {} cancelled, {} engine failures\n",
                self.shed, self.expired, self.unservable, self.cancelled, self.engine_failures
            ));
        }
        for pl in &self.per_priority {
            if pl.requests > 0 {
                s.push_str(&format!(
                    "  class {:<12} {} requests  p50={:.0}µs p99={:.0}µs\n",
                    pl.priority.name(),
                    pl.requests,
                    pl.p50_us,
                    pl.p99_us
                ));
            }
        }
        for (k, v) in &self.per_point {
            s.push_str(&format!("  point {k}: {v} requests\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.record_batch(
            "p4",
            &[
                (100.0, Priority::Hi),
                (200.0, Priority::Normal),
                (300.0, Priority::Normal),
            ],
            0.5,
        );
        m.record_batch("p8", &[(400.0, Priority::BestEffort)], 0.4);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch, 2.0);
        assert!((s.total_giga_flips - 0.9).abs() < 1e-12);
        assert_eq!(s.per_point.len(), 2);
        assert!(s.p99_us >= s.p50_us);
        assert_eq!(s.per_priority.len(), 3);
        assert_eq!(s.per_priority[0].requests, 1); // Hi
        assert_eq!(s.per_priority[1].requests, 2); // Normal
        assert_eq!(s.per_priority[2].requests, 1); // BestEffort
        assert_eq!(s.per_priority[0].p50_us, 100.0);
    }

    #[test]
    fn rejection_counters() {
        let m = Metrics::new();
        m.record_shed();
        m.record_shed();
        m.record_expired();
        m.record_unservable();
        m.record_cancelled();
        m.record_engine_failure();
        let s = m.snapshot();
        assert_eq!(
            (s.shed, s.expired, s.unservable, s.cancelled, s.engine_failures),
            (2, 1, 1, 1, 1)
        );
        assert!(s.report().contains("2 shed"));
    }
}
