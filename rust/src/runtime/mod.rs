//! PJRT runtime: load and execute the AOT-lowered JAX/Pallas artifacts
//! (`artifacts/hlo/*.hlo.txt`, written by `python -m compile.aot`).
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). Python never runs at serving time —
//! after `make artifacts` the binary is self-contained.

pub mod artifact;
pub mod executable;

pub use artifact::{ArtifactManifest, ExecSpec};
pub use executable::{CpuRuntime, LoadedModel};
