//! PJRT runtime: load and execute the AOT-lowered JAX/Pallas artifacts
//! (`artifacts/hlo/*.hlo.txt`, written by `python -m compile.aot`).
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes
//! `HloModuleProto`s with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). Python never runs at serving time —
//! after `make artifacts` the binary is self-contained.
//!
//! The real PJRT wrapper needs the `xla` bindings, which the offline
//! registry does not carry; it is therefore gated behind the `pjrt`
//! feature. Default builds get [`executable_stub`] — same API, every
//! constructor errors — so the serving stack compiles and falls back
//! to the native integer engine.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod executable;
#[cfg(not(feature = "pjrt"))]
#[path = "executable_stub.rs"]
pub mod executable;

pub use artifact::{ArtifactManifest, ExecSpec};
pub use executable::{CpuRuntime, LoadedModel};
