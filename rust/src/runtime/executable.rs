//! PJRT CPU client wrapper: HLO text → compiled executable → run.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client (one per process is plenty).
pub struct CpuRuntime {
    client: xla::PjRtClient,
}

/// A compiled model with its static batch/input/output geometry.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    /// Fixed batch the artifact was lowered with.
    pub batch: usize,
    /// Flattened per-sample input length.
    pub sample_len: usize,
    /// Input shape including batch, as lowered.
    pub input_shape: Vec<usize>,
    /// Per-sample output length (e.g. #classes); discovered on first run.
    out_len: std::cell::Cell<usize>,
}

impl CpuRuntime {
    /// Create a PJRT CPU client.
    pub fn new() -> Result<CpuRuntime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(CpuRuntime { client })
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact lowered with batch-leading input
    /// shape `input_shape` (e.g. `[8, 1, 16, 16]`).
    pub fn load(&self, path: &Path, input_shape: &[usize]) -> Result<LoadedModel> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        let batch = input_shape[0];
        let sample_len: usize = input_shape[1..].iter().product();
        Ok(LoadedModel {
            exe,
            batch,
            sample_len,
            input_shape: input_shape.to_vec(),
            out_len: std::cell::Cell::new(0),
        })
    }
}

impl LoadedModel {
    /// Execute on a full batch (`batch × sample_len` f32s); returns the
    /// flattened outputs (`batch × out_len`).
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            input.len() == self.batch * self.sample_len,
            "input length {} != {}×{}",
            input.len(),
            self.batch,
            self.sample_len
        );
        let dims: Vec<i64> = self.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .context("reshape input literal")?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1().context("unwrap result tuple")?;
        let v: Vec<f32> = out.to_vec().context("read f32 output")?;
        if self.out_len.get() == 0 && !v.is_empty() {
            self.out_len.set(v.len() / self.batch);
        }
        Ok(v)
    }

    /// Per-sample output length (0 before the first run).
    pub fn out_len(&self) -> usize {
        self.out_len.get()
    }

    /// Run `n ≤ batch` samples by zero-padding to the static batch.
    pub fn run_padded(&self, input: &[f32], n: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(n <= self.batch && input.len() == n * self.sample_len);
        let mut full = vec![0.0f32; self.batch * self.sample_len];
        full[..input.len()].copy_from_slice(input);
        let out = self.run(&full)?;
        let ol = out.len() / self.batch;
        Ok(out[..n * ol].to_vec())
    }
}
