//! Stub PJRT runtime, compiled when the `pjrt` feature is off.
//!
//! The offline registry for this build carries no `xla_extension`
//! bindings, so the default build swaps the real PJRT wrapper
//! (`executable.rs`) for this API-identical stub: every constructor
//! fails with a clear message and the native integer engine serves
//! everything. Call sites (`coordinator`, `pann-cli serve`, the
//! `serve_e2e` example) compile unchanged and fall back gracefully.

use anyhow::{bail, Result};
use std::path::Path;

/// Stub PJRT CPU client; construction always fails.
pub struct CpuRuntime {
    _private: (),
}

/// Stub compiled model; never constructible through the public API,
/// but keeps the geometry fields the serving layer reads.
pub struct LoadedModel {
    /// Fixed batch the artifact was lowered with.
    pub batch: usize,
    /// Flattened per-sample input length.
    pub sample_len: usize,
    /// Input shape including batch, as lowered.
    pub input_shape: Vec<usize>,
}

impl CpuRuntime {
    /// Always fails: this build carries no PJRT bindings.
    pub fn new() -> Result<CpuRuntime> {
        bail!("built without the `pjrt` feature: PJRT execution is unavailable (use the native engine)")
    }

    /// Stub platform label.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Always fails: this build carries no PJRT bindings.
    pub fn load(&self, _path: &Path, _input_shape: &[usize]) -> Result<LoadedModel> {
        bail!("built without the `pjrt` feature")
    }
}

impl LoadedModel {
    /// Always fails: this build carries no PJRT bindings.
    pub fn run(&self, _input: &[f32]) -> Result<Vec<f32>> {
        bail!("built without the `pjrt` feature")
    }

    /// Always fails: this build carries no PJRT bindings.
    pub fn run_padded(&self, _input: &[f32], _n: usize) -> Result<Vec<f32>> {
        bail!("built without the `pjrt` feature")
    }

    /// Per-sample output length (0 before the first run).
    pub fn out_len(&self) -> usize {
        0
    }
}
