//! The AOT artifact manifest (`artifacts/hlo/manifest.json`).

use crate::util::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One lowered executable's description.
#[derive(Clone, Debug)]
pub struct ExecSpec {
    /// Model the executable was lowered from.
    pub model: String,
    /// `"fp32"` or `"pann-p<bits>"`.
    pub variant: String,
    /// Path of the HLO text artifact.
    pub file: PathBuf,
    /// Fixed batch the artifact was lowered with.
    pub batch: usize,
    /// Input shape including the batch dimension.
    pub input_shape: Vec<usize>,
    /// Giga bit flips per sample (0 for fp32 — treated as unbounded
    /// cost by the budget policy).
    pub giga_flips_per_sample: f64,
    /// PANN metadata when applicable.
    pub bx_tilde: Option<u32>,
    /// PANN additions budget when applicable.
    pub r: Option<f64>,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    /// Every lowered executable the manifest lists.
    pub executables: Vec<ExecSpec>,
}

impl ArtifactManifest {
    /// Load `manifest.json` from the HLO artifacts directory.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).context("parse hlo manifest")?;
        let mut executables = Vec::new();
        for e in j.req("executables")?.as_arr().context("executables array")? {
            executables.push(ExecSpec {
                model: e.req("model")?.as_str().unwrap_or("").to_string(),
                variant: e.req("variant")?.as_str().unwrap_or("").to_string(),
                file: dir.join(e.req("file")?.as_str().unwrap_or("")),
                batch: e.req("batch")?.as_usize().unwrap_or(1),
                input_shape: {
                    let mut v = vec![e.req("batch")?.as_usize().unwrap_or(1)];
                    v.extend(
                        e.req("input")?
                            .as_arr()
                            .context("input")?
                            .iter()
                            .map(|x| x.as_usize().unwrap_or(0)),
                    );
                    v
                },
                giga_flips_per_sample: e
                    .get("giga_flips_per_sample")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(0.0),
                bx_tilde: e.get("bx_tilde").and_then(|x| x.as_usize()).map(|v| v as u32),
                r: e.get("r").and_then(|x| x.as_f64()),
            });
        }
        Ok(ArtifactManifest { executables })
    }

    /// Executables of one model, PANN variants sorted by power.
    pub fn points_for(&self, model: &str) -> Vec<&ExecSpec> {
        let mut v: Vec<&ExecSpec> = self
            .executables
            .iter()
            .filter(|e| e.model == model)
            .collect();
        v.sort_by(|a, b| {
            a.giga_flips_per_sample
                .partial_cmp(&b.giga_flips_per_sample)
                .unwrap()
        });
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("pann_test_hlo");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"executables":[
              {"model":"m","variant":"fp32","file":"m_fp32.hlo.txt","batch":8,
               "input":[1,16,16],"giga_flips_per_sample":0.0},
              {"model":"m","variant":"pann-p4","file":"m_p4.hlo.txt","batch":8,
               "input":[1,16,16],"giga_flips_per_sample":0.002,"bx_tilde":7,"r":2.9}
            ]}"#,
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.executables.len(), 2);
        let pts = m.points_for("m");
        assert_eq!(pts[0].variant, "fp32");
        assert_eq!(pts[1].bx_tilde, Some(7));
        assert_eq!(pts[1].input_shape, vec![8, 1, 16, 16]);
    }
}
