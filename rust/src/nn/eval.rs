//! Dataset evaluation loops (fp32 and quantized), threaded across the
//! batch with `std::thread::scope` (the offline registry has no rayon).

use super::model::Model;
use super::quantized::QuantizedModel;
use super::tensor::Tensor;
use crate::data::Dataset;
use anyhow::Result;

/// Classification result of one evaluation.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// Correctly classified samples.
    pub correct: usize,
    /// Samples evaluated.
    pub total: usize,
    /// Giga bit flips consumed (0 for fp32 runs).
    pub giga_flips: f64,
    /// Flips per sample.
    pub flips_per_sample: f64,
}

impl EvalResult {
    /// Top-1 accuracy (0 when nothing was evaluated).
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

/// Batch a dataset slice into a tensor.
pub fn batch_tensor(ds: &Dataset, start: usize, len: usize) -> Tensor {
    let d = ds.sample_len();
    let mut shape = vec![len];
    shape.extend_from_slice(&ds.sample_shape);
    Tensor { shape, data: ds.x[start * d..(start + len) * d].to_vec() }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Number of worker threads (can be overridden with PANN_THREADS).
pub fn n_threads() -> usize {
    if let Ok(v) = std::env::var("PANN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// fp32 accuracy over a dataset.
pub fn eval_fp32(model: &Model, ds: &Dataset) -> Result<EvalResult> {
    let chunks = split(ds.len(), n_threads());
    let correct = std::thread::scope(|s| -> Result<usize> {
        let mut handles = Vec::new();
        for (start, len) in chunks {
            handles.push(s.spawn(move || -> Result<usize> {
                let x = batch_tensor(ds, start, len);
                let y = model.forward(&x)?;
                let classes = y.sample_len();
                let mut c = 0;
                for i in 0..len {
                    if argmax(&y.data[i * classes..(i + 1) * classes]) == ds.y[start + i] as usize {
                        c += 1;
                    }
                }
                Ok(c)
            }));
        }
        let mut total = 0;
        for h in handles {
            total += h.join().expect("eval worker panicked")?;
        }
        Ok(total)
    })?;
    Ok(EvalResult { correct, total: ds.len(), giga_flips: 0.0, flips_per_sample: 0.0 })
}

/// Quantized accuracy + power over a dataset.
///
/// Parallelism lives *above* the engine here: each worker thread gets
/// one contiguous dataset chunk, compiles nothing (the shared
/// [`crate::nn::ExecutionPlan`] is read-only) and runs its chunk as a
/// single batched forward with a thread-local scratch arena and
/// `threads = 1` inside the GEMMs (no nested thread explosion).
pub fn eval_quantized(qm: &QuantizedModel, ds: &Dataset) -> Result<EvalResult> {
    let plan = qm.plan();
    let chunks = split(ds.len(), n_threads());
    let (correct, flips) = std::thread::scope(|s| -> Result<(usize, f64)> {
        let mut handles = Vec::new();
        for (start, len) in chunks {
            let plan = &plan;
            handles.push(s.spawn(move || -> Result<(usize, f64)> {
                let x = batch_tensor(ds, start, len);
                let mut scratch = crate::nn::Scratch::for_plan(plan, len);
                let mut meter = plan.new_meter();
                let y = plan.forward_batch(&x, &mut scratch, &mut meter, 1)?;
                let classes = y.sample_len();
                let mut c = 0;
                for i in 0..len {
                    if argmax(&y.data[i * classes..(i + 1) * classes]) == ds.y[start + i] as usize {
                        c += 1;
                    }
                }
                Ok((c, meter.total_flips()))
            }));
        }
        let mut total = 0;
        let mut fl = 0.0;
        for h in handles {
            let (c, f) = h.join().expect("eval worker panicked")?;
            total += c;
            fl += f;
        }
        Ok((total, fl))
    })?;
    Ok(EvalResult {
        correct,
        total: ds.len(),
        giga_flips: flips / 1e9,
        flips_per_sample: flips / ds.len().max(1) as f64,
    })
}

/// Split `n` items into up to `k` contiguous chunks.
fn split(n: usize, k: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let k = k.min(n).max(1);
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        if len > 0 {
            out.push((start, len));
        }
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quantized::{QuantConfig, QuantizedModel};
    use crate::quant::ActQuantMethod;

    #[test]
    fn split_covers_everything() {
        for n in [0usize, 1, 7, 100] {
            for k in [1usize, 3, 8] {
                let chunks = split(n, k);
                let total: usize = chunks.iter().map(|(_, l)| l).sum();
                assert_eq!(total, n);
                // contiguous
                let mut pos = 0;
                for (s, l) in chunks {
                    assert_eq!(s, pos);
                    pos += l;
                }
            }
        }
    }

    #[test]
    fn fp32_eval_runs() {
        let model = Model::reference_cnn(1);
        let ds = Dataset::from_synth(crate::data::synth::digits(32, 2));
        let r = eval_fp32(&model, &ds).unwrap();
        assert_eq!(r.total, 32);
        assert!(r.correct <= 32);
    }

    #[test]
    fn quantized_eval_powers() {
        let mut model = Model::reference_cnn(3);
        let ds = Dataset::from_synth(crate::data::synth::digits(16, 4));
        let x = batch_tensor(&ds, 0, 8);
        model.record_act_stats(&x).unwrap();
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::unsigned_baseline(6, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        let r = eval_quantized(&qm, &ds).unwrap();
        assert_eq!(r.total, 16);
        assert!(r.giga_flips > 0.0);
        assert!(r.flips_per_sample > 0.0);
    }

    #[test]
    fn threaded_matches_single_threaded() {
        let mut model = Model::reference_cnn(5);
        let ds = Dataset::from_synth(crate::data::synth::digits(24, 6));
        let x = batch_tensor(&ds, 0, 12);
        model.record_act_stats(&x).unwrap();
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::unsigned_baseline(5, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        std::env::set_var("PANN_THREADS", "1");
        let single = eval_quantized(&qm, &ds).unwrap();
        std::env::set_var("PANN_THREADS", "4");
        let multi = eval_quantized(&qm, &ds).unwrap();
        std::env::remove_var("PANN_THREADS");
        assert_eq!(single.correct, multi.correct);
        assert!((single.giga_flips - multi.giga_flips).abs() < 1e-12);
    }
}
