//! GEMM kernels and im2col — the engine's hot path.
//!
//! Three kernels: f32 (reference forward), i32 (quantized baselines)
//! and a dual i32 kernel for the W⁺/W⁻ unsigned split that reuses each
//! activation tile for both banks (the activation-reuse argument of the
//! paper's App. A.8, and the same reuse the L1 Pallas kernel performs
//! in VMEM).
//!
//! All kernels compute `out[m][n] = Σ_k a[m][k] · b[n][k]` — note `b`
//! is pre-transposed (`[n][k]`, i.e. weights stored `[out][in]`), which
//! makes the inner loop a contiguous dot product on both operands.

/// f32 GEMM: `out[m][n] = Σ_k a[m*K+k] * bt[n*K+k]`.
///
/// Four parallel accumulators break the loop-carried dependency of a
/// naive dot product so the compiler can keep several FMA chains in
/// flight (§Perf in EXPERIMENTS.md: ~3× over the naive loop).
pub fn gemm_f32(a: &[f32], bt: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let br = &bt[j * k..(j + 1) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let chunks = k / 4 * 4;
            let mut kk = 0;
            while kk < chunks {
                a0 += ar[kk] * br[kk];
                a1 += ar[kk + 1] * br[kk + 1];
                a2 += ar[kk + 2] * br[kk + 2];
                a3 += ar[kk + 3] * br[kk + 3];
                kk += 4;
            }
            let mut acc = (a0 + a1) + (a2 + a3);
            for kk in chunks..k {
                acc += ar[kk] * br[kk];
            }
            or[j] = acc;
        }
    }
}

/// i32 GEMM with i64 accumulation.
pub fn gemm_i32(a: &[i32], bt: &[i32], out: &mut [i64], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let br = &bt[j * k..(j + 1) * k];
            // i32 products accumulated pairwise in i64 with four
            // parallel chains (values are quantization codes, far from
            // overflowing the intermediate i64s).
            let (mut a0, mut a1, mut a2, mut a3) = (0i64, 0i64, 0i64, 0i64);
            let chunks = k / 4 * 4;
            let mut kk = 0;
            while kk < chunks {
                a0 += ar[kk] as i64 * br[kk] as i64;
                a1 += ar[kk + 1] as i64 * br[kk + 1] as i64;
                a2 += ar[kk + 2] as i64 * br[kk + 2] as i64;
                a3 += ar[kk + 3] as i64 * br[kk + 3] as i64;
                kk += 4;
            }
            let mut acc = (a0 + a1) + (a2 + a3);
            for kk in chunks..k {
                acc += ar[kk] as i64 * br[kk] as i64;
            }
            or[j] = acc;
        }
    }
}

/// Dual-bank i32 GEMM: one pass computes `pos·a` and `neg·a`, reusing
/// the `a` tile; returns into `out = pos_result - neg_result` while
/// also accumulating the per-bank L1 statistics needed for power
/// accounting of the unsigned/PANN paths.
pub fn gemm_i32_split(
    a: &[i32],
    pos_t: &[i32],
    neg_t: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(pos_t.len(), n * k);
    assert_eq!(neg_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let pr = &pos_t[j * k..(j + 1) * k];
            let nr = &neg_t[j * k..(j + 1) * k];
            // The subtraction distributes over the accumulation, so a
            // single combined chain `x·(p−n)` halves the multiply count
            // while reusing the x tile for both banks (the VMEM-reuse
            // story of the L1 kernel, and ~2× on this path).
            let (mut a0, mut a1, mut a2, mut a3) = (0i64, 0i64, 0i64, 0i64);
            let chunks = k / 4 * 4;
            let mut kk = 0;
            while kk < chunks {
                a0 += ar[kk] as i64 * (pr[kk] as i64 - nr[kk] as i64);
                a1 += ar[kk + 1] as i64 * (pr[kk + 1] as i64 - nr[kk + 1] as i64);
                a2 += ar[kk + 2] as i64 * (pr[kk + 2] as i64 - nr[kk + 2] as i64);
                a3 += ar[kk + 3] as i64 * (pr[kk + 3] as i64 - nr[kk + 3] as i64);
                kk += 4;
            }
            let mut acc = (a0 + a1) + (a2 + a3);
            for kk in chunks..k {
                acc += ar[kk] as i64 * (pr[kk] as i64 - nr[kk] as i64);
            }
            or[j] = acc;
        }
    }
}

/// i32 GEMM with *narrow* (i32) accumulation — valid only when the
/// caller guarantees `max|a| · max|b| · k < 2^31` (quantization codes
/// are small, so the quantized executor proves this bound at prepare
/// time and picks this ~3× faster vectorizable path).
pub fn gemm_i32_narrow(a: &[i32], bt: &[i32], out: &mut [i64], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let br = &bt[j * k..(j + 1) * k];
            let mut acc = 0i32;
            for kk in 0..k {
                acc = acc.wrapping_add(ar[kk].wrapping_mul(br[kk]));
            }
            or[j] = acc as i64;
        }
    }
}

/// Narrow-accumulation variant of [`gemm_i32_split`]; same overflow
/// precondition as [`gemm_i32_narrow`].
pub fn gemm_i32_split_narrow(
    a: &[i32],
    pos_t: &[i32],
    neg_t: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(pos_t.len(), n * k);
    assert_eq!(neg_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let pr = &pos_t[j * k..(j + 1) * k];
            let nr = &neg_t[j * k..(j + 1) * k];
            let mut acc = 0i32;
            for kk in 0..k {
                acc = acc.wrapping_add(ar[kk].wrapping_mul(pr[kk] - nr[kk]));
            }
            or[j] = acc as i64;
        }
    }
}

/// im2col for NCHW convolution: input `[c, h, w]` (one sample) into
/// columns `[oh*ow, c*kh*kw]` with given stride/pad (zero padding).
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = c * kh * kw;
    out.clear();
    out.resize(oh * ow * cols, 0.0);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * cols;
            for ci in 0..c {
                for ky in 0..kh {
                    let iy = oy * stride + ky;
                    if iy < pad || iy - pad >= h {
                        continue;
                    }
                    let iy = iy - pad;
                    for kx in 0..kw {
                        let ix = ox * stride + kx;
                        if ix < pad || ix - pad >= w {
                            continue;
                        }
                        let ix = ix - pad;
                        out[row + ci * kh * kw + ky * kw + kx] = x[ci * h * w + iy * w + ix];
                    }
                }
            }
        }
    }
    (oh, ow)
}

/// Output spatial size of a convolution.
pub fn conv_out_size(h: usize, w: usize, kh: usize, kw: usize, stride: usize, pad: usize) -> (usize, usize) {
    ((h + 2 * pad - kh) / stride + 1, (w + 2 * pad - kw) / stride + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn f32_gemm_matches_naive() {
        let (m, n, k) = (3, 4, 5);
        let mut r = Rng::new(1);
        let a: Vec<f32> = (0..m * k).map(|_| r.normal() as f32).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| r.normal() as f32).collect();
        let mut out = vec![0.0; m * n];
        gemm_f32(&a, &bt, &mut out, m, n, k);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| a[i * k + kk] * bt[j * k + kk]).sum();
                assert!((out[i * n + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn split_gemm_equals_signed_gemm() {
        // Sec. 4's claim: splitting W into W⁺/W⁻ is functionally exact.
        let (m, n, k) = (4, 6, 16);
        let mut r = Rng::new(2);
        let a: Vec<i32> = (0..m * k).map(|_| r.range_i64(0, 16) as i32).collect();
        let w: Vec<i32> = (0..n * k).map(|_| r.range_i64(-8, 8) as i32).collect();
        let pos: Vec<i32> = w.iter().map(|&v| v.max(0)).collect();
        let neg: Vec<i32> = w.iter().map(|&v| (-v).max(0)).collect();
        let mut out_signed = vec![0i64; m * n];
        let mut out_split = vec![0i64; m * n];
        gemm_i32(&a, &w, &mut out_signed, m, n, k);
        gemm_i32_split(&a, &pos, &neg, &mut out_split, m, n, k);
        assert_eq!(out_signed, out_split);
    }

    #[test]
    fn narrow_matches_wide_within_bounds() {
        let (m, n, k) = (5, 7, 33);
        let mut r = Rng::new(9);
        let a: Vec<i32> = (0..m * k).map(|_| r.range_i64(0, 256) as i32).collect();
        let w: Vec<i32> = (0..n * k).map(|_| r.range_i64(-127, 128) as i32).collect();
        let pos: Vec<i32> = w.iter().map(|&v| v.max(0)).collect();
        let neg: Vec<i32> = w.iter().map(|&v| (-v).max(0)).collect();
        let mut wide = vec![0i64; m * n];
        let mut narrow = vec![0i64; m * n];
        gemm_i32(&a, &w, &mut wide, m, n, k);
        gemm_i32_narrow(&a, &w, &mut narrow, m, n, k);
        assert_eq!(wide, narrow);
        gemm_i32_split(&a, &pos, &neg, &mut wide, m, n, k);
        gemm_i32_split_narrow(&a, &pos, &neg, &mut narrow, m, n, k);
        assert_eq!(wide, narrow);
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: columns equal the input pixels.
        let x: Vec<f32> = (0..2 * 3 * 3).map(|i| i as f32).collect();
        let mut cols = Vec::new();
        let (oh, ow) = im2col(&x, 2, 3, 3, 1, 1, 1, 0, &mut cols);
        assert_eq!((oh, ow), (3, 3));
        for p in 0..9 {
            assert_eq!(cols[p * 2], x[p]);
            assert_eq!(cols[p * 2 + 1], x[9 + p]);
        }
    }

    #[test]
    fn im2col_padding_zeroes() {
        let x = vec![1.0f32; 1 * 2 * 2];
        let mut cols = Vec::new();
        let (oh, ow) = im2col(&x, 1, 2, 2, 3, 3, 1, 1, &mut cols);
        assert_eq!((oh, ow), (2, 2));
        // top-left output: kernel overlaps 1 row/col of padding
        let c0 = &cols[0..9];
        assert_eq!(c0, &[0., 0., 0., 0., 1., 1., 0., 1., 1.]);
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        let (c, h, w, co, kh, kw, stride, pad) = (2, 5, 5, 3, 3, 3, 1, 1);
        let mut r = Rng::new(3);
        let x: Vec<f32> = (0..c * h * w).map(|_| r.normal() as f32).collect();
        let wt: Vec<f32> = (0..co * c * kh * kw).map(|_| r.normal() as f32).collect();
        let mut cols = Vec::new();
        let (oh, ow) = im2col(&x, c, h, w, kh, kw, stride, pad, &mut cols);
        let k = c * kh * kw;
        let mut out = vec![0.0; oh * ow * co];
        gemm_f32(&cols, &wt, &mut out, oh * ow, co, k);
        // direct convolution
        for o in 0..co {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ci in 0..c {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let iy = oy as isize + ky as isize - pad as isize;
                                let ix = ox as isize + kx as isize - pad as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                    continue;
                                }
                                acc += x[ci * h * w + iy as usize * w + ix as usize]
                                    * wt[o * k + ci * kh * kw + ky * kw + kx];
                            }
                        }
                    }
                    let got = out[(oy * ow + ox) * co + o];
                    assert!((got - acc).abs() < 1e-4, "{got} vs {acc}");
                }
            }
        }
    }
}
