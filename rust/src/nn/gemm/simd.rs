//! Runtime SIMD dispatch for the integer GEMM hot path.
//!
//! The level is detected **once per process** ([`active_level`], an
//! atomically-initialized cache) and frozen into every
//! [`ExecutionPlan`](crate::nn::plan::ExecutionPlan) at compile time —
//! the hot loops never re-probe CPU features. The scalar kernels in
//! [`super::scalar`] stay untouched as the bit-exactness oracle; every
//! vector path is property-tested identical to them (wrapping-i32
//! semantics included, see `tests/properties.rs`).
//!
//! Escape hatches, for A/B debugging and the CI scalar-fallback leg:
//!
//! - `PANN_FORCE_SCALAR=1` (any value other than empty/`0`) in the
//!   environment at first use;
//! - the `force-scalar` cargo feature (compile-time);
//! - [`ExecutionPlan::force_scalar`](crate::nn::plan::ExecutionPlan::force_scalar)
//!   on an already-compiled plan.

use std::sync::OnceLock;

/// Instruction set the integer dot-product kernels dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Scalar reference kernels (the bit-exactness oracle).
    Scalar,
    /// x86-64 AVX2: 256-bit lanes via `std::arch`, runtime-detected.
    Avx2,
    /// AArch64 NEON: 128-bit lanes, baseline on every aarch64 target.
    Neon,
}

impl SimdLevel {
    /// Short lowercase name for bench labels and reports.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Clamp to what this machine actually supports: a level the
    /// running CPU cannot execute falls back to `Scalar`. This is what
    /// keeps the public `*_blocked_at` kernels safe for arbitrary
    /// arguments — the unsafe intrinsic paths are only entered behind
    /// a successful feature check.
    pub fn supported(self) -> SimdLevel {
        match self {
            SimdLevel::Scalar => SimdLevel::Scalar,
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            SimdLevel::Avx2 if is_x86_feature_detected!("avx2") => SimdLevel::Avx2,
            #[cfg(all(target_arch = "aarch64", not(miri)))]
            SimdLevel::Neon => SimdLevel::Neon,
            _ => SimdLevel::Scalar,
        }
    }
}

/// The level this process dispatches to, detected once and cached.
pub fn active_level() -> SimdLevel {
    static ACTIVE: OnceLock<SimdLevel> = OnceLock::new();
    *ACTIVE.get_or_init(detect)
}

/// Detect the best level, honouring the force-scalar escape hatches
/// (the `PANN_FORCE_SCALAR` env var and the `force-scalar` feature).
pub fn detect() -> SimdLevel {
    let force = cfg!(feature = "force-scalar")
        || std::env::var_os("PANN_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0");
    detect_with(force)
}

/// Pure detection given an explicit force-scalar flag (testable
/// without touching the process environment).
pub fn detect_with(force_scalar: bool) -> SimdLevel {
    if force_scalar {
        return SimdLevel::Scalar;
    }
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if is_x86_feature_detected!("avx2") {
            SimdLevel::Avx2
        } else {
            SimdLevel::Scalar
        }
    }
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    {
        SimdLevel::Neon
    }
    #[cfg(any(miri, not(any(target_arch = "x86_64", target_arch = "aarch64"))))]
    {
        SimdLevel::Scalar
    }
}

// ---------------------------------------------------------------------
// Dispatched row dots. Callers (the blocked kernels) resolve `level`
// through `SimdLevel::supported()` once per GEMM call, so the unsafe
// arms below are only reachable with the feature present.
// ---------------------------------------------------------------------

/// Dispatched wide dot (Σ a·b, i64 accumulation).
#[inline]
pub(super) fn dot_i64(level: SimdLevel, a: &[i32], b: &[i32]) -> i64 {
    match level {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        // SAFETY: `Avx2` survives `supported()` only when the CPU has it.
        SimdLevel::Avx2 => unsafe { super::avx2::dot_i64(a, b) },
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        SimdLevel::Neon => super::neon::dot_i64(a, b),
        _ => super::scalar::dot_i64(a, b),
    }
}

/// Dispatched wide split dot (Σ a·(p − n), i64 accumulation).
#[inline]
pub(super) fn dot_i64_split(level: SimdLevel, a: &[i32], p: &[i32], n: &[i32]) -> i64 {
    match level {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        // SAFETY: `Avx2` survives `supported()` only when the CPU has it.
        SimdLevel::Avx2 => unsafe { super::avx2::dot_i64_split(a, p, n) },
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        SimdLevel::Neon => super::neon::dot_i64_split(a, p, n),
        _ => super::scalar::dot_i64_split(a, p, n),
    }
}

/// Dispatched narrow dot (wrapping-i32 Σ a·b).
#[inline]
pub(super) fn dot_i32_wrapping(level: SimdLevel, a: &[i32], b: &[i32]) -> i32 {
    match level {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        // SAFETY: `Avx2` survives `supported()` only when the CPU has it.
        SimdLevel::Avx2 => unsafe { super::avx2::dot_i32_wrapping(a, b) },
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        SimdLevel::Neon => super::neon::dot_i32_wrapping(a, b),
        _ => super::scalar::dot_i32_wrapping(a, b),
    }
}

/// Dispatched narrow split dot (wrapping-i32 Σ a·(p ⊖ n)).
#[inline]
pub(super) fn dot_i32_split_wrapping(level: SimdLevel, a: &[i32], p: &[i32], n: &[i32]) -> i32 {
    match level {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        // SAFETY: `Avx2` survives `supported()` only when the CPU has it.
        SimdLevel::Avx2 => unsafe { super::avx2::dot_i32_split_wrapping(a, p, n) },
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        SimdLevel::Neon => super::neon::dot_i32_split_wrapping(a, p, n),
        _ => super::scalar::dot_i32_split_wrapping(a, p, n),
    }
}

/// Dispatched packed narrow dot (wrapping-i32 Σ a·b over i16 codes).
#[inline]
pub(super) fn dot_i16_wrapping(level: SimdLevel, a: &[i16], b: &[i16]) -> i32 {
    match level {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        // SAFETY: `Avx2` survives `supported()` only when the CPU has it.
        SimdLevel::Avx2 => unsafe { super::avx2::dot_i16_wrapping(a, b) },
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        SimdLevel::Neon => super::neon::dot_i16_wrapping(a, b),
        _ => super::scalar::dot_i16_wrapping(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_scalar_wins_over_any_hardware() {
        assert_eq!(detect_with(true), SimdLevel::Scalar);
    }

    #[test]
    fn detected_level_is_supported_and_stable() {
        let l = active_level();
        assert_eq!(l.supported(), l, "active level must be executable");
        assert_eq!(active_level(), l, "detection is cached per process");
    }

    #[test]
    fn scalar_is_always_supported() {
        assert_eq!(SimdLevel::Scalar.supported(), SimdLevel::Scalar);
    }
}
