//! GEMM kernels and im2col — the engine's hot path.
//!
//! Three kernel families: f32 (reference forward), i32 (quantized
//! baselines) and a dual i32 family for the W⁺/W⁻ unsigned split that
//! reuses each activation tile for both banks (the activation-reuse
//! argument of the paper's App. A.8, and the same reuse the L1 Pallas
//! kernel performs in VMEM).
//!
//! The module is layered:
//!
//! - the **scalar reference kernels** in this file and [`scalar`] are
//!   the bit-exactness oracle — untouched, boring, and what everything
//!   else is property-tested against;
//! - the `*_blocked` kernels tile m/n/k so the weight panel stays
//!   cache-resident and split the m rows over scoped threads;
//! - [`simd`] dispatches the blocked kernels' inner row-dots to AVX2
//!   ([`avx2`](self)) or NEON ([`neon`](self)) at runtime, detected
//!   once per process and frozen into each `ExecutionPlan`;
//! - [`packed`] stores narrow weight codes densely in i16 lanes so one
//!   vector multiply covers twice the elements
//!   ([`gemm_i16_narrow_blocked_at`] consumes them).
//!
//! All kernels compute `out[m][n] = Σ_k a[m][k] · b[n][k]` — note `b`
//! is pre-transposed (`[n][k]`, i.e. weights stored `[out][in]`), which
//! makes the inner loop a contiguous dot product on both operands.

// The intrinsic modules are compiled out under Miri (which interprets
// no vendor intrinsics); dispatch pins to Scalar there, so the CI Miri
// leg checks the scalar oracle and everything above it.
#[cfg(all(target_arch = "x86_64", not(miri)))]
mod avx2;
#[cfg(all(target_arch = "aarch64", not(miri)))]
mod neon;
pub mod packed;
mod scalar;
pub mod simd;

pub use packed::{pack_codes_i16, pack_diff_i16};
pub use simd::{active_level, detect, detect_with, SimdLevel};

/// f32 GEMM: `out[m][n] = Σ_k a[m*K+k] * bt[n*K+k]`.
///
/// Four parallel accumulators break the loop-carried dependency of a
/// naive dot product so the compiler can keep several FMA chains in
/// flight (§Perf in EXPERIMENTS.md: ~3× over the naive loop).
pub fn gemm_f32(a: &[f32], bt: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let br = &bt[j * k..(j + 1) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let chunks = k / 4 * 4;
            let mut kk = 0;
            while kk < chunks {
                a0 += ar[kk] * br[kk];
                a1 += ar[kk + 1] * br[kk + 1];
                a2 += ar[kk + 2] * br[kk + 2];
                a3 += ar[kk + 3] * br[kk + 3];
                kk += 4;
            }
            let mut acc = (a0 + a1) + (a2 + a3);
            for kk in chunks..k {
                acc += ar[kk] * br[kk];
            }
            or[j] = acc;
        }
    }
}

/// i32 GEMM with i64 accumulation.
pub fn gemm_i32(a: &[i32], bt: &[i32], out: &mut [i64], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let br = &bt[j * k..(j + 1) * k];
            or[j] = scalar::dot_i64(ar, br);
        }
    }
}

/// Dual-bank i32 GEMM: one pass computes `pos·a` and `neg·a`, reusing
/// the `a` tile; returns into `out = pos_result - neg_result` while
/// also accumulating the per-bank L1 statistics needed for power
/// accounting of the unsigned/PANN paths.
pub fn gemm_i32_split(
    a: &[i32],
    pos_t: &[i32],
    neg_t: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(pos_t.len(), n * k);
    assert_eq!(neg_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let pr = &pos_t[j * k..(j + 1) * k];
            let nr = &neg_t[j * k..(j + 1) * k];
            // The subtraction distributes over the accumulation, so a
            // single combined chain `x·(p−n)` halves the multiply count
            // while reusing the x tile for both banks (the VMEM-reuse
            // story of the L1 kernel, and ~2× on this path).
            or[j] = scalar::dot_i64_split(ar, pr, nr);
        }
    }
}

/// i32 GEMM with *narrow* (i32) accumulation — valid only when the
/// caller guarantees `max|a| · max|b| · k < 2^31` (quantization codes
/// are small, so the quantized executor proves this bound at prepare
/// time and picks this ~3× faster vectorizable path).
pub fn gemm_i32_narrow(a: &[i32], bt: &[i32], out: &mut [i64], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let br = &bt[j * k..(j + 1) * k];
            or[j] = scalar::dot_i32_wrapping(ar, br) as i64;
        }
    }
}

/// Narrow-accumulation variant of [`gemm_i32_split`]; same overflow
/// precondition as [`gemm_i32_narrow`]. The bank difference wraps
/// (`wrapping_sub`), keeping the kernel total over arbitrary i32
/// banks.
pub fn gemm_i32_split_narrow(
    a: &[i32],
    pos_t: &[i32],
    neg_t: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(pos_t.len(), n * k);
    assert_eq!(neg_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let or = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let pr = &pos_t[j * k..(j + 1) * k];
            let nr = &neg_t[j * k..(j + 1) * k];
            or[j] = scalar::dot_i32_split_wrapping(ar, pr, nr) as i64;
        }
    }
}

// ---------------------------------------------------------------------
// Cache-blocked, row-parallel kernels.
//
// The scalar kernels above are the bit-exact references; the
// `*_blocked` variants tile the same arithmetic over m/n/k so the
// weight panel stays in cache across the batch, and split the m rows
// over `threads` scoped threads (each thread owns a disjoint slice of
// `out`, so no synchronization is needed). Integer addition is
// associative — wrapping i32 included — so any tiling/threading order
// produces bit-identical results to the scalar reference, and the
// same argument covers the SIMD lane reorderings: every `*_blocked_at`
// kernel is bit-exact for any `SimdLevel`.
// ---------------------------------------------------------------------

/// Rows per m tile inside one thread.
const BLOCK_M: usize = 32;
/// Columns (output features) per n tile.
const BLOCK_N: usize = 64;
/// Depth per k tile (i32 operands: 4 KiB per row tile).
const BLOCK_K: usize = 1024;

/// Split the `m` rows of `a`/`out` into up to `threads` contiguous
/// chunks and run `f(a_rows, out_rows, rows)` on each, in parallel.
/// Generic over the activation element (i32, or i16 on the packed
/// path).
fn par_rows<T, F>(a: &[T], out: &mut [i64], m: usize, n: usize, k: usize, threads: usize, f: F)
where
    T: Sync,
    F: Fn(&[T], &mut [i64], usize) + Sync,
{
    let t = threads.clamp(1, m.max(1));
    if t <= 1 {
        f(a, out, m);
        return;
    }
    let base = m / t;
    let rem = m % t;
    std::thread::scope(|s| {
        let fr = &f;
        let mut a_rest = a;
        let mut out_rest = out;
        for i in 0..t {
            let rows = base + usize::from(i < rem);
            if rows == 0 {
                continue;
            }
            let (a_chunk, a_tail) = a_rest.split_at(rows * k);
            let (o_chunk, o_tail) = std::mem::take(&mut out_rest).split_at_mut(rows * n);
            a_rest = a_tail;
            out_rest = o_tail;
            s.spawn(move || fr(a_chunk, o_chunk, rows));
        }
    });
}

/// Tile loop shared by all blocked variants. `partial` folds one
/// (i, j, k-tile) contribution into `out[i·n + j]`.
#[inline]
fn block_rows<T, P>(a: &[T], out: &mut [i64], rows: usize, n: usize, k: usize, partial: P)
where
    P: Fn(&[T], usize, std::ops::Range<usize>, &mut [i64]),
{
    out.fill(0);
    for ib in (0..rows).step_by(BLOCK_M) {
        let iend = (ib + BLOCK_M).min(rows);
        for kb in (0..k).step_by(BLOCK_K) {
            let kend = (kb + BLOCK_K).min(k);
            for jb in (0..n).step_by(BLOCK_N) {
                let jend = (jb + BLOCK_N).min(n);
                for i in ib..iend {
                    let ar = &a[i * k + kb..i * k + kend];
                    let or = &mut out[i * n..(i + 1) * n];
                    partial(ar, kb, jb..jend, or);
                }
            }
        }
    }
}

/// Blocked, row-parallel [`gemm_i32`] (i64 accumulation) at an
/// explicit dispatch level. Bit-exact with the scalar reference for
/// any `level`/`threads`; unsupported levels clamp to scalar.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_blocked_at(
    level: SimdLevel,
    a: &[i32],
    bt: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    let level = level.supported();
    par_rows(a, out, m, n, k, threads, |ar, or, rows| {
        block_rows(ar, or, rows, n, k, |arow, kb, js, orow| {
            let kl = arow.len();
            for j in js {
                let br = &bt[j * k + kb..j * k + kb + kl];
                orow[j] += simd::dot_i64(level, arow, br);
            }
        });
    });
}

/// Blocked, row-parallel [`gemm_i32`] at the process-wide detected
/// dispatch level ([`active_level`]).
pub fn gemm_i32_blocked(
    a: &[i32],
    bt: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    gemm_i32_blocked_at(active_level(), a, bt, out, m, n, k, threads);
}

/// Blocked, row-parallel [`gemm_i32_narrow`] at an explicit dispatch
/// level. Partial sums combine with the same wrapping-i32 arithmetic
/// as the scalar reference, so results are bit-exact even at the
/// overflow boundary, for any `level`/`threads`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_narrow_blocked_at(
    level: SimdLevel,
    a: &[i32],
    bt: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    let level = level.supported();
    par_rows(a, out, m, n, k, threads, |ar, or, rows| {
        block_rows(ar, or, rows, n, k, |arow, kb, js, orow| {
            let kl = arow.len();
            for j in js {
                let br = &bt[j * k + kb..j * k + kb + kl];
                let prev = orow[j] as i32;
                orow[j] = prev.wrapping_add(simd::dot_i32_wrapping(level, arow, br)) as i64;
            }
        });
    });
}

/// Blocked, row-parallel [`gemm_i32_narrow`] at the process-wide
/// detected dispatch level.
pub fn gemm_i32_narrow_blocked(
    a: &[i32],
    bt: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    gemm_i32_narrow_blocked_at(active_level(), a, bt, out, m, n, k, threads);
}

/// Blocked, row-parallel [`gemm_i32_split`] at an explicit dispatch
/// level.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_split_blocked_at(
    level: SimdLevel,
    a: &[i32],
    pos_t: &[i32],
    neg_t: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(pos_t.len(), n * k);
    assert_eq!(neg_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    let level = level.supported();
    par_rows(a, out, m, n, k, threads, |ar, or, rows| {
        block_rows(ar, or, rows, n, k, |arow, kb, js, orow| {
            let kl = arow.len();
            for j in js {
                let pr = &pos_t[j * k + kb..j * k + kb + kl];
                let nr = &neg_t[j * k + kb..j * k + kb + kl];
                orow[j] += simd::dot_i64_split(level, arow, pr, nr);
            }
        });
    });
}

/// Blocked, row-parallel [`gemm_i32_split`] at the process-wide
/// detected dispatch level.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_split_blocked(
    a: &[i32],
    pos_t: &[i32],
    neg_t: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    gemm_i32_split_blocked_at(active_level(), a, pos_t, neg_t, out, m, n, k, threads);
}

/// Blocked, row-parallel [`gemm_i32_split_narrow`] at an explicit
/// dispatch level.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_split_narrow_blocked_at(
    level: SimdLevel,
    a: &[i32],
    pos_t: &[i32],
    neg_t: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(pos_t.len(), n * k);
    assert_eq!(neg_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    let level = level.supported();
    par_rows(a, out, m, n, k, threads, |ar, or, rows| {
        block_rows(ar, or, rows, n, k, |arow, kb, js, orow| {
            let kl = arow.len();
            for j in js {
                let pr = &pos_t[j * k + kb..j * k + kb + kl];
                let nr = &neg_t[j * k + kb..j * k + kb + kl];
                let prev = orow[j] as i32;
                let dot = simd::dot_i32_split_wrapping(level, arow, pr, nr);
                orow[j] = prev.wrapping_add(dot) as i64;
            }
        });
    });
}

/// Blocked, row-parallel [`gemm_i32_split_narrow`] at the process-wide
/// detected dispatch level.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_split_narrow_blocked(
    a: &[i32],
    pos_t: &[i32],
    neg_t: &[i32],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    gemm_i32_split_narrow_blocked_at(active_level(), a, pos_t, neg_t, out, m, n, k, threads);
}

/// Blocked, row-parallel narrow GEMM over *packed* i16 activation
/// codes and a packed i16 weight bank (see [`packed`]), with the
/// narrow path's exact wrapping-i32 arithmetic over the widened
/// values. Serves both the unified narrow bank ([`pack_codes_i16`])
/// and the split narrow banks via the packed `W⁺ − W⁻` difference
/// ([`pack_diff_i16`]) — the subtraction distributes over the
/// accumulation, so the difference bank is functionally identical.
/// Bit-exact with [`gemm_i32_narrow`] over the widened codes, for any
/// `level`/`threads`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i16_narrow_blocked_at(
    level: SimdLevel,
    a: &[i16],
    bt: &[i16],
    out: &mut [i64],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    let level = level.supported();
    par_rows(a, out, m, n, k, threads, |ar, or, rows| {
        block_rows(ar, or, rows, n, k, |arow, kb, js, orow| {
            let kl = arow.len();
            for j in js {
                let br = &bt[j * k + kb..j * k + kb + kl];
                let prev = orow[j] as i32;
                orow[j] = prev.wrapping_add(simd::dot_i16_wrapping(level, arow, br)) as i64;
            }
        });
    });
}

/// im2col for NCHW convolution: input `[c, h, w]` (one sample) into
/// columns `[oh*ow, c*kh*kw]` with given stride/pad (zero padding).
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = c * kh * kw;
    out.clear();
    out.resize(oh * ow * cols, 0.0);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * cols;
            for ci in 0..c {
                for ky in 0..kh {
                    let iy = oy * stride + ky;
                    if iy < pad || iy - pad >= h {
                        continue;
                    }
                    let iy = iy - pad;
                    for kx in 0..kw {
                        let ix = ox * stride + kx;
                        if ix < pad || ix - pad >= w {
                            continue;
                        }
                        let ix = ix - pad;
                        out[row + ci * kh * kw + ky * kw + kx] = x[ci * h * w + iy * w + ix];
                    }
                }
            }
        }
    }
    (oh, ow)
}

/// Output spatial size of a convolution.
pub fn conv_out_size(h: usize, w: usize, kh: usize, kw: usize, stride: usize, pad: usize) -> (usize, usize) {
    ((h + 2 * pad - kh) / stride + 1, (w + 2 * pad - kw) / stride + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn f32_gemm_matches_naive() {
        let (m, n, k) = (3, 4, 5);
        let mut r = Rng::new(1);
        let a: Vec<f32> = (0..m * k).map(|_| r.normal() as f32).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| r.normal() as f32).collect();
        let mut out = vec![0.0; m * n];
        gemm_f32(&a, &bt, &mut out, m, n, k);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| a[i * k + kk] * bt[j * k + kk]).sum();
                assert!((out[i * n + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn split_gemm_equals_signed_gemm() {
        // Sec. 4's claim: splitting W into W⁺/W⁻ is functionally exact.
        let (m, n, k) = (4, 6, 16);
        let mut r = Rng::new(2);
        let a: Vec<i32> = (0..m * k).map(|_| r.range_i64(0, 16) as i32).collect();
        let w: Vec<i32> = (0..n * k).map(|_| r.range_i64(-8, 8) as i32).collect();
        let pos: Vec<i32> = w.iter().map(|&v| v.max(0)).collect();
        let neg: Vec<i32> = w.iter().map(|&v| (-v).max(0)).collect();
        let mut out_signed = vec![0i64; m * n];
        let mut out_split = vec![0i64; m * n];
        gemm_i32(&a, &w, &mut out_signed, m, n, k);
        gemm_i32_split(&a, &pos, &neg, &mut out_split, m, n, k);
        assert_eq!(out_signed, out_split);
    }

    #[test]
    fn narrow_matches_wide_within_bounds() {
        let (m, n, k) = (5, 7, 33);
        let mut r = Rng::new(9);
        let a: Vec<i32> = (0..m * k).map(|_| r.range_i64(0, 256) as i32).collect();
        let w: Vec<i32> = (0..n * k).map(|_| r.range_i64(-127, 128) as i32).collect();
        let pos: Vec<i32> = w.iter().map(|&v| v.max(0)).collect();
        let neg: Vec<i32> = w.iter().map(|&v| (-v).max(0)).collect();
        let mut wide = vec![0i64; m * n];
        let mut narrow = vec![0i64; m * n];
        gemm_i32(&a, &w, &mut wide, m, n, k);
        gemm_i32_narrow(&a, &w, &mut narrow, m, n, k);
        assert_eq!(wide, narrow);
        gemm_i32_split(&a, &pos, &neg, &mut wide, m, n, k);
        gemm_i32_split_narrow(&a, &pos, &neg, &mut narrow, m, n, k);
        assert_eq!(wide, narrow);
    }

    // Broad blocked-vs-scalar bit-exactness (all kernel variants ×
    // dispatch levels × random odd sizes × thread counts) lives in
    // tests/properties.rs; here we keep the wrap-around edges the
    // property tests' value ranges cannot reach.
    #[test]
    fn narrow_blocked_wraps_like_scalar() {
        // Drive the i32 accumulator past wrap-around: the blocked
        // variant must reproduce the scalar wrapping bit pattern at
        // every dispatch level.
        let (m, n, k) = (2, 3, 2100);
        let a = vec![1 << 15; m * k];
        let w = vec![1 << 15; n * k]; // products of 2^30, k of them: wraps
        let mut want = vec![0i64; m * n];
        let mut got = vec![0i64; m * n];
        gemm_i32_narrow(&a, &w, &mut want, m, n, k);
        for level in [SimdLevel::Scalar, active_level()] {
            gemm_i32_narrow_blocked_at(level, &a, &w, &mut got, m, n, k, 2);
            assert_eq!(want, got, "level {level:?}");
        }
    }

    #[test]
    fn split_narrow_wrapping_sub_at_i32_extremes() {
        // Regression: the bank difference used a plain `-`, which
        // overflows (debug-build panic) for p = i32::MAX, n = i32::MIN.
        // It must wrap — MAX ⊖ MIN ≡ −1 — identically at every level.
        let (m, n, k) = (2, 2, 5);
        let a = vec![3i32; m * k];
        let pos = vec![i32::MAX; n * k];
        let neg = vec![i32::MIN; n * k];
        let mut want = vec![0i64; m * n];
        let mut got = vec![0i64; m * n];
        gemm_i32_split_narrow(&a, &pos, &neg, &mut want, m, n, k);
        assert!(want.iter().all(|&v| v == -(3 * k as i64)), "{want:?}");
        for level in [SimdLevel::Scalar, active_level()] {
            gemm_i32_split_narrow_blocked_at(level, &a, &pos, &neg, &mut got, m, n, k, 2);
            assert_eq!(want, got, "level {level:?}");
        }
    }

    #[test]
    fn packed_narrow_matches_widened_reference() {
        // The packed i16 kernel must reproduce gemm_i32_narrow over the
        // widened codes bit-for-bit — including genuine i32 wrap-around
        // (full-range i16 products overflow the accumulator fast).
        let (m, n, k) = (5, 6, 77);
        let mut r = Rng::new(21);
        let a16: Vec<i16> = (0..m * k)
            .map(|_| r.range_i64(i16::MIN as i64, i16::MAX as i64 + 1) as i16)
            .collect();
        let w16: Vec<i16> = (0..n * k)
            .map(|_| r.range_i64(i16::MIN as i64, i16::MAX as i64 + 1) as i16)
            .collect();
        let a32: Vec<i32> = a16.iter().map(|&v| v as i32).collect();
        let w32: Vec<i32> = w16.iter().map(|&v| v as i32).collect();
        let mut want = vec![0i64; m * n];
        let mut got = vec![0i64; m * n];
        gemm_i32_narrow(&a32, &w32, &mut want, m, n, k);
        for level in [SimdLevel::Scalar, active_level()] {
            for threads in [1, 3] {
                gemm_i16_narrow_blocked_at(level, &a16, &w16, &mut got, m, n, k, threads);
                assert_eq!(want, got, "level {level:?} t={threads}");
            }
        }
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: columns equal the input pixels.
        let x: Vec<f32> = (0..2 * 3 * 3).map(|i| i as f32).collect();
        let mut cols = Vec::new();
        let (oh, ow) = im2col(&x, 2, 3, 3, 1, 1, 1, 0, &mut cols);
        assert_eq!((oh, ow), (3, 3));
        for p in 0..9 {
            assert_eq!(cols[p * 2], x[p]);
            assert_eq!(cols[p * 2 + 1], x[9 + p]);
        }
    }

    #[test]
    fn im2col_padding_zeroes() {
        let x = vec![1.0f32; 1 * 2 * 2];
        let mut cols = Vec::new();
        let (oh, ow) = im2col(&x, 1, 2, 2, 3, 3, 1, 1, &mut cols);
        assert_eq!((oh, ow), (2, 2));
        // top-left output: kernel overlaps 1 row/col of padding
        let c0 = &cols[0..9];
        assert_eq!(c0, &[0., 0., 0., 0., 1., 1., 0., 1., 1.]);
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        let (c, h, w, co, kh, kw, stride, pad) = (2, 5, 5, 3, 3, 3, 1, 1);
        let mut r = Rng::new(3);
        let x: Vec<f32> = (0..c * h * w).map(|_| r.normal() as f32).collect();
        let wt: Vec<f32> = (0..co * c * kh * kw).map(|_| r.normal() as f32).collect();
        let mut cols = Vec::new();
        let (oh, ow) = im2col(&x, c, h, w, kh, kw, stride, pad, &mut cols);
        let k = c * kh * kw;
        let mut out = vec![0.0; oh * ow * co];
        gemm_f32(&cols, &wt, &mut out, oh * ow, co, k);
        // direct convolution
        for o in 0..co {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ci in 0..c {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let iy = oy as isize + ky as isize - pad as isize;
                                let ix = ox as isize + kx as isize - pad as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                    continue;
                                }
                                acc += x[ci * h * w + iy as usize * w + ix as usize]
                                    * wt[o * k + ci * kh * kw + ky * kw + kx];
                            }
                        }
                    }
                    let got = out[(oy * ow + ox) * co + o];
                    assert!((got - acc).abs() < 1e-4, "{got} vs {acc}");
                }
            }
        }
    }
}
