//! NEON (aarch64) row-dot kernels behind the [`super::simd`] dispatch.
//!
//! NEON is baseline on every aarch64 target, so these are safe
//! functions with unsafe intrinsic bodies — no runtime feature gate is
//! needed beyond the `target_arch` compile gate. Lane semantics match
//! the scalar oracle the same way the AVX2 kernels do: widening
//! multiply-accumulate (`vmlal`/`vmlsl`) for the wide variants, and
//! plain wrapping i32 lane arithmetic (`vmlaq_s32`) for the narrow
//! variants, which is bit-identical to the scalar wrapping fold for
//! all inputs. All loads are unaligned-tolerant (`vld1q`).

use std::arch::aarch64::*;

/// Wide dot: Σ a·b with i64 accumulation.
pub(super) fn dot_i64(a: &[i32], b: &[i32]) -> i64 {
    let len = a.len().min(b.len());
    let mut i = 0usize;
    // SAFETY: in-bounds pointer loads; NEON is baseline on aarch64.
    let mut out = unsafe {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = vdupq_n_s64(0);
        while i + 4 <= len {
            let va = vld1q_s32(pa.add(i));
            let vb = vld1q_s32(pb.add(i));
            acc = vmlal_s32(acc, vget_low_s32(va), vget_low_s32(vb));
            acc = vmlal_high_s32(acc, va, vb);
            i += 4;
        }
        vaddvq_s64(acc)
    };
    while i < len {
        out = out.wrapping_add(a[i] as i64 * b[i] as i64);
        i += 1;
    }
    out
}

/// Wide split dot: Σ a·(p − n) with i64 accumulation
/// (`vmlal` on the W⁺ bank, `vmlsl` on the W⁻ bank — the subtraction
/// distributes over the accumulation).
pub(super) fn dot_i64_split(a: &[i32], p: &[i32], n: &[i32]) -> i64 {
    let len = a.len().min(p.len()).min(n.len());
    let mut i = 0usize;
    // SAFETY: in-bounds pointer loads; NEON is baseline on aarch64.
    let mut out = unsafe {
        let pa = a.as_ptr();
        let pp = p.as_ptr();
        let pn = n.as_ptr();
        let mut acc = vdupq_n_s64(0);
        while i + 4 <= len {
            let va = vld1q_s32(pa.add(i));
            let vp = vld1q_s32(pp.add(i));
            let vn = vld1q_s32(pn.add(i));
            acc = vmlal_s32(acc, vget_low_s32(va), vget_low_s32(vp));
            acc = vmlal_high_s32(acc, va, vp);
            acc = vmlsl_s32(acc, vget_low_s32(va), vget_low_s32(vn));
            acc = vmlsl_high_s32(acc, va, vn);
            i += 4;
        }
        vaddvq_s64(acc)
    };
    while i < len {
        out = out.wrapping_add(a[i] as i64 * (p[i] as i64 - n[i] as i64));
        i += 1;
    }
    out
}

/// Narrow dot: wrapping-i32 Σ a·b.
pub(super) fn dot_i32_wrapping(a: &[i32], b: &[i32]) -> i32 {
    let len = a.len().min(b.len());
    let mut i = 0usize;
    // SAFETY: in-bounds pointer loads; NEON is baseline on aarch64.
    let mut out = unsafe {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = vdupq_n_s32(0);
        while i + 4 <= len {
            acc = vmlaq_s32(acc, vld1q_s32(pa.add(i)), vld1q_s32(pb.add(i)));
            i += 4;
        }
        vaddvq_s32(acc)
    };
    while i < len {
        out = out.wrapping_add(a[i].wrapping_mul(b[i]));
        i += 1;
    }
    out
}

/// Narrow split dot: wrapping-i32 Σ a·(p ⊖ n) (`vsubq_s32` wraps, same
/// as the oracle's `wrapping_sub`).
pub(super) fn dot_i32_split_wrapping(a: &[i32], p: &[i32], n: &[i32]) -> i32 {
    let len = a.len().min(p.len()).min(n.len());
    let mut i = 0usize;
    // SAFETY: in-bounds pointer loads; NEON is baseline on aarch64.
    let mut out = unsafe {
        let pa = a.as_ptr();
        let pp = p.as_ptr();
        let pn = n.as_ptr();
        let mut acc = vdupq_n_s32(0);
        while i + 4 <= len {
            let d = vsubq_s32(vld1q_s32(pp.add(i)), vld1q_s32(pn.add(i)));
            acc = vmlaq_s32(acc, vld1q_s32(pa.add(i)), d);
            i += 4;
        }
        vaddvq_s32(acc)
    };
    while i < len {
        out = out.wrapping_add(a[i].wrapping_mul(p[i].wrapping_sub(n[i])));
        i += 1;
    }
    out
}

/// Packed narrow dot: wrapping-i32 Σ a·b over i16 codes, 8 lanes per
/// widening multiply-accumulate.
pub(super) fn dot_i16_wrapping(a: &[i16], b: &[i16]) -> i32 {
    let len = a.len().min(b.len());
    let mut i = 0usize;
    // SAFETY: in-bounds pointer loads; NEON is baseline on aarch64.
    let mut out = unsafe {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = vdupq_n_s32(0);
        while i + 8 <= len {
            let va = vld1q_s16(pa.add(i));
            let vb = vld1q_s16(pb.add(i));
            acc = vmlal_s16(acc, vget_low_s16(va), vget_low_s16(vb));
            acc = vmlal_high_s16(acc, va, vb);
            i += 8;
        }
        vaddvq_s32(acc)
    };
    while i < len {
        out = out.wrapping_add(a[i] as i32 * b[i] as i32);
        i += 1;
    }
    out
}
