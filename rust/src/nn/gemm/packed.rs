//! Dense i16 packing of narrow weight codes.
//!
//! The paper's premise is that 2–8-bit codes should be *cheaper* than
//! full-width arithmetic, but i32 storage wastes the narrow width: a
//! 256-bit vector covers only 8 codes. Packing the bank into i16 lanes
//! doubles the codes per load and unlocks the paired-multiply
//! instructions (`pmaddwd` on AVX2, `vmlal_s16` on NEON) — one vector
//! multiply covers 2× the elements, with the widen folded into the
//! instruction itself.
//!
//! Packing applies exactly when the plan's narrow-accumulation proof
//! already holds **and** both activation codes (`≤ 2^b̃x − 1`) and
//! weight codes fit i16. The split path packs the `W⁺ − W⁻`
//! *difference* (exact in i64, checked per element): the subtraction
//! distributes over the accumulation, so the difference bank is
//! functionally identical to the two-bank form — the power model still
//! charges the split datapath, which is an accounting concern, not an
//! arithmetic one.

/// Pack i32 codes into i16 lanes. `None` if any code is out of range —
/// the caller keeps the unpacked bank.
pub fn pack_codes_i16(codes: &[i32]) -> Option<Vec<i16>> {
    codes
        .iter()
        .map(|&c| i16::try_from(c).ok())
        .collect::<Option<Vec<i16>>>()
}

/// Pack the split difference `W⁺ − W⁻` into i16 lanes (difference
/// computed in i64, so arbitrary i32 banks can't overflow here).
/// `None` if the banks differ in length or any difference is out of
/// i16 range.
pub fn pack_diff_i16(pos: &[i32], neg: &[i32]) -> Option<Vec<i16>> {
    if pos.len() != neg.len() {
        return None;
    }
    pos.iter()
        .zip(neg)
        .map(|(&p, &n)| i16::try_from(p as i64 - n as i64).ok())
        .collect::<Option<Vec<i16>>>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrips_and_rejects_out_of_range() {
        assert_eq!(
            pack_codes_i16(&[0, 1, -1, i16::MAX as i32, i16::MIN as i32]),
            Some(vec![0, 1, -1, i16::MAX, i16::MIN])
        );
        assert_eq!(pack_codes_i16(&[i16::MAX as i32 + 1]), None);
        assert_eq!(pack_codes_i16(&[i16::MIN as i32 - 1]), None);
    }

    #[test]
    fn pack_diff_is_exact_and_total() {
        assert_eq!(pack_diff_i16(&[5, 0, 7], &[0, 3, 7]), Some(vec![5, -3, 0]));
        // non-negative banks whose difference leaves i16
        assert_eq!(pack_diff_i16(&[40_000], &[0]), None);
        // arbitrary i32 banks must not overflow the difference itself
        assert_eq!(pack_diff_i16(&[i32::MAX], &[i32::MIN]), None);
        assert_eq!(pack_diff_i16(&[1, 2], &[1]), None);
    }
}
