//! Scalar row-dot kernels — the bit-exactness oracle every SIMD path
//! is property-tested against.
//!
//! These are deliberately boring: four-chain i64 accumulation for the
//! wide variants (the chains break the loop-carried dependency) and a
//! plain wrapping fold for the narrow variants, whose arithmetic is
//! *defined* as wrapping-i32 so any summation order is bit-identical.

/// Four-chain i64 dot product over equal-length i32 slices.
#[inline]
pub(super) fn dot_i64(ar: &[i32], br: &[i32]) -> i64 {
    let len = ar.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0i64, 0i64, 0i64, 0i64);
    let chunks = len / 4 * 4;
    let mut kk = 0;
    while kk < chunks {
        a0 += ar[kk] as i64 * br[kk] as i64;
        a1 += ar[kk + 1] as i64 * br[kk + 1] as i64;
        a2 += ar[kk + 2] as i64 * br[kk + 2] as i64;
        a3 += ar[kk + 3] as i64 * br[kk + 3] as i64;
        kk += 4;
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    for kk in chunks..len {
        acc += ar[kk] as i64 * br[kk] as i64;
    }
    acc
}

/// Four-chain i64 dot against a split (pos − neg) bank.
#[inline]
pub(super) fn dot_i64_split(ar: &[i32], pr: &[i32], nr: &[i32]) -> i64 {
    let len = ar.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0i64, 0i64, 0i64, 0i64);
    let chunks = len / 4 * 4;
    let mut kk = 0;
    while kk < chunks {
        a0 += ar[kk] as i64 * (pr[kk] as i64 - nr[kk] as i64);
        a1 += ar[kk + 1] as i64 * (pr[kk + 1] as i64 - nr[kk + 1] as i64);
        a2 += ar[kk + 2] as i64 * (pr[kk + 2] as i64 - nr[kk + 2] as i64);
        a3 += ar[kk + 3] as i64 * (pr[kk + 3] as i64 - nr[kk + 3] as i64);
        kk += 4;
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    for kk in chunks..len {
        acc += ar[kk] as i64 * (pr[kk] as i64 - nr[kk] as i64);
    }
    acc
}

/// Wrapping-i32 dot product (the narrow path's exact arithmetic).
#[inline]
pub(super) fn dot_i32_wrapping(ar: &[i32], br: &[i32]) -> i32 {
    ar.iter()
        .zip(br)
        .fold(0i32, |acc, (&a, &b)| acc.wrapping_add(a.wrapping_mul(b)))
}

/// Wrapping-i32 dot against a split (pos − neg) bank.
///
/// The bank difference is a `wrapping_sub`: plan-built banks are
/// non-negative (so the difference always fits), but the kernel is
/// public and must stay total over arbitrary i32 banks — a plain `-`
/// overflowed (debug-build panic) for inputs like `p = i32::MAX,
/// n = i32::MIN`, and the SIMD lanes wrap here too.
#[inline]
pub(super) fn dot_i32_split_wrapping(ar: &[i32], pr: &[i32], nr: &[i32]) -> i32 {
    ar.iter()
        .zip(pr.iter().zip(nr))
        .fold(0i32, |acc, (&a, (&p, &n))| {
            acc.wrapping_add(a.wrapping_mul(p.wrapping_sub(n)))
        })
}

/// Wrapping-i32 dot over *packed* i16 codes (the packed narrow path's
/// scalar reference). Each i16·i16 product is exactly representable in
/// i32, so only the accumulation wraps — same ring as
/// [`dot_i32_wrapping`] over the widened values.
#[inline]
pub(super) fn dot_i16_wrapping(ar: &[i16], br: &[i16]) -> i32 {
    ar.iter()
        .zip(br)
        .fold(0i32, |acc, (&a, &b)| acc.wrapping_add(a as i32 * b as i32))
}
