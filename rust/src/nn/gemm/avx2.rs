//! AVX2 (x86-64) row-dot kernels behind the [`super::simd`] dispatch.
//!
//! Lane semantics mirror the scalar oracle exactly:
//!
//! - wide variants widen i32×i32 products to i64 lanes
//!   (`_mm256_mul_epi32` on the even/odd halves) and accumulate in
//!   i64 — identical to the scalar chains wherever the scalar chains
//!   don't overflow (the plan's code bounds guarantee they don't);
//! - narrow variants use `_mm256_mullo_epi32` / `_mm256_add_epi32`,
//!   which are *exactly* wrapping-i32 multiply/add — bit-identical to
//!   the scalar wrapping fold for **all** inputs, since wrapping i32
//!   arithmetic is a commutative ring (any summation order agrees);
//! - the packed path multiplies 16 i16 lanes per `_mm256_madd_epi16`,
//!   whose pairwise i32 sums also wrap — again bit-identical to the
//!   scalar packed fold for all inputs.
//!
//! All loads are unaligned (`loadu`), so callers owe no alignment
//! contract — `Vec`-backed scratch slabs and weight banks work as-is.

use std::arch::x86_64::*;

/// Wide dot: Σ a·b with i64 accumulation.
///
/// # Safety
/// AVX2 must be available on the running CPU (guaranteed by
/// [`super::simd::SimdLevel::supported`]).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_i64(a: &[i32], b: &[i32]) -> i64 {
    let len = a.len().min(b.len());
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 8 <= len {
        // SAFETY: `i + 8 <= len ≤ a.len(), b.len()` keeps both 8-lane
        // unaligned reads in bounds; `loadu` has no alignment contract.
        let (va, vb) = unsafe {
            (
                _mm256_loadu_si256(pa.add(i) as *const __m256i),
                _mm256_loadu_si256(pb.add(i) as *const __m256i),
            )
        };
        // even lanes sit in the low half of each 64-bit element; the
        // odd lanes get there via a logical 64-bit shift (mul_epi32
        // sign-extends from bit 31 of the low half, so both are exact)
        let even = _mm256_mul_epi32(va, vb);
        let odd = _mm256_mul_epi32(_mm256_srli_epi64(va, 32), _mm256_srli_epi64(vb, 32));
        acc = _mm256_add_epi64(acc, _mm256_add_epi64(even, odd));
        i += 8;
    }
    let mut out = hsum_i64x4(acc);
    while i < len {
        out = out.wrapping_add(a[i] as i64 * b[i] as i64);
        i += 1;
    }
    out
}

/// Wide split dot: Σ a·(p − n) with i64 accumulation.
///
/// # Safety
/// AVX2 must be available on the running CPU.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_i64_split(a: &[i32], p: &[i32], n: &[i32]) -> i64 {
    let len = a.len().min(p.len()).min(n.len());
    let pa = a.as_ptr();
    let pp = p.as_ptr();
    let pn = n.as_ptr();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 8 <= len {
        // SAFETY: `i + 8 <= len`, the min of all three slice lengths,
        // keeps every 8-lane unaligned read in bounds.
        let (va, vp, vn) = unsafe {
            (
                _mm256_loadu_si256(pa.add(i) as *const __m256i),
                _mm256_loadu_si256(pp.add(i) as *const __m256i),
                _mm256_loadu_si256(pn.add(i) as *const __m256i),
            )
        };
        let va_o = _mm256_srli_epi64(va, 32);
        // Σ a·p − Σ a·n ≡ Σ a·(p − n): the subtraction distributes, and
        // i64 lane adds/subs form the same mod-2^64 ring as the oracle
        let pe = _mm256_mul_epi32(va, vp);
        let po = _mm256_mul_epi32(va_o, _mm256_srli_epi64(vp, 32));
        let ne = _mm256_mul_epi32(va, vn);
        let no = _mm256_mul_epi32(va_o, _mm256_srli_epi64(vn, 32));
        let d = _mm256_sub_epi64(_mm256_add_epi64(pe, po), _mm256_add_epi64(ne, no));
        acc = _mm256_add_epi64(acc, d);
        i += 8;
    }
    let mut out = hsum_i64x4(acc);
    while i < len {
        out = out.wrapping_add(a[i] as i64 * (p[i] as i64 - n[i] as i64));
        i += 1;
    }
    out
}

/// Narrow dot: wrapping-i32 Σ a·b.
///
/// # Safety
/// AVX2 must be available on the running CPU.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_i32_wrapping(a: &[i32], b: &[i32]) -> i32 {
    let len = a.len().min(b.len());
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 8 <= len {
        // SAFETY: `i + 8 <= len ≤ a.len(), b.len()` keeps both 8-lane
        // unaligned reads in bounds.
        let (va, vb) = unsafe {
            (
                _mm256_loadu_si256(pa.add(i) as *const __m256i),
                _mm256_loadu_si256(pb.add(i) as *const __m256i),
            )
        };
        acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(va, vb));
        i += 8;
    }
    let mut out = hsum_i32x8_wrapping(acc);
    while i < len {
        out = out.wrapping_add(a[i].wrapping_mul(b[i]));
        i += 1;
    }
    out
}

/// Narrow split dot: wrapping-i32 Σ a·(p ⊖ n).
///
/// # Safety
/// AVX2 must be available on the running CPU.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_i32_split_wrapping(a: &[i32], p: &[i32], n: &[i32]) -> i32 {
    let len = a.len().min(p.len()).min(n.len());
    let pa = a.as_ptr();
    let pp = p.as_ptr();
    let pn = n.as_ptr();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 8 <= len {
        // SAFETY: `i + 8 <= len`, the min of all three slice lengths,
        // keeps every 8-lane unaligned read in bounds.
        let (va, vp, vn) = unsafe {
            (
                _mm256_loadu_si256(pa.add(i) as *const __m256i),
                _mm256_loadu_si256(pp.add(i) as *const __m256i),
                _mm256_loadu_si256(pn.add(i) as *const __m256i),
            )
        };
        // sub_epi32 wraps — same as the oracle's p.wrapping_sub(n)
        let d = _mm256_sub_epi32(vp, vn);
        acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(va, d));
        i += 8;
    }
    let mut out = hsum_i32x8_wrapping(acc);
    while i < len {
        out = out.wrapping_add(a[i].wrapping_mul(p[i].wrapping_sub(n[i])));
        i += 1;
    }
    out
}

/// Packed narrow dot: wrapping-i32 Σ a·b over i16 codes, 16 lanes per
/// multiply (`pmaddwd` pairs two products into each i32 lane).
///
/// # Safety
/// AVX2 must be available on the running CPU.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_i16_wrapping(a: &[i16], b: &[i16]) -> i32 {
    let len = a.len().min(b.len());
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 16 <= len {
        // SAFETY: `i + 16 <= len ≤ a.len(), b.len()` keeps both
        // 16-lane (i16) unaligned reads in bounds.
        let (va, vb) = unsafe {
            (
                _mm256_loadu_si256(pa.add(i) as *const __m256i),
                _mm256_loadu_si256(pb.add(i) as *const __m256i),
            )
        };
        // madd's pairwise horizontal add wraps mod 2^32 (no
        // saturation), so the whole chain stays in the wrapping ring
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
        i += 16;
    }
    let mut out = hsum_i32x8_wrapping(acc);
    while i < len {
        out = out.wrapping_add(a[i] as i32 * b[i] as i32);
        i += 1;
    }
    out
}

/// Horizontal sum of 4 i64 lanes (wrapping adds). Safe
/// `#[target_feature]` fn: value-only intrinsics, callable safely from
/// the AVX2-enabled kernels above.
#[inline]
#[target_feature(enable = "avx2")]
fn hsum_i64x4(v: __m256i) -> i64 {
    let lo = _mm256_castsi256_si128(v);
    let hi = _mm256_extracti128_si256(v, 1);
    let s = _mm_add_epi64(lo, hi);
    let s = _mm_add_epi64(s, _mm_unpackhi_epi64(s, s));
    _mm_cvtsi128_si64(s)
}

/// Horizontal sum of 8 i32 lanes (wrapping adds — part of the narrow
/// paths' defined arithmetic). Safe `#[target_feature]` fn, like
/// [`hsum_i64x4`].
#[inline]
#[target_feature(enable = "avx2")]
fn hsum_i32x8_wrapping(v: __m256i) -> i32 {
    let lo = _mm256_castsi256_si128(v);
    let hi = _mm256_extracti128_si256(v, 1);
    let s = _mm_add_epi32(lo, hi);
    let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 1));
    _mm_cvtsi128_si32(s)
}
