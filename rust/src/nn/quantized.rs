//! Quantized model execution with bit-flip power metering.
//!
//! This module owns the *configuration* vocabulary ([`QuantConfig`],
//! [`WeightQuantMethod`], [`Arithmetic`]) and the [`QuantizedModel`]
//! convenience wrapper. The heavy lifting is split plan/exec:
//!
//! - [`super::plan::ExecutionPlan`] compiles a [`Model`] + config into
//!   an immutable, shareable plan (quantized weight banks, kernel
//!   selection, scratch geometry) — "plan once";
//! - [`super::exec`] runs batches through the blocked integer GEMM
//!   kernels with a reusable [`super::exec::Scratch`] arena —
//!   "execute many".
//!
//! `QuantizedModel` keeps the seed's one-call API for experiments and
//! tests: `prepare` compiles a plan, `forward` runs one batch with the
//! full thread budget. Serving-path callers should hold the
//! [`Arc<ExecutionPlan>`] from [`QuantizedModel::plan`] and drive
//! `forward_batch` with their own scratch.

use super::exec::Scratch;
use super::model::Model;
use super::plan::ExecutionPlan;
use super::power_meter::PowerMeter;
use super::tensor::Tensor;
use crate::quant::ActQuantMethod;
use anyhow::Result;
use std::sync::Arc;

/// How weights are quantized.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightQuantMethod {
    /// Signed RUQ at `bw` bits (all MAC baselines).
    Ruq,
    /// RUQ + AdaRound-style reconstruction on the calibration set.
    RuqRecon,
    /// PANN (Eq. 12) at `r` additions per element.
    Pann { r: f64 },
}

/// Arithmetic / datapath mode, which fixes the power model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arithmetic {
    /// Conventional signed MACs with a `acc_bits`-wide accumulator
    /// (Eqs. 1–2 / 7).
    SignedMac { acc_bits: u32 },
    /// The Sec. 4 unsigned W⁺/W⁻ split (Eqs. 3–4). Function identical
    /// to signed; power differs.
    UnsignedMac,
    /// Multiplier-free PANN datapath (Eq. 13). Requires
    /// [`WeightQuantMethod::Pann`].
    Pann,
}

/// Full quantization configuration.
#[derive(Clone, Copy, Debug)]
pub struct QuantConfig {
    /// Weight bits (RUQ baselines; ignored by PANN weights).
    pub bw: u32,
    /// Activation bits (`b_x`, or `b̃_x` for PANN).
    pub bx: u32,
    /// How weights are quantized.
    pub weight_quant: WeightQuantMethod,
    /// Which integer datapath executes the MACs.
    pub arithmetic: Arithmetic,
    /// How activation ranges are fitted.
    pub act_method: ActQuantMethod,
    /// Count the single per-output-element subtraction of Eq. (6)
    /// (the paper neglects it; off by default to match the tables).
    pub count_readout_sub: bool,
}

impl QuantConfig {
    /// A conventional signed-MAC baseline at equal weight/activation
    /// bits with a 32-bit accumulator.
    pub fn signed_baseline(bits: u32, act: ActQuantMethod) -> Self {
        QuantConfig {
            bw: bits,
            bx: bits,
            weight_quant: WeightQuantMethod::Ruq,
            arithmetic: Arithmetic::SignedMac { acc_bits: 32 },
            act_method: act,
            count_readout_sub: false,
        }
    }

    /// The same baseline converted to unsigned arithmetic (Sec. 4).
    pub fn unsigned_baseline(bits: u32, act: ActQuantMethod) -> Self {
        QuantConfig { arithmetic: Arithmetic::UnsignedMac, ..Self::signed_baseline(bits, act) }
    }

    /// PANN at `(b̃_x, R)` with the chosen activation quantizer.
    pub fn pann(bx_tilde: u32, r: f64, act: ActQuantMethod) -> Self {
        QuantConfig {
            bw: 0,
            bx: bx_tilde,
            weight_quant: WeightQuantMethod::Pann { r },
            arithmetic: Arithmetic::Pann,
            act_method: act,
            count_readout_sub: false,
        }
    }
}

/// A model frozen under a [`QuantConfig`] — thin handle over a shared
/// [`ExecutionPlan`].
pub struct QuantizedModel {
    /// The configuration the model was frozen under.
    pub config: QuantConfig,
    plan: Arc<ExecutionPlan>,
    /// MACs per sample, for power accounting without running.
    pub macs_per_sample: u64,
}

impl QuantizedModel {
    /// Freeze `model` under `config`. `calib` supplies calibration
    /// inputs for the methods that need them (ACIQ, Recon; Dynamic
    /// needs none; BN-stats and DFQ use the manifest statistics).
    pub fn prepare(model: &Model, config: QuantConfig, calib: Option<&Tensor>) -> Result<QuantizedModel> {
        Self::prepare_with_layers(model, config, None, calib)
    }

    /// Freeze `model` under `config` with an optional per-layer
    /// activation-width override (see
    /// [`ExecutionPlan::compile_with_layers`]): `layer_bits[k]`
    /// replaces `config.bx` for the `k`-th MAC layer in graph order.
    /// This is how a mixed-precision menu point compiles into one
    /// plan.
    pub fn prepare_with_layers(
        model: &Model,
        config: QuantConfig,
        layer_bits: Option<&[u32]>,
        calib: Option<&Tensor>,
    ) -> Result<QuantizedModel> {
        let plan =
            Arc::new(ExecutionPlan::compile_with_layers(model, config, layer_bits, calib)?);
        let macs_per_sample = plan.macs_per_sample;
        Ok(QuantizedModel { config, plan, macs_per_sample })
    }

    /// The shared compiled plan (`Send + Sync`): serving and eval
    /// loops clone this and drive `forward_batch` with per-thread
    /// scratch.
    pub fn plan(&self) -> Arc<ExecutionPlan> {
        self.plan.clone()
    }

    /// Create a fresh meter with this model's layer slots.
    pub fn new_meter(&self) -> PowerMeter {
        self.plan.new_meter()
    }

    /// Quantized forward over a batch, metering power into `meter`.
    ///
    /// One-shot convenience: allocates scratch for this call and uses
    /// the full `PANN_THREADS` budget. Loops should use
    /// [`ExecutionPlan::forward_batch`] with a reusable scratch.
    pub fn forward(&self, x: &Tensor, meter: &mut PowerMeter) -> Result<Tensor> {
        let mut scratch = Scratch::for_plan(&self.plan, x.batch());
        self.plan
            .forward_batch(x, &mut scratch, meter, super::eval::n_threads())
    }

    /// Storage bits per weight code (Table 14's `b_R`).
    pub fn weight_code_bits(&self) -> u32 {
        self.plan.weight_code_bits()
    }

    /// Mean achieved additions per element across MAC layers,
    /// MAC-weighted (the effective network R).
    pub fn achieved_r(&self) -> f64 {
        self.plan.achieved_r()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn test_input(n: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut x = Tensor::zeros(vec![n, 1, 16, 16]);
        x.data.iter_mut().for_each(|v| *v = r.f32());
        x
    }

    #[test]
    fn unsigned_split_matches_signed_exactly() {
        // Sec. 4: the conversion must not change the function.
        let mut model = Model::reference_cnn(1);
        let x = test_input(4, 2);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(8, 3);
        let signed = QuantizedModel::prepare(
            &model,
            QuantConfig::signed_baseline(6, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let unsigned = QuantizedModel::prepare(
            &model,
            QuantConfig::unsigned_baseline(6, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let mut m1 = signed.new_meter();
        let mut m2 = unsigned.new_meter();
        let y1 = signed.forward(&x, &mut m1).unwrap();
        let y2 = unsigned.forward(&x, &mut m2).unwrap();
        assert_eq!(y1.data, y2.data, "unsigned conversion changed the function");
        // ... but the power must drop (Observation 1)
        // Exact expected ratio at b=6, B=32: unsigned 42 vs signed 52
        // flips per MAC (Eqs. 1-4).
        let ratio = m2.total_flips() / m1.total_flips();
        assert!((ratio - 42.0 / 52.0).abs() < 0.01, "power ratio {ratio}");
    }

    #[test]
    fn quantized_tracks_fp32_at_high_bits() {
        let mut model = Model::reference_cnn(4);
        let x = test_input(4, 5);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(8, 6);
        let q8 = QuantizedModel::prepare(
            &model,
            QuantConfig::signed_baseline(8, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let mut meter = q8.new_meter();
        let yq = q8.forward(&x, &mut meter).unwrap();
        let yf = model.forward(&x).unwrap();
        let scale = yf.max_abs().max(1e-6);
        for (a, b) in yq.data.iter().zip(&yf.data) {
            assert!((a - b).abs() / scale < 0.12, "{a} vs {b}");
        }
    }

    #[test]
    fn pann_mode_runs_and_meters_eq13() {
        let mut model = Model::reference_cnn(7);
        let x = test_input(2, 8);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(4, 9);
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::pann(6, 2.0, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let mut meter = qm.new_meter();
        let y = qm.forward(&x, &mut meter).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
        // flips == sum over layers of macs * (R_layer + 0.5) * bx
        let r = qm.achieved_r();
        assert!(r > 1.0 && r < 2.2, "achieved R {r}");
        let macs = meter.total_macs() as f64;
        let bound_lo = macs * (1.0 + 0.5) * 6.0 * 0.8;
        let bound_hi = macs * (2.2 + 0.5) * 6.0;
        let flips = meter.total_flips();
        assert!(flips > bound_lo && flips < bound_hi, "flips {flips}");
    }

    #[test]
    fn mixed_precision_meters_between_the_uniform_extremes() {
        // Per-layer Eq. (13): a plan with some layers at b̃x = 8 and
        // some at 2 must consume strictly less energy than uniform-8
        // and strictly more than uniform-2.
        let mut model = Model::reference_cnn(23);
        let x = test_input(2, 24);
        model.record_act_stats(&x).unwrap();
        let run = |bits: Option<&[u32]>, bx: u32| {
            let cfg = QuantConfig::pann(bx, 2.0, ActQuantMethod::BnStats);
            let qm = QuantizedModel::prepare_with_layers(&model, cfg, bits, None).unwrap();
            let mut meter = qm.new_meter();
            qm.forward(&x, &mut meter).unwrap();
            meter.total_flips()
        };
        let n = {
            let cfg = QuantConfig::pann(8, 2.0, ActQuantMethod::BnStats);
            QuantizedModel::prepare(&model, cfg, None).unwrap().plan().layer_certs().len()
        };
        let hi = run(None, 8);
        let lo = run(None, 2);
        let mut bits = vec![8u32; n];
        bits[n - 1] = 2;
        let mixed = run(Some(&bits), 8);
        assert!(lo < hi);
        assert!(mixed < hi, "mixed {mixed} must undercut uniform hi {hi}");
        assert!(mixed > lo, "mixed {mixed} must exceed uniform lo {lo}");
    }

    #[test]
    fn readout_sub_config_charges_extra_flips() {
        let mut model = Model::reference_cnn(9);
        let x = test_input(2, 10);
        model.record_act_stats(&x).unwrap();
        let base = QuantConfig::pann(6, 2.0, ActQuantMethod::BnStats);
        let with_sub = QuantConfig { count_readout_sub: true, ..base };
        let run = |cfg| {
            let qm = QuantizedModel::prepare(&model, cfg, None).unwrap();
            let mut meter = qm.new_meter();
            qm.forward(&x, &mut meter).unwrap();
            (meter.total_flips(), meter.total_macs())
        };
        let (f0, m0) = run(base);
        let (f1, m1) = run(with_sub);
        assert_eq!(m0, m1, "readout subs must not inflate the MAC count");
        // per output element: one 2·b̃x = 12-bit subtraction
        assert!(f1 > f0, "readout accounting should add flips");
        let extra = f1 - f0;
        // conv1 (8·16·16) + conv2 (16·8·8) + fc (10) outputs × 2 samples × 12 bits
        let want = (2 * (8 * 16 * 16 + 16 * 8 * 8 + 10) * 12) as f64;
        assert!((extra - want).abs() < 1e-6, "extra {extra} want {want}");
    }

    #[test]
    fn dynamic_and_bnstats_methods_run() {
        let mut model = Model::reference_cnn(10);
        let x = test_input(2, 11);
        model.record_act_stats(&x).unwrap();
        for act in [ActQuantMethod::Dynamic, ActQuantMethod::BnStats, ActQuantMethod::Dfq] {
            let qm = QuantizedModel::prepare(&model, QuantConfig::unsigned_baseline(6, act), None)
                .unwrap_or_else(|e| panic!("{act:?}: {e}"));
            let mut meter = qm.new_meter();
            let y = qm.forward(&x, &mut meter).unwrap();
            assert_eq!(y.shape, vec![2, 10], "{act:?}");
            assert!(meter.total_flips() > 0.0);
        }
    }

    #[test]
    fn recon_prepares_with_calibration() {
        let mut model = Model::reference_cnn(12);
        let x = test_input(2, 13);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(6, 14);
        let cfg = QuantConfig {
            weight_quant: WeightQuantMethod::RuqRecon,
            ..QuantConfig::unsigned_baseline(3, ActQuantMethod::Recon)
        };
        let qm = QuantizedModel::prepare(&model, cfg, Some(&calib)).unwrap();
        let mut meter = qm.new_meter();
        let y = qm.forward(&x, &mut meter).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
    }

    #[test]
    fn residual_model_quantizes() {
        let mut model = Model::reference_resnet(15);
        let x = test_input(2, 16);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(4, 17);
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::unsigned_baseline(5, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let mut meter = qm.new_meter();
        let y = qm.forward(&x, &mut meter).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
    }

    #[test]
    fn power_ordering_signed_unsigned_pann() {
        // At comparable precision the paper's ordering must hold:
        // signed > unsigned > PANN-at-2bit-budget.
        let mut model = Model::reference_cnn(18);
        let x = test_input(2, 19);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(4, 20);
        let run = |cfg: QuantConfig| {
            let qm = QuantizedModel::prepare(&model, cfg, Some(&calib)).unwrap();
            let mut meter = qm.new_meter();
            qm.forward(&x, &mut meter).unwrap();
            meter.total_flips()
        };
        let p_signed = run(QuantConfig::signed_baseline(4, ActQuantMethod::Aciq));
        let p_unsigned = run(QuantConfig::unsigned_baseline(4, ActQuantMethod::Aciq));
        // PANN tuned to the 4-bit unsigned budget: P = 24 flips/MAC,
        // b̃x = 6 -> R = 3.5
        let p_pann = run(QuantConfig::pann(6, 3.5, ActQuantMethod::Aciq));
        assert!(p_signed > p_unsigned);
        assert!(
            (p_pann - p_unsigned).abs() / p_unsigned < 0.25,
            "pann {p_pann} vs unsigned {p_unsigned} should be at similar budget"
        );
    }

    #[test]
    fn weight_code_bits_reported() {
        let mut model = Model::reference_cnn(21);
        let x = test_input(2, 22);
        model.record_act_stats(&x).unwrap();
        let qm =
            QuantizedModel::prepare(&model, QuantConfig::pann(6, 1.0, ActQuantMethod::BnStats), None)
                .unwrap();
        let bits = qm.weight_code_bits();
        assert!(bits >= 1 && bits <= 16, "bits {bits}");
    }
}
