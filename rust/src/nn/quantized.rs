//! Quantized model execution with bit-flip power metering.
//!
//! [`QuantizedModel::prepare`] freezes a [`Model`] under a
//! [`QuantConfig`]: weights are quantized once (RUQ / RUQ+reconstruction
//! / PANN), activation quantizers are fitted (dynamically, from
//! calibration data, or data-free from stored statistics), and DFQ's
//! cross-layer equalization + bias correction are applied when selected.
//! The forward pass then runs genuine integer arithmetic (i32 codes,
//! i64 accumulation) through the GEMM kernels and meters power with the
//! paper's per-MAC models.

use super::gemm;
use super::layers::Op;
use super::model::Model;
use super::power_meter::PowerMeter;
use super::tensor::Tensor;
use crate::quant::{aciq, pann::PannQuant, recon, ruq, ActQuantMethod, QParams};
use anyhow::{bail, Context, Result};

/// How weights are quantized.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightQuantMethod {
    /// Signed RUQ at `bw` bits (all MAC baselines).
    Ruq,
    /// RUQ + AdaRound-style reconstruction on the calibration set.
    RuqRecon,
    /// PANN (Eq. 12) at `r` additions per element.
    Pann { r: f64 },
}

/// Arithmetic / datapath mode, which fixes the power model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arithmetic {
    /// Conventional signed MACs with a `acc_bits`-wide accumulator
    /// (Eqs. 1–2 / 7).
    SignedMac { acc_bits: u32 },
    /// The Sec. 4 unsigned W⁺/W⁻ split (Eqs. 3–4). Function identical
    /// to signed; power differs.
    UnsignedMac,
    /// Multiplier-free PANN datapath (Eq. 13). Requires
    /// [`WeightQuantMethod::Pann`].
    Pann,
}

/// Full quantization configuration.
#[derive(Clone, Copy, Debug)]
pub struct QuantConfig {
    /// Weight bits (RUQ baselines; ignored by PANN weights).
    pub bw: u32,
    /// Activation bits (`b_x`, or `b̃_x` for PANN).
    pub bx: u32,
    pub weight_quant: WeightQuantMethod,
    pub arithmetic: Arithmetic,
    pub act_method: ActQuantMethod,
    /// Count the single per-output-element subtraction of Eq. (6)
    /// (the paper neglects it; off by default to match the tables).
    pub count_readout_sub: bool,
}

impl QuantConfig {
    /// A conventional signed-MAC baseline at equal weight/activation
    /// bits with a 32-bit accumulator.
    pub fn signed_baseline(bits: u32, act: ActQuantMethod) -> Self {
        QuantConfig {
            bw: bits,
            bx: bits,
            weight_quant: WeightQuantMethod::Ruq,
            arithmetic: Arithmetic::SignedMac { acc_bits: 32 },
            act_method: act,
            count_readout_sub: false,
        }
    }

    /// The same baseline converted to unsigned arithmetic (Sec. 4).
    pub fn unsigned_baseline(bits: u32, act: ActQuantMethod) -> Self {
        QuantConfig { arithmetic: Arithmetic::UnsignedMac, ..Self::signed_baseline(bits, act) }
    }

    /// PANN at `(b̃_x, R)` with the chosen activation quantizer.
    pub fn pann(bx_tilde: u32, r: f64, act: ActQuantMethod) -> Self {
        QuantConfig {
            bw: 0,
            bx: bx_tilde,
            weight_quant: WeightQuantMethod::Pann { r },
            arithmetic: Arithmetic::Pann,
            act_method: act,
            count_readout_sub: false,
        }
    }
}

/// Activation quantizer of one layer.
#[derive(Clone, Debug)]
enum ActQ {
    /// Frozen parameters (calibrated or data-free).
    Fixed(QParams),
    /// Min/max fitted per forward batch ("Dynamic").
    Dynamic,
}

/// Weight codes of one layer.
#[derive(Clone, Debug)]
struct WeightForm {
    /// W⁺ codes, `[out][k]` (all of W for the signed path).
    pos: Vec<i32>,
    /// W⁻ codes (empty for the signed path).
    neg: Vec<i32>,
    scale: f32,
    /// signed path keeps combined codes in `pos`
    split: bool,
    /// PANN: achieved ‖w_q‖₁ / (d·out) — additions per element.
    adds_per_element: f64,
    /// max |code| (storage bits, Table 14).
    max_code: i64,
}

/// A frozen MAC layer ready for integer execution.
#[derive(Clone, Debug)]
struct PreparedMac {
    /// Graph node index.
    node: usize,
    /// Meter slot.
    meter: usize,
    weights: WeightForm,
    bias: Vec<f32>,
    act: ActQ,
    /// conv only: (ci, kh, kw, stride, pad, co)
    conv: Option<(usize, usize, usize, usize, usize, usize)>,
    /// linear only: (out, in)
    linear: Option<(usize, usize)>,
    /// MAC-depth per output element (k).
    depth: usize,
}

/// A model frozen under a [`QuantConfig`].
pub struct QuantizedModel {
    pub config: QuantConfig,
    model: Model,
    prepared: Vec<Option<PreparedMac>>,
    meter_names: Vec<String>,
    /// MACs per sample, for power accounting without running.
    pub macs_per_sample: u64,
}

impl QuantizedModel {
    /// Freeze `model` under `config`. `calib` supplies calibration
    /// inputs for the methods that need them (ACIQ, Recon, Dynamic
    /// needs none; BN-stats and DFQ use the manifest statistics).
    pub fn prepare(model: &Model, config: QuantConfig, calib: Option<&Tensor>) -> Result<QuantizedModel> {
        let mut model = model.clone();
        if config.act_method == ActQuantMethod::Dfq {
            apply_dfq_equalization(&mut model)?;
        }
        let shapes = model.shapes()?;
        let calib_outs = match calib {
            Some(x) => Some(model.forward_all(x).context("calibration forward")?),
            None => None,
        };

        let mut prepared: Vec<Option<PreparedMac>> = vec![None; model.nodes.len()];
        let mut meter_names = Vec::new();
        for i in 0..model.nodes.len() {
            if !model.nodes[i].op.is_mac_layer() {
                continue;
            }
            let input_idx = model.nodes[i].input;
            // --- activation quantizer for this layer's input ---
            let act = fit_activation_quantizer(
                &model,
                &config,
                input_idx,
                calib.map(|c| (c, calib_outs.as_ref().unwrap().as_slice())),
            )?;
            // --- weight quantization ---
            let (w, b, conv, linear, depth, out_ch) = match &model.nodes[i].op {
                Op::Conv { w, b, stride, pad } => {
                    let (co, ci, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                    (
                        w.clone(),
                        b.clone(),
                        Some((ci, kh, kw, *stride, *pad, co)),
                        None,
                        ci * kh * kw,
                        co,
                    )
                }
                Op::Linear { w, b } => {
                    let (o, k) = (w.shape[0], w.shape[1]);
                    (w.clone(), b.clone(), None, Some((o, k)), k, o)
                }
                _ => unreachable!(),
            };
            let weights = quantize_weights(
                &w.data,
                out_ch,
                depth,
                &config,
                calib.map(|c| (c, calib_outs.as_ref().unwrap().as_slice())),
                &model,
                i,
            )?;
            // --- DFQ bias correction ---
            let mut bias = b;
            if config.act_method == ActQuantMethod::Dfq {
                if let Some(corr) = dfq_bias_correction(&model, i, &w.data, &weights, out_ch, depth) {
                    for (bo, c) in bias.iter_mut().zip(corr) {
                        *bo -= c;
                    }
                }
            }
            let meter = meter_names.len();
            meter_names.push(format!("{}{}", model.nodes[i].op.name(), i));
            prepared[i] = Some(PreparedMac {
                node: i,
                meter,
                weights,
                bias,
                act,
                conv,
                linear,
                depth,
            });
        }
        let macs_per_sample = shapes.iter().map(|(m, _)| m).sum();
        Ok(QuantizedModel { config, model, prepared, meter_names, macs_per_sample })
    }

    /// Create a fresh meter with this model's layer slots.
    pub fn new_meter(&self) -> PowerMeter {
        let mut m = PowerMeter::new();
        for n in &self.meter_names {
            m.add_layer(n);
        }
        m
    }

    /// Quantized forward over a batch, metering power into `meter`.
    pub fn forward(&self, x: &Tensor, meter: &mut PowerMeter) -> Result<Tensor> {
        let mut outs: Vec<Tensor> = Vec::with_capacity(self.model.nodes.len());
        for (i, node) in self.model.nodes.iter().enumerate() {
            let input = if node.input < 0 { x } else { &outs[node.input as usize] };
            let y = match &self.prepared[i] {
                Some(p) => self.forward_mac(p, input, meter)?,
                None => {
                    let rhs = match node.op {
                        Op::Add { rhs } => Some(&outs[rhs]),
                        _ => None,
                    };
                    super::layers::forward_f32(&node.op, input, rhs)
                        .with_context(|| format!("node {i}"))?
                }
            };
            outs.push(y);
        }
        Ok(outs.pop().expect("non-empty model"))
    }

    /// Flips per MAC under this config (for a layer whose achieved
    /// PANN budget is `adds`).
    fn flips_per_mac(&self, adds: f64) -> f64 {
        let c = &self.config;
        match c.arithmetic {
            Arithmetic::SignedMac { acc_bits } => {
                crate::power::model::mult_power_mixed_signed(c.bw, c.bx)
                    + 0.5 * acc_bits as f64
                    + (c.bw + c.bx) as f64
            }
            Arithmetic::UnsignedMac => {
                crate::power::model::mult_power_mixed_signed(c.bw, c.bx)
                    + 1.5 * (c.bw + c.bx) as f64
            }
            Arithmetic::Pann => crate::power::model::pann_power_per_element(adds, c.bx),
        }
    }

    fn forward_mac(&self, p: &PreparedMac, x: &Tensor, meter: &mut PowerMeter) -> Result<Tensor> {
        let n = x.batch();
        // activation quantizer (dynamic fits on the live tensor)
        let qx = match &p.act {
            ActQ::Fixed(q) => *q,
            ActQ::Dynamic => ruq::fit_unsigned(&x.data, self.config.bx),
        };
        let wscale = p.weights.scale;
        let deq = wscale * qx.scale;
        let out = if let Some((ci, kh, kw, stride, pad, co)) = p.conv {
            let (h, w) = match x.shape.as_slice() {
                [_, c, h, w] if *c == ci => (*h, *w),
                other => bail!("conv input shape {other:?}"),
            };
            let (oh, ow) = gemm::conv_out_size(h, w, kh, kw, stride, pad);
            let k = ci * kh * kw;
            let mut cols_f = Vec::new();
            let mut cols_q = vec![0i32; oh * ow * k];
            let mut acc = vec![0i64; oh * ow * co];
            let mut out = Tensor::zeros(vec![n, co, oh, ow]);
            for s in 0..n {
                gemm::im2col(x.sample(s), ci, h, w, kh, kw, stride, pad, &mut cols_f);
                for (dst, &v) in cols_q.iter_mut().zip(cols_f.iter()) {
                    *dst = qx.quantize(v) as i32;
                }
                self.run_gemm(p, &cols_q, &mut acc, oh * ow, co, k);
                let dst = &mut out.data[s * co * oh * ow..(s + 1) * co * oh * ow];
                for pix in 0..oh * ow {
                    for o in 0..co {
                        dst[o * oh * ow + pix] = acc[pix * co + o] as f32 * deq + p.bias[o];
                    }
                }
            }
            out
        } else {
            let (out_d, k) = p.linear.unwrap();
            if x.sample_len() != k {
                bail!("linear input {} != {k}", x.sample_len());
            }
            let xq: Vec<i32> = x.data.iter().map(|&v| qx.quantize(v) as i32).collect();
            let mut acc = vec![0i64; n * out_d];
            self.run_gemm(p, &xq, &mut acc, n, out_d, k);
            let mut out = Tensor::zeros(vec![n, out_d]);
            for i in 0..n {
                for o in 0..out_d {
                    out.data[i * out_d + o] = acc[i * out_d + o] as f32 * deq + p.bias[o];
                }
            }
            out
        };
        // --- power accounting ---
        let macs = out.sample_len() as u64 * p.depth as u64 * n as u64 / {
            // conv: out elements per sample = co*oh*ow, each depth k
            // linear: out elements = out_d
            1
        };
        match self.config.arithmetic {
            Arithmetic::Pann => {
                meter.record_pann(p.meter, macs, p.weights.adds_per_element, self.config.bx);
                if self.config.count_readout_sub {
                    // one B≈2b̃x-bit subtraction per output element
                    let subs = out.len() as u64;
                    meter.record(p.meter, 0, 0.0);
                    meter.layers[p.meter].flips += subs as f64 * (2 * self.config.bx) as f64;
                }
            }
            _ => meter.record(p.meter, macs, self.flips_per_mac(0.0)),
        }
        Ok(out)
    }

    fn run_gemm(&self, p: &PreparedMac, xq: &[i32], acc: &mut [i64], m: usize, nd: usize, k: usize) {
        // Overflow-safety proof for the narrow (i32-accumulate) path:
        // every |product| ≤ act_qmax · max|code|, and at most k of them
        // sum up — if that bound stays below 2^30 the i32 accumulator
        // cannot wrap.
        let act_qmax = ((1i64 << self.config.bx.min(30)) - 1).max(1);
        let narrow = act_qmax
            .saturating_mul(p.weights.max_code.max(1))
            .saturating_mul(k as i64)
            < (1i64 << 30);
        if p.weights.split {
            if narrow {
                gemm::gemm_i32_split_narrow(xq, &p.weights.pos, &p.weights.neg, acc, m, nd, k);
            } else {
                gemm::gemm_i32_split(xq, &p.weights.pos, &p.weights.neg, acc, m, nd, k);
            }
        } else if narrow {
            gemm::gemm_i32_narrow(xq, &p.weights.pos, acc, m, nd, k);
        } else {
            gemm::gemm_i32(xq, &p.weights.pos, acc, m, nd, k);
        }
    }

    /// Storage bits per weight code (Table 14's `b_R`).
    pub fn weight_code_bits(&self) -> u32 {
        self.prepared
            .iter()
            .flatten()
            .map(|p| 64 - (p.weights.max_code.unsigned_abs().max(1)).leading_zeros())
            .max()
            .unwrap_or(1)
    }

    /// Mean achieved additions per element across MAC layers,
    /// MAC-weighted (the effective network R).
    pub fn achieved_r(&self) -> f64 {
        let shapes = self.model.shapes().unwrap_or_default();
        let mut num = 0.0;
        let mut den = 0.0;
        for p in self.prepared.iter().flatten() {
            let macs = shapes[p.node].0 as f64;
            num += macs * p.weights.adds_per_element;
            den += macs;
        }
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }
}

/// Fit the activation quantizer for the input of a MAC layer.
fn fit_activation_quantizer(
    model: &Model,
    config: &QuantConfig,
    input_idx: isize,
    calib: Option<(&Tensor, &[Tensor])>,
) -> Result<ActQ> {
    use ActQuantMethod::*;
    Ok(match config.act_method {
        Dynamic => ActQ::Dynamic,
        Aciq | Recon => {
            let (cx, couts) = calib.context("ACIQ/Recon need a calibration set")?;
            let data: &[f32] = if input_idx < 0 { &cx.data } else { &couts[input_idx as usize].data };
            ActQ::Fixed(aciq::fit_relu_activations(data, config.bx))
        }
        BnStats | Dfq => {
            if input_idx < 0 {
                // model input: ranges are part of the data contract
                // (inputs normalized to [0, 1] by the datasets).
                ActQ::Fixed(ruq::fit_unsigned_clipped(1.0, config.bx))
            } else {
                let stats = model
                    .act_stats
                    .get(&(input_idx as usize))
                    .context("manifest lacks act_stats for data-free quantization")?;
                ActQ::Fixed(stats.fit_activations(config.bx))
            }
        }
    })
}

/// Quantize one layer's weights under the config.
fn quantize_weights(
    w: &[f32],
    out_ch: usize,
    depth: usize,
    config: &QuantConfig,
    calib: Option<(&Tensor, &[Tensor])>,
    model: &Model,
    node: usize,
) -> Result<WeightForm> {
    let split = !matches!(config.arithmetic, Arithmetic::SignedMac { .. });
    let mk = |codes: Vec<i64>, scale: f32, adds: f64| -> WeightForm {
        let max_code = codes.iter().map(|c| c.abs()).max().unwrap_or(0);
        if split {
            let pos: Vec<i32> = codes.iter().map(|&c| c.max(0) as i32).collect();
            let neg: Vec<i32> = codes.iter().map(|&c| (-c).max(0) as i32).collect();
            WeightForm { pos, neg, scale, split: true, adds_per_element: adds, max_code }
        } else {
            WeightForm {
                pos: codes.iter().map(|&c| c as i32).collect(),
                neg: Vec::new(),
                scale,
                split: false,
                adds_per_element: adds,
                max_code,
            }
        }
    };
    match config.weight_quant {
        WeightQuantMethod::Ruq => {
            let q = ruq::fit_signed(w, config.bw);
            let codes = q.quantize_slice(w);
            Ok(mk(codes, q.scale, 0.0))
        }
        WeightQuantMethod::RuqRecon => {
            let q = ruq::fit_signed(w, config.bw);
            let codes = match calib {
                Some((cx, couts)) => {
                    let input_idx = model.nodes[node].input;
                    let xin = if input_idx < 0 { cx } else { &couts[input_idx as usize] };
                    let rows = recon_rows(&model.nodes[node].op, xin, depth, 48)?;
                    let nrows = rows.len() / depth;
                    let mut all = Vec::with_capacity(w.len());
                    for o in 0..out_ch {
                        let wrow = &w[o * depth..(o + 1) * depth];
                        all.extend(recon::reconstruct_row(wrow, &q, &rows, nrows, 6));
                    }
                    all
                }
                None => q.quantize_slice(w),
            };
            Ok(mk(codes, q.scale, 0.0))
        }
        WeightQuantMethod::Pann { r } => {
            let pq = PannQuant::new(r);
            let pw = pq.quantize(w);
            Ok(mk(pw.codes.clone(), pw.gamma, pw.adds_per_element))
        }
    }
}

/// Calibration rows (`[n][depth]`) for rounding reconstruction.
fn recon_rows(op: &Op, xin: &Tensor, depth: usize, max_rows: usize) -> Result<Vec<f32>> {
    match op {
        Op::Linear { .. } => {
            let n = xin.batch().min(max_rows);
            Ok(xin.data[..n * depth].to_vec())
        }
        Op::Conv { w, stride, pad, .. } => {
            let (ci, kh, kw) = (w.shape[1], w.shape[2], w.shape[3]);
            let (h, wd) = match xin.shape.as_slice() {
                [_, _, h, w] => (*h, *w),
                other => bail!("conv calib input {other:?}"),
            };
            let mut cols = Vec::new();
            let mut rows = Vec::new();
            let samples = xin.batch().min(4);
            for s in 0..samples {
                gemm::im2col(xin.sample(s), ci, h, wd, kh, kw, *stride, *pad, &mut cols);
                let nrows = cols.len() / depth;
                // take evenly spaced rows
                let want = (max_rows / samples).max(1);
                let step = (nrows / want).max(1);
                for r in (0..nrows).step_by(step).take(want) {
                    rows.extend_from_slice(&cols[r * depth..(r + 1) * depth]);
                }
            }
            Ok(rows)
        }
        _ => bail!("recon rows on non-mac layer"),
    }
}

/// DFQ cross-layer equalization on directly-chained MAC pairs
/// (conv→[relu/pool]→conv and linear→relu→linear).
fn apply_dfq_equalization(model: &mut Model) -> Result<()> {
    let n = model.nodes.len();
    // find MAC pairs connected through shape-preserving per-channel ops
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for i in 0..n {
        if !model.nodes[i].op.is_mac_layer() {
            continue;
        }
        // walk forward through relu/maxpool only, following single-consumer chains
        let mut cur = i;
        'walk: loop {
            // find the unique consumer of cur
            let consumers: Vec<usize> = (0..n)
                .filter(|&j| {
                    model.nodes[j].input == cur as isize
                        || matches!(model.nodes[j].op, Op::Add { rhs } if rhs == cur)
                })
                .collect();
            if consumers.len() != 1 {
                break 'walk;
            }
            let j = consumers[0];
            match model.nodes[j].op {
                Op::Relu | Op::MaxPool { .. } => {
                    cur = j;
                }
                Op::Conv { .. } | Op::Linear { .. } => {
                    pairs.push((i, j));
                    break 'walk;
                }
                _ => break 'walk,
            }
        }
    }
    for (a, b) in pairs {
        equalize_nodes(model, a, b)?;
    }
    Ok(())
}

/// Equalize one (producer, consumer) MAC pair in place.
fn equalize_nodes(model: &mut Model, a: usize, b: usize) -> Result<()> {
    // Extract producer rows [mid][ka] and consumer columns grouped by
    // producer channel: consumer weight [out][mid * g] where g = spatial
    // group size (kh*kw for conv, h*w collapsed for linear-after-conv).
    let (mid, ka) = match &model.nodes[a].op {
        Op::Conv { w, .. } => (w.shape[0], w.shape[1] * w.shape[2] * w.shape[3]),
        Op::Linear { w, .. } => (w.shape[0], w.shape[1]),
        _ => bail!("not a mac node"),
    };
    let (out_b, kb) = match &model.nodes[b].op {
        Op::Conv { w, .. } => (w.shape[0], w.shape[1] * w.shape[2] * w.shape[3]),
        Op::Linear { w, .. } => (w.shape[0], w.shape[1]),
        _ => bail!("not a mac node"),
    };
    // consumer input features per producer channel
    let cin_b = match &model.nodes[b].op {
        Op::Conv { w, .. } => w.shape[1],
        Op::Linear { .. } => {
            if kb % mid != 0 {
                return Ok(()); // shapes don't group cleanly; skip pair
            }
            mid
        }
        _ => unreachable!(),
    };
    if cin_b != mid {
        return Ok(()); // channel mismatch (e.g. flatten regrouping failed)
    }
    let g = kb / mid;
    // per-channel ranges
    let (r1, r2) = {
        let wa = match &model.nodes[a].op {
            Op::Conv { w, .. } | Op::Linear { w, .. } => &w.data,
            _ => unreachable!(),
        };
        let wb = match &model.nodes[b].op {
            Op::Conv { w, .. } | Op::Linear { w, .. } => &w.data,
            _ => unreachable!(),
        };
        let r1: Vec<f32> = (0..mid)
            .map(|c| wa[c * ka..(c + 1) * ka].iter().fold(0.0f32, |m, &x| m.max(x.abs())))
            .collect();
        let r2: Vec<f32> = (0..mid)
            .map(|c| {
                let mut m = 0.0f32;
                for o in 0..out_b {
                    for gg in 0..g {
                        m = m.max(wb[o * kb + c * g + gg].abs());
                    }
                }
                m
            })
            .collect();
        (r1, r2)
    };
    let scales: Vec<f32> = r1
        .iter()
        .zip(&r2)
        .map(|(&x, &y)| if x <= 1e-12 || y <= 1e-12 { 1.0 } else { (x / y).sqrt().clamp(1e-3, 1e3) })
        .collect();
    // apply
    if let Op::Conv { w, b: bias, .. } | Op::Linear { w, b: bias } = &mut model.nodes[a].op {
        for c in 0..mid {
            let s = scales[c];
            for v in &mut w.data[c * ka..(c + 1) * ka] {
                *v /= s;
            }
            bias[c] /= s;
        }
    }
    if let Op::Conv { w, .. } | Op::Linear { w, .. } = &mut model.nodes[b].op {
        for o in 0..out_b {
            for c in 0..mid {
                let s = scales[c];
                for gg in 0..g {
                    w.data[o * kb + c * g + gg] *= s;
                }
            }
        }
    }
    // keep act_stats of the producer's chain consistent: scale them too
    let idxs: Vec<usize> = model.act_stats.keys().copied().collect();
    for idx in idxs {
        // only stats of nodes between a and b along the chain carry the
        // producer's channel dimension; scaling them keeps BN-stats
        // quantizers correct after equalization.
        if idx >= a && idx < b {
            if let Some(st) = model.act_stats.get_mut(&idx) {
                if st.mean.len() == mid {
                    for c in 0..mid {
                        st.mean[c] /= scales[c];
                        st.std[c] /= scales[c];
                    }
                }
            }
        }
    }
    Ok(())
}

/// DFQ bias correction for one layer, from the manifest's activation
/// statistics of the producer node. Returns the per-output correction
/// `E[ε·x]` to subtract, or `None` if stats are missing.
fn dfq_bias_correction(
    model: &Model,
    node: usize,
    w: &[f32],
    wf: &WeightForm,
    out_ch: usize,
    depth: usize,
) -> Option<Vec<f32>> {
    let input_idx = model.nodes[node].input;
    if input_idx < 0 {
        return None;
    }
    let stats = model.act_stats.get(&(input_idx as usize))?;
    let ch = stats.mean.len();
    if ch == 0 || depth % ch != 0 {
        return None;
    }
    let g = depth / ch;
    // expected input per position: post-ReLU mean per channel
    let mean_in: Vec<f32> = (0..depth).map(|i| stats.mean[i / g].max(0.0)).collect();
    let mut corr = vec![0.0f32; out_ch];
    for o in 0..out_ch {
        let mut acc = 0.0f32;
        for i in 0..depth {
            let code = if wf.split {
                wf.pos[o * depth + i] as i64 - wf.neg[o * depth + i] as i64
            } else {
                wf.pos[o * depth + i] as i64
            };
            let err = wf.scale * code as f32 - w[o * depth + i];
            acc += err * mean_in[i];
        }
        corr[o] = acc;
    }
    Some(corr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn test_input(n: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut x = Tensor::zeros(vec![n, 1, 16, 16]);
        x.data.iter_mut().for_each(|v| *v = r.f32());
        x
    }

    #[test]
    fn unsigned_split_matches_signed_exactly() {
        // Sec. 4: the conversion must not change the function.
        let mut model = Model::reference_cnn(1);
        let x = test_input(4, 2);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(8, 3);
        let signed = QuantizedModel::prepare(
            &model,
            QuantConfig::signed_baseline(6, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let unsigned = QuantizedModel::prepare(
            &model,
            QuantConfig::unsigned_baseline(6, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let mut m1 = signed.new_meter();
        let mut m2 = unsigned.new_meter();
        let y1 = signed.forward(&x, &mut m1).unwrap();
        let y2 = unsigned.forward(&x, &mut m2).unwrap();
        assert_eq!(y1.data, y2.data, "unsigned conversion changed the function");
        // ... but the power must drop (Observation 1)
        // Exact expected ratio at b=6, B=32: unsigned 42 vs signed 52
        // flips per MAC (Eqs. 1-4).
        let ratio = m2.total_flips() / m1.total_flips();
        assert!((ratio - 42.0 / 52.0).abs() < 0.01, "power ratio {ratio}");
    }

    #[test]
    fn quantized_tracks_fp32_at_high_bits() {
        let mut model = Model::reference_cnn(4);
        let x = test_input(4, 5);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(8, 6);
        let q8 = QuantizedModel::prepare(
            &model,
            QuantConfig::signed_baseline(8, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let mut meter = q8.new_meter();
        let yq = q8.forward(&x, &mut meter).unwrap();
        let yf = model.forward(&x).unwrap();
        let scale = yf.max_abs().max(1e-6);
        for (a, b) in yq.data.iter().zip(&yf.data) {
            assert!((a - b).abs() / scale < 0.12, "{a} vs {b}");
        }
    }

    #[test]
    fn pann_mode_runs_and_meters_eq13() {
        let mut model = Model::reference_cnn(7);
        let x = test_input(2, 8);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(4, 9);
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::pann(6, 2.0, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let mut meter = qm.new_meter();
        let y = qm.forward(&x, &mut meter).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
        // flips == sum over layers of macs * (R_layer + 0.5) * bx
        let r = qm.achieved_r();
        assert!(r > 1.0 && r < 2.2, "achieved R {r}");
        let macs = meter.total_macs() as f64;
        let bound_lo = macs * (1.0 + 0.5) * 6.0 * 0.8;
        let bound_hi = macs * (2.2 + 0.5) * 6.0;
        let flips = meter.total_flips();
        assert!(flips > bound_lo && flips < bound_hi, "flips {flips}");
    }

    #[test]
    fn dynamic_and_bnstats_methods_run() {
        let mut model = Model::reference_cnn(10);
        let x = test_input(2, 11);
        model.record_act_stats(&x).unwrap();
        for act in [ActQuantMethod::Dynamic, ActQuantMethod::BnStats, ActQuantMethod::Dfq] {
            let qm = QuantizedModel::prepare(&model, QuantConfig::unsigned_baseline(6, act), None)
                .unwrap_or_else(|e| panic!("{act:?}: {e}"));
            let mut meter = qm.new_meter();
            let y = qm.forward(&x, &mut meter).unwrap();
            assert_eq!(y.shape, vec![2, 10], "{act:?}");
            assert!(meter.total_flips() > 0.0);
        }
    }

    #[test]
    fn recon_prepares_with_calibration() {
        let mut model = Model::reference_cnn(12);
        let x = test_input(2, 13);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(6, 14);
        let cfg = QuantConfig {
            weight_quant: WeightQuantMethod::RuqRecon,
            ..QuantConfig::unsigned_baseline(3, ActQuantMethod::Recon)
        };
        let qm = QuantizedModel::prepare(&model, cfg, Some(&calib)).unwrap();
        let mut meter = qm.new_meter();
        let y = qm.forward(&x, &mut meter).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
    }

    #[test]
    fn residual_model_quantizes() {
        let mut model = Model::reference_resnet(15);
        let x = test_input(2, 16);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(4, 17);
        let qm = QuantizedModel::prepare(
            &model,
            QuantConfig::unsigned_baseline(5, ActQuantMethod::Aciq),
            Some(&calib),
        )
        .unwrap();
        let mut meter = qm.new_meter();
        let y = qm.forward(&x, &mut meter).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
    }

    #[test]
    fn power_ordering_signed_unsigned_pann() {
        // At comparable precision the paper's ordering must hold:
        // signed > unsigned > PANN-at-2bit-budget.
        let mut model = Model::reference_cnn(18);
        let x = test_input(2, 19);
        model.record_act_stats(&x).unwrap();
        let calib = test_input(4, 20);
        let run = |cfg: QuantConfig| {
            let qm = QuantizedModel::prepare(&model, cfg, Some(&calib)).unwrap();
            let mut meter = qm.new_meter();
            qm.forward(&x, &mut meter).unwrap();
            meter.total_flips()
        };
        let p_signed = run(QuantConfig::signed_baseline(4, ActQuantMethod::Aciq));
        let p_unsigned = run(QuantConfig::unsigned_baseline(4, ActQuantMethod::Aciq));
        // PANN tuned to the 4-bit unsigned budget: P = 24 flips/MAC,
        // b̃x = 6 -> R = 3.5
        let p_pann = run(QuantConfig::pann(6, 3.5, ActQuantMethod::Aciq));
        assert!(p_signed > p_unsigned);
        assert!(
            (p_pann - p_unsigned).abs() / p_unsigned < 0.25,
            "pann {p_pann} vs unsigned {p_unsigned} should be at similar budget"
        );
    }

    #[test]
    fn weight_code_bits_reported() {
        let mut model = Model::reference_cnn(21);
        let x = test_input(2, 22);
        model.record_act_stats(&x).unwrap();
        let qm =
            QuantizedModel::prepare(&model, QuantConfig::pann(6, 1.0, ActQuantMethod::BnStats), None)
                .unwrap();
        let bits = qm.weight_code_bits();
        assert!(bits >= 1 && bits <= 16, "bits {bits}");
    }
}
