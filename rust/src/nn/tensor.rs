//! Dense row-major f32 tensor.

use anyhow::{bail, Result};

/// A dense row-major tensor of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Row-major elements (`prod(shape)` of them).
    pub data: Vec<f32>,
}

impl Tensor {
    /// A tensor from shape + matching row-major data.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    /// An all-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Leading (batch) dimension.
    pub fn batch(&self) -> usize {
        *self.shape.first().unwrap_or(&0)
    }

    /// Elements per sample (all dims but the first).
    pub fn sample_len(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?} mismatch", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// Max |x| over the tensor.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Row-major sample slice.
    pub fn sample(&self, i: usize) -> &[f32] {
        let d = self.sample_len();
        &self.data[i * d..(i + 1) * d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]).unwrap();
        assert_eq!(t.batch(), 2);
        assert_eq!(t.sample_len(), 3);
        let r = t.reshape(vec![3, 2]).unwrap();
        assert_eq!(r.shape, vec![3, 2]);
        assert!(Tensor::new(vec![2, 2], vec![0.0]).is_err());
        assert!(r.reshape(vec![5]).is_err());
    }

    #[test]
    fn max_abs_works() {
        let t = Tensor::new(vec![3], vec![-2.5, 1.0, 2.0]).unwrap();
        assert_eq!(t.max_abs(), 2.5);
    }
}
