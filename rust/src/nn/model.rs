//! Model graphs: manifest loading, fp32 forward, calibration capture
//! and built-in reference architectures.
//!
//! A manifest (`manifest.json`, written by `python/compile/train.py`)
//! lists nodes in SSA order; weight tensors live as `.ptns` files next
//! to it. Per-node output activation statistics (recorded on the
//! training set) power the data-free quantizers.

use super::layers::{forward_f32, Op};
use super::tensor::Tensor;
use crate::quant::bnstats::BnStats;
use crate::util::{Json, Rng};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One SSA node.
#[derive(Clone, Debug)]
pub struct Node {
    /// The operation this node applies.
    pub op: Op,
    /// Producer index; -1 = model input.
    pub input: isize,
}

/// A loaded model.
#[derive(Clone, Debug)]
pub struct Model {
    /// Model name (artifact directory stem).
    pub name: String,
    /// Input shape per sample (e.g. `[1, 16, 16]` or `[64]`).
    pub input_shape: Vec<usize>,
    /// SSA nodes in topological order.
    pub nodes: Vec<Node>,
    /// Per-node output activation statistics (per-channel mean/std),
    /// recorded at training time; used by the data-free quantizers.
    pub act_stats: BTreeMap<usize, BnStats>,
}

impl Model {
    /// Total MACs for one sample (the paper's per-network constant).
    pub fn num_macs(&self) -> u64 {
        self.shapes().map(|v| v.iter().map(|(m, _)| m).sum()).unwrap_or(0)
    }

    /// Per-node (macs, out_shape) in SSA order.
    pub fn shapes(&self) -> Result<Vec<(u64, Vec<usize>)>> {
        let mut out: Vec<(u64, Vec<usize>)> = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let in_shape = if node.input < 0 {
                self.input_shape.clone()
            } else {
                out[node.input as usize].1.clone()
            };
            let (m, s) = self
                .nodes[i]
                .op
                .macs_and_out_shape(&in_shape)
                .with_context(|| format!("node {i} ({})", node.op.name()))?;
            out.push((m, s));
        }
        Ok(out)
    }

    /// fp32 forward over a batch; returns the final node's output.
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        Ok(self.forward_all(x)?.pop().expect("non-empty model"))
    }

    /// fp32 forward retaining every node output (calibration capture).
    pub fn forward_all(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        if self.nodes.is_empty() {
            bail!("empty model");
        }
        let mut outs: Vec<Tensor> = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let input = if node.input < 0 { x } else { &outs[node.input as usize] };
            let rhs = match node.op {
                Op::Add { rhs } => Some(&outs[rhs]),
                _ => None,
            };
            let y = forward_f32(&node.op, input, rhs)
                .with_context(|| format!("node {i} ({})", node.op.name()))?;
            outs.push(y);
        }
        Ok(outs)
    }

    /// Load from `dir/manifest.json` + `.ptns` weight files.
    pub fn load(dir: &Path) -> Result<Model> {
        let manifest = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {}/manifest.json", dir.display()))?;
        let j = Json::parse(&manifest).context("parse manifest.json")?;
        let name = j.req("name")?.as_str().unwrap_or("model").to_string();
        let input_shape: Vec<usize> = j
            .req("input")?
            .as_arr()
            .context("input must be array")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let load_w = |v: &Json| -> Result<Tensor> {
            let fname = v.as_str().context("tensor ref must be a string")?;
            let t = crate::data::tensor_io::read_tensor(&dir.join(fname))?;
            let (shape, data) = t.into_f32()?;
            Tensor::new(shape, data)
        };
        let mut nodes = Vec::new();
        for (i, nj) in j.req("layers")?.as_arr().context("layers must be array")?.iter().enumerate() {
            let op_name = nj.req("op")?.as_str().context("op must be string")?;
            let input = nj.get("input").and_then(|v| v.as_f64()).unwrap_or(i as f64 - 1.0) as isize;
            let op = match op_name {
                "conv" => {
                    let w = load_w(nj.req("w")?)?;
                    let b = load_w(nj.req("b")?)?.data;
                    let stride = nj.get("stride").and_then(|v| v.as_usize()).unwrap_or(1);
                    let pad = nj.get("pad").and_then(|v| v.as_usize()).unwrap_or(0);
                    Op::Conv { w, b, stride, pad }
                }
                "linear" => {
                    let w = load_w(nj.req("w")?)?;
                    let b = load_w(nj.req("b")?)?.data;
                    Op::Linear { w, b }
                }
                "relu" => Op::Relu,
                "maxpool" => Op::MaxPool { k: nj.get("k").and_then(|v| v.as_usize()).unwrap_or(2) },
                "gap" => Op::GlobalAvgPool,
                "flatten" => Op::Flatten,
                "add" => Op::Add {
                    rhs: nj.req("rhs")?.as_usize().context("rhs must be index")?,
                },
                other => bail!("unknown op '{other}' at node {i}"),
            };
            nodes.push(Node { op, input });
        }
        // activation statistics
        let mut act_stats = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("act_stats") {
            for (k, v) in m {
                let idx: usize = k.parse().with_context(|| format!("bad act_stats key {k}"))?;
                let mean: Vec<f32> = v
                    .req("mean")?
                    .as_arr()
                    .context("mean must be array")?
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                    .collect();
                let std: Vec<f32> = v
                    .req("std")?
                    .as_arr()
                    .context("std must be array")?
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                    .collect();
                act_stats.insert(idx, BnStats::new(mean, std));
            }
        }
        let model = Model { name, input_shape, nodes, act_stats };
        model.shapes().context("shape check failed")?; // validate graph
        Ok(model)
    }

    /// Content fingerprint of the graph: a 64-bit FNV-1a over the
    /// name, input shape, node topology, op parameters, exact weight
    /// bits and recorded activation statistics. Compiled-menu
    /// artifacts (`menu.json`) persist it so a menu is never
    /// recompiled against a different model than it was measured on —
    /// any weight, wiring or calibration-stat change moves the hash.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        fn eat(h: &mut u64, bytes: &[u8]) {
            for &b in bytes {
                *h ^= b as u64;
                *h = h.wrapping_mul(FNV_PRIME);
            }
        }
        fn eat_usize(h: &mut u64, v: usize) {
            eat(h, &(v as u64).to_le_bytes());
        }
        fn eat_f32s(h: &mut u64, vs: &[f32]) {
            eat_usize(h, vs.len());
            for v in vs {
                eat(h, &v.to_bits().to_le_bytes());
            }
        }
        let mut h = FNV_OFFSET;
        eat(&mut h, self.name.as_bytes());
        for &d in &self.input_shape {
            eat_usize(&mut h, d);
        }
        for node in &self.nodes {
            eat(&mut h, node.op.name().as_bytes());
            eat(&mut h, &(node.input as i64).to_le_bytes());
            match &node.op {
                Op::Conv { w, b, stride, pad } => {
                    for &d in &w.shape {
                        eat_usize(&mut h, d);
                    }
                    eat_f32s(&mut h, &w.data);
                    eat_f32s(&mut h, b);
                    eat_usize(&mut h, *stride);
                    eat_usize(&mut h, *pad);
                }
                Op::Linear { w, b } => {
                    for &d in &w.shape {
                        eat_usize(&mut h, d);
                    }
                    eat_f32s(&mut h, &w.data);
                    eat_f32s(&mut h, b);
                }
                Op::MaxPool { k } => eat_usize(&mut h, *k),
                Op::Add { rhs } => eat_usize(&mut h, *rhs),
                Op::Relu | Op::GlobalAvgPool | Op::Flatten => {}
            }
        }
        for (idx, stats) in &self.act_stats {
            eat_usize(&mut h, *idx);
            eat_f32s(&mut h, &stats.mean);
            eat_f32s(&mut h, &stats.std);
        }
        h
    }

    /// Record per-node output statistics on a batch (used when a
    /// manifest lacks them and for the built-in reference models).
    pub fn record_act_stats(&mut self, x: &Tensor) -> Result<()> {
        let outs = self.forward_all(x)?;
        let shapes = self.shapes()?;
        self.act_stats.clear();
        for (i, out) in outs.iter().enumerate() {
            let ch = shapes[i].1[0];
            let per = out.sample_len() / ch.max(1);
            let n = out.batch();
            let mut mean = vec![0.0f32; ch];
            let mut std = vec![0.0f32; ch];
            for c in 0..ch {
                let mut acc = 0.0f64;
                let mut acc2 = 0.0f64;
                let mut cnt = 0usize;
                for s in 0..n {
                    let base = s * out.sample_len() + c * per;
                    for p in 0..per {
                        let v = out.data[base + p] as f64;
                        acc += v;
                        acc2 += v * v;
                        cnt += 1;
                    }
                }
                let m = acc / cnt.max(1) as f64;
                mean[c] = m as f32;
                std[c] = ((acc2 / cnt.max(1) as f64 - m * m).max(0.0)).sqrt() as f32;
            }
            self.act_stats.insert(i, BnStats::new(mean, std));
        }
        Ok(())
    }

    /// A small random CNN for tests/benches (conv-relu-pool ×2 + fc),
    /// 16×16 single-channel input, 10 classes.
    pub fn reference_cnn(seed: u64) -> Model {
        let mut r = Rng::new(seed);
        let mut t = |shape: Vec<usize>, scale: f32| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| r.normal() as f32 * scale).collect()).unwrap()
        };
        let conv1 = Op::Conv { w: t(vec![8, 1, 3, 3], 0.3), b: vec![0.0; 8], stride: 1, pad: 1 };
        let conv2 = Op::Conv { w: t(vec![16, 8, 3, 3], 0.1), b: vec![0.0; 16], stride: 1, pad: 1 };
        let fc = Op::Linear { w: t(vec![10, 16 * 4 * 4], 0.1), b: vec![0.0; 10] };
        Model {
            name: "ref-cnn".into(),
            input_shape: vec![1, 16, 16],
            nodes: vec![
                Node { op: conv1, input: -1 },
                Node { op: Op::Relu, input: 0 },
                Node { op: Op::MaxPool { k: 2 }, input: 1 },
                Node { op: conv2, input: 2 },
                Node { op: Op::Relu, input: 3 },
                Node { op: Op::MaxPool { k: 2 }, input: 4 },
                Node { op: Op::Flatten, input: 5 },
                Node { op: fc, input: 6 },
            ],
            act_stats: BTreeMap::new(),
        }
    }

    /// A small residual CNN for tests (conv + identity-join).
    pub fn reference_resnet(seed: u64) -> Model {
        let mut r = Rng::new(seed);
        let mut t = |shape: Vec<usize>, scale: f32| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| r.normal() as f32 * scale).collect()).unwrap()
        };
        let stem = Op::Conv { w: t(vec![8, 1, 3, 3], 0.3), b: vec![0.0; 8], stride: 1, pad: 1 };
        let block = Op::Conv { w: t(vec![8, 8, 3, 3], 0.1), b: vec![0.0; 8], stride: 1, pad: 1 };
        let fc = Op::Linear { w: t(vec![10, 8], 0.3), b: vec![0.0; 10] };
        Model {
            name: "ref-resnet".into(),
            input_shape: vec![1, 16, 16],
            nodes: vec![
                Node { op: stem, input: -1 },                 // 0
                Node { op: Op::Relu, input: 0 },              // 1
                Node { op: block, input: 1 },                 // 2
                Node { op: Op::Relu, input: 2 },              // 3
                Node { op: Op::Add { rhs: 1 }, input: 3 },    // 4 residual
                Node { op: Op::GlobalAvgPool, input: 4 },     // 5
                Node { op: fc, input: 5 },                    // 6
            ],
            act_stats: BTreeMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_cnn_forward_shape() {
        let m = Model::reference_cnn(1);
        let x = Tensor::zeros(vec![3, 1, 16, 16]);
        let y = m.forward(&x).unwrap();
        assert_eq!(y.shape, vec![3, 10]);
        assert_eq!(m.num_macs(), 8*9*256 + 16*8*9*64 + 10*256);
    }

    #[test]
    fn residual_join_works() {
        let m = Model::reference_resnet(2);
        let mut x = Tensor::zeros(vec![2, 1, 16, 16]);
        x.data.iter_mut().enumerate().for_each(|(i, v)| *v = (i % 7) as f32 * 0.1);
        let y = m.forward(&x).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
        // outputs differ per sample
        assert!(y.data[..10].iter().zip(&y.data[10..]).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn manifest_roundtrip() {
        // Write a tiny manifest + weights, load it, compare forward
        // with the in-memory model.
        let dir = std::env::temp_dir().join("pann_test_model");
        std::fs::create_dir_all(&dir).unwrap();
        let w = Tensor::new(vec![2, 3], vec![0.5, -1.0, 0.25, 1.0, 0.0, -0.5]).unwrap();
        crate::data::tensor_io::write_tensor(
            &dir.join("w.ptns"),
            &crate::data::tensor_io::TensorData::F32(w.shape.clone(), w.data.clone()),
        )
        .unwrap();
        crate::data::tensor_io::write_tensor(
            &dir.join("b.ptns"),
            &crate::data::tensor_io::TensorData::F32(vec![2], vec![0.1, -0.1]),
        )
        .unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"name":"tiny","input":[3],"layers":[
                {"op":"linear","w":"w.ptns","b":"b.ptns","input":-1},
                {"op":"relu","input":0}
            ],"act_stats":{"0":{"mean":[0.0,0.0],"std":[1.0,1.0]}}}"#,
        )
        .unwrap();
        let m = Model::load(&dir).unwrap();
        assert_eq!(m.name, "tiny");
        let x = Tensor::new(vec![1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let y = m.forward(&x).unwrap();
        // linear: [0.5-2+0.75+0.1, 1+0-1.5-0.1] = [-0.65, -0.6] -> relu 0
        assert_eq!(y.data, vec![0.0, 0.0]);
        assert!(m.act_stats.contains_key(&0));
    }

    #[test]
    fn bad_manifest_rejected() {
        let dir = std::env::temp_dir().join("pann_test_badmodel");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"name":"x","input":[3],"layers":[{"op":"nope"}]}"#)
            .unwrap();
        assert!(Model::load(&dir).is_err());
    }

    #[test]
    fn fingerprint_tracks_content() {
        let m1 = Model::reference_cnn(3);
        let m2 = Model::reference_cnn(3);
        assert_eq!(m1.fingerprint(), m2.fingerprint(), "same seed, same fingerprint");
        assert_ne!(
            m1.fingerprint(),
            Model::reference_cnn(4).fingerprint(),
            "different weights must move the fingerprint"
        );
        assert_ne!(
            m1.fingerprint(),
            Model::reference_resnet(3).fingerprint(),
            "different topology must move the fingerprint"
        );
        // a single weight bit moves it too
        let mut m3 = Model::reference_cnn(3);
        if let Op::Conv { w, .. } = &mut m3.nodes[0].op {
            w.data[0] += 1e-3;
        }
        assert_ne!(m1.fingerprint(), m3.fingerprint());
        // recording stats moves it (stats feed the data-free quantizers)
        let mut m4 = Model::reference_cnn(3);
        let x = Tensor::zeros(vec![2, 1, 16, 16]);
        m4.record_act_stats(&x).unwrap();
        assert_ne!(m1.fingerprint(), m4.fingerprint());
    }

    #[test]
    fn act_stats_recording() {
        let mut m = Model::reference_cnn(3);
        let mut x = Tensor::zeros(vec![4, 1, 16, 16]);
        let mut r = crate::util::Rng::new(5);
        x.data.iter_mut().for_each(|v| *v = r.f32());
        m.record_act_stats(&x).unwrap();
        assert_eq!(m.act_stats.len(), m.nodes.len());
        // post-relu stats are non-negative means
        let relu_stats = &m.act_stats[&1];
        assert!(relu_stats.mean.iter().all(|&v| v >= 0.0));
    }
}
