//! Execute-time machinery for a compiled [`ExecutionPlan`]: a reusable
//! per-thread [`Scratch`] arena and the batched integer forward pass.
//!
//! The executor runs im2col over the **whole batch** into one slab and
//! issues a single `m = n·oh·ow` GEMM per conv node (instead of `n`
//! separate `m = oh·ow` GEMMs), through the cache-blocked, optionally
//! row-parallel kernels of [`super::gemm`]. All buffers live in the
//! caller-owned [`Scratch`], so a serving worker allocates once and
//! reuses across requests — the seed engine freshly `Vec`-allocated
//! every buffer inside every layer call.
//!
//! Bit-exactness: integer addition is associative, so batching,
//! blocking and row-parallelism all produce bit-identical accumulators
//! (the narrow kernels combine partial sums with the same wrapping
//! i32 arithmetic as their scalar references).

use super::gemm;
use super::layers::Op;
use super::plan::{ActQ, ExecutionPlan, GemmKernel, PlannedMac};
use super::power_meter::PowerMeter;
use super::quantized::Arithmetic;
use super::tensor::Tensor;
use crate::quant::ruq;
use anyhow::{bail, Context, Result};

/// Reusable per-thread scratch buffers for plan execution.
///
/// Create one per worker thread (cheap when empty — buffers grow on
/// first use and are reused afterwards). Not shared between threads;
/// the *plan* is the shared immutable half.
#[derive(Default)]
pub struct Scratch {
    /// f32 im2col columns of one sample.
    cols_f: Vec<f32>,
    /// Quantized activation codes for the whole batch (`m × k`).
    cols_q: Vec<i32>,
    /// Packed i16 activation codes for the SIMD narrow path (`m × k`;
    /// used instead of `cols_q` when the node carries a packed bank).
    cols_q16: Vec<i16>,
    /// Integer accumulators for the whole batch (`m × out`).
    acc: Vec<i64>,
}

impl Scratch {
    /// Empty arena; buffers grow on first use.
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Pre-reserve for running `plan` at batch size `n` (optional —
    /// buffers also grow on demand).
    pub fn for_plan(plan: &ExecutionPlan, n: usize) -> Scratch {
        let (cols, acc) = plan.scratch_hint(n);
        Scratch {
            cols_f: Vec::with_capacity(plan.max_cols_per_sample),
            cols_q: Vec::with_capacity(cols),
            cols_q16: Vec::new(),
            acc: Vec::with_capacity(acc),
        }
    }

    /// Bytes currently held (for reports).
    pub fn bytes(&self) -> usize {
        self.cols_f.capacity() * 4
            + self.cols_q.capacity() * 4
            + self.cols_q16.capacity() * 2
            + self.acc.capacity() * 8
    }
}

impl ExecutionPlan {
    /// Quantized forward over a batch, metering power into `meter`.
    ///
    /// `threads` bounds the row-parallelism of the GEMM hot path
    /// (1 = fully single-threaded, for callers that already
    /// parallelize above the engine, e.g. the dataset eval loops and
    /// the serving worker pool).
    pub fn forward_batch(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        meter: &mut PowerMeter,
        threads: usize,
    ) -> Result<Tensor> {
        self.forward_impl(&x.shape, &x.data, Some(x), scratch, meter, threads)
    }

    /// [`forward_batch`](Self::forward_batch) over a *borrowed* flat
    /// input of `n` samples shaped per [`input_shape`](Self::input_shape)
    /// — the serving hot path, which receives request bytes as slices
    /// and must not copy them into a fresh `Tensor` per batch.
    pub fn forward_slice(
        &self,
        data: &[f32],
        n: usize,
        scratch: &mut Scratch,
        meter: &mut PowerMeter,
        threads: usize,
    ) -> Result<Tensor> {
        let mut shape = Vec::with_capacity(1 + self.input_shape().len());
        shape.push(n);
        shape.extend_from_slice(self.input_shape());
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            bail!("input length {} != batch {n} × sample {:?}", data.len(), self.input_shape());
        }
        self.forward_impl(&shape, data, None, scratch, meter, threads)
    }

    /// Shared node loop. `input_tensor`, when given, is the `Tensor`
    /// that owns `shape`/`data` (borrowed by f32 fallback nodes);
    /// otherwise one is materialized lazily if such a node consumes
    /// the raw input (MAC nodes — the common entry — never need it).
    fn forward_impl(
        &self,
        shape: &[usize],
        data: &[f32],
        input_tensor: Option<&Tensor>,
        scratch: &mut Scratch,
        meter: &mut PowerMeter,
        threads: usize,
    ) -> Result<Tensor> {
        let mut outs: Vec<Tensor> = Vec::with_capacity(self.model.nodes.len());
        let mut lazy_input: Option<Tensor> = None;
        for (i, node) in self.model.nodes.iter().enumerate() {
            let y = match &self.steps[i] {
                Some(p) => {
                    let (in_shape, in_data) = if node.input < 0 {
                        (shape, data)
                    } else {
                        let t = &outs[node.input as usize];
                        (t.shape.as_slice(), t.data.as_slice())
                    };
                    self.forward_mac(p, in_shape, in_data, scratch, meter, threads)
                        .with_context(|| format!("node {i}"))?
                }
                None => {
                    let input: &Tensor = if node.input < 0 {
                        match input_tensor {
                            Some(t) => t,
                            None => lazy_input.get_or_insert_with(|| {
                                Tensor { shape: shape.to_vec(), data: data.to_vec() }
                            }),
                        }
                    } else {
                        &outs[node.input as usize]
                    };
                    let rhs = match node.op {
                        Op::Add { rhs } => Some(&outs[rhs]),
                        _ => None,
                    };
                    super::layers::forward_f32(&node.op, input, rhs)
                        .with_context(|| format!("node {i}"))?
                }
            };
            outs.push(y);
        }
        Ok(outs.pop().expect("non-empty model"))
    }

    /// One MAC node over the whole batch (`data` flat, `shape[0] = n`).
    fn forward_mac(
        &self,
        p: &PlannedMac,
        shape: &[usize],
        data: &[f32],
        scratch: &mut Scratch,
        meter: &mut PowerMeter,
        threads: usize,
    ) -> Result<Tensor> {
        let n = shape.first().copied().unwrap_or(0);
        let sample_len: usize = shape[1..].iter().product();
        // activation quantizer (dynamic fits on the live batch)
        let qx = match &p.act {
            ActQ::Fixed(q) => *q,
            ActQ::Dynamic => ruq::fit_unsigned(data, p.bx),
        };
        let deq = p.weights.scale * qx.scale;
        let out = if let Some((ci, kh, kw, stride, pad, co)) = p.conv {
            let (h, w) = match shape {
                [_, c, h, w] if *c == ci => (*h, *w),
                other => bail!("conv input shape {other:?}"),
            };
            let (oh, ow) = gemm::conv_out_size(h, w, kh, kw, stride, pad);
            let k = ci * kh * kw;
            let spatial = oh * ow;
            let m = n * spatial;
            // whole-batch im2col + quantization into one slab. Only
            // growth zero-fills: every element is overwritten below
            // (im2col sizes cols_f to exactly spatial·k), and the
            // blocked kernels zero their own accumulators.
            scratch.acc.resize(m * co, 0);
            if let Some(wp) = p.weights.packed.as_deref() {
                // packed narrow path: activation codes fit i16 (the
                // plan packs only when act_qmax ≤ i16::MAX), so
                // quantize straight into the dense i16 slab.
                scratch.cols_q16.resize(m * k, 0);
                for s in 0..n {
                    let sample = &data[s * sample_len..(s + 1) * sample_len];
                    gemm::im2col(sample, ci, h, w, kh, kw, stride, pad, &mut scratch.cols_f);
                    let dst = &mut scratch.cols_q16[s * spatial * k..(s + 1) * spatial * k];
                    for (d, &v) in dst.iter_mut().zip(scratch.cols_f.iter()) {
                        *d = qx.quantize(v) as i16;
                    }
                }
                gemm::gemm_i16_narrow_blocked_at(
                    self.simd,
                    &scratch.cols_q16,
                    wp,
                    &mut scratch.acc,
                    m,
                    co,
                    k,
                    threads,
                );
            } else {
                scratch.cols_q.resize(m * k, 0);
                for s in 0..n {
                    let sample = &data[s * sample_len..(s + 1) * sample_len];
                    gemm::im2col(sample, ci, h, w, kh, kw, stride, pad, &mut scratch.cols_f);
                    let dst = &mut scratch.cols_q[s * spatial * k..(s + 1) * spatial * k];
                    for (d, &v) in dst.iter_mut().zip(scratch.cols_f.iter()) {
                        *d = qx.quantize(v) as i32;
                    }
                }
                run_gemm(self.simd, p, &scratch.cols_q, &mut scratch.acc, m, co, k, threads);
            }
            // scatter accumulators back to NCHW
            let mut out = Tensor::zeros(vec![n, co, oh, ow]);
            for s in 0..n {
                let acc_s = &scratch.acc[s * spatial * co..(s + 1) * spatial * co];
                let dst = &mut out.data[s * co * spatial..(s + 1) * co * spatial];
                for pix in 0..spatial {
                    for o in 0..co {
                        dst[o * spatial + pix] = acc_s[pix * co + o] as f32 * deq + p.bias[o];
                    }
                }
            }
            out
        } else {
            let (out_d, k) = p.linear.unwrap();
            if sample_len != k {
                bail!("linear input {sample_len} != {k}");
            }
            scratch.acc.resize(n * out_d, 0);
            if let Some(wp) = p.weights.packed.as_deref() {
                scratch.cols_q16.clear();
                scratch.cols_q16.reserve(n * k);
                scratch
                    .cols_q16
                    .extend(data.iter().map(|&v| qx.quantize(v) as i16));
                gemm::gemm_i16_narrow_blocked_at(
                    self.simd,
                    &scratch.cols_q16,
                    wp,
                    &mut scratch.acc,
                    n,
                    out_d,
                    k,
                    threads,
                );
            } else {
                scratch.cols_q.clear();
                scratch.cols_q.reserve(n * k);
                scratch
                    .cols_q
                    .extend(data.iter().map(|&v| qx.quantize(v) as i32));
                run_gemm(self.simd, p, &scratch.cols_q, &mut scratch.acc, n, out_d, k, threads);
            }
            let mut out = Tensor::zeros(vec![n, out_d]);
            for i in 0..n {
                for o in 0..out_d {
                    out.data[i * out_d + o] = scratch.acc[i * out_d + o] as f32 * deq + p.bias[o];
                }
            }
            out
        };
        // --- power accounting ---
        // out elements per sample (co·oh·ow for conv, out_d for linear),
        // each the result of `depth` MACs, times the batch.
        let macs = out.sample_len() as u64 * p.depth as u64 * n as u64;
        match self.config.arithmetic {
            Arithmetic::Pann => {
                // charge Eq. (13) at the layer's *effective* width, so
                // mixed-precision plans meter each layer at its own b̃x
                meter.record_pann(p.meter, macs, p.weights.adds_per_element, p.bx);
                if self.config.count_readout_sub {
                    // one B≈2b̃x-bit subtraction per output element (Eq. 6)
                    meter.record_readout_sub(p.meter, out.len() as u64, 2 * p.bx);
                }
            }
            _ => meter.record(p.meter, macs, p.flips_per_mac),
        }
        Ok(out)
    }
}

/// Dispatch to the plan-selected blocked kernel at the plan's frozen
/// SIMD level (the unpacked paths; packed banks go straight to
/// [`gemm::gemm_i16_narrow_blocked_at`] in `forward_mac`).
#[allow(clippy::too_many_arguments)]
fn run_gemm(
    level: gemm::SimdLevel,
    p: &PlannedMac,
    xq: &[i32],
    acc: &mut [i64],
    m: usize,
    nd: usize,
    k: usize,
    threads: usize,
) {
    let w = &p.weights;
    match p.kernel {
        GemmKernel::Wide => gemm::gemm_i32_blocked_at(level, xq, &w.pos, acc, m, nd, k, threads),
        GemmKernel::Narrow => {
            gemm::gemm_i32_narrow_blocked_at(level, xq, &w.pos, acc, m, nd, k, threads)
        }
        GemmKernel::SplitWide => {
            gemm::gemm_i32_split_blocked_at(level, xq, &w.pos, &w.neg, acc, m, nd, k, threads)
        }
        GemmKernel::SplitNarrow => gemm::gemm_i32_split_narrow_blocked_at(
            level, xq, &w.pos, &w.neg, acc, m, nd, k, threads,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quantized::QuantConfig;
    use crate::nn::Model;
    use crate::quant::ActQuantMethod;
    use crate::util::Rng;

    fn test_input(n: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let mut x = Tensor::zeros(vec![n, 1, 16, 16]);
        x.data.iter_mut().for_each(|v| *v = r.f32());
        x
    }

    /// The headline invariant: one batched forward == per-sample
    /// forwards, bit-for-bit, in both logits and metered flips.
    #[test]
    fn batched_forward_matches_per_sample() {
        for (name, cfg) in [
            ("unsigned6", QuantConfig::unsigned_baseline(6, ActQuantMethod::BnStats)),
            ("signed8", QuantConfig::signed_baseline(8, ActQuantMethod::BnStats)),
            ("pann", QuantConfig::pann(6, 2.0, ActQuantMethod::BnStats)),
        ] {
            let mut model = Model::reference_cnn(50);
            let x = test_input(6, 51);
            model.record_act_stats(&x).unwrap();
            let plan = ExecutionPlan::compile(&model, cfg, None).unwrap();

            let mut scratch = Scratch::for_plan(&plan, 6);
            let mut meter_b = plan.new_meter();
            let batched = plan.forward_batch(&x, &mut scratch, &mut meter_b, 3).unwrap();

            let mut meter_s = plan.new_meter();
            let classes = batched.sample_len();
            for s in 0..x.batch() {
                let xs = Tensor::new(vec![1, 1, 16, 16], x.sample(s).to_vec()).unwrap();
                let ys = plan.forward_batch(&xs, &mut scratch, &mut meter_s, 1).unwrap();
                assert_eq!(
                    ys.data,
                    &batched.data[s * classes..(s + 1) * classes],
                    "{name}: sample {s} logits diverge"
                );
            }
            assert_eq!(meter_b.total_macs(), meter_s.total_macs(), "{name}: macs");
            assert!(
                (meter_b.total_flips() - meter_s.total_flips()).abs() < 1e-6,
                "{name}: flips {} vs {}",
                meter_b.total_flips(),
                meter_s.total_flips()
            );
        }
    }

    /// The serving entry: a borrowed flat slice must produce exactly
    /// what the owned-`Tensor` entry produces (logits and flips).
    #[test]
    fn forward_slice_matches_forward_batch() {
        let mut model = Model::reference_cnn(60);
        let x = test_input(5, 61);
        model.record_act_stats(&x).unwrap();
        let plan = ExecutionPlan::compile(
            &model,
            QuantConfig::pann(6, 2.0, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        let mut scratch = Scratch::new();
        let mut m1 = plan.new_meter();
        let y1 = plan.forward_batch(&x, &mut scratch, &mut m1, 1).unwrap();
        let mut m2 = plan.new_meter();
        let y2 = plan.forward_slice(&x.data, 5, &mut scratch, &mut m2, 1).unwrap();
        assert_eq!(y1.data, y2.data);
        assert_eq!(y1.shape, y2.shape);
        assert_eq!(m1.total_flips(), m2.total_flips());
        assert_eq!(m1.total_macs(), m2.total_macs());
        // a length mismatch is an error, not a mis-shaped forward
        assert!(plan.forward_slice(&x.data[1..], 5, &mut scratch, &mut m2, 1).is_err());
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut model = Model::reference_cnn(52);
        let x = test_input(8, 53);
        model.record_act_stats(&x).unwrap();
        let plan = ExecutionPlan::compile(
            &model,
            QuantConfig::unsigned_baseline(5, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        let mut scratch = Scratch::new();
        let mut m1 = plan.new_meter();
        let y1 = plan.forward_batch(&x, &mut scratch, &mut m1, 1).unwrap();
        for t in [2, 3, 7] {
            let mut mt = plan.new_meter();
            let yt = plan.forward_batch(&x, &mut scratch, &mut mt, t).unwrap();
            assert_eq!(y1.data, yt.data, "threads={t}");
            assert_eq!(m1.total_macs(), mt.total_macs());
            assert_eq!(m1.total_flips(), mt.total_flips());
        }
    }

    /// SIMD dispatch (including the packed i16 banks) must be
    /// invisible: a plan downgraded with `force_scalar` produces the
    /// same logits and metered totals as the detected-level plan, for
    /// every kernel family the configs below exercise (SplitNarrow +
    /// packed, Narrow, and the PANN split path).
    #[test]
    fn simd_and_forced_scalar_plans_bit_identical() {
        for (name, cfg) in [
            ("unsigned4", QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats)),
            ("signed8", QuantConfig::signed_baseline(8, ActQuantMethod::BnStats)),
            ("pann", QuantConfig::pann(6, 2.0, ActQuantMethod::BnStats)),
        ] {
            let mut model = Model::reference_cnn(70);
            let x = test_input(5, 71);
            model.record_act_stats(&x).unwrap();
            let simd_plan = ExecutionPlan::compile(&model, cfg, None).unwrap();
            let mut scalar_plan = ExecutionPlan::compile(&model, cfg, None).unwrap();
            scalar_plan.force_scalar();

            let mut scratch = Scratch::new();
            let mut m1 = simd_plan.new_meter();
            let y1 = simd_plan.forward_batch(&x, &mut scratch, &mut m1, 2).unwrap();
            let mut m2 = scalar_plan.new_meter();
            let y2 = scalar_plan.forward_batch(&x, &mut scratch, &mut m2, 2).unwrap();
            assert_eq!(y1.data, y2.data, "{name}: logits diverge across dispatch");
            assert_eq!(m1.total_macs(), m2.total_macs(), "{name}: macs");
            assert_eq!(m1.total_flips(), m2.total_flips(), "{name}: flips");
        }
    }

    #[test]
    fn residual_model_runs_batched() {
        let mut model = Model::reference_resnet(54);
        let x = test_input(4, 55);
        model.record_act_stats(&x).unwrap();
        let plan = ExecutionPlan::compile(
            &model,
            QuantConfig::unsigned_baseline(5, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        let mut scratch = Scratch::for_plan(&plan, 4);
        let mut meter = plan.new_meter();
        let y = plan.forward_batch(&x, &mut scratch, &mut meter, 2).unwrap();
        assert_eq!(y.shape, vec![4, 10]);
        assert!(meter.total_flips() > 0.0);
    }
}
