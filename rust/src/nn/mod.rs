//! Integer NN inference engine with exact power metering.
//!
//! A small SSA-graph executor for the conv/linear/ReLU/pool networks
//! the paper evaluates, able to run each model in four arithmetic
//! modes while accounting bit-flip power per layer:
//!
//! - **fp32** — reference forward (also used to collect calibration
//!   activations).
//! - **signed MAC** — weights/activations quantized to `b_w`/`b_x`
//!   bits, signed integer arithmetic, power per Eqs. (1)–(2)/(7).
//! - **unsigned MAC** — the Sec. 4 W⁺/W⁻ split; *identical function*,
//!   power per Eqs. (3)–(4).
//! - **PANN** — multiplier-free weight quantization of Sec. 5, power
//!   per Eq. (13) with the *achieved* additions budget.
//!
//! The quantized engine is a plan/exec split ("plan once, execute
//! many"): [`plan`] compiles a [`Model`] + [`quantized::QuantConfig`]
//! into an immutable, `Send + Sync` [`ExecutionPlan`] (weight banks,
//! per-node kernel selection, scratch geometry); [`exec`] runs whole
//! batches through the cache-blocked, row-parallel GEMM kernels with a
//! reusable per-thread [`Scratch`] arena. [`quantized`] keeps the
//! one-call [`QuantizedModel`] wrapper plus the config vocabulary.
//!
//! Modules: [`tensor`] (shape + storage), [`gemm`] (f32/integer GEMM,
//! blocked + threaded variants with runtime AVX2/NEON dispatch and
//! packed-i16 narrow banks, im2col), [`layers`]/[`model`] (graph +
//! manifest), [`plan`] (compile), [`exec`] (batched execution),
//! [`quantized`] (config + wrapper), [`power_meter`] (accounting),
//! [`eval`] (dataset accuracy loops).

pub mod eval;
pub mod exec;
pub mod gemm;
pub mod layers;
pub mod model;
pub mod plan;
pub mod power_meter;
pub mod quantized;
pub mod tensor;

pub use exec::Scratch;
pub use gemm::SimdLevel;
pub use model::Model;
pub use plan::{ExecutionPlan, GemmKernel};
pub use power_meter::PowerMeter;
pub use quantized::{Arithmetic, QuantConfig, QuantizedModel, WeightQuantMethod};
pub use tensor::Tensor;
