//! Integer NN inference engine with exact power metering.
//!
//! A small SSA-graph executor for the conv/linear/ReLU/pool networks
//! the paper evaluates, able to run each model in four arithmetic
//! modes while accounting bit-flip power per layer:
//!
//! - **fp32** — reference forward (also used to collect calibration
//!   activations).
//! - **signed MAC** — weights/activations quantized to `b_w`/`b_x`
//!   bits, signed integer arithmetic, power per Eqs. (1)–(2)/(7).
//! - **unsigned MAC** — the Sec. 4 W⁺/W⁻ split; *identical function*,
//!   power per Eqs. (3)–(4).
//! - **PANN** — multiplier-free weight quantization of Sec. 5, power
//!   per Eq. (13) with the *achieved* additions budget.
//!
//! Modules: [`tensor`] (shape + storage), [`gemm`] (f32 and integer
//! GEMM + im2col), [`layers`]/[`model`] (graph + manifest), [`quantized`]
//! (prepared quantized execution), [`power_meter`] (accounting),
//! [`eval`] (dataset accuracy loops).

pub mod eval;
pub mod gemm;
pub mod layers;
pub mod model;
pub mod power_meter;
pub mod quantized;
pub mod tensor;

pub use model::Model;
pub use power_meter::PowerMeter;
pub use quantized::{Arithmetic, QuantConfig, QuantizedModel, WeightQuantMethod};
pub use tensor::Tensor;
