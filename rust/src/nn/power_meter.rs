//! Per-layer bit-flip power accounting.
//!
//! The meter accumulates, per MAC layer, the number of MACs executed
//! and the bit flips they cost under the active arithmetic mode, using
//! the analytic models of [`crate::power`] — exactly how the paper
//! computes its table columns (power = per-MAC flips × #MACs).

/// One layer's tally.
#[derive(Clone, Debug, Default)]
pub struct LayerTally {
    /// Layer label (graph node name).
    pub name: String,
    /// MACs executed (or elements processed, for PANN).
    pub macs: u64,
    /// Bit flips consumed.
    pub flips: f64,
    /// PANN only: achieved additions per element.
    pub adds_per_element: f64,
}

/// Accumulated power over a run.
#[derive(Clone, Debug, Default)]
pub struct PowerMeter {
    /// One tally per registered MAC layer.
    pub layers: Vec<LayerTally>,
}

impl PowerMeter {
    /// Meter with no layers registered yet.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a layer slot; returns its index.
    pub fn add_layer(&mut self, name: &str) -> usize {
        self.layers.push(LayerTally { name: name.to_string(), ..Default::default() });
        self.layers.len() - 1
    }

    /// Record `macs` MAC operations at `flips_per_mac`.
    pub fn record(&mut self, layer: usize, macs: u64, flips_per_mac: f64) {
        let t = &mut self.layers[layer];
        t.macs += macs;
        t.flips += macs as f64 * flips_per_mac;
    }

    /// Record a PANN burst: `elements` weight/activation pairs at the
    /// achieved additions budget.
    pub fn record_pann(&mut self, layer: usize, elements: u64, adds_per_element: f64, bx_tilde: u32) {
        let t = &mut self.layers[layer];
        t.macs += elements;
        t.adds_per_element = adds_per_element;
        t.flips += elements as f64 * crate::power::model::pann_power_per_element(adds_per_element, bx_tilde);
    }

    /// Record the per-output readout subtractions of Eq. (6): `subs`
    /// subtractions, each a `bits`-wide adder pass (~`bits` flips).
    /// Charged as pure flips — the MAC count is unchanged, matching
    /// how the paper's tables separate MAC energy from readout.
    pub fn record_readout_sub(&mut self, layer: usize, subs: u64, bits: u32) {
        self.layers[layer].flips += subs as f64 * bits as f64;
    }

    /// Total flips.
    pub fn total_flips(&self) -> f64 {
        self.layers.iter().map(|l| l.flips).sum()
    }

    /// Total flips in Giga bit flips (the paper's table unit).
    pub fn giga(&self) -> f64 {
        self.total_flips() / 1e9
    }

    /// Total MACs.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Zero every tally, keeping the registered layers.
    pub fn reset(&mut self) {
        for l in &mut self.layers {
            l.macs = 0;
            l.flips = 0.0;
        }
    }

    /// Pretty per-layer report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for l in &self.layers {
            s.push_str(&format!(
                "{:<18} macs={:<12} flips={:.3e}\n",
                l.name, l.macs, l.flips
            ));
        }
        s.push_str(&format!("TOTAL  macs={}  {:.4} Gflips\n", self.total_macs(), self.giga()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = PowerMeter::new();
        let a = m.add_layer("conv1");
        let b = m.add_layer("fc");
        m.record(a, 1000, 36.0);
        m.record(b, 500, 24.0);
        assert_eq!(m.total_macs(), 1500);
        assert!((m.total_flips() - 48_000.0).abs() < 1e-9);
        m.reset();
        assert_eq!(m.total_flips(), 0.0);
    }

    #[test]
    fn pann_record_uses_eq13() {
        let mut m = PowerMeter::new();
        let a = m.add_layer("conv1");
        m.record_pann(a, 100, 2.0, 4);
        // (2 + 0.5) * 4 = 10 flips per element
        assert!((m.total_flips() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn readout_sub_charges_flips_only() {
        let mut m = PowerMeter::new();
        let a = m.add_layer("fc");
        m.record_pann(a, 100, 2.0, 4);
        let before = m.total_flips();
        m.record_readout_sub(a, 50, 8);
        assert_eq!(m.total_macs(), 100, "readout subs must not count as MACs");
        assert!((m.total_flips() - before - 400.0).abs() < 1e-9);
    }
}
