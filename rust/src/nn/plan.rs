//! Plan-time compilation: freeze a [`Model`] + [`QuantConfig`] into an
//! immutable, `Send + Sync` [`ExecutionPlan`].
//!
//! Everything that used to be (re)decided inside the forward pass is
//! decided exactly once here:
//!
//! - weights are quantized into their integer banks (RUQ / RUQ+recon /
//!   PANN, split into W⁺/W⁻ for the unsigned paths),
//! - activation quantizers are fitted (dynamic, calibrated, or
//!   data-free from stored statistics) and DFQ equalization + bias
//!   correction are applied when selected,
//! - the GEMM kernel for every MAC node is selected (narrow vs wide
//!   accumulation × split vs unified banks — previously re-proved on
//!   every `run_gemm` call),
//! - per-MAC flip costs and scratch-buffer sizes are precomputed.
//!
//! The plan owns no mutable state, so one `Arc<ExecutionPlan>` can be
//! shared by a whole worker pool; per-thread mutable state lives in
//! [`super::exec::Scratch`].

use super::gemm;
use super::gemm::SimdLevel;
use super::layers::Op;
use super::model::Model;
use super::power_meter::PowerMeter;
use super::quantized::{Arithmetic, QuantConfig, WeightQuantMethod};
use super::tensor::Tensor;
use crate::analysis::{Interval, KernelCert};
use crate::quant::{aciq, pann::PannQuant, recon, ruq, ActQuantMethod, QParams};
use anyhow::{bail, Context, Result};

/// Which integer GEMM kernel a MAC node runs — fixed at plan time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKernel {
    /// Unified bank, i64 accumulation.
    Wide,
    /// Unified bank, i32 accumulation (overflow bound proven at plan
    /// time).
    Narrow,
    /// W⁺/W⁻ banks, i64 accumulation.
    SplitWide,
    /// W⁺/W⁻ banks, i32 accumulation.
    SplitNarrow,
}

/// Activation quantizer of one layer.
#[derive(Clone, Debug)]
pub(crate) enum ActQ {
    /// Frozen parameters (calibrated or data-free).
    Fixed(QParams),
    /// Min/max fitted per forward batch ("Dynamic").
    Dynamic,
}

/// Weight codes of one layer.
#[derive(Clone, Debug)]
pub(crate) struct WeightForm {
    /// W⁺ codes, `[out][k]` (all of W for the signed path).
    pub pos: Vec<i32>,
    /// W⁻ codes (empty for the signed path).
    pub neg: Vec<i32>,
    pub scale: f32,
    /// signed path keeps combined codes in `pos`
    pub split: bool,
    /// PANN: achieved ‖w_q‖₁ / (d·out) — additions per element.
    pub adds_per_element: f64,
    /// max |code| (storage bits, Table 14).
    pub max_code: i64,
    /// Smallest effective per-element code (`p − n` on the split
    /// path), before any storage cast — prover input.
    pub code_lo: i64,
    /// Largest effective per-element code — prover input.
    pub code_hi: i64,
    /// Dense i16 bank for the SIMD narrow path (the unified codes, or
    /// the `W⁺ − W⁻` difference on the split path — see
    /// [`gemm::packed`]). `None` when the plan runs scalar, the kernel
    /// is wide, or the codes don't fit i16.
    pub packed: Option<Vec<i16>>,
}

/// A frozen MAC layer ready for integer execution.
#[derive(Clone, Debug)]
pub(crate) struct PlannedMac {
    /// Graph node index.
    pub node: usize,
    /// Meter slot.
    pub meter: usize,
    pub weights: WeightForm,
    pub bias: Vec<f32>,
    pub act: ActQ,
    /// conv only: (ci, kh, kw, stride, pad, co)
    pub conv: Option<(usize, usize, usize, usize, usize, usize)>,
    /// linear only: (out, in)
    pub linear: Option<(usize, usize)>,
    /// MAC-depth per output element (k).
    pub depth: usize,
    /// Kernel selected at plan time.
    pub kernel: GemmKernel,
    /// The overflow-soundness certificate the kernel was selected
    /// from (see [`crate::analysis`]).
    pub cert: KernelCert,
    /// Precomputed flips per MAC (non-PANN arithmetic; 0 for PANN,
    /// whose cost is charged through `record_pann`).
    pub flips_per_mac: f64,
    /// Effective activation width `b̃x` of this layer — the config's
    /// uniform width, or this layer's entry of the per-layer override
    /// ([`ExecutionPlan::compile_with_layers`]). Execution quantizes
    /// and meters against this, never `config.bx`.
    pub bx: u32,
}

/// A model compiled under a [`QuantConfig`]: immutable weight banks,
/// kernel choices and scratch geometry. `Send + Sync` by construction
/// (plain owned data), so serving holds one `Arc<ExecutionPlan>` per
/// operating point.
pub struct ExecutionPlan {
    /// The configuration the plan was compiled under.
    pub config: QuantConfig,
    pub(crate) model: Model,
    pub(crate) steps: Vec<Option<PlannedMac>>,
    meter_names: Vec<String>,
    /// MACs per sample, for power accounting without running.
    pub macs_per_sample: u64,
    /// Largest per-sample im2col column buffer any node needs.
    pub max_cols_per_sample: usize,
    /// Largest per-sample accumulator buffer any node needs.
    pub max_acc_per_sample: usize,
    /// SIMD level the plan's GEMMs dispatch to — frozen at compile
    /// time from the process-wide detection ([`gemm::active_level`]),
    /// so the hot loops never re-probe CPU features. Downgrade with
    /// [`ExecutionPlan::force_scalar`] for A/B checks.
    pub simd: SimdLevel,
}

impl ExecutionPlan {
    /// Compile `model` under `config`. `calib` supplies calibration
    /// inputs for the methods that need them (ACIQ, Recon; Dynamic
    /// needs none; BN-stats and DFQ use the manifest statistics).
    pub fn compile(model: &Model, config: QuantConfig, calib: Option<&Tensor>) -> Result<ExecutionPlan> {
        Self::compile_with_layers(model, config, None, calib)
    }

    /// Compile with an optional per-layer activation-width override:
    /// `layer_bits[k]` replaces `config.bx` for the `k`-th MAC layer in
    /// graph order (the order of [`ExecutionPlan::layer_certs`]). All
    /// other configuration — weight quantizer, additions budget `R`,
    /// arithmetic — stays uniform; kernel selection remains
    /// certificate-driven per layer, so a mixed-precision plan goes
    /// through exactly the same overflow prover as a uniform one.
    ///
    /// The override must name every MAC layer and every width must be
    /// in `1..=31` (the i32 activation slab); anything else is a typed
    /// compile error.
    pub fn compile_with_layers(
        model: &Model,
        config: QuantConfig,
        layer_bits: Option<&[u32]>,
        calib: Option<&Tensor>,
    ) -> Result<ExecutionPlan> {
        if let Some(lb) = layer_bits {
            let mac_layers =
                model.nodes.iter().filter(|n| n.op.is_mac_layer()).count();
            anyhow::ensure!(
                lb.len() == mac_layers,
                "per-layer widths name {} layers but the model has {mac_layers} MAC layers",
                lb.len()
            );
            for (k, &b) in lb.iter().enumerate() {
                anyhow::ensure!(
                    (1..=31).contains(&b),
                    "per-layer width b̃x = {b} for MAC layer {k} is outside 1..=31 \
                     (the i32 activation slab)"
                );
            }
        }
        let mut model = model.clone();
        if config.act_method == ActQuantMethod::Dfq {
            apply_dfq_equalization(&mut model)?;
        }
        let shapes = model.shapes()?;
        let calib_outs = match calib {
            Some(x) => Some(model.forward_all(x).context("calibration forward")?),
            None => None,
        };

        let simd = gemm::active_level();
        let mut steps: Vec<Option<PlannedMac>> = vec![None; model.nodes.len()];
        let mut meter_names = Vec::new();
        let mut max_cols = 0usize;
        let mut max_acc = 0usize;
        let mut mac_idx = 0usize;
        for i in 0..model.nodes.len() {
            if !model.nodes[i].op.is_mac_layer() {
                continue;
            }
            // effective activation width of this layer: the per-layer
            // override when given, the uniform config width otherwise
            let bx = layer_bits.map_or(config.bx, |lb| lb[mac_idx]);
            mac_idx += 1;
            let input_idx = model.nodes[i].input;
            // --- activation quantizer for this layer's input ---
            let act = fit_activation_quantizer(
                &model,
                &config,
                bx,
                input_idx,
                calib.map(|c| (c, calib_outs.as_ref().unwrap().as_slice())),
            )?;
            // --- weight quantization ---
            let (w, b, conv, linear, depth, out_ch) = match &model.nodes[i].op {
                Op::Conv { w, b, stride, pad } => {
                    let (co, ci, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                    (
                        w.clone(),
                        b.clone(),
                        Some((ci, kh, kw, *stride, *pad, co)),
                        None,
                        ci * kh * kw,
                        co,
                    )
                }
                Op::Linear { w, b } => {
                    let (o, k) = (w.shape[0], w.shape[1]);
                    (w.clone(), b.clone(), None, Some((o, k)), k, o)
                }
                _ => unreachable!(),
            };
            let mut weights = quantize_weights(
                &w.data,
                out_ch,
                depth,
                &config,
                calib.map(|c| (c, calib_outs.as_ref().unwrap().as_slice())),
                &model,
                i,
            )?;
            // --- DFQ bias correction ---
            let mut bias = b;
            if config.act_method == ActQuantMethod::Dfq {
                if let Some(corr) = dfq_bias_correction(&model, i, &w.data, &weights, out_ch, depth) {
                    for (bo, c) in bias.iter_mut().zip(corr) {
                        *bo -= c;
                    }
                }
            }
            // --- kernel selection: per-layer overflow certificate ---
            // The prover (`crate::analysis`) runs exact i128 interval
            // arithmetic over this layer's activation-code range,
            // effective weight-code range and reduction depth, and
            // certifies which accumulator widths provably cannot wrap.
            // (This replaces the old `< 2^30` magnitude heuristic,
            // which both under-admitted safe narrow layers and — via a
            // `bx.min(30)` clamp — understated the activation range
            // for b̃x > 30.)
            let act_iv = match &act {
                ActQ::Fixed(q) => Interval::new(q.qmin as i128, q.qmax as i128),
                // Dynamic refits per batch; the static bound is the
                // full unsigned b̃x code range, unclamped (the shift
                // cap only guards the i128 shift itself).
                ActQ::Dynamic => Interval::new(0, (1i128 << bx.min(126)) - 1),
            };
            if !act_iv.fits_i32() {
                bail!(
                    "node {i}: activation codes [{}, {}] (b̃x = {bx}) do not fit the i32 \
                     activation slab",
                    act_iv.lo,
                    act_iv.hi,
                );
            }
            let cert = KernelCert::certify(
                act_iv,
                Interval::new(weights.code_lo as i128, weights.code_hi as i128),
                depth as u64,
                weights.split,
            );
            if !cert.admits_wide() {
                bail!(
                    "node {i}: cannot prove i64 accumulation exact (accumulator interval \
                     [{}, {}] at depth {depth})",
                    cert.acc.lo,
                    cert.acc.hi
                );
            }
            let kernel = match (weights.split, cert.admits_narrow()) {
                (true, true) => GemmKernel::SplitNarrow,
                (true, false) => GemmKernel::SplitWide,
                (false, true) => GemmKernel::Narrow,
                (false, false) => GemmKernel::Wide,
            };
            // --- packed i16 bank for the SIMD narrow path ---
            // Admitted only when the certificate proves the narrow
            // verdict *and* both operand streams fit i16 lanes.
            // Skipped on scalar plans so the forced-scalar escape
            // hatch runs the pristine original path.
            if simd != SimdLevel::Scalar && cert.admits_packed() {
                weights.packed = match kernel {
                    GemmKernel::Narrow => gemm::pack_codes_i16(&weights.pos),
                    GemmKernel::SplitNarrow => gemm::pack_diff_i16(&weights.pos, &weights.neg),
                    GemmKernel::Wide | GemmKernel::SplitWide => None,
                };
            }
            // --- scratch geometry (im2col columns `oh·ow·k` and
            // accumulators `co·oh·ow` per sample; `k` / `out` for
            // linear) ---
            let out_elems_per_sample: usize = shapes[i].1.iter().product();
            let spatial = out_elems_per_sample / out_ch.max(1);
            max_cols = max_cols.max(spatial * depth);
            max_acc = max_acc.max(out_elems_per_sample);

            let meter = meter_names.len();
            meter_names.push(format!("{}{}", model.nodes[i].op.name(), i));
            steps[i] = Some(PlannedMac {
                node: i,
                meter,
                flips_per_mac: flips_per_mac(&config, bx),
                weights,
                bias,
                act,
                conv,
                linear,
                depth,
                kernel,
                cert,
                bx,
            });
        }
        let macs_per_sample = shapes.iter().map(|(m, _)| m).sum();
        Ok(ExecutionPlan {
            config,
            model,
            steps,
            meter_names,
            macs_per_sample,
            max_cols_per_sample: max_cols,
            max_acc_per_sample: max_acc,
            simd,
        })
    }

    /// Downgrade this plan to the scalar reference kernels: clears the
    /// SIMD level and drops the packed i16 banks, so subsequent
    /// forwards take exactly the pre-SIMD code path. For A/B
    /// bit-exactness checks and scalar-baseline benchmarking.
    pub fn force_scalar(&mut self) {
        self.simd = SimdLevel::Scalar;
        for p in self.steps.iter_mut().flatten() {
            p.weights.packed = None;
        }
    }

    /// Create a fresh meter with this plan's layer slots.
    pub fn new_meter(&self) -> PowerMeter {
        let mut m = PowerMeter::new();
        for n in &self.meter_names {
            m.add_layer(n);
        }
        m
    }

    /// The frozen model graph (non-MAC nodes still execute in f32).
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Per-sample input shape the plan expects.
    pub fn input_shape(&self) -> &[usize] {
        &self.model.input_shape
    }

    /// Kernel selected for node `i`, if it is a planned MAC node.
    pub fn kernel_of(&self, node: usize) -> Option<GemmKernel> {
        self.steps.get(node).and_then(|s| s.as_ref()).map(|p| p.kernel)
    }

    /// Overflow-soundness certificate proven for node `i`, if it is a
    /// planned MAC node (the certificate the kernel was selected from).
    pub fn cert_of(&self, node: usize) -> Option<KernelCert> {
        self.steps.get(node).and_then(|s| s.as_ref()).map(|p| p.cert)
    }

    /// Every planned MAC layer's `(node, kernel, certificate)` triple
    /// in graph order — the offline audit surface consumed by
    /// `pann-cli verify`.
    pub fn layer_certs(&self) -> Vec<(usize, GemmKernel, KernelCert)> {
        self.steps
            .iter()
            .flatten()
            .map(|p| (p.node, p.kernel, p.cert))
            .collect()
    }

    /// Effective activation width `b̃x` of every planned MAC layer in
    /// graph order — uniform plans repeat `config.bx`; mixed plans
    /// ([`ExecutionPlan::compile_with_layers`]) report their override.
    pub fn layer_widths(&self) -> Vec<u32> {
        self.steps.iter().flatten().map(|p| p.bx).collect()
    }

    /// Scratch elements (`cols`, `acc`) needed to run a batch of `n`.
    pub fn scratch_hint(&self, n: usize) -> (usize, usize) {
        (self.max_cols_per_sample * n, self.max_acc_per_sample * n)
    }

    /// Storage bits per weight code (Table 14's `b_R`).
    pub fn weight_code_bits(&self) -> u32 {
        self.steps
            .iter()
            .flatten()
            .map(|p| 64 - (p.weights.max_code.unsigned_abs().max(1)).leading_zeros())
            .max()
            .unwrap_or(1)
    }

    /// Mean achieved additions per element across MAC layers,
    /// MAC-weighted (the effective network R).
    pub fn achieved_r(&self) -> f64 {
        let shapes = self.model.shapes().unwrap_or_default();
        let mut num = 0.0;
        let mut den = 0.0;
        for p in self.steps.iter().flatten() {
            let macs = shapes[p.node].0 as f64;
            num += macs * p.weights.adds_per_element;
            den += macs;
        }
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }
}

/// Flips per MAC under `config` at the layer's effective activation
/// width `bx`. PANN layers are charged through
/// [`PowerMeter::record_pann`] with their achieved additions budget
/// instead, so they return 0 here.
fn flips_per_mac(config: &QuantConfig, bx: u32) -> f64 {
    match config.arithmetic {
        Arithmetic::SignedMac { acc_bits } => {
            crate::power::model::mult_power_mixed_signed(config.bw, bx)
                + 0.5 * acc_bits as f64
                + (config.bw + bx) as f64
        }
        Arithmetic::UnsignedMac => {
            crate::power::model::mult_power_mixed_signed(config.bw, bx)
                + 1.5 * (config.bw + bx) as f64
        }
        Arithmetic::Pann => 0.0,
    }
}

/// Fit the activation quantizer for the input of a MAC layer at its
/// effective width `bx` (uniform `config.bx`, or the layer's entry of
/// a per-layer override).
fn fit_activation_quantizer(
    model: &Model,
    config: &QuantConfig,
    bx: u32,
    input_idx: isize,
    calib: Option<(&Tensor, &[Tensor])>,
) -> Result<ActQ> {
    use ActQuantMethod::*;
    // The fitted paths produce codes for the i32 activation slab, so
    // b̃x is bounded by what the fitters can represent; Dynamic defers
    // to the prover in `compile`, which rejects the same configs with
    // the certified range in the message.
    if !matches!(config.act_method, Dynamic) && !(1..=31).contains(&bx) {
        bail!(
            "activation bit-width b̃x = {bx} unsupported: fitted activation codes must fit \
             the i32 activation slab (1..=31 bits)"
        );
    }
    Ok(match config.act_method {
        Dynamic => ActQ::Dynamic,
        Aciq | Recon => {
            let (cx, couts) = calib.context("ACIQ/Recon need a calibration set")?;
            let data: &[f32] = if input_idx < 0 { &cx.data } else { &couts[input_idx as usize].data };
            ActQ::Fixed(aciq::fit_relu_activations(data, bx))
        }
        BnStats | Dfq => {
            if input_idx < 0 {
                // model input: ranges are part of the data contract
                // (inputs normalized to [0, 1] by the datasets).
                ActQ::Fixed(ruq::fit_unsigned_clipped(1.0, bx))
            } else {
                let stats = model
                    .act_stats
                    .get(&(input_idx as usize))
                    .context("manifest lacks act_stats for data-free quantization")?;
                ActQ::Fixed(stats.fit_activations(bx))
            }
        }
    })
}

/// Quantize one layer's weights under the config.
fn quantize_weights(
    w: &[f32],
    out_ch: usize,
    depth: usize,
    config: &QuantConfig,
    calib: Option<(&Tensor, &[Tensor])>,
    model: &Model,
    node: usize,
) -> Result<WeightForm> {
    let split = !matches!(config.arithmetic, Arithmetic::SignedMac { .. });
    let mk = |codes: Vec<i64>, scale: f32, adds: f64| -> Result<WeightForm> {
        let code_lo = codes.iter().copied().min().unwrap_or(0);
        let code_hi = codes.iter().copied().max().unwrap_or(0);
        // The storage banks are i32; a code outside i32 would
        // previously truncate silently in the `as i32` casts below.
        if code_lo < i32::MIN as i64 || code_hi > i32::MAX as i64 {
            bail!(
                "weight codes [{code_lo}, {code_hi}] do not fit the i32 weight banks"
            );
        }
        let max_code = code_lo.unsigned_abs().max(code_hi.unsigned_abs()) as i64;
        Ok(if split {
            let pos: Vec<i32> = codes.iter().map(|&c| c.max(0) as i32).collect();
            let neg: Vec<i32> = codes.iter().map(|&c| (-c).max(0) as i32).collect();
            WeightForm {
                pos,
                neg,
                scale,
                split: true,
                adds_per_element: adds,
                max_code,
                code_lo,
                code_hi,
                packed: None,
            }
        } else {
            WeightForm {
                pos: codes.iter().map(|&c| c as i32).collect(),
                neg: Vec::new(),
                scale,
                split: false,
                adds_per_element: adds,
                max_code,
                code_lo,
                code_hi,
                packed: None,
            }
        })
    };
    match config.weight_quant {
        WeightQuantMethod::Ruq => {
            let q = ruq::fit_signed(w, config.bw);
            let codes = q.quantize_slice(w);
            mk(codes, q.scale, 0.0)
        }
        WeightQuantMethod::RuqRecon => {
            let q = ruq::fit_signed(w, config.bw);
            let codes = match calib {
                Some((cx, couts)) => {
                    let input_idx = model.nodes[node].input;
                    let xin = if input_idx < 0 { cx } else { &couts[input_idx as usize] };
                    let rows = recon_rows(&model.nodes[node].op, xin, depth, 48)?;
                    let nrows = rows.len() / depth;
                    let mut all = Vec::with_capacity(w.len());
                    for o in 0..out_ch {
                        let wrow = &w[o * depth..(o + 1) * depth];
                        all.extend(recon::reconstruct_row(wrow, &q, &rows, nrows, 6));
                    }
                    all
                }
                None => q.quantize_slice(w),
            };
            mk(codes, q.scale, 0.0)
        }
        WeightQuantMethod::Pann { r } => {
            let pq = PannQuant::new(r);
            let pw = pq.quantize(w);
            mk(pw.codes.clone(), pw.gamma, pw.adds_per_element)
        }
    }
}

/// Calibration rows (`[n][depth]`) for rounding reconstruction.
fn recon_rows(op: &Op, xin: &Tensor, depth: usize, max_rows: usize) -> Result<Vec<f32>> {
    match op {
        Op::Linear { .. } => {
            let n = xin.batch().min(max_rows);
            Ok(xin.data[..n * depth].to_vec())
        }
        Op::Conv { w, stride, pad, .. } => {
            let (ci, kh, kw) = (w.shape[1], w.shape[2], w.shape[3]);
            let (h, wd) = match xin.shape.as_slice() {
                [_, _, h, w] => (*h, *w),
                other => bail!("conv calib input {other:?}"),
            };
            let mut cols = Vec::new();
            let mut rows = Vec::new();
            let samples = xin.batch().min(4);
            for s in 0..samples {
                gemm::im2col(xin.sample(s), ci, h, wd, kh, kw, *stride, *pad, &mut cols);
                let nrows = cols.len() / depth;
                // take evenly spaced rows
                let want = (max_rows / samples).max(1);
                let step = (nrows / want).max(1);
                for r in (0..nrows).step_by(step).take(want) {
                    rows.extend_from_slice(&cols[r * depth..(r + 1) * depth]);
                }
            }
            Ok(rows)
        }
        _ => bail!("recon rows on non-mac layer"),
    }
}

/// DFQ cross-layer equalization on directly-chained MAC pairs
/// (conv→[relu/pool]→conv and linear→relu→linear).
fn apply_dfq_equalization(model: &mut Model) -> Result<()> {
    let n = model.nodes.len();
    // find MAC pairs connected through shape-preserving per-channel ops
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for i in 0..n {
        if !model.nodes[i].op.is_mac_layer() {
            continue;
        }
        // walk forward through relu/maxpool only, following single-consumer chains
        let mut cur = i;
        'walk: loop {
            // find the unique consumer of cur
            let consumers: Vec<usize> = (0..n)
                .filter(|&j| {
                    model.nodes[j].input == cur as isize
                        || matches!(model.nodes[j].op, Op::Add { rhs } if rhs == cur)
                })
                .collect();
            if consumers.len() != 1 {
                break 'walk;
            }
            let j = consumers[0];
            match model.nodes[j].op {
                Op::Relu | Op::MaxPool { .. } => {
                    cur = j;
                }
                Op::Conv { .. } | Op::Linear { .. } => {
                    pairs.push((i, j));
                    break 'walk;
                }
                _ => break 'walk,
            }
        }
    }
    for (a, b) in pairs {
        equalize_nodes(model, a, b)?;
    }
    Ok(())
}

/// Equalize one (producer, consumer) MAC pair in place.
fn equalize_nodes(model: &mut Model, a: usize, b: usize) -> Result<()> {
    // Extract producer rows [mid][ka] and consumer columns grouped by
    // producer channel: consumer weight [out][mid * g] where g = spatial
    // group size (kh*kw for conv, h*w collapsed for linear-after-conv).
    let (mid, ka) = match &model.nodes[a].op {
        Op::Conv { w, .. } => (w.shape[0], w.shape[1] * w.shape[2] * w.shape[3]),
        Op::Linear { w, .. } => (w.shape[0], w.shape[1]),
        _ => bail!("not a mac node"),
    };
    let (out_b, kb) = match &model.nodes[b].op {
        Op::Conv { w, .. } => (w.shape[0], w.shape[1] * w.shape[2] * w.shape[3]),
        Op::Linear { w, .. } => (w.shape[0], w.shape[1]),
        _ => bail!("not a mac node"),
    };
    // consumer input features per producer channel
    let cin_b = match &model.nodes[b].op {
        Op::Conv { w, .. } => w.shape[1],
        Op::Linear { .. } => {
            if kb % mid != 0 {
                return Ok(()); // shapes don't group cleanly; skip pair
            }
            mid
        }
        _ => unreachable!(),
    };
    if cin_b != mid {
        return Ok(()); // channel mismatch (e.g. flatten regrouping failed)
    }
    let g = kb / mid;
    // per-channel ranges
    let (r1, r2) = {
        let wa = match &model.nodes[a].op {
            Op::Conv { w, .. } | Op::Linear { w, .. } => &w.data,
            _ => unreachable!(),
        };
        let wb = match &model.nodes[b].op {
            Op::Conv { w, .. } | Op::Linear { w, .. } => &w.data,
            _ => unreachable!(),
        };
        let r1: Vec<f32> = (0..mid)
            .map(|c| wa[c * ka..(c + 1) * ka].iter().fold(0.0f32, |m, &x| m.max(x.abs())))
            .collect();
        let r2: Vec<f32> = (0..mid)
            .map(|c| {
                let mut m = 0.0f32;
                for o in 0..out_b {
                    for gg in 0..g {
                        m = m.max(wb[o * kb + c * g + gg].abs());
                    }
                }
                m
            })
            .collect();
        (r1, r2)
    };
    let scales: Vec<f32> = r1
        .iter()
        .zip(&r2)
        .map(|(&x, &y)| if x <= 1e-12 || y <= 1e-12 { 1.0 } else { (x / y).sqrt().clamp(1e-3, 1e3) })
        .collect();
    // apply
    if let Op::Conv { w, b: bias, .. } | Op::Linear { w, b: bias } = &mut model.nodes[a].op {
        for c in 0..mid {
            let s = scales[c];
            for v in &mut w.data[c * ka..(c + 1) * ka] {
                *v /= s;
            }
            bias[c] /= s;
        }
    }
    if let Op::Conv { w, .. } | Op::Linear { w, .. } = &mut model.nodes[b].op {
        for o in 0..out_b {
            for c in 0..mid {
                let s = scales[c];
                for gg in 0..g {
                    w.data[o * kb + c * g + gg] *= s;
                }
            }
        }
    }
    // keep act_stats of the producer's chain consistent: scale them too
    let idxs: Vec<usize> = model.act_stats.keys().copied().collect();
    for idx in idxs {
        // only stats of nodes between a and b along the chain carry the
        // producer's channel dimension; scaling them keeps BN-stats
        // quantizers correct after equalization.
        if idx >= a && idx < b {
            if let Some(st) = model.act_stats.get_mut(&idx) {
                if st.mean.len() == mid {
                    for c in 0..mid {
                        st.mean[c] /= scales[c];
                        st.std[c] /= scales[c];
                    }
                }
            }
        }
    }
    Ok(())
}

/// DFQ bias correction for one layer, from the manifest's activation
/// statistics of the producer node. Returns the per-output correction
/// `E[ε·x]` to subtract, or `None` if stats are missing.
fn dfq_bias_correction(
    model: &Model,
    node: usize,
    w: &[f32],
    wf: &WeightForm,
    out_ch: usize,
    depth: usize,
) -> Option<Vec<f32>> {
    let input_idx = model.nodes[node].input;
    if input_idx < 0 {
        return None;
    }
    let stats = model.act_stats.get(&(input_idx as usize))?;
    let ch = stats.mean.len();
    if ch == 0 || depth % ch != 0 {
        return None;
    }
    let g = depth / ch;
    // expected input per position: post-ReLU mean per channel
    let mean_in: Vec<f32> = (0..depth).map(|i| stats.mean[i / g].max(0.0)).collect();
    let mut corr = vec![0.0f32; out_ch];
    for o in 0..out_ch {
        let mut acc = 0.0f32;
        for i in 0..depth {
            let code = if wf.split {
                wf.pos[o * depth + i] as i64 - wf.neg[o * depth + i] as i64
            } else {
                wf.pos[o * depth + i] as i64
            };
            let err = wf.scale * code as f32 - w[o * depth + i];
            acc += err * mean_in[i];
        }
        corr[o] = acc;
    }
    Some(corr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ActQuantMethod;

    #[test]
    fn kernel_selection_is_static_and_sane() {
        let mut model = Model::reference_cnn(40);
        let x = Tensor::zeros(vec![2, 1, 16, 16]);
        model.record_act_stats(&x).unwrap();
        // 4-bit unsigned: small codes, shallow depth -> narrow split path
        let plan = ExecutionPlan::compile(
            &model,
            QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        for i in 0..plan.model().nodes.len() {
            if let Some(k) = plan.kernel_of(i) {
                assert!(
                    matches!(k, GemmKernel::SplitNarrow | GemmKernel::SplitWide),
                    "unsigned arithmetic must pick a split kernel, got {k:?}"
                );
            }
        }
        // signed path picks a unified kernel
        let plan = ExecutionPlan::compile(
            &model,
            QuantConfig::signed_baseline(4, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        let kernels: Vec<_> = (0..plan.model().nodes.len())
            .filter_map(|i| plan.kernel_of(i))
            .collect();
        assert!(!kernels.is_empty());
        assert!(kernels
            .iter()
            .all(|k| matches!(k, GemmKernel::Narrow | GemmKernel::Wide)));
    }

    #[test]
    fn scratch_hint_covers_reference_cnn() {
        let mut model = Model::reference_cnn(41);
        let x = Tensor::zeros(vec![2, 1, 16, 16]);
        model.record_act_stats(&x).unwrap();
        let plan = ExecutionPlan::compile(
            &model,
            QuantConfig::unsigned_baseline(6, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        // conv1: 16x16 spatial, k = 1*3*3 -> 2304 cols; conv2: 8x8, k=72 -> 4608
        let (cols, acc) = plan.scratch_hint(1);
        assert!(cols >= 4608, "cols {cols}");
        // conv1 out 8*16*16 = 2048 accumulators dominate
        assert!(acc >= 2048, "acc {acc}");
        let (cols8, _) = plan.scratch_hint(8);
        assert_eq!(cols8, cols * 8);
    }

    #[test]
    fn bx32_dynamic_is_rejected_not_misplanned() {
        // Regression: the old selector modeled the act range as
        // `(1 << bx.min(30)) - 1`, so a b̃x = 32 Dynamic config
        // compiled — and could select a narrow kernel — even though
        // its activation codes cannot fit the i32 slab at all (the
        // per-batch fitter would then panic at exec time). The prover
        // must reject it at compile time instead.
        let mut model = Model::reference_cnn(43);
        let err = ExecutionPlan::compile(
            &model,
            QuantConfig::pann(32, 2.0, ActQuantMethod::Dynamic),
            None,
        )
        .err()
        .expect("b̃x = 32 must be rejected at compile time");
        assert!(format!("{err:#}").contains("i32 activation slab"), "{err:#}");
        // the fitted paths reject the same range with a typed error
        // (they used to assert inside the fitters)
        model.record_act_stats(&Tensor::zeros(vec![2, 1, 16, 16])).unwrap();
        let err = ExecutionPlan::compile(
            &model,
            QuantConfig { bx: 32, ..QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats) },
            None,
        )
        .err()
        .expect("fitted b̃x = 32 must be rejected too");
        assert!(format!("{err:#}").contains("1..=31"), "{err:#}");
    }

    #[test]
    fn bx31_true_act_range_blocks_narrow_kernels() {
        // b̃x = 31 fits the slab, but its qmax = 2^31 − 1: times any
        // nonzero code at depth ≥ 2 that exceeds i32. The old clamp
        // understated the range by 2× ((1 << 30) − 1) and could still
        // admit a narrow kernel here; the certificate cannot.
        let model = Model::reference_cnn(44);
        let plan = ExecutionPlan::compile(
            &model,
            QuantConfig::pann(31, 2.0, ActQuantMethod::Dynamic),
            None,
        )
        .unwrap();
        let certs = plan.layer_certs();
        assert!(!certs.is_empty());
        let mut nonzero_layers = 0;
        for (node, kernel, cert) in certs {
            if cert.weight.lo == 0 && cert.weight.hi == 0 {
                continue; // an all-zero bank is trivially narrow-safe
            }
            nonzero_layers += 1;
            assert!(!cert.i32_ok, "node {node} cert wrongly admits i32");
            assert!(
                matches!(kernel, GemmKernel::Wide | GemmKernel::SplitWide),
                "node {node} selected {kernel:?} despite act range 2^31 − 1"
            );
            assert!(plan.steps[node].as_ref().unwrap().weights.packed.is_none());
        }
        assert!(nonzero_layers > 0, "test model quantized to all-zero codes");
    }

    #[test]
    fn kernels_always_match_their_certificates() {
        let mut model = Model::reference_cnn(45);
        model.record_act_stats(&Tensor::zeros(vec![2, 1, 16, 16])).unwrap();
        for cfg in [
            QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats),
            QuantConfig::signed_baseline(8, ActQuantMethod::BnStats),
            QuantConfig::pann(6, 2.0, ActQuantMethod::Dynamic),
        ] {
            let plan = ExecutionPlan::compile(&model, cfg, None).unwrap();
            for (node, kernel, cert) in plan.layer_certs() {
                assert!(cert.i64_ok, "node {node}: plans must always prove wide");
                let narrow =
                    matches!(kernel, GemmKernel::Narrow | GemmKernel::SplitNarrow);
                assert_eq!(narrow, cert.admits_narrow(), "node {node} under {cfg:?}");
                if plan.steps[node].as_ref().unwrap().weights.packed.is_some() {
                    assert!(cert.admits_packed(), "node {node} packed without proof");
                }
            }
        }
    }

    #[test]
    fn per_layer_widths_compile_and_are_certified() {
        let mut model = Model::reference_cnn(46);
        model.record_act_stats(&Tensor::zeros(vec![2, 1, 16, 16])).unwrap();
        let cfg = QuantConfig::pann(8, 2.0, ActQuantMethod::BnStats);
        let uniform = ExecutionPlan::compile(&model, cfg, None).unwrap();
        let n_layers = uniform.layer_certs().len();
        assert!(n_layers >= 2, "reference model must have several MAC layers");
        assert_eq!(uniform.layer_widths(), vec![8; n_layers]);
        // downgrade every layer but the first
        let mut bits = vec![8u32; n_layers];
        for b in bits.iter_mut().skip(1) {
            *b = 2;
        }
        let mixed =
            ExecutionPlan::compile_with_layers(&model, cfg, Some(&bits), None).unwrap();
        assert_eq!(mixed.layer_widths(), bits);
        // structure is preserved: same MAC layers, same MACs/sample,
        // and every layer still carries a proven certificate
        assert_eq!(mixed.layer_certs().len(), n_layers);
        assert_eq!(mixed.macs_per_sample, uniform.macs_per_sample);
        for (node, kernel, cert) in mixed.layer_certs() {
            assert!(cert.i64_ok, "node {node}: mixed plans must prove wide");
            let narrow = matches!(kernel, GemmKernel::Narrow | GemmKernel::SplitNarrow);
            assert_eq!(narrow, cert.admits_narrow(), "node {node}");
        }
        // the downgraded layers quantize at the narrower width: the
        // fitted quantizer's code range must shrink accordingly
        for (p, &b) in mixed.steps.iter().flatten().zip(&bits) {
            if let ActQ::Fixed(q) = &p.act {
                assert!(q.qmax < (1i64 << b), "layer at b̃x={b} has qmax {}", q.qmax);
            }
            assert_eq!(p.bx, b);
        }
    }

    #[test]
    fn per_layer_width_overrides_are_validated() {
        let mut model = Model::reference_cnn(47);
        model.record_act_stats(&Tensor::zeros(vec![2, 1, 16, 16])).unwrap();
        let cfg = QuantConfig::pann(8, 2.0, ActQuantMethod::BnStats);
        let n = ExecutionPlan::compile(&model, cfg, None).unwrap().layer_certs().len();
        // wrong arity
        let e = ExecutionPlan::compile_with_layers(&model, cfg, Some(&vec![8; n + 1]), None)
            .unwrap_err();
        assert!(format!("{e:#}").contains("MAC layers"), "{e:#}");
        // out-of-range width
        let mut bad = vec![8u32; n];
        bad[0] = 32;
        let e = ExecutionPlan::compile_with_layers(&model, cfg, Some(&bad), None).unwrap_err();
        assert!(format!("{e:#}").contains("1..=31"), "{e:#}");
        bad[0] = 0;
        let e = ExecutionPlan::compile_with_layers(&model, cfg, Some(&bad), None).unwrap_err();
        assert!(format!("{e:#}").contains("1..=31"), "{e:#}");
    }

    #[test]
    fn plan_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ExecutionPlan>();
    }

    #[test]
    fn narrow_plans_pack_weight_banks_when_simd_active() {
        let mut model = Model::reference_cnn(42);
        let x = Tensor::zeros(vec![2, 1, 16, 16]);
        model.record_act_stats(&x).unwrap();
        let mut plan = ExecutionPlan::compile(
            &model,
            QuantConfig::unsigned_baseline(4, ActQuantMethod::BnStats),
            None,
        )
        .unwrap();
        assert_eq!(plan.simd, gemm::active_level());
        for p in plan.steps.iter().flatten() {
            match (plan.simd, p.kernel) {
                // 4-bit codes always fit i16, so every narrow kernel
                // must carry a packed bank on a SIMD plan...
                (l, GemmKernel::Narrow | GemmKernel::SplitNarrow) if l != SimdLevel::Scalar => {
                    let packed = p.weights.packed.as_ref().expect("packed bank");
                    assert_eq!(packed.len(), p.weights.pos.len());
                    for (i, &q) in packed.iter().enumerate() {
                        let want = p.weights.pos[i] as i64
                            - p.weights.neg.get(i).copied().unwrap_or(0) as i64;
                        assert_eq!(q as i64, want);
                    }
                }
                // ...and never on a scalar plan or a wide kernel.
                _ => assert!(p.weights.packed.is_none()),
            }
        }
        // force_scalar drops the banks and the level together.
        plan.force_scalar();
        assert_eq!(plan.simd, SimdLevel::Scalar);
        assert!(plan.steps.iter().flatten().all(|p| p.weights.packed.is_none()));
    }
}
