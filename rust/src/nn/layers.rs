//! Graph node definitions and the fp32 forward of each op.
//!
//! Models are small SSA graphs: node `i` consumes the output of node
//! `input` (or the model input when `input == -1`) and, for `Add`, a
//! second producer — enough to express the MLP / CNN / residual-CNN /
//! VGG-ish architectures of the experiments. Batch-norm layers are
//! folded into conv/linear weights at export time (paper footnote 3).

use super::gemm;
use super::tensor::Tensor;
use anyhow::{bail, Result};

/// One graph node's operation.
#[derive(Clone, Debug)]
pub enum Op {
    /// Convolution; weights `[co, ci, kh, kw]`, bias `[co]`.
    Conv { w: Tensor, b: Vec<f32>, stride: usize, pad: usize },
    /// Fully connected; weights `[out, in]`, bias `[out]`.
    Linear { w: Tensor, b: Vec<f32> },
    /// Elementwise `max(x, 0)`.
    Relu,
    /// Max pooling with square kernel = stride = `k`.
    MaxPool { k: usize },
    /// Global average pool `[n,c,h,w] -> [n,c]`.
    GlobalAvgPool,
    /// Flatten to `[n, rest]`.
    Flatten,
    /// Elementwise add with the output of node `rhs` (residual join).
    Add { rhs: usize },
}

impl Op {
    /// Short op name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Op::Conv { .. } => "conv",
            Op::Linear { .. } => "linear",
            Op::Relu => "relu",
            Op::MaxPool { .. } => "maxpool",
            Op::GlobalAvgPool => "gap",
            Op::Flatten => "flatten",
            Op::Add { .. } => "add",
        }
    }

    /// Is this a MAC layer (quantization target)?
    pub fn is_mac_layer(&self) -> bool {
        matches!(self, Op::Conv { .. } | Op::Linear { .. })
    }

    /// MACs per sample given the input shape `[c, h, w]`-style (no
    /// batch dim); also returns the output shape.
    pub fn macs_and_out_shape(&self, in_shape: &[usize]) -> Result<(u64, Vec<usize>)> {
        match self {
            Op::Conv { w, stride, pad, .. } => {
                let (co, ci, kh, kw) = conv_dims(w)?;
                let (c, h, wd) = chw(in_shape)?;
                if c != ci {
                    bail!("conv expects {ci} channels, got {c}");
                }
                let (oh, ow) = gemm::conv_out_size(h, wd, kh, kw, *stride, *pad);
                Ok(((co * ci * kh * kw * oh * ow) as u64, vec![co, oh, ow]))
            }
            Op::Linear { w, .. } => {
                let (out, inp) = (w.shape[0], w.shape[1]);
                let flat: usize = in_shape.iter().product();
                if flat != inp {
                    bail!("linear expects {inp} inputs, got {flat}");
                }
                Ok(((out * inp) as u64, vec![out]))
            }
            Op::Relu | Op::Add { .. } => Ok((0, in_shape.to_vec())),
            Op::MaxPool { k } => {
                let (c, h, w) = chw(in_shape)?;
                Ok((0, vec![c, h / k, w / k]))
            }
            Op::GlobalAvgPool => {
                let (c, _, _) = chw(in_shape)?;
                Ok((0, vec![c]))
            }
            Op::Flatten => Ok((0, vec![in_shape.iter().product()])),
        }
    }
}

fn chw(shape: &[usize]) -> Result<(usize, usize, usize)> {
    match shape {
        [c, h, w] => Ok((*c, *h, *w)),
        other => bail!("expected [c,h,w] shape, got {other:?}"),
    }
}

fn conv_dims(w: &Tensor) -> Result<(usize, usize, usize, usize)> {
    match w.shape.as_slice() {
        [co, ci, kh, kw] => Ok((*co, *ci, *kh, *kw)),
        other => bail!("conv weights must be 4-D, got {other:?}"),
    }
}

/// fp32 forward of one op on a batched input.
pub fn forward_f32(op: &Op, x: &Tensor, rhs: Option<&Tensor>) -> Result<Tensor> {
    match op {
        Op::Conv { w, b, stride, pad } => conv_f32(x, w, b, *stride, *pad),
        Op::Linear { w, b } => linear_f32(x, w, b),
        Op::Relu => Ok(Tensor {
            shape: x.shape.clone(),
            data: x.data.iter().map(|&v| v.max(0.0)).collect(),
        }),
        Op::MaxPool { k } => maxpool_f32(x, *k),
        Op::GlobalAvgPool => gap_f32(x),
        Op::Flatten => {
            let n = x.batch();
            let d = x.sample_len();
            x.clone().reshape(vec![n, d])
        }
        Op::Add { .. } => {
            let r = rhs.ok_or_else(|| anyhow::anyhow!("add node missing rhs"))?;
            if r.shape != x.shape {
                bail!("add shape mismatch {:?} vs {:?}", x.shape, r.shape);
            }
            Ok(Tensor {
                shape: x.shape.clone(),
                data: x.data.iter().zip(&r.data).map(|(a, b)| a + b).collect(),
            })
        }
    }
}

/// Batched conv via im2col + f32 GEMM. Output layout `[n, co, oh, ow]`.
pub fn conv_f32(x: &Tensor, w: &Tensor, b: &[f32], stride: usize, pad: usize) -> Result<Tensor> {
    let (co, ci, kh, kw) = conv_dims(w)?;
    let (n, c, h, wd) = match x.shape.as_slice() {
        [n, c, h, w] => (*n, *c, *h, *w),
        other => bail!("conv input must be 4-D, got {other:?}"),
    };
    if c != ci {
        bail!("conv expects {ci} channels, got {c}");
    }
    if b.len() != co {
        bail!("bias length {} != {co}", b.len());
    }
    let (oh, ow) = gemm::conv_out_size(h, wd, kh, kw, stride, pad);
    let k = ci * kh * kw;
    let mut out = Tensor::zeros(vec![n, co, oh, ow]);
    let mut cols = Vec::new();
    let mut prod = vec![0.0f32; oh * ow * co];
    for i in 0..n {
        gemm::im2col(x.sample(i), c, h, wd, kh, kw, stride, pad, &mut cols);
        gemm::gemm_f32(&cols, &w.data, &mut prod, oh * ow, co, k);
        // prod is [oh*ow, co]; transpose into [co, oh, ow] with bias
        let dst = &mut out.data[i * co * oh * ow..(i + 1) * co * oh * ow];
        for p in 0..oh * ow {
            for o in 0..co {
                dst[o * oh * ow + p] = prod[p * co + o] + b[o];
            }
        }
    }
    Ok(out)
}

/// Batched linear. Output `[n, out]`.
pub fn linear_f32(x: &Tensor, w: &Tensor, b: &[f32]) -> Result<Tensor> {
    let (out_d, in_d) = (w.shape[0], w.shape[1]);
    let n = x.batch();
    if x.sample_len() != in_d {
        bail!("linear expects {in_d} inputs, got {}", x.sample_len());
    }
    let mut out = Tensor::zeros(vec![n, out_d]);
    gemm::gemm_f32(&x.data, &w.data, &mut out.data, n, out_d, in_d);
    for i in 0..n {
        for o in 0..out_d {
            out.data[i * out_d + o] += b[o];
        }
    }
    Ok(out)
}

fn maxpool_f32(x: &Tensor, k: usize) -> Result<Tensor> {
    let (n, c, h, w) = match x.shape.as_slice() {
        [n, c, h, w] => (*n, *c, *h, *w),
        other => bail!("maxpool input must be 4-D, got {other:?}"),
    };
    let (oh, ow) = (h / k, w / k);
    let mut out = Tensor::zeros(vec![n, c, oh, ow]);
    for i in 0..n {
        for ci in 0..c {
            let src = &x.data[(i * c + ci) * h * w..(i * c + ci + 1) * h * w];
            let dst = &mut out.data[(i * c + ci) * oh * ow..(i * c + ci + 1) * oh * ow];
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..k {
                        for kx in 0..k {
                            m = m.max(src[(oy * k + ky) * w + ox * k + kx]);
                        }
                    }
                    dst[oy * ow + ox] = m;
                }
            }
        }
    }
    Ok(out)
}

fn gap_f32(x: &Tensor) -> Result<Tensor> {
    let (n, c, h, w) = match x.shape.as_slice() {
        [n, c, h, w] => (*n, *c, *h, *w),
        other => bail!("gap input must be 4-D, got {other:?}"),
    };
    let mut out = Tensor::zeros(vec![n, c]);
    let inv = 1.0 / (h * w) as f32;
    for i in 0..n {
        for ci in 0..c {
            let s: f32 = x.data[(i * c + ci) * h * w..(i * c + ci + 1) * h * w].iter().sum();
            out.data[i * c + ci] = s * inv;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn relu_and_add() {
        let x = Tensor::new(vec![1, 3], vec![-1.0, 0.5, 2.0]).unwrap();
        let r = forward_f32(&Op::Relu, &x, None).unwrap();
        assert_eq!(r.data, vec![0.0, 0.5, 2.0]);
        let s = forward_f32(&Op::Add { rhs: 0 }, &x, Some(&r)).unwrap();
        assert_eq!(s.data, vec![-1.0, 1.0, 4.0]);
    }

    #[test]
    fn maxpool_known() {
        let x = Tensor::new(
            vec![1, 1, 4, 4],
            vec![
                1., 2., 3., 4., //
                5., 6., 7., 8., //
                9., 10., 11., 12., //
                13., 14., 15., 16.,
            ],
        )
        .unwrap();
        let y = forward_f32(&Op::MaxPool { k: 2 }, &x, None).unwrap();
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        assert_eq!(y.data, vec![6., 8., 14., 16.]);
    }

    #[test]
    fn gap_known() {
        let x = Tensor::new(vec![1, 2, 2, 2], vec![1., 1., 1., 1., 2., 2., 2., 6.]).unwrap();
        let y = forward_f32(&Op::GlobalAvgPool, &x, None).unwrap();
        assert_eq!(y.data, vec![1.0, 3.0]);
    }

    #[test]
    fn linear_bias() {
        let w = Tensor::new(vec![2, 3], vec![1., 0., 0., 0., 1., 1.]).unwrap();
        let x = Tensor::new(vec![1, 3], vec![3., 4., 5.]).unwrap();
        let y = forward_f32(&Op::Linear { w, b: vec![10.0, 0.0] }, &x, None).unwrap();
        assert_eq!(y.data, vec![13.0, 9.0]);
    }

    #[test]
    fn conv_macs_counting() {
        let mut r = Rng::new(1);
        let w = Tensor::new(vec![4, 2, 3, 3], (0..72).map(|_| r.normal() as f32).collect()).unwrap();
        let op = Op::Conv { w, b: vec![0.0; 4], stride: 1, pad: 1 };
        let (macs, out) = op.macs_and_out_shape(&[2, 8, 8]).unwrap();
        assert_eq!(out, vec![4, 8, 8]);
        assert_eq!(macs, (4 * 2 * 3 * 3 * 8 * 8) as u64);
    }

    #[test]
    fn shape_errors() {
        let w = Tensor::new(vec![2, 3], vec![0.0; 6]).unwrap();
        let op = Op::Linear { w, b: vec![0.0; 2] };
        assert!(op.macs_and_out_shape(&[4]).is_err());
    }
}
