//! `pann-trace/v1` — replayable workload traces.
//!
//! A trace is a sorted list of arrival events, each carrying the full
//! per-request QoS surface of [`InferRequest`]: arrival offset from
//! trace start, optional target model, optional start-by deadline,
//! optional per-request energy cap, scheduling priority, and an
//! optional shard-affinity key. Offsets are virtual microseconds —
//! nothing in a trace references the wall clock, and the seeded
//! generators draw every value from [`crate::util::Rng`], so the same
//! seed and parameters produce a byte-identical trace (the property
//! `prop_trace_generator_deterministic_and_sorted` locks in).
//!
//! Four generator families cover the workload shapes the low-power
//! serving literature says dominate realized energy:
//!
//! - [`TraceFamily::Diurnal`] — a two-peak sinusoidal day/night cycle.
//! - [`TraceFamily::FlashCrowd`] — a uniform baseline with 60% of all
//!   events compressed into a 10%-of-duration burst.
//! - [`TraceFamily::DeadlineMix`] — an adversarial mix of tight-deadline
//!   `Hi` traffic, default `Normal` traffic, and energy-capped
//!   `BestEffort` traffic, all bunched into the first half of the
//!   trace so queues actually fill.
//! - [`TraceFamily::TenantSkew`] — one hot tenant sending 85% of the
//!   traffic next to paced cold tenants, each with a stable affinity
//!   key.

use crate::coordinator::{InferRequest, Priority};
use crate::util::{bench, Json, Rng};
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::time::Duration;

/// Schema tag every trace file carries.
pub const TRACE_SCHEMA: &str = "pann-trace/v1";

/// Smallest admissible event deadline (µs): anything tighter than a
/// millisecond is below the resolution the replay engine models.
pub const MIN_DEADLINE_US: u64 = 1_000;

/// Largest admissible event deadline (µs): ten seconds, far beyond any
/// generated trace duration — effectively "no pressure".
pub const MAX_DEADLINE_US: u64 = 10_000_000;

/// Inverse of [`Priority::name`] for the trace schema.
pub fn priority_from_name(name: &str) -> Option<Priority> {
    Priority::ALL.into_iter().find(|p| p.name() == name)
}

/// The four seeded workload shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFamily {
    /// Two-peak day/night arrival cycle.
    Diurnal,
    /// Uniform baseline plus a dense burst.
    FlashCrowd,
    /// Adversarial deadline/priority mix under pressure.
    DeadlineMix,
    /// One hot tenant, several cold ones, keyed affinity.
    TenantSkew,
}

impl TraceFamily {
    /// Every family, in reporting order.
    pub const ALL: [TraceFamily; 4] = [
        TraceFamily::Diurnal,
        TraceFamily::FlashCrowd,
        TraceFamily::DeadlineMix,
        TraceFamily::TenantSkew,
    ];

    /// Stable lower-case label (trace files, reports, CLI).
    pub fn name(self) -> &'static str {
        match self {
            TraceFamily::Diurnal => "diurnal",
            TraceFamily::FlashCrowd => "flash-crowd",
            TraceFamily::DeadlineMix => "deadline-mix",
            TraceFamily::TenantSkew => "tenant-skew",
        }
    }

    /// Inverse of [`TraceFamily::name`].
    pub fn from_name(name: &str) -> Option<TraceFamily> {
        Self::ALL.into_iter().find(|f| f.name() == name)
    }
}

/// Generator knobs shared by all families.
#[derive(Clone, Copy, Debug)]
pub struct TraceParams {
    /// PRNG seed — the only source of entropy.
    pub seed: u64,
    /// Number of events to generate.
    pub events: usize,
    /// Trace length in virtual microseconds.
    pub duration_us: u64,
    /// Number of distinct affinity keys (`tenant-0` … `tenant-N-1`).
    pub tenants: usize,
}

impl Default for TraceParams {
    fn default() -> TraceParams {
        TraceParams { seed: 7, events: 512, duration_us: 2_000_000, tenants: 4 }
    }
}

/// One arrival in a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Arrival offset from trace start, virtual microseconds.
    pub offset_us: u64,
    /// Target registered model (fleet traces); `None` routes to the
    /// only model.
    pub model: Option<String>,
    /// Start-by deadline relative to arrival, virtual microseconds.
    pub deadline_us: Option<u64>,
    /// Per-request energy cap, Giga bit flips per sample.
    pub max_gflips: Option<f64>,
    /// Scheduling class.
    pub priority: Priority,
    /// Shard-affinity key ([`crate::net::rendezvous_order`] placement).
    pub affinity: Option<String>,
}

impl TraceEvent {
    /// Map this event onto a live [`InferRequest`] carrying `input` —
    /// the bridge from a replayable trace to the real
    /// [`crate::coordinator::ServerBuilder`] /
    /// [`crate::net::ShardRouter`] stack.
    pub fn to_request(&self, input: Vec<f32>) -> InferRequest {
        let mut req = InferRequest::new(input).priority(self.priority);
        if let Some(m) = &self.model {
            req = req.model(m.clone());
        }
        if let Some(d) = self.deadline_us {
            req = req.deadline(Duration::from_micros(d));
        }
        if let Some(g) = self.max_gflips {
            req = req.max_gflips(g);
        }
        if let Some(a) = &self.affinity {
            req = req.affinity(a.clone());
        }
        req
    }

    /// JSON form; `None` fields are omitted.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("offset_us", Json::Num(self.offset_us as f64)),
            ("priority", Json::from(self.priority.name())),
        ];
        if let Some(m) = &self.model {
            pairs.push(("model", Json::from(m.clone())));
        }
        if let Some(d) = self.deadline_us {
            pairs.push(("deadline_us", Json::Num(d as f64)));
        }
        if let Some(g) = self.max_gflips {
            pairs.push(("max_gflips", Json::Num(g)));
        }
        if let Some(a) = &self.affinity {
            pairs.push(("affinity", Json::from(a.clone())));
        }
        Json::obj(pairs)
    }

    fn from_json(j: &Json, idx: usize) -> Result<TraceEvent> {
        let offset_us = j
            .req("offset_us")?
            .as_f64()
            .with_context(|| format!("event {idx}: offset_us must be a number"))?
            as u64;
        let priority_name = j
            .req("priority")?
            .as_str()
            .with_context(|| format!("event {idx}: priority must be a string"))?;
        let priority = priority_from_name(priority_name)
            .with_context(|| format!("event {idx}: unknown priority '{priority_name}'"))?;
        Ok(TraceEvent {
            offset_us,
            model: j.get("model").and_then(Json::as_str).map(str::to_string),
            deadline_us: j.get("deadline_us").and_then(Json::as_f64).map(|d| d as u64),
            max_gflips: j.get("max_gflips").and_then(Json::as_f64),
            priority,
            affinity: j.get("affinity").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// A named, seeded, sorted event stream.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Trace name (defaults to `<family>-s<seed>`).
    pub name: String,
    /// Generator family this trace was drawn from.
    pub family: TraceFamily,
    /// Generator seed.
    pub seed: u64,
    /// Trace length in virtual microseconds.
    pub duration_us: u64,
    /// Events sorted by non-decreasing `offset_us`.
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Generate a trace. Same `family` + `params` ⇒ identical result.
    pub fn generate(family: TraceFamily, params: &TraceParams) -> Trace {
        let mut rng = Rng::new(params.seed);
        let events = match family {
            TraceFamily::Diurnal => gen_diurnal(&mut rng, params),
            TraceFamily::FlashCrowd => gen_flash_crowd(&mut rng, params),
            TraceFamily::DeadlineMix => gen_deadline_mix(&mut rng, params),
            TraceFamily::TenantSkew => gen_tenant_skew(&mut rng, params),
        };
        Trace {
            name: format!("{}-s{}", family.name(), params.seed),
            family,
            seed: params.seed,
            duration_us: params.duration_us,
            events,
        }
    }

    /// Check the schema invariants: sorted offsets within the trace
    /// duration, deadlines within
    /// [`MIN_DEADLINE_US`]`..=`[`MAX_DEADLINE_US`], finite positive
    /// energy caps.
    pub fn validate(&self) -> Result<()> {
        let mut prev = 0u64;
        for (i, e) in self.events.iter().enumerate() {
            if e.offset_us < prev {
                bail!("event {i}: offset {} < previous offset {prev} (unsorted)", e.offset_us);
            }
            if e.offset_us > self.duration_us {
                bail!("event {i}: offset {} beyond duration {}", e.offset_us, self.duration_us);
            }
            if let Some(d) = e.deadline_us {
                if !(MIN_DEADLINE_US..=MAX_DEADLINE_US).contains(&d) {
                    bail!(
                        "event {i}: deadline {d}µs outside \
                         [{MIN_DEADLINE_US}, {MAX_DEADLINE_US}]"
                    );
                }
            }
            if let Some(g) = e.max_gflips {
                if !(g.is_finite() && g > 0.0) {
                    bail!("event {i}: max_gflips {g} must be finite and positive");
                }
            }
            prev = e.offset_us;
        }
        Ok(())
    }

    /// Provenance-stamped `pann-trace/v1` document.
    pub fn to_json(&self) -> Json {
        bench::stamped(
            TRACE_SCHEMA,
            "seeded generator output; same seed and params regenerate this file byte-identically",
            vec![
                ("name", Json::from(self.name.clone())),
                ("family", Json::from(self.family.name())),
                ("seed", Json::Num(self.seed as f64)),
                ("duration_us", Json::Num(self.duration_us as f64)),
                ("events", Json::Arr(self.events.iter().map(TraceEvent::to_json).collect())),
            ],
        )
    }

    /// Parse and [`Trace::validate`] a `pann-trace/v1` document.
    pub fn from_json(j: &Json) -> Result<Trace> {
        let schema = j.req("schema")?.as_str().context("schema must be a string")?;
        if schema != TRACE_SCHEMA {
            bail!("unsupported trace schema '{schema}' (want '{TRACE_SCHEMA}')");
        }
        let family_name = j.req("family")?.as_str().context("family must be a string")?;
        let family = TraceFamily::from_name(family_name)
            .with_context(|| format!("unknown trace family '{family_name}'"))?;
        let events_json = j.req("events")?.as_arr().context("events must be an array")?;
        let mut events = Vec::with_capacity(events_json.len());
        for (i, ej) in events_json.iter().enumerate() {
            events.push(TraceEvent::from_json(ej, i)?);
        }
        let trace = Trace {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or(family_name)
                .to_string(),
            family,
            seed: j.req("seed")?.as_f64().context("seed must be a number")? as u64,
            duration_us: j.req("duration_us")?.as_f64().context("duration_us")? as u64,
            events,
        };
        trace.validate()?;
        Ok(trace)
    }

    /// Save as a provenance-stamped JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        bench::write_json(&path.to_string_lossy(), &self.to_json())
            .with_context(|| format!("write trace {}", path.display()))
    }

    /// Load and validate a trace file.
    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read trace {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Trace::from_json(&j)
    }
}

/// Deadline draw clamped into the schema bounds.
fn clamp_deadline(x: f64) -> u64 {
    x.max(MIN_DEADLINE_US as f64).min(MAX_DEADLINE_US as f64) as u64
}

/// Draw a priority from a `(hi, normal)` probability split; the
/// remainder is `BestEffort`.
fn pick_priority(rng: &mut Rng, hi: f64, normal: f64) -> Priority {
    let u = rng.f64();
    if u < hi {
        Priority::Hi
    } else if u < hi + normal {
        Priority::Normal
    } else {
        Priority::BestEffort
    }
}

fn tenant_key(idx: usize) -> String {
    format!("tenant-{idx}")
}

/// Two-peak sinusoidal arrival intensity: events are apportioned over
/// 16 equal time buckets with weight `1 + 0.85·sin(2·τ·k/16)`
/// (cumulative rounding, so the bucket counts always sum to exactly
/// `params.events`), uniform within each bucket.
fn gen_diurnal(rng: &mut Rng, p: &TraceParams) -> Vec<TraceEvent> {
    const BUCKETS: usize = 16;
    let weights: Vec<f64> = (0..BUCKETS)
        .map(|k| 1.0 + 0.85 * (std::f64::consts::TAU * 2.0 * k as f64 / BUCKETS as f64).sin())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut offsets = Vec::with_capacity(p.events);
    let (mut assigned, mut cum) = (0usize, 0.0f64);
    for (k, w) in weights.iter().enumerate() {
        cum += w;
        let upto = ((cum / total) * p.events as f64).round() as usize;
        let lo = p.duration_us as f64 * k as f64 / BUCKETS as f64;
        let hi = p.duration_us as f64 * (k + 1) as f64 / BUCKETS as f64;
        for _ in 0..upto.saturating_sub(assigned) {
            offsets.push((lo + rng.f64() * (hi - lo)) as u64);
        }
        assigned = upto.max(assigned);
    }
    offsets.sort_unstable();
    offsets
        .into_iter()
        .map(|offset_us| TraceEvent {
            offset_us,
            model: None,
            deadline_us: Some(clamp_deadline(rng.normal_ms(60_000.0, 15_000.0))),
            max_gflips: None,
            priority: pick_priority(rng, 0.2, 0.6),
            affinity: Some(tenant_key(rng.below(p.tenants.max(1)))),
        })
        .collect()
}

/// Uniform baseline (40% of events over the whole duration) plus a
/// flash crowd: 60% of events land uniformly inside
/// `[0.45·T, 0.55·T)`.
fn gen_flash_crowd(rng: &mut Rng, p: &TraceParams) -> Vec<TraceEvent> {
    let n_burst = p.events * 3 / 5;
    let t = p.duration_us as f64;
    let mut offsets: Vec<u64> = Vec::with_capacity(p.events);
    for _ in 0..p.events - n_burst {
        offsets.push((rng.f64() * t) as u64);
    }
    for _ in 0..n_burst {
        offsets.push((t * 0.45 + rng.f64() * t * 0.10) as u64);
    }
    offsets.sort_unstable();
    offsets
        .into_iter()
        .map(|offset_us| TraceEvent {
            offset_us,
            model: None,
            deadline_us: Some(clamp_deadline(rng.normal_ms(30_000.0, 8_000.0))),
            max_gflips: None,
            priority: pick_priority(rng, 0.2, 0.6),
            affinity: Some(tenant_key(rng.below(p.tenants.max(1)))),
        })
        .collect()
}

/// Adversarial deadline mix bunched into the first half of the trace:
/// 30% `Hi` with tight deadlines, 40% `Normal`, 30% `BestEffort` with
/// generous deadlines, half of them energy-capped.
fn gen_deadline_mix(rng: &mut Rng, p: &TraceParams) -> Vec<TraceEvent> {
    let t_half = p.duration_us as f64 / 2.0;
    let mut offsets: Vec<u64> = (0..p.events).map(|_| (rng.f64() * t_half) as u64).collect();
    offsets.sort_unstable();
    offsets
        .into_iter()
        .map(|offset_us| {
            let priority = pick_priority(rng, 0.3, 0.4);
            let deadline_us = Some(clamp_deadline(match priority {
                Priority::Hi => rng.normal_ms(20_000.0, 5_000.0),
                Priority::Normal => rng.normal_ms(60_000.0, 15_000.0),
                Priority::BestEffort => rng.normal_ms(250_000.0, 50_000.0),
            }));
            let max_gflips = if priority == Priority::BestEffort && rng.f64() < 0.5 {
                Some(0.1 + 0.4 * rng.f64())
            } else {
                None
            };
            TraceEvent {
                offset_us,
                model: None,
                deadline_us,
                max_gflips,
                priority,
                affinity: Some(tenant_key(rng.below(p.tenants.max(1)))),
            }
        })
        .collect()
}

/// Multi-tenant skew: `tenant-0` sends 85% of all events; the
/// remaining 15% spread over the cold tenants. All arrivals are
/// uniform over the duration with generous deadlines — the pressure
/// comes purely from the hot key's density.
fn gen_tenant_skew(rng: &mut Rng, p: &TraceParams) -> Vec<TraceEvent> {
    let tenants = p.tenants.max(2);
    let t = p.duration_us as f64;
    let mut offsets: Vec<u64> = (0..p.events).map(|_| (rng.f64() * t) as u64).collect();
    offsets.sort_unstable();
    offsets
        .into_iter()
        .map(|offset_us| {
            let tenant =
                if rng.f64() < 0.85 { 0 } else { 1 + rng.below(tenants - 1) };
            TraceEvent {
                offset_us,
                model: None,
                deadline_us: Some(clamp_deadline(rng.normal_ms(100_000.0, 20_000.0))),
                max_gflips: None,
                priority: Priority::Normal,
                affinity: Some(tenant_key(tenant)),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic_and_valid() {
        let params = TraceParams { seed: 42, events: 200, duration_us: 500_000, tenants: 3 };
        for family in TraceFamily::ALL {
            let a = Trace::generate(family, &params);
            let b = Trace::generate(family, &params);
            assert_eq!(a, b, "{} not deterministic", family.name());
            a.validate().unwrap();
            assert_eq!(a.events.len(), params.events, "{}", family.name());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = Trace::generate(TraceFamily::Diurnal, &TraceParams::default());
        let b =
            Trace::generate(TraceFamily::Diurnal, &TraceParams { seed: 8, ..Default::default() });
        assert_ne!(a.events, b.events);
    }

    #[test]
    fn json_roundtrip_is_lossless_and_byte_stable() {
        for family in TraceFamily::ALL {
            let t = Trace::generate(family, &TraceParams { events: 64, ..Default::default() });
            let doc = t.to_json();
            let back = Trace::from_json(&doc).unwrap();
            assert_eq!(back, t);
            assert_eq!(doc.to_string(), back.to_json().to_string());
        }
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        let t = Trace::generate(TraceFamily::FlashCrowd, &TraceParams::default());
        // wrong schema tag
        let mut doc = t.to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("schema".into(), Json::from("pann-trace/v0"));
        }
        assert!(Trace::from_json(&doc).is_err());
        // unsorted events
        let mut unsorted = t.clone();
        unsorted.events.swap(0, 1);
        if unsorted.events[0].offset_us != unsorted.events[1].offset_us {
            assert!(Trace::from_json(&unsorted.to_json()).is_err());
        }
        // out-of-bounds deadline
        let mut bad = t;
        bad.events[0].deadline_us = Some(MAX_DEADLINE_US + 1);
        assert!(Trace::from_json(&bad.to_json()).is_err());
    }

    #[test]
    fn flash_crowd_is_burst_heavy() {
        let p = TraceParams::default();
        let t = Trace::generate(TraceFamily::FlashCrowd, &p);
        let (lo, hi) = (p.duration_us * 45 / 100, p.duration_us * 55 / 100);
        let in_burst =
            t.events.iter().filter(|e| (lo..hi).contains(&e.offset_us)).count();
        // 60% were placed there on purpose; the uniform 40% adds a bit
        assert!(in_burst as f64 >= 0.55 * p.events as f64, "burst {in_burst}");
    }

    #[test]
    fn tenant_skew_is_hot_on_tenant_zero() {
        let p = TraceParams::default();
        let t = Trace::generate(TraceFamily::TenantSkew, &p);
        let hot = t
            .events
            .iter()
            .filter(|e| e.affinity.as_deref() == Some("tenant-0"))
            .count();
        assert!(hot as f64 > 0.7 * p.events as f64, "hot {hot}");
        assert!(hot < p.events, "cold tenants must exist");
    }

    #[test]
    fn to_request_carries_the_full_qos_surface() {
        let e = TraceEvent {
            offset_us: 10,
            model: Some("cnn-s".into()),
            deadline_us: Some(5_000),
            max_gflips: Some(0.25),
            priority: Priority::Hi,
            affinity: Some("tenant-1".into()),
        };
        let req = e.to_request(vec![0.0; 4]);
        let dbg = format!("{req:?}");
        assert!(dbg.contains("cnn-s") && dbg.contains("tenant-1") && dbg.contains("Hi"), "{dbg}");
    }

    #[test]
    fn priority_names_roundtrip() {
        for p in Priority::ALL {
            assert_eq!(priority_from_name(p.name()), Some(p));
        }
        assert_eq!(priority_from_name("nope"), None);
    }
}
