//! Named device profiles — per-device parameterizations of the
//! paper's power model.
//!
//! The per-instruction formulas in [`crate::power::model`] count
//! *logical* bit flips; what a flip costs, how many the device can
//! execute per second, and how deep its admission queue runs are all
//! properties of the deployment target. Hashemi et al. (PAPERS.md)
//! show energy/accuracy conclusions shift materially across device
//! classes, so the scenario harness makes the device an explicit,
//! named input: the same trace replayed under `jetson` and `server`
//! answers "what does this envelope do to p99 and accuracy on device
//! X" without touching the menu.
//!
//! Two calibrated classes ship today:
//!
//! | profile  | process scale | acc. width | envelope (GF/s) | drain (GF/s) | queue |
//! |----------|---------------|------------|-----------------|--------------|-------|
//! | `jetson` | 0.8           | 32 bit     | 4               | 25           | 16    |
//! | `server` | 1.0           | 64 bit     | 40              | 250          | 64    |
//!
//! The *flip-energy scale* each profile applies to menu costs is
//! derived from the power model itself rather than stated: it is the
//! process scale times the ratio of the device's signed-MAC flip count
//! (at its accumulator width, Eq. (2): `P_acc = 0.5·B + 2b`) to the
//! 32-bit reference — a server-class 64-bit accumulator makes every
//! flip-count higher, a low-power process makes each flip cheaper.

use crate::power::model::{mac_power_signed, PowerBreakdown};

/// Reference operand width used to derive the accumulator-width part
/// of the flip-energy scale.
const REF_BITS: u32 = 8;

/// One named deployment target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Stable profile name (`--device` on the CLI).
    pub name: &'static str,
    /// Silicon/process energy factor applied to logical flip counts
    /// (see [`PowerBreakdown::scaled`]).
    pub process_scale: f64,
    /// Physical accumulator width, bits (Eq. (2) parameter `B`).
    pub acc_bits: u32,
    /// Default sustained energy envelope, Giga bit flips per second.
    pub envelope_gflips_per_sec: f64,
    /// Compute throughput: the rate a busy device retires modeled
    /// flips, Giga bit flips per second. Virtual service time of a
    /// request is `point cost / this rate`.
    pub service_gflips_per_sec: f64,
    /// Admission-queue bound per shard.
    pub queue_depth: usize,
}

impl DeviceProfile {
    /// Jetson-class edge device: low-power process, 32-bit
    /// accumulators, tight envelope, modest drain rate.
    pub fn jetson() -> DeviceProfile {
        DeviceProfile {
            name: "jetson",
            process_scale: 0.8,
            acc_bits: 32,
            envelope_gflips_per_sec: 4.0,
            service_gflips_per_sec: 25.0,
            queue_depth: 16,
        }
    }

    /// Server-class machine: standard process, 64-bit accumulators,
    /// wide envelope, high drain rate.
    pub fn server() -> DeviceProfile {
        DeviceProfile {
            name: "server",
            process_scale: 1.0,
            acc_bits: 64,
            envelope_gflips_per_sec: 40.0,
            service_gflips_per_sec: 250.0,
            queue_depth: 64,
        }
    }

    /// Every named profile, CLI/report order.
    pub fn all() -> [DeviceProfile; 2] {
        [DeviceProfile::jetson(), DeviceProfile::server()]
    }

    /// Look a profile up by its [`DeviceProfile::name`].
    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        DeviceProfile::all().into_iter().find(|d| d.name == name)
    }

    /// The factor menu costs are multiplied by on this device: process
    /// scale × (device signed-MAC flips at `acc_bits` / 32-bit
    /// reference flips), both at the [`REF_BITS`] operand width.
    pub fn flip_energy_scale(&self) -> f64 {
        let reference = mac_power_signed(REF_BITS, 32).total();
        let device = self.mac_breakdown(REF_BITS).total();
        device / reference
    }

    /// This device's per-MAC breakdown at operand width `b`: the
    /// paper's signed-MAC model at the device accumulator width,
    /// scaled by the process factor.
    pub fn mac_breakdown(&self, b: u32) -> PowerBreakdown {
        mac_power_signed(b, self.acc_bits).scaled(self.process_scale)
    }

    /// A menu point's effective per-sample cost on this device.
    pub fn point_cost(&self, gflips_per_sample: f64) -> f64 {
        gflips_per_sample * self.flip_energy_scale()
    }

    /// Virtual service time for one request at `cost_gflips` on this
    /// device, microseconds (at least 1).
    pub fn service_us(&self, cost_gflips: f64) -> u64 {
        ((cost_gflips / self.service_gflips_per_sec) * 1e6).ceil().max(1.0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_roundtrips() {
        for d in DeviceProfile::all() {
            assert_eq!(DeviceProfile::by_name(d.name), Some(d));
        }
        assert_eq!(DeviceProfile::by_name("toaster"), None);
    }

    #[test]
    fn jetson_flips_are_cheaper_per_sample() {
        let j = DeviceProfile::jetson().flip_energy_scale();
        let s = DeviceProfile::server().flip_energy_scale();
        // low-power process beats the reference; 64-bit accumulators
        // cost more than the 32-bit reference
        assert!(j < 1.0, "jetson scale {j}");
        assert!(s > 1.0, "server scale {s}");
        assert!(j < s);
    }

    #[test]
    fn server_scale_matches_eq2_by_hand() {
        // signed MAC at b=8: mult = 0.5·64 + 8 = 40;
        // acc(B=32) = 16 + 16 = 32 → 72; acc(B=64) = 32 + 16 = 48 → 88
        let s = DeviceProfile::server().flip_energy_scale();
        assert!((s - 88.0 / 72.0).abs() < 1e-12, "scale {s}");
    }

    #[test]
    fn service_time_scales_with_cost_and_never_rounds_to_zero() {
        let d = DeviceProfile::server();
        assert_eq!(d.service_us(0.0), 1);
        let one = d.service_us(0.25); // 0.25 GF / 250 GF/s = 1 ms
        assert_eq!(one, 1_000);
        assert_eq!(d.service_us(0.5), 2 * one);
    }
}
