//! Trace-driven scenario harness: replayable workloads, per-device
//! power profiles, and a deterministic fleet test rig.
//!
//! The serving stack ([`crate::coordinator`], [`crate::net`]) is
//! exercised everywhere else by live tests that pace real threads with
//! sleeps — useful as smoke, but slow, racy under CI load, and unable
//! to answer the questions the paper's deployment story raises:
//! *what does a flash crowd do to p99 under a 4 GF/s envelope? does a
//! hot tenant starve a cold one? which priority class sheds first?*
//! This module answers those questions reproducibly:
//!
//! - [`trace`] — a versioned workload format (`pann-trace/v1`): each
//!   event is an arrival offset in virtual microseconds plus the full
//!   per-request QoS surface (deadline, energy cap, priority, affinity
//!   key). Seeded generators produce four workload families — diurnal
//!   cycles, flash crowds, adversarial deadline mixes, multi-tenant
//!   skew — and the same seed regenerates the same trace byte for
//!   byte. No generator reads a wall clock.
//! - [`device`] — named [`DeviceProfile`]s (`jetson`, `server`): the
//!   paper's power model parameterized per deployment target
//!   (process-energy scale, accumulator width, default envelope,
//!   drain rate, queue depth), so one menu replays differently — and
//!   comparably — across device classes.
//! - [`replay`] — the deterministic rig: a virtual-clock
//!   discrete-event engine that drives the *real* [`Governor`]
//!   (injected instants), the *real* [`PowerPolicy`] and the router's
//!   *real* rendezvous placement over N simulated shards, and folds
//!   the outcome into a provenance-stamped [`ScenarioReport`]
//!   (`scenario-report/v1`): per-window p50/p99 and shed/expired
//!   counts, per-priority and per-tenant outcomes, per-shard governor
//!   residency and switches. Identical inputs produce byte-identical
//!   reports.
//!
//! Three surfaces share this engine: `pann-cli replay --trace t.json
//! --menu menu.json [--device jetson] [--shards N]`, the scenario
//! matrix in `tests/scenarios.rs`, and `benches/scenarios.rs` (the
//! committed `BENCH_scenarios.json`).
//!
//! [`Governor`]: crate::coordinator::Governor
//! [`PowerPolicy`]: crate::coordinator::PowerPolicy

pub mod device;
pub mod replay;
pub mod trace;

pub use device::DeviceProfile;
pub use replay::{
    frontier_from_menu, replay, FrontierPoint, OutcomeCounts, ReplayConfig, ScenarioReport,
    ShardGovernorSummary, WindowStat, REPORT_SCHEMA,
};
pub use trace::{priority_from_name, Trace, TraceEvent, TraceFamily, TraceParams, TRACE_SCHEMA};
