//! Deterministic trace replay against a virtual clock.
//!
//! The replay engine is a discrete-event simulation over virtual
//! microsecond offsets that reuses the *real* serving-stack decision
//! components rather than re-modeling them:
//!
//! - point selection is a real [`PowerPolicy`] over the menu frontier,
//!   selecting under `min(governed budget, per-request cap)` exactly
//!   like the server's scheduler;
//! - the energy feedback loop is a real [`Governor`] per shard, driven
//!   with injected [`Instant`]s derived from virtual time (the same
//!   synthetic-instant protocol the governor unit tests use — the
//!   governor never reads the wall clock);
//! - keyed shard placement is the router's own rendezvous rule,
//!   [`crate::net::rendezvous_order`]; keyless events rotate
//!   round-robin, as in [`crate::net::ShardRouter`].
//!
//! Around those components the simulation models each shard as a
//! single-server queue: three priority lanes drained highest-first, a
//! bounded total depth, deterministic per-request service time
//! `point cost / device drain rate`
//! ([`DeviceProfile::service_us`]), and start-time deadline expiry
//! (matching the scheduler's start-by contract). When a shard is full
//! the simulation first tries to *evict* the newest request from the
//! lowest-priority non-empty lane below the arrival's class (the
//! single-shard analogue of the router shedding cheap work and
//! retrying it elsewhere), then walks the remaining shards in
//! preference order, and only then sheds the arrival itself.
//!
//! Because every input is virtual and every component deterministic,
//! a [`ScenarioReport`] contains **no wall-clock data at all**: two
//! replays of the same trace under the same config produce
//! byte-identical JSON. That is the property the CI scenario leg
//! checks by diffing two independent `pann-cli replay` runs.

use super::device::DeviceProfile;
use super::trace::Trace;
use crate::coordinator::{Costed, EnergyEnvelope, Governor, GovernorConfig, PowerPolicy, Priority};
use crate::net::rendezvous_order;
use crate::pann::menu::MenuArtifact;
use crate::util::{bench, stats, Json};
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Schema tag every scenario report carries.
pub const REPORT_SCHEMA: &str = "scenario-report/v1";

/// Provenance string stamped on every report. Deliberately free of
/// timestamps: the report must be byte-identical across runs.
const REPORT_PROVENANCE: &str =
    "deterministic virtual-clock replay; identical trace and config reproduce this report \
     byte-for-byte";

/// Number of priority lanes (mirrors the server's queue).
const N_LANES: usize = 3;

/// One operating point of the replayed frontier: a name, a per-sample
/// energy cost (already device-scaled), and the validation accuracy
/// the menu compiler measured for it — the accuracy proxy realized
/// throughput is scored with.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontierPoint {
    /// Point name (menu order key).
    pub name: String,
    /// Per-sample cost on the replay device, Giga bit flips.
    pub cost_gflips: f64,
    /// Validation accuracy of the point, `[0, 1]`.
    pub acc_proxy: f64,
}

impl Costed for FrontierPoint {
    fn point_name(&self) -> &str {
        &self.name
    }
    fn cost_gflips(&self) -> f64 {
        self.cost_gflips
    }
}

/// Lift a compiled menu artifact onto `device`: every point's modeled
/// cost is scaled by the device's flip-energy factor
/// ([`DeviceProfile::point_cost`]), sorted ascending, with
/// duplicate-cost points dropped (the governor's budget cell cannot
/// distinguish them — same rule as [`Governor`] construction).
pub fn frontier_from_menu(menu: &MenuArtifact, device: &DeviceProfile) -> Vec<FrontierPoint> {
    let mut points: Vec<FrontierPoint> = menu
        .points
        .iter()
        .map(|p| FrontierPoint {
            name: p.name.clone(),
            cost_gflips: device.point_cost(p.gflips_per_sample),
            acc_proxy: p.val_acc,
        })
        .collect();
    points.sort_by(|a, b| a.cost_gflips.total_cmp(&b.cost_gflips));
    points.dedup_by(|b, a| a.cost_gflips == b.cost_gflips);
    points
}

/// Replay knobs beyond the trace and the frontier.
#[derive(Clone, Copy, Debug)]
pub struct ReplayConfig {
    /// Device profile costs, drain rate and queue depth come from.
    pub device: DeviceProfile,
    /// Number of simulated shards (min 1).
    pub shards: usize,
    /// Cluster envelope override, Gflips/sec; defaults to the device
    /// profile's envelope. Split evenly across shards.
    pub envelope_gflips_per_sec: Option<f64>,
    /// Governor decision-window length, virtual µs.
    pub governor_window_us: u64,
    /// Governor decision horizon, windows.
    pub hysteresis: u32,
    /// Report aggregation window, virtual µs.
    pub report_window_us: u64,
    /// Per-shard queue-depth override; defaults to the device profile.
    pub queue_depth: Option<usize>,
    /// Replay only the first N events (`--quick`).
    pub max_events: Option<usize>,
}

impl ReplayConfig {
    /// Defaults for `device`: 1 shard, device envelope, 10 ms governor
    /// windows with hysteresis 2, 100 ms report windows.
    pub fn new(device: DeviceProfile) -> ReplayConfig {
        ReplayConfig {
            device,
            shards: 1,
            envelope_gflips_per_sec: None,
            governor_window_us: 10_000,
            hysteresis: 2,
            report_window_us: 100_000,
            queue_depth: None,
            max_events: None,
        }
    }
}

/// Served / shed / expired accounting for one slice of the trace.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OutcomeCounts {
    /// Events that arrived in this slice.
    pub arrivals: u64,
    /// Events served to completion.
    pub served: u64,
    /// Events shed by admission control (queue full / evicted).
    pub shed: u64,
    /// Events whose deadline passed before service started.
    pub expired: u64,
}

impl OutcomeCounts {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arrivals", Json::Num(self.arrivals as f64)),
            ("served", Json::Num(self.served as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("expired", Json::Num(self.expired as f64)),
        ])
    }

    fn add(&mut self, other: &OutcomeCounts) {
        self.arrivals += other.arrivals;
        self.served += other.served;
        self.shed += other.shed;
        self.expired += other.expired;
    }
}

/// Per-report-window aggregate (windows are indexed by arrival time).
#[derive(Clone, Debug)]
pub struct WindowStat {
    /// Window index (`arrival offset / report window`).
    pub index: usize,
    /// Outcomes of events that arrived in this window.
    pub counts: OutcomeCounts,
    /// Median served latency, virtual µs (0 when nothing served).
    pub p50_us: f64,
    /// 99th-percentile served latency, virtual µs.
    pub p99_us: f64,
    /// Mean accuracy proxy of the points that served this window's
    /// events (0 when nothing served).
    pub mean_acc_proxy: f64,
}

/// End-of-replay view of one shard's governor.
#[derive(Clone, Debug)]
pub struct ShardGovernorSummary {
    /// Shard index.
    pub shard: usize,
    /// Final operating point after the trailing idle flush.
    pub point: String,
    /// Frontier steps taken.
    pub switches: u64,
    /// Decision windows closed.
    pub windows: u64,
    /// Closed windows spent at each point, cheapest first.
    pub residency: Vec<(String, u64)>,
}

/// Everything one replay produced. Contains no wall-clock data:
/// identical inputs serialize byte-identically.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    /// Replayed trace name.
    pub trace_name: String,
    /// Trace family label.
    pub family: String,
    /// Trace generator seed.
    pub seed: u64,
    /// Device profile name.
    pub device: String,
    /// Simulated shard count.
    pub shards: usize,
    /// Cluster envelope rate, Gflips/sec.
    pub envelope_gflips_per_sec: f64,
    /// Governor window, virtual µs.
    pub governor_window_us: u64,
    /// Report window, virtual µs.
    pub report_window_us: u64,
    /// Events replayed (after any `--quick` cap).
    pub events: u64,
    /// Whole-trace outcome totals.
    pub totals: OutcomeCounts,
    /// Outcomes per priority class, [`Priority::ALL`] order.
    pub per_priority: Vec<(String, OutcomeCounts)>,
    /// Outcomes per affinity key (`(none)` for keyless events).
    pub per_tenant: BTreeMap<String, OutcomeCounts>,
    /// `(point name, served count, accuracy proxy)` in frontier order.
    pub per_point: Vec<(String, u64, f64)>,
    /// Per-window aggregates, ascending index.
    pub windows: Vec<WindowStat>,
    /// One governor summary per shard.
    pub governors: Vec<ShardGovernorSummary>,
    /// Whole-trace served-latency median, virtual µs.
    pub p50_us: f64,
    /// Whole-trace served-latency p99, virtual µs.
    pub p99_us: f64,
    /// Mean accuracy proxy over every served event.
    pub mean_acc_proxy: f64,
}

impl ScenarioReport {
    /// Provenance-stamped `scenario-report/v1` document.
    pub fn to_json(&self) -> Json {
        let windows: Vec<Json> = self
            .windows
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("index", Json::Num(w.index as f64)),
                    ("counts", w.counts.to_json()),
                    ("p50_us", Json::Num(w.p50_us)),
                    ("p99_us", Json::Num(w.p99_us)),
                    ("mean_acc_proxy", Json::Num(w.mean_acc_proxy)),
                ])
            })
            .collect();
        let governors: Vec<Json> = self
            .governors
            .iter()
            .map(|g| {
                Json::obj(vec![
                    ("shard", Json::Num(g.shard as f64)),
                    ("point", Json::from(g.point.clone())),
                    ("switches", Json::Num(g.switches as f64)),
                    ("windows", Json::Num(g.windows as f64)),
                    (
                        "residency",
                        Json::Obj(
                            g.residency
                                .iter()
                                .map(|(n, w)| (n.clone(), Json::Num(*w as f64)))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let per_priority = Json::Obj(
            self.per_priority.iter().map(|(n, c)| (n.clone(), c.to_json())).collect(),
        );
        let per_tenant =
            Json::Obj(self.per_tenant.iter().map(|(n, c)| (n.clone(), c.to_json())).collect());
        let per_point: Vec<Json> = self
            .per_point
            .iter()
            .map(|(name, served, acc)| {
                Json::obj(vec![
                    ("name", Json::from(name.clone())),
                    ("served", Json::Num(*served as f64)),
                    ("acc_proxy", Json::Num(*acc)),
                ])
            })
            .collect();
        bench::stamped(
            REPORT_SCHEMA,
            REPORT_PROVENANCE,
            vec![
                ("trace_name", Json::from(self.trace_name.clone())),
                ("family", Json::from(self.family.clone())),
                ("seed", Json::Num(self.seed as f64)),
                ("device", Json::from(self.device.clone())),
                ("shards", Json::Num(self.shards as f64)),
                ("envelope_gflips_per_sec", Json::Num(self.envelope_gflips_per_sec)),
                ("governor_window_us", Json::Num(self.governor_window_us as f64)),
                ("report_window_us", Json::Num(self.report_window_us as f64)),
                ("events", Json::Num(self.events as f64)),
                ("totals", self.totals.to_json()),
                ("per_priority", per_priority),
                ("per_tenant", per_tenant),
                ("per_point", Json::Arr(per_point)),
                ("windows", Json::Arr(windows)),
                ("governors", Json::Arr(governors)),
                ("p50_us", Json::Num(self.p50_us)),
                ("p99_us", Json::Num(self.p99_us)),
                ("mean_acc_proxy", Json::Num(self.mean_acc_proxy)),
            ],
        )
    }

    /// Check the report's internal accounting identities. An empty
    /// vector means the report is sound; findings map to the CLI's
    /// exit-2 contract.
    pub fn invariants(&self) -> Vec<String> {
        let mut findings = Vec::new();
        let t = &self.totals;
        if t.arrivals != self.events {
            findings.push(format!("arrivals {} != events {}", t.arrivals, self.events));
        }
        if t.served + t.shed + t.expired != t.arrivals {
            findings.push(format!(
                "served {} + shed {} + expired {} != arrivals {}",
                t.served, t.shed, t.expired, t.arrivals
            ));
        }
        let mut win_sum = OutcomeCounts::default();
        for w in &self.windows {
            win_sum.add(&w.counts);
            if w.p99_us < w.p50_us {
                findings.push(format!("window {}: p99 {} < p50 {}", w.index, w.p99_us, w.p50_us));
            }
            if !(0.0..=1.0).contains(&w.mean_acc_proxy) {
                let (i, a) = (w.index, w.mean_acc_proxy);
                findings.push(format!("window {i}: acc proxy {a} outside [0,1]"));
            }
        }
        if win_sum != *t {
            findings.push(format!("window sums {win_sum:?} != totals {t:?}"));
        }
        let mut pri_sum = OutcomeCounts::default();
        for (_, c) in &self.per_priority {
            pri_sum.add(c);
        }
        if pri_sum != *t {
            findings.push(format!("priority sums {pri_sum:?} != totals {t:?}"));
        }
        let mut tenant_sum = OutcomeCounts::default();
        for c in self.per_tenant.values() {
            tenant_sum.add(c);
        }
        if tenant_sum != *t {
            findings.push(format!("tenant sums {tenant_sum:?} != totals {t:?}"));
        }
        let point_served: u64 = self.per_point.iter().map(|(_, s, _)| s).sum();
        if point_served != t.served {
            findings.push(format!("per-point served {point_served} != served {}", t.served));
        }
        for g in &self.governors {
            let res: u64 = g.residency.iter().map(|(_, w)| w).sum();
            if res != g.windows {
                findings.push(format!(
                    "shard {}: residency sum {res} != windows {}",
                    g.shard, g.windows
                ));
            }
        }
        if !(0.0..=1.0).contains(&self.mean_acc_proxy) {
            findings.push(format!("mean acc proxy {} outside [0,1]", self.mean_acc_proxy));
        }
        findings
    }

    /// Human summary for the CLI's stderr channel.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "replayed {} events ({} family, seed {}) on {} x{} shards under {} GF/s:\n\
             \x20 served {} / shed {} / expired {}; p50 {:.0}µs p99 {:.0}µs; \
             mean acc proxy {:.4}\n",
            self.events,
            self.family,
            self.seed,
            self.device,
            self.shards,
            self.envelope_gflips_per_sec,
            self.totals.served,
            self.totals.shed,
            self.totals.expired,
            self.p50_us,
            self.p99_us,
            self.mean_acc_proxy,
        );
        for g in &self.governors {
            let residency: Vec<String> =
                g.residency.iter().map(|(n, w)| format!("{n}:{w}")).collect();
            s.push_str(&format!(
                "  shard {}: final point {}, {} switches over {} windows [{}]\n",
                g.shard,
                g.point,
                g.switches,
                g.windows,
                residency.join(" ")
            ));
        }
        s
    }
}

/// One queued arrival inside the simulation.
struct QueuedEvent {
    event_idx: usize,
    offset_us: u64,
    deadline_us: Option<u64>,
    max_gflips: Option<f64>,
}

/// One simulated shard: real policy + governor, modeled queue.
struct SimShard {
    policy: PowerPolicy<FrontierPoint>,
    governor: Governor,
    budget_bits: Arc<AtomicU64>,
    lanes: [VecDeque<QueuedEvent>; N_LANES],
    queued: usize,
    free_at_us: u64,
}

/// Accounting sinks shared by the event loop.
struct Recorder {
    totals: OutcomeCounts,
    per_priority: [OutcomeCounts; N_LANES],
    per_tenant: BTreeMap<String, OutcomeCounts>,
    per_point_served: Vec<u64>,
    window_counts: Vec<OutcomeCounts>,
    window_latencies: Vec<Vec<f64>>,
    window_acc: Vec<(f64, u64)>,
    latencies: Vec<f64>,
    acc_sum: f64,
}

/// What became of one event (indices into the recorder).
#[derive(Clone, Copy)]
enum Outcome {
    Served { point: usize, latency_us: u64 },
    Shed,
    Expired,
}

impl Recorder {
    fn record(
        &mut self,
        lane: usize,
        tenant: &str,
        window: usize,
        acc: &[FrontierPoint],
        outcome: Outcome,
    ) {
        let tenant_slot = self.per_tenant.entry(tenant.to_string()).or_default();
        match outcome {
            Outcome::Served { point, latency_us } => {
                self.totals.served += 1;
                self.per_priority[lane].served += 1;
                tenant_slot.served += 1;
                self.per_point_served[point] += 1;
                self.window_counts[window].served += 1;
                self.window_latencies[window].push(latency_us as f64);
                self.window_acc[window].0 += acc[point].acc_proxy;
                self.window_acc[window].1 += 1;
                self.latencies.push(latency_us as f64);
                self.acc_sum += acc[point].acc_proxy;
            }
            Outcome::Shed => {
                self.totals.shed += 1;
                self.per_priority[lane].shed += 1;
                tenant_slot.shed += 1;
                self.window_counts[window].shed += 1;
            }
            Outcome::Expired => {
                self.totals.expired += 1;
                self.per_priority[lane].expired += 1;
                tenant_slot.expired += 1;
                self.window_counts[window].expired += 1;
            }
        }
    }
}

/// The lane an event's priority drains on (0 = `Hi`).
fn lane_of(p: Priority) -> usize {
    Priority::ALL.iter().position(|q| *q == p).unwrap_or(1)
}

/// Replay `trace` over `frontier` under `cfg`. The frontier must be
/// non-empty; duplicate-cost points are dropped (cheapest-first
/// ordering is established internally, so callers may pass any
/// order). See the module docs for the simulation model.
pub fn replay(
    trace: &Trace,
    frontier: &[FrontierPoint],
    cfg: &ReplayConfig,
) -> Result<ScenarioReport> {
    trace.validate().context("trace failed schema validation")?;
    ensure!(!frontier.is_empty(), "replay needs a non-empty frontier");
    ensure!(cfg.governor_window_us > 0, "governor window must be positive");
    ensure!(cfg.report_window_us > 0, "report window must be positive");
    let mut points = frontier.to_vec();
    points.sort_by(|a, b| a.cost_gflips.total_cmp(&b.cost_gflips));
    points.dedup_by(|b, a| a.cost_gflips == b.cost_gflips);
    for p in &points {
        ensure!(
            p.cost_gflips.is_finite() && p.cost_gflips >= 0.0,
            "point '{}' has non-finite cost",
            p.name
        );
    }

    let n_shards = cfg.shards.max(1);
    let device = cfg.device;
    let envelope_total =
        cfg.envelope_gflips_per_sec.unwrap_or(device.envelope_gflips_per_sec);
    ensure!(
        envelope_total.is_finite() && envelope_total > 0.0,
        "envelope rate must be finite and positive, got {envelope_total}"
    );
    let per_shard_rate = envelope_total / n_shards as f64;
    let depth = cfg.queue_depth.unwrap_or(device.queue_depth).max(1);
    let top_cost = points[points.len() - 1].cost_gflips;
    let menu_pairs: Vec<(String, f64)> =
        points.iter().map(|p| (p.name.clone(), p.cost_gflips)).collect();

    // Virtual-clock anchor: one arbitrary epoch; every governor
    // decision sees `epoch + offset`, so nothing depends on when the
    // replay itself runs.
    let epoch = Instant::now();
    let at = |us: u64| epoch + Duration::from_micros(us);

    let mut shards = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        let budget_bits = Arc::new(AtomicU64::new(top_cost.to_bits()));
        let gov_cfg = GovernorConfig {
            window: Duration::from_micros(cfg.governor_window_us),
            hysteresis: cfg.hysteresis,
            ..GovernorConfig::new(EnergyEnvelope::gflips_per_sec(per_shard_rate))
        };
        let governor = Governor::new(gov_cfg, menu_pairs.clone(), Arc::clone(&budget_bits), epoch)
            .context("build shard governor")?;
        let policy = PowerPolicy::new(points.clone())
            .map_err(|e| anyhow::anyhow!("build shard policy: {e}"))?;
        shards.push(SimShard {
            policy,
            governor,
            budget_bits,
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            queued: 0,
            free_at_us: 0,
        });
    }

    let events: Vec<_> = match cfg.max_events {
        Some(cap) => trace.events.iter().take(cap).collect(),
        None => trace.events.iter().collect(),
    };
    let n_windows = (trace.duration_us / cfg.report_window_us + 1) as usize;
    let mut rec = Recorder {
        totals: OutcomeCounts::default(),
        per_priority: [OutcomeCounts::default(); N_LANES],
        per_tenant: BTreeMap::new(),
        per_point_served: vec![0; points.len()],
        window_counts: vec![OutcomeCounts::default(); n_windows],
        window_latencies: vec![Vec::new(); n_windows],
        window_acc: vec![(0.0, 0); n_windows],
        latencies: Vec::new(),
        acc_sum: 0.0,
    };
    // event metadata the drain loop needs when an outcome lands later
    // than admission: (lane, tenant, window)
    let meta: Vec<(usize, String, usize)> = events
        .iter()
        .map(|e| {
            let lane = lane_of(e.priority);
            let tenant = e.affinity.clone().unwrap_or_else(|| "(none)".to_string());
            let window =
                ((e.offset_us / cfg.report_window_us) as usize).min(n_windows.saturating_sub(1));
            (lane, tenant, window)
        })
        .collect();
    for (lane, tenant, window) in &meta {
        rec.totals.arrivals += 1;
        rec.per_priority[*lane].arrivals += 1;
        rec.per_tenant.entry(tenant.clone()).or_default().arrivals += 1;
        rec.window_counts[*window].arrivals += 1;
    }

    let mut rr = 0usize;
    for (i, e) in events.iter().enumerate() {
        let order: Vec<usize> = match &e.affinity {
            Some(key) => rendezvous_order(key, n_shards),
            None => {
                let start = rr % n_shards;
                rr += 1;
                (start..n_shards).chain(0..start).collect()
            }
        };
        let lane = meta[i].0;
        let qe = QueuedEvent {
            event_idx: i,
            offset_us: e.offset_us,
            deadline_us: e.deadline_us,
            max_gflips: e.max_gflips,
        };
        let mut pending = Some(qe);
        for &s in &order {
            drain_shard(&mut shards[s], e.offset_us, &points, &device, &at, &meta, &mut rec)?;
            let shard = &mut shards[s];
            if shard.queued < depth {
                let qe = pending.take().context("event admitted twice")?;
                shard.lanes[lane].push_back(qe);
                shard.queued += 1;
                // a newly idle shard starts the request immediately
                drain_shard(&mut shards[s], e.offset_us, &points, &device, &at, &meta, &mut rec)?;
                break;
            }
            // full: evict the newest request of the lowest-priority
            // non-empty lane strictly below this arrival's class
            let victim_lane = (lane + 1..N_LANES).rev().find(|&l| !shard.lanes[l].is_empty());
            if let Some(vl) = victim_lane {
                if let Some(victim) = shard.lanes[vl].pop_back() {
                    shard.queued -= 1;
                    let (v_lane, v_tenant, v_window) = &meta[victim.event_idx];
                    rec.record(*v_lane, v_tenant, *v_window, &points, Outcome::Shed);
                }
                let qe = pending.take().context("event admitted twice")?;
                shard.lanes[lane].push_back(qe);
                shard.queued += 1;
                break;
            }
        }
        if let Some(_dropped) = pending.take() {
            let (lane, tenant, window) = &meta[i];
            rec.record(*lane, tenant, *window, &points, Outcome::Shed);
        }
    }

    // Drain every queue to completion, then flush enough idle governor
    // windows for the recovery climb back up the frontier to finish.
    let mut end_us = trace.duration_us;
    for s in 0..n_shards {
        drain_shard(&mut shards[s], u64::MAX, &points, &device, &at, &meta, &mut rec)?;
        end_us = end_us.max(shards[s].free_at_us);
    }
    let flush_windows = 2 * cfg.hysteresis as u64 * (points.len() as u64 + 2) + 4;
    let flush_us = end_us + flush_windows * cfg.governor_window_us;
    for shard in &shards {
        shard.governor.observe(at(flush_us), 0, 0, 0.0, false);
    }

    let windows = (0..n_windows)
        .map(|w| {
            let lat = &rec.window_latencies[w];
            let (acc_sum, acc_n) = rec.window_acc[w];
            WindowStat {
                index: w,
                counts: rec.window_counts[w],
                p50_us: stats::percentile(lat, 50.0),
                p99_us: stats::percentile(lat, 99.0),
                mean_acc_proxy: if acc_n > 0 { acc_sum / acc_n as f64 } else { 0.0 },
            }
        })
        .collect();
    let governors = shards
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let snap = s.governor.snapshot();
            ShardGovernorSummary {
                shard: i,
                point: snap.point,
                switches: snap.switches,
                windows: snap.windows,
                residency: snap.residency,
            }
        })
        .collect();
    let report = ScenarioReport {
        trace_name: trace.name.clone(),
        family: trace.family.name().to_string(),
        seed: trace.seed,
        device: device.name.to_string(),
        shards: n_shards,
        envelope_gflips_per_sec: envelope_total,
        governor_window_us: cfg.governor_window_us,
        report_window_us: cfg.report_window_us,
        events: events.len() as u64,
        totals: rec.totals,
        per_priority: Priority::ALL
            .iter()
            .enumerate()
            .map(|(l, p)| (p.name().to_string(), rec.per_priority[l]))
            .collect(),
        per_tenant: rec.per_tenant,
        per_point: points
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.clone(), rec.per_point_served[i], p.acc_proxy))
            .collect(),
        windows,
        governors,
        p50_us: stats::percentile(&rec.latencies, 50.0),
        p99_us: stats::percentile(&rec.latencies, 99.0),
        mean_acc_proxy: if rec.totals.served > 0 {
            rec.acc_sum / rec.totals.served as f64
        } else {
            0.0
        },
    };
    Ok(report)
}

/// Start every queued request whose service can begin by `now_us`,
/// highest lane first: check the start-by deadline, select the point
/// under `min(governed budget, per-request cap)`, charge the governor
/// with virtual instants, advance the shard's busy horizon.
#[allow(clippy::too_many_arguments)]
fn drain_shard(
    shard: &mut SimShard,
    now_us: u64,
    points: &[FrontierPoint],
    device: &DeviceProfile,
    at: &dyn Fn(u64) -> Instant,
    meta: &[(usize, String, usize)],
    rec: &mut Recorder,
) -> Result<()> {
    while shard.queued > 0 && shard.free_at_us <= now_us {
        let Some(lane) = (0..N_LANES).find(|&l| !shard.lanes[l].is_empty()) else {
            break;
        };
        let Some(qe) = shard.lanes[lane].pop_front() else {
            break;
        };
        shard.queued -= 1;
        let start_us = shard.free_at_us.max(qe.offset_us);
        let (m_lane, m_tenant, m_window) = &meta[qe.event_idx];
        if let Some(d) = qe.deadline_us {
            if start_us > qe.offset_us + d {
                rec.record(*m_lane, m_tenant, *m_window, points, Outcome::Expired);
                continue;
            }
        }
        let budget = f64::from_bits(shard.budget_bits.load(Ordering::Relaxed));
        let effective = match qe.max_gflips {
            Some(cap) => budget.min(cap),
            None => budget,
        };
        let idx = shard
            .policy
            .select(effective)
            .map_err(|e| anyhow::anyhow!("policy select: {e}"))?;
        let cost = points[idx].cost_gflips;
        let service_us = device.service_us(cost);
        let done_us = start_us + service_us;
        shard.governor.batch_started(at(start_us));
        shard.governor.observe(at(done_us), idx, 1, cost, true);
        shard.governor.batch_finished(at(start_us));
        shard.free_at_us = done_us;
        rec.record(
            *m_lane,
            m_tenant,
            *m_window,
            points,
            Outcome::Served { point: idx, latency_us: done_us - qe.offset_us },
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::trace::{TraceEvent, TraceFamily, TraceParams};

    fn frontier3() -> Vec<FrontierPoint> {
        vec![
            FrontierPoint { name: "cheap".into(), cost_gflips: 0.02, acc_proxy: 0.90 },
            FrontierPoint { name: "mid".into(), cost_gflips: 0.08, acc_proxy: 0.95 },
            FrontierPoint { name: "rich".into(), cost_gflips: 0.32, acc_proxy: 0.985 },
        ]
    }

    fn manual_trace(events: Vec<TraceEvent>, duration_us: u64) -> Trace {
        Trace {
            name: "manual".into(),
            family: TraceFamily::DeadlineMix,
            seed: 0,
            duration_us,
            events,
        }
    }

    fn ev(offset_us: u64) -> TraceEvent {
        TraceEvent {
            offset_us,
            model: None,
            deadline_us: None,
            max_gflips: None,
            priority: Priority::Normal,
            affinity: None,
        }
    }

    #[test]
    fn accounting_identities_hold_on_every_family() {
        let params = TraceParams { seed: 11, events: 256, duration_us: 1_000_000, tenants: 4 };
        for family in TraceFamily::ALL {
            let trace = Trace::generate(family, &params);
            let cfg = ReplayConfig::new(DeviceProfile::server());
            let report = replay(&trace, &frontier3(), &cfg).unwrap();
            assert!(report.invariants().is_empty(), "{family:?}: {:?}", report.invariants());
            assert_eq!(report.totals.arrivals, 256);
        }
    }

    #[test]
    fn replay_is_byte_deterministic() {
        let trace = Trace::generate(TraceFamily::FlashCrowd, &TraceParams::default());
        let mut cfg = ReplayConfig::new(DeviceProfile::jetson());
        cfg.shards = 2;
        let a = replay(&trace, &frontier3(), &cfg).unwrap().to_json().to_string();
        let b = replay(&trace, &frontier3(), &cfg).unwrap().to_json().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn saturating_flood_degrades_then_recovers() {
        // 200 arrivals every 500µs: at `rich` (1.28ms service) the
        // shard saturates, so observed energy runs at the full drain
        // rate (250 GF/s) — far over the 5 GF/s envelope — and the
        // governor must step down; the trailing idle flush must climb
        // back to the top of the frontier.
        let events: Vec<TraceEvent> = (0..200).map(|i| ev(i * 500)).collect();
        let trace = manual_trace(events, 200 * 500);
        let mut cfg = ReplayConfig::new(DeviceProfile::server());
        cfg.envelope_gflips_per_sec = Some(5.0);
        let report = replay(&trace, &frontier3(), &cfg).unwrap();
        assert!(report.invariants().is_empty(), "{:?}", report.invariants());
        let g = &report.governors[0];
        assert!(g.switches >= 2, "switches {}", g.switches);
        assert_eq!(g.point, "rich", "must recover after the flood");
        let cheap_windows: u64 = g
            .residency
            .iter()
            .filter(|(n, _)| n != "rich")
            .map(|(_, w)| w)
            .sum();
        assert!(cheap_windows > 0, "residency {:?}", g.residency);
    }

    #[test]
    fn full_queue_evicts_best_effort_before_hi() {
        // One slow point (1 GF ⇒ 40ms on jetson), queue depth 1: the
        // first arrival occupies the device, the second queues, the
        // third (Hi) finds the queue full and must evict the queued
        // BestEffort instead of being shed itself.
        let slow = vec![FrontierPoint { name: "only".into(), cost_gflips: 1.0, acc_proxy: 0.9 }];
        let mut e1 = ev(0);
        e1.priority = Priority::BestEffort;
        let mut e2 = ev(1);
        e2.priority = Priority::BestEffort;
        let mut e3 = ev(2);
        e3.priority = Priority::Hi;
        let trace = manual_trace(vec![e1, e2, e3], 100_000);
        let mut cfg = ReplayConfig::new(DeviceProfile::jetson());
        cfg.queue_depth = Some(1);
        let report = replay(&trace, &slow, &cfg).unwrap();
        assert!(report.invariants().is_empty(), "{:?}", report.invariants());
        assert_eq!(report.totals.served, 2);
        assert_eq!(report.totals.shed, 1);
        let by_name: BTreeMap<_, _> =
            report.per_priority.iter().map(|(n, c)| (n.as_str(), *c)).collect();
        assert_eq!(by_name["best-effort"].shed, 1);
        assert_eq!(by_name["hi"].shed, 0);
        assert_eq!(by_name["hi"].served, 1);
    }

    #[test]
    fn start_by_deadline_expires_queued_events() {
        // The first request holds the device for 40ms; the second has
        // a 5ms start-by deadline and must expire unexecuted.
        let slow = vec![FrontierPoint { name: "only".into(), cost_gflips: 1.0, acc_proxy: 0.9 }];
        let e1 = ev(0);
        let mut e2 = ev(1);
        e2.deadline_us = Some(5_000);
        let trace = manual_trace(vec![e1, e2], 100_000);
        let cfg = ReplayConfig::new(DeviceProfile::jetson());
        let report = replay(&trace, &slow, &cfg).unwrap();
        assert_eq!(report.totals.served, 1);
        assert_eq!(report.totals.expired, 1);
        assert!(report.invariants().is_empty(), "{:?}", report.invariants());
    }

    #[test]
    fn per_request_cap_forces_the_cheap_point() {
        let mut e = ev(0);
        e.max_gflips = Some(0.05); // only `cheap` (0.02) fits
        let trace = manual_trace(vec![e], 1_000);
        let cfg = ReplayConfig::new(DeviceProfile::server());
        let report = replay(&trace, &frontier3(), &cfg).unwrap();
        assert_eq!(report.per_point[0].1, 1, "cheap must serve: {:?}", report.per_point);
        assert_eq!(report.totals.served, 1);
    }

    #[test]
    fn keyed_events_follow_the_router_rendezvous_rule() {
        // All events share one key: with 2 shards exactly one shard
        // must see traffic, and it must be the router's pick.
        let events: Vec<TraceEvent> = (0..8)
            .map(|i| {
                let mut e = ev(i * 10_000);
                e.affinity = Some("tenant-0".into());
                e
            })
            .collect();
        let trace = manual_trace(events, 100_000);
        let mut cfg = ReplayConfig::new(DeviceProfile::server());
        cfg.shards = 2;
        let report = replay(&trace, &frontier3(), &cfg).unwrap();
        // a single key maps to exactly one shard under the router's
        // rendezvous rule, and the load is light: everything serves
        assert_eq!(report.totals.served, 8);
        assert_eq!(report.totals.shed, 0);
        assert_eq!(report.per_tenant["tenant-0"].served, 8);
        let primary = crate::net::rendezvous_order("tenant-0", 2)[0];
        assert!(primary < 2);
        assert_eq!(report.governors.len(), 2);
    }

    #[test]
    fn frontier_from_menu_scales_and_dedups() {
        use crate::pann::menu::{MenuArtifact, MenuPointSpec};
        use crate::quant::ActQuantMethod;
        let point = |name: &str, gf: f64, acc: f64| MenuPointSpec {
            name: name.into(),
            bx_tilde: 4,
            r: 1.0,
            gflips_per_sample: gf,
            val_acc: acc,
            quant_method: ActQuantMethod::BnStats,
            achieved_adds_per_element: 1.0,
            weight_code_bits: 4,
            measured_gflips_per_sample: None,
            layer_bits: None,
        };
        let menu = MenuArtifact {
            model_name: "m".into(),
            model_fingerprint: 0,
            macs_per_sample: 0,
            swept: 3,
            points: vec![point("a", 0.1, 0.9), point("b", 0.1, 0.91), point("c", 0.4, 0.95)],
        };
        let device = DeviceProfile::jetson();
        let f = frontier_from_menu(&menu, &device);
        assert_eq!(f.len(), 2, "duplicate cost dropped: {f:?}");
        assert!((f[0].cost_gflips - 0.1 * device.flip_energy_scale()).abs() < 1e-12);
        assert!(f[0].cost_gflips < f[1].cost_gflips);
    }
}
