//! L4 network edge: the power–accuracy frontier over a socket.
//!
//! Everything below this module serves *in-process* callers — a
//! [`Client`](crate::coordinator::Client) handle into one
//! [`Server`](crate::coordinator::Server). This module is the boundary
//! where the paper's deployment story ("traverse the power–accuracy
//! trade-off at deployment time, no hardware changes") becomes a wire
//! protocol any load balancer or `curl` can drive:
//!
//! - [`http`] — bounded, std-only HTTP/1.1 framing (no async runtime,
//!   no TLS: thread-per-connection over [`std::net::TcpListener`]).
//! - [`wire`] — the JSON schema: `POST /v1/infer` maps 1:1 onto
//!   [`InferRequest`](crate::coordinator::InferRequest) (deadline,
//!   energy cap, priority, pin, tag, affinity), and every
//!   [`ServeError`](crate::coordinator::ServeError) variant has a
//!   fixed HTTP status and a machine-readable `kind`.
//! - [`shard`] — the [`ShardRouter`]: one logical model spread over N
//!   in-process servers, with rendezvous-hash affinity placement,
//!   deadline-aware retry of shed requests, and a cluster
//!   [`EnergyEnvelope`](crate::coordinator::EnergyEnvelope) split
//!   across shards by the same demand-weighted water-filling the
//!   multi-model fleet uses ([`crate::coordinator::arbiter`]).
//! - [`server`] — the [`NetServer`]: acceptor + bounded handler pool
//!   in front of a router, four endpoints (`/v1/infer`, `/v1/models`,
//!   `/v1/governor`, `/metrics`), graceful drain on shutdown.
//!
//! CLI: `pann-cli serve --menu MENU.json --listen 127.0.0.1:8080
//! --shards 2 --hold`.

pub mod http;
pub mod server;
pub mod shard;
pub mod wire;

pub use server::{NetConfig, NetServer};
pub use shard::{
    rendezvous_order, RouterSnapshot, ShardRouter, ShardRouterBuilder, ShardStatus, ShardTicket,
};
