//! JSON wire schema of the network edge.
//!
//! Maps the HTTP surface 1:1 onto the in-process serving API: a
//! `POST /v1/infer` body becomes an [`InferRequest`] (same fields,
//! same defaults), a [`Response`] becomes the reply object, and every
//! [`ServeError`] variant has a fixed HTTP status ([`status_of`]) and
//! a stable machine-readable `kind` ([`error_kind`]) so clients can
//! branch without parsing prose.
//!
//! The request schema is *strict*: unknown top-level keys are a 400,
//! not silently ignored — a client that misspells `max_gflips` should
//! learn about it from the first response, not from an energy bill.
//!
//! ```json
//! {
//!   "input": [0.0, 1.0, ...],      // required, flattened f32 sample
//!   "model": "cnn-s",              // optional, fleet routing
//!   "deadline_ms": 50,             // optional, start-by deadline
//!   "max_gflips": 0.5,             // optional, per-request energy cap
//!   "priority": "hi",              // optional: hi | normal | best-effort
//!   "pin": "b2",                   // optional, pin an operating point
//!   "tag": "trace-17",             // optional, echoed back
//!   "affinity": "user-42"         // optional, shard stickiness key
//! }
//! ```

// Request-handling surface: panics are banned (see clippy.toml);
// fail with a typed `ServeError` instead.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use std::time::Duration;

use super::http::HttpError;
use crate::coordinator::{InferRequest, Priority, Response, ServeError};
use crate::util::Json;

/// HTTP status for a [`ServeError`]. Client-side mistakes (bad input,
/// unknown names) map to 4xx, capacity and lifecycle to 503/408, and
/// server-side configuration or engine failures to 500.
pub fn status_of(e: &ServeError) -> u16 {
    match e {
        ServeError::QueueFull { .. } | ServeError::ServerStopped => 503,
        ServeError::DeadlineExceeded => 408,
        ServeError::BadInput { .. } | ServeError::BadBudget | ServeError::ModelRequired => 400,
        ServeError::UnknownPoint(_) | ServeError::UnknownModel(_) => 404,
        ServeError::Engine(_) | ServeError::BadMenu(_) | ServeError::Internal(_) => 500,
    }
}

/// Stable machine-readable kind label for a [`ServeError`].
pub fn error_kind(e: &ServeError) -> &'static str {
    match e {
        ServeError::QueueFull { .. } => "queue_full",
        ServeError::DeadlineExceeded => "deadline_exceeded",
        ServeError::BadInput { .. } => "bad_input",
        ServeError::UnknownPoint(_) => "unknown_point",
        ServeError::ServerStopped => "server_stopped",
        ServeError::Engine(_) => "engine",
        ServeError::BadMenu(_) => "bad_menu",
        ServeError::BadBudget => "bad_budget",
        ServeError::UnknownModel(_) => "unknown_model",
        ServeError::ModelRequired => "model_required",
        ServeError::Internal(_) => "internal",
    }
}

/// JSON error body for a [`ServeError`]:
/// `{"error": {"kind": ..., "status": ..., "message": ...}}`.
pub fn serve_error_body(e: &ServeError) -> Json {
    error_body(status_of(e), error_kind(e), &e.to_string())
}

/// JSON error body for a framing/schema failure ([`HttpError`]).
pub fn http_error_body(e: &HttpError) -> Json {
    let kind = match e.status {
        400 => "bad_request",
        404 => "not_found",
        405 => "method_not_allowed",
        408 => "timeout",
        413 => "payload_too_large",
        501 => "not_implemented",
        503 => "overloaded",
        _ => "error",
    };
    error_body(e.status, kind, &e.msg)
}

fn error_body(status: u16, kind: &str, message: &str) -> Json {
    Json::obj(vec![(
        "error",
        Json::obj(vec![
            ("kind", Json::from(kind)),
            ("status", Json::from(status as usize)),
            ("message", Json::from(message)),
        ]),
    )])
}

fn field_err(key: &str, want: &str) -> HttpError {
    HttpError::new(400, format!("field '{key}' must be {want}"))
}

/// Parse a strict `POST /v1/infer` body into an [`InferRequest`].
/// Unknown top-level keys, wrong types and non-finite/negative
/// `deadline_ms` are all 400s; `max_gflips` passes through verbatim
/// (the server's own `BadBudget` check covers NaN).
pub fn parse_infer(body: &str) -> Result<InferRequest, HttpError> {
    let doc = Json::parse(body).map_err(|e| HttpError::new(400, e.to_string()))?;
    let obj = doc
        .as_obj()
        .ok_or_else(|| HttpError::new(400, "request body must be a JSON object"))?;
    const KNOWN: [&str; 8] =
        ["input", "model", "deadline_ms", "max_gflips", "priority", "pin", "tag", "affinity"];
    if let Some(k) = obj.keys().find(|k| !KNOWN.contains(&k.as_str())) {
        return Err(HttpError::new(400, format!("unknown field '{k}'")));
    }
    let input = obj
        .get("input")
        .ok_or_else(|| HttpError::new(400, "missing required field 'input'"))?
        .as_arr()
        .ok_or_else(|| field_err("input", "an array of numbers"))?
        .iter()
        .map(|v| v.as_f64().map(|n| n as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| field_err("input", "an array of numbers"))?;
    let mut req = InferRequest::new(input);
    if let Some(v) = obj.get("model") {
        req = req.model(v.as_str().ok_or_else(|| field_err("model", "a string"))?);
    }
    if let Some(v) = obj.get("deadline_ms") {
        let ms = v.as_f64().ok_or_else(|| field_err("deadline_ms", "a number"))?;
        if !ms.is_finite() || ms < 0.0 {
            return Err(field_err("deadline_ms", "a finite non-negative number"));
        }
        req = req.deadline(Duration::from_secs_f64(ms / 1e3));
    }
    if let Some(v) = obj.get("max_gflips") {
        req = req.max_gflips(v.as_f64().ok_or_else(|| field_err("max_gflips", "a number"))?);
    }
    if let Some(v) = obj.get("priority") {
        let p = match v.as_str() {
            Some("hi") => Priority::Hi,
            Some("normal") => Priority::Normal,
            Some("best-effort") => Priority::BestEffort,
            _ => return Err(field_err("priority", "one of 'hi', 'normal', 'best-effort'")),
        };
        req = req.priority(p);
    }
    if let Some(v) = obj.get("pin") {
        req = req.pin_point(v.as_str().ok_or_else(|| field_err("pin", "a string"))?);
    }
    if let Some(v) = obj.get("tag") {
        req = req.tag(v.as_str().ok_or_else(|| field_err("tag", "a string"))?);
    }
    if let Some(v) = obj.get("affinity") {
        req = req.affinity(v.as_str().ok_or_else(|| field_err("affinity", "a string"))?);
    }
    Ok(req)
}

fn opt_str(s: &Option<String>) -> Json {
    match s {
        Some(s) => Json::from(s.as_str()),
        None => Json::Null,
    }
}

/// Serialize one served [`Response`], stamped with the shard that
/// executed it.
pub fn response_json(shard: usize, r: &Response) -> Json {
    Json::obj(vec![
        ("output", Json::nums(r.output.iter().map(|&x| x as f64))),
        ("model", opt_str(&r.model)),
        ("point", Json::from(r.point.as_str())),
        ("latency_us", Json::from(r.latency.as_micros() as f64)),
        ("giga_flips", Json::from(r.giga_flips)),
        (
            "measured_gflips",
            match r.measured_gflips {
                Some(g) => Json::from(g),
                None => Json::Null,
            },
        ),
        ("tag", opt_str(&r.tag)),
        ("shard", Json::from(shard)),
    ])
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;

    #[test]
    fn serve_errors_map_to_expected_statuses() {
        let cases = [
            (ServeError::QueueFull { depth: 8 }, 503, "queue_full"),
            (ServeError::DeadlineExceeded, 408, "deadline_exceeded"),
            (ServeError::BadInput { expected: 4, got: 2 }, 400, "bad_input"),
            (ServeError::UnknownPoint("x".into()), 404, "unknown_point"),
            (ServeError::ServerStopped, 503, "server_stopped"),
            (ServeError::Engine("boom".into()), 500, "engine"),
            (ServeError::BadMenu("empty".into()), 500, "bad_menu"),
            (ServeError::BadBudget, 400, "bad_budget"),
            (ServeError::UnknownModel("ghost".into()), 404, "unknown_model"),
            (ServeError::ModelRequired, 400, "model_required"),
            (ServeError::Internal("queue poisoned".into()), 500, "internal"),
        ];
        for (e, status, kind) in cases {
            assert_eq!(status_of(&e), status, "{e}");
            assert_eq!(error_kind(&e), kind, "{e}");
            let body = serve_error_body(&e);
            let err = body.get("error").unwrap();
            assert_eq!(err.get("status").unwrap().as_usize(), Some(status as usize));
            assert_eq!(err.get("kind").unwrap().as_str(), Some(kind));
        }
    }

    #[test]
    fn parse_full_request() {
        let r = parse_infer(
            r#"{"input": [1, 2.5], "model": "cnn-s", "deadline_ms": 50,
                "max_gflips": 0.5, "priority": "hi", "pin": "b2",
                "tag": "t1", "affinity": "user-42"}"#,
        )
        .unwrap();
        assert_eq!(r.input, vec![1.0f32, 2.5]);
        assert_eq!(r.model.as_deref(), Some("cnn-s"));
        assert_eq!(r.deadline, Some(Duration::from_millis(50)));
        assert_eq!(r.max_gflips, Some(0.5));
        assert_eq!(r.priority, Priority::Hi);
        assert_eq!(r.pin.as_deref(), Some("b2"));
        assert_eq!(r.tag.as_deref(), Some("t1"));
        assert_eq!(r.affinity.as_deref(), Some("user-42"));
    }

    #[test]
    fn parse_minimal_request_defaults() {
        let r = parse_infer(r#"{"input": []}"#).unwrap();
        assert!(r.input.is_empty());
        assert!(r.model.is_none() && r.deadline.is_none() && r.max_gflips.is_none());
        assert_eq!(r.priority, Priority::Normal);
    }

    #[test]
    fn parse_rejects_bad_bodies_with_400() {
        for body in [
            "not json at all",
            "[1, 2]",                                   // not an object
            "{}",                                       // missing input
            r#"{"input": "nope"}"#,                     // wrong input type
            r#"{"input": [1, "x"]}"#,                   // non-numeric element
            r#"{"input": [], "max_gflipz": 1}"#,        // misspelled key
            r#"{"input": [], "priority": "urgent"}"#,   // unknown class
            r#"{"input": [], "deadline_ms": -5}"#,      // negative deadline
            r#"{"input": [], "deadline_ms": "soon"}"#,  // wrong deadline type
            r#"{"input": [], "pin": 3}"#,               // wrong pin type
        ] {
            let e = parse_infer(body).unwrap_err();
            assert_eq!(e.status, 400, "{body} -> {e}");
        }
    }

    #[test]
    fn response_round_trips_through_json() {
        let resp = Response {
            output: vec![1.5, -2.0],
            model: Some("cnn-s".into()),
            point: "b2".into(),
            latency: Duration::from_micros(730),
            giga_flips: 0.25,
            measured_gflips: None,
            tag: Some("t1".into()),
        };
        let j = response_json(1, &resp);
        let j = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j.get("point").unwrap().as_str(), Some("b2"));
        assert_eq!(j.get("model").unwrap().as_str(), Some("cnn-s"));
        assert_eq!(j.get("latency_us").unwrap().as_f64(), Some(730.0));
        assert_eq!(j.get("shard").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("measured_gflips"), Some(&Json::Null));
        let out = j.get("output").unwrap().as_arr().unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_f64(), Some(1.5));
    }
}
