//! The HTTP edge: a [`ShardRouter`] behind a socket.
//!
//! Std-only by design — [`std::net::TcpListener`], a fixed pool of
//! blocking handler threads, and a bounded accept→handler channel. No
//! async runtime: the serving hot path is already thread-per-worker
//! inside each shard, the edge only has to keep a handful of
//! connections fed, and the offline registry stays empty. Back
//! pressure is explicit at both layers: a full handler channel answers
//! `503` at accept time, a full shard queue is retried/shed by the
//! router ([`ServeError::QueueFull`] → `503` with a JSON body).
//!
//! Endpoints:
//!
//! | method+path       | answer                                        |
//! |-------------------|-----------------------------------------------|
//! | `POST /v1/infer`  | run one request ([`super::wire`] schema)      |
//! | `GET /v1/models`  | registered models, shard count, sample length |
//! | `GET /v1/governor`| cluster envelope + per-shard governor state   |
//! | `GET /metrics`    | Prometheus-style text counters                |
//!
//! Shutdown is graceful: [`NetServer::shutdown`] stops the acceptor
//! (waking its blocking `accept` with a loopback self-connect), lets
//! every handler finish the request it is serving, joins all threads,
//! and only then shuts the shards down — no admitted request is
//! dropped.
//!
//! [`ServeError::QueueFull`]: crate::coordinator::ServeError::QueueFull

// Request-handling surface: panics are banned (see clippy.toml);
// answer errors over the wire instead.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::http::{self, HttpError, HttpRequest, ReadOutcome};
use super::shard::ShardRouter;
use super::wire;
use crate::util::Json;

/// Tuning knobs of a [`NetServer`].
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Connection-handler threads (each serves one connection at a
    /// time, keep-alive included).
    pub handler_threads: usize,
    /// Largest accepted request body, bytes (413 beyond).
    pub max_body: usize,
    /// Accepted-but-unhandled connection backlog; connections beyond
    /// it are answered `503` at accept time.
    pub pending_conns: usize,
    /// How often an idle keep-alive handler wakes to poll the stop
    /// flag (the socket read timeout).
    pub idle_poll: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            handler_threads: 4,
            max_body: 4 << 20,
            pending_conns: 64,
            idle_poll: Duration::from_millis(250),
        }
    }
}

/// Edge-level counters, reported on `/metrics`.
#[derive(Default)]
struct NetStats {
    /// HTTP requests parsed (any endpoint, any outcome).
    requests: AtomicU64,
    /// Responses with a 4xx/5xx status, accept-time 503s included.
    errors: AtomicU64,
}

struct EdgeState {
    router: ShardRouter,
    stats: NetStats,
    stop: Arc<AtomicBool>,
    max_body: usize,
    idle_poll: Duration,
}

/// The HTTP edge server. Bind with [`NetServer::bind`], stop with
/// [`NetServer::shutdown`] (dropping it shuts down too).
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
    state: Option<Arc<EdgeState>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// serve `router` on it with `config`'s pool sizes.
    pub fn bind(
        addr: impl ToSocketAddrs,
        router: ShardRouter,
        config: NetConfig,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).context("binding the edge listener")?;
        let local = listener.local_addr().context("reading the bound address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(EdgeState {
            router,
            stats: NetStats::default(),
            stop: stop.clone(),
            max_body: config.max_body,
            idle_poll: config.idle_poll,
        });
        let (tx, rx) = mpsc::sync_channel::<TcpStream>(config.pending_conns.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let mut handlers = Vec::with_capacity(config.handler_threads.max(1));
        for i in 0..config.handler_threads.max(1) {
            let rx = rx.clone();
            let state = state.clone();
            handlers.push(
                std::thread::Builder::new()
                    .name(format!("pann-edge-{i}"))
                    .spawn(move || loop {
                        // hold the lock only to dequeue, not to serve;
                        // a poisoned guard (a sibling handler panicked
                        // mid-recv) is recovered — the channel itself
                        // is still consistent
                        let conn = rx
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .recv();
                        match conn {
                            Ok(stream) => handle_connection(stream, &state),
                            Err(_) => break, // acceptor gone: drained
                        }
                    })
                    .context("spawning an edge handler")?,
            );
        }
        let acceptor = {
            let state = state.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("pann-edge-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break; // the wake-up self-connect lands here
                        }
                        let stream = match conn {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        match tx.try_send(stream) {
                            Ok(()) => {}
                            Err(mpsc::TrySendError::Full(stream)) => {
                                // overloaded: answer 503 inline rather
                                // than queueing unboundedly
                                state.stats.errors.fetch_add(1, Ordering::Relaxed);
                                let body = wire::http_error_body(&HttpError::new(
                                    503,
                                    "connection backlog full",
                                ))
                                .to_string();
                                let mut w = &stream;
                                let _ = http::write_response(
                                    &mut w,
                                    503,
                                    "application/json",
                                    body.as_bytes(),
                                    true,
                                );
                            }
                            Err(mpsc::TrySendError::Disconnected(_)) => break,
                        }
                    }
                    // dropping tx here lets handlers drain and exit
                })
                .context("spawning the edge acceptor")?
        };
        Ok(NetServer { addr: local, stop, acceptor: Some(acceptor), handlers, state: Some(state) })
    }

    /// The bound address (with the real port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful stop: no new connections, in-flight requests finish,
    /// every thread joins, then the shards shut down.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if self.state.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // wake the acceptor out of its blocking accept
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
        if let Some(state) = self.state.take() {
            if let Ok(state) = Arc::try_unwrap(state) {
                state.router.shutdown();
            }
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Serve one connection until it closes, errors, or the server stops.
fn handle_connection(stream: TcpStream, state: &EdgeState) {
    let _ = stream.set_read_timeout(Some(state.idle_poll));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let mut reader = BufReader::new(&stream);
    let mut writer = &stream;
    loop {
        let req = match http::read_request(&mut reader, state.max_body) {
            Ok(ReadOutcome::Request(r)) => r,
            Ok(ReadOutcome::Closed) => return,
            Ok(ReadOutcome::Idle) => {
                if state.stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(e) => {
                // framing failure: answer what we can, then drop the
                // connection — the stream offset is unreliable now
                state.stats.requests.fetch_add(1, Ordering::Relaxed);
                state.stats.errors.fetch_add(1, Ordering::Relaxed);
                let body = wire::http_error_body(&e).to_string();
                let _ = http::write_response(
                    &mut writer,
                    e.status,
                    "application/json",
                    body.as_bytes(),
                    true,
                );
                return;
            }
        };
        state.stats.requests.fetch_add(1, Ordering::Relaxed);
        let close = req.wants_close() || state.stop.load(Ordering::SeqCst);
        let (status, content_type, body) = route(state, &req);
        if status >= 400 {
            state.stats.errors.fetch_add(1, Ordering::Relaxed);
        }
        let sent = http::write_response(&mut writer, status, content_type, body.as_bytes(), close);
        if sent.is_err() || close {
            return;
        }
    }
}

/// Dispatch one parsed request to its endpoint.
fn route(state: &EdgeState, req: &HttpRequest) -> (u16, &'static str, String) {
    let path = req.path.split('?').next().unwrap_or("");
    let want = match path {
        "/v1/infer" => "POST",
        "/v1/models" | "/v1/governor" | "/metrics" => "GET",
        _ => return err(HttpError::new(404, format!("no such endpoint: {path}"))),
    };
    if req.method != want {
        return err(HttpError::new(
            405,
            format!("{} is not supported on {path} (use {want})", req.method),
        ));
    }
    match path {
        "/v1/infer" => infer(state, req),
        "/v1/models" => (200, "application/json", models_json(state).to_string()),
        "/v1/governor" => (200, "application/json", governor_json(state).to_string()),
        _ => (200, "text/plain; version=0.0.4", metrics_text(state)),
    }
}

fn err(e: HttpError) -> (u16, &'static str, String) {
    (e.status, "application/json", wire::http_error_body(&e).to_string())
}

fn infer(state: &EdgeState, req: &HttpRequest) -> (u16, &'static str, String) {
    let body = match req.body_str().and_then(wire::parse_infer) {
        Ok(r) => r,
        Err(e) => return err(e),
    };
    // wait inline: the handler thread *is* this request's thread
    let answered = state.router.submit(body).and_then(|t| {
        let shard = t.shard;
        t.wait().map(|resp| (shard, resp))
    });
    match answered {
        Ok((shard, resp)) => {
            (200, "application/json", wire::response_json(shard, &resp).to_string())
        }
        Err(e) => {
            (wire::status_of(&e), "application/json", wire::serve_error_body(&e).to_string())
        }
    }
}

fn models_json(state: &EdgeState) -> Json {
    let c = state.router.primary();
    Json::obj(vec![
        (
            "models",
            Json::Arr(c.models().into_iter().map(Json::from).collect()),
        ),
        ("shards", Json::from(state.router.n_shards())),
        ("sample_len", Json::from(c.sample_len())),
        ("budget_gflips", Json::from(c.budget())),
    ])
}

fn governor_json(state: &EdgeState) -> Json {
    let snap = state.router.snapshot();
    Json::obj(vec![
        (
            "envelope_gflips_per_sec",
            snap.envelope_rate.map(Json::from).unwrap_or(Json::Null),
        ),
        (
            "shards",
            Json::Arr(
                snap.shards
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            (
                                "share_gflips_per_sec",
                                s.envelope_share.map(Json::from).unwrap_or(Json::Null),
                            ),
                            (
                                "demand_samples_per_sec",
                                s.demand_rate.map(Json::from).unwrap_or(Json::Null),
                            ),
                            (
                                "governor",
                                match &s.governor {
                                    None => Json::Null,
                                    Some(g) => Json::obj(vec![
                                        ("point", Json::from(g.point.as_str())),
                                        ("level", Json::from(g.level)),
                                        ("switches", Json::from(g.switches as f64)),
                                        ("windows", Json::from(g.windows as f64)),
                                        (
                                            "target_gflips_per_window",
                                            Json::from(g.target_gflips_per_window),
                                        ),
                                    ]),
                                },
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn metrics_text(state: &EdgeState) -> String {
    let snap = state.router.snapshot();
    let mut out = String::new();
    out.push_str(&format!(
        "pann_http_requests_total {}\n",
        state.stats.requests.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "pann_http_errors_total {}\n",
        state.stats.errors.load(Ordering::Relaxed)
    ));
    if let Some(rate) = snap.envelope_rate {
        out.push_str(&format!("pann_envelope_gflips_per_sec {rate}\n"));
    }
    for (i, s) in snap.shards.iter().enumerate() {
        out.push_str(&format!("pann_shard_requests_total{{shard=\"{i}\"}} {}\n", s.requests));
        out.push_str(&format!("pann_shard_shed_total{{shard=\"{i}\"}} {}\n", s.shed));
        out.push_str(&format!("pann_shard_retries_total{{shard=\"{i}\"}} {}\n", s.retries));
        out.push_str(&format!("pann_shard_queue_depth{{shard=\"{i}\"}} {}\n", s.queue_depth));
        out.push_str(&format!(
            "pann_shard_expired_total{{shard=\"{i}\"}} {}\n",
            s.metrics.expired
        ));
        if let Some(share) = s.envelope_share {
            out.push_str(&format!(
                "pann_shard_envelope_share_gflips_per_sec{{shard=\"{i}\"}} {share}\n"
            ));
        }
        if let Some(rate) = s.demand_rate {
            out.push_str(&format!(
                "pann_shard_demand_samples_per_sec{{shard=\"{i}\"}} {rate}\n"
            ));
        }
        // operating-point residency: where on the frontier this
        // shard's requests actually ran
        for (point, served) in &s.metrics.per_point {
            out.push_str(&format!(
                "pann_point_residency_total{{shard=\"{i}\",point=\"{point}\"}} {served}\n"
            ));
        }
    }
    out
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;
    use crate::coordinator::server::tests_support::MockEngine;
    use crate::coordinator::{Menu, Server, SharedPoint};
    use std::io::{Read, Write};

    fn bind_mock(n_shards: usize) -> NetServer {
        let router = ShardRouter::builder()
            .build(n_shards, |_, _| {
                let menu = Menu::shared(vec![SharedPoint {
                    measured_gflips_per_sample: None,
                    name: "p".into(),
                    giga_flips_per_sample: 1.0,
                    engine: std::sync::Arc::new(MockEngine::new(4, 2, 1)),
                }]);
                Server::builder().workers(1).queue_depth(8).serve(menu)
            })
            .unwrap();
        let cfg = NetConfig { handler_threads: 2, ..NetConfig::default() };
        NetServer::bind("127.0.0.1:0", router, cfg).unwrap()
    }

    /// One raw HTTP exchange over a fresh connection.
    fn call(addr: SocketAddr, raw: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let status: u16 = buf
            .split_whitespace()
            .nth(1)
            .expect("status line")
            .parse()
            .expect("numeric status");
        let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    fn post_infer(addr: SocketAddr, json: &str) -> (u16, String) {
        call(
            addr,
            &format!(
                "POST /v1/infer HTTP/1.1\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{}",
                json.len(),
                json
            ),
        )
    }

    #[test]
    fn serves_infer_models_governor_and_metrics() {
        let srv = bind_mock(2);
        let addr = srv.local_addr();

        let (status, body) = post_infer(addr, r#"{"input": [2, 3], "tag": "t1"}"#);
        assert_eq!(status, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("output").unwrap().as_arr().unwrap()[0].as_f64(), Some(5.0));
        assert_eq!(j.get("point").unwrap().as_str(), Some("p"));
        assert_eq!(j.get("tag").unwrap().as_str(), Some("t1"));
        assert!(j.get("shard").unwrap().as_usize().unwrap() < 2);

        let (status, body) = call(addr, "GET /v1/models HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("sample_len").unwrap().as_usize(), Some(2));

        let (status, body) = call(addr, "GET /v1/governor HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("shards").unwrap().as_arr().unwrap().len(), 2);

        let (status, body) = call(addr, "GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert_eq!(status, 200);
        assert!(body.contains("pann_http_requests_total"), "{body}");
        assert!(body.contains("pann_shard_requests_total{shard=\"0\"}"), "{body}");
        assert!(body.contains("pann_point_residency_total{shard="), "{body}");

        srv.shutdown();
    }

    #[test]
    fn maps_wire_and_routing_failures_to_statuses() {
        let srv = bind_mock(1);
        let addr = srv.local_addr();

        // malformed JSON body
        let (status, body) = post_infer(addr, "{not json");
        assert_eq!(status, 400, "{body}");
        // schema violation
        let (status, _) = post_infer(addr, r#"{"input": [1, 2], "bogus": 1}"#);
        assert_eq!(status, 400);
        // unknown pinned point -> 404 via ServeError mapping
        let (status, body) = post_infer(addr, r#"{"input": [1, 2], "pin": "ghost"}"#);
        assert_eq!(status, 404, "{body}");
        assert!(body.contains("unknown_point"), "{body}");
        // a named model on a single-model server -> 404
        let (status, body) = post_infer(addr, r#"{"input": [1, 2], "model": "ghost"}"#);
        assert_eq!(status, 404, "{body}");
        assert!(body.contains("unknown_model"), "{body}");
        // already-expired deadline -> 408
        let (status, body) = post_infer(addr, r#"{"input": [1, 2], "deadline_ms": 0}"#);
        assert_eq!(status, 408, "{body}");
        // unknown path / wrong method
        let (status, _) = call(addr, "GET /nope HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert_eq!(status, 404);
        let (status, _) = call(addr, "GET /v1/infer HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert_eq!(status, 405);

        // the error counter saw all of the above
        let (_, body) = call(addr, "GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
        let errors: u64 = body
            .lines()
            .find(|l| l.starts_with("pann_http_errors_total"))
            .and_then(|l| l.split_whitespace().nth(1))
            .unwrap()
            .parse()
            .unwrap();
        assert!(errors >= 7, "expected >= 7 counted errors, metrics said {errors}");

        srv.shutdown();
    }

    #[test]
    fn keep_alive_serves_sequential_requests_on_one_connection() {
        let srv = bind_mock(1);
        let addr = srv.local_addr();
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        for k in 0..3 {
            let json = format!(r#"{{"input": [{k}, 1]}}"#);
            let raw = format!(
                "POST /v1/infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
                json.len(),
                json
            );
            s.write_all(raw.as_bytes()).unwrap();
            // read exactly one response off the still-open stream
            let mut buf = Vec::new();
            let mut byte = [0u8; 1];
            while !buf.ends_with(b"\r\n\r\n") {
                s.read_exact(&mut byte).unwrap();
                buf.push(byte[0]);
            }
            let head = String::from_utf8(buf).unwrap();
            assert!(head.starts_with("HTTP/1.1 200"), "{head}");
            let len: usize = head
                .lines()
                .find(|l| l.to_ascii_lowercase().starts_with("content-length:"))
                .and_then(|l| l.split(':').nth(1))
                .unwrap()
                .trim()
                .parse()
                .unwrap();
            let mut body = vec![0u8; len];
            s.read_exact(&mut body).unwrap();
            let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            let out = j.get("output").unwrap().as_arr().unwrap()[0].as_f64().unwrap();
            assert_eq!(out, k as f64 + 1.0);
        }
        drop(s);
        srv.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drop_safe() {
        let srv = bind_mock(1);
        let addr = srv.local_addr();
        srv.shutdown();
        // the port is released: a fresh bind on the same address works
        let l = TcpListener::bind(addr);
        assert!(l.is_ok(), "address not released after shutdown");
        drop(l);
        // dropping without shutdown must also stop cleanly
        let srv = bind_mock(1);
        drop(srv);
    }
}
