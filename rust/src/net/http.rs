//! Minimal HTTP/1.1 framing for the network edge.
//!
//! Just enough of RFC 9112 for `curl`, load generators and the
//! loopback tests to speak to [`super::server::NetServer`]: request
//! line + headers + `Content-Length` bodies in, fixed-length responses
//! out, keep-alive by default. Deliberately *not* implemented: chunked
//! transfer encoding (501), HTTP/2, TLS — the edge targets trusted
//! LANs and loopback, and the offline registry carries no TLS or async
//! dependencies (the acceptor is plain [`std::net::TcpListener`]).
//!
//! Reads are bounded everywhere: the head is capped at
//! [`MAX_HEAD_BYTES`], header count at [`MAX_HEADERS`], and the body
//! at the caller's limit (413 beyond it) — a malicious peer cannot
//! buffer unbounded memory. With a read timeout set on the socket,
//! [`read_request`] distinguishes an *idle* keep-alive connection
//! (no bytes yet — [`ReadOutcome::Idle`], poll your stop flag and try
//! again) from a peer that stalled mid-request (408).

// Request-handling surface: panics are banned (see clippy.toml);
// fail with typed errors instead.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use std::io::{self, BufRead, Read, Write};

/// Upper bound on the request head: request line plus all headers.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Upper bound on the number of request headers.
pub const MAX_HEADERS: usize = 64;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    /// Request method, upper-case as sent (`GET`, `POST`, …).
    pub method: String,
    /// Request target (path + optional query), as sent.
    pub path: String,
    /// Header `(name, value)` pairs in arrival order.
    pub headers: Vec<(String, String)>,
    /// The request body (empty without a `Content-Length`).
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header value under `name`, case-insensitively.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Whether the peer asked to close the connection after this
    /// exchange (`Connection: close`).
    pub fn wants_close(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }

    /// The body as UTF-8, or a 400 [`HttpError`].
    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::new(400, "request body is not valid UTF-8"))
    }
}

/// A framing-level failure, carrying the status the peer should see.
#[derive(Debug)]
pub struct HttpError {
    /// HTTP status to answer with (400, 408, 413, 501, …).
    pub status: u16,
    /// Human-readable cause, safe to echo to the peer.
    pub msg: String,
}

impl HttpError {
    /// An error answering `status` with `msg`.
    pub fn new(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError { status, msg: msg.into() }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "http {}: {}", self.status, self.msg)
    }
}

impl std::error::Error for HttpError {}

/// What one read attempt on a kept-alive connection produced.
pub enum ReadOutcome {
    /// A complete request.
    Request(HttpRequest),
    /// Clean EOF before any byte of a next request: the peer closed.
    Closed,
    /// The socket's read timeout expired before any byte arrived —
    /// the connection is idle, not broken; poll your stop flag and
    /// call [`read_request`] again.
    Idle,
}

enum Line {
    Text(String),
    Eof,
    Timeout,
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Read one CRLF-terminated line, bounded at `max` bytes.
fn read_line(r: &mut impl BufRead, max: usize) -> Result<Line, HttpError> {
    let mut buf = Vec::new();
    // the +2 leaves room for the CRLF of a line of exactly `max` bytes
    match r.take(max as u64 + 2).read_until(b'\n', &mut buf) {
        Ok(0) => {
            if buf.is_empty() {
                Ok(Line::Eof)
            } else {
                Err(HttpError::new(400, "truncated request head"))
            }
        }
        Ok(_) => {
            if buf.last() != Some(&b'\n') {
                // either EOF mid-line or the bound was hit first
                return Err(HttpError::new(400, "request head line too long or truncated"));
            }
            while matches!(buf.last(), Some(b'\n' | b'\r')) {
                buf.pop();
            }
            String::from_utf8(buf)
                .map(Line::Text)
                .map_err(|_| HttpError::new(400, "request head is not valid UTF-8"))
        }
        Err(e) if is_timeout(&e) => {
            if buf.is_empty() {
                Ok(Line::Timeout)
            } else {
                Err(HttpError::new(408, "timed out mid-request"))
            }
        }
        Err(e) => Err(HttpError::new(400, format!("read failed: {e}"))),
    }
}

/// Read and parse one request from `r`, with the body bounded at
/// `max_body` bytes (413 beyond it). See [`ReadOutcome`] for the
/// idle/EOF cases; every malformed head is a 400 [`HttpError`].
pub fn read_request(r: &mut impl BufRead, max_body: usize) -> Result<ReadOutcome, HttpError> {
    let line = match read_line(r, MAX_HEAD_BYTES)? {
        Line::Eof => return Ok(ReadOutcome::Closed),
        Line::Timeout => return Ok(ReadOutcome::Idle),
        Line::Text(l) => l,
    };
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::new(400, "empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| HttpError::new(400, "request line has no target"))?
        .to_string();
    let version = parts
        .next()
        .ok_or_else(|| HttpError::new(400, "request line has no version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(400, format!("unsupported version '{version}'")));
    }
    let mut headers = Vec::new();
    loop {
        let line = match read_line(r, MAX_HEAD_BYTES)? {
            Line::Text(l) => l,
            // EOF or a stall inside the head is a broken request, not
            // an idle connection
            Line::Eof => return Err(HttpError::new(400, "EOF inside request head")),
            Line::Timeout => return Err(HttpError::new(408, "timed out inside request head")),
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::new(400, "too many headers"));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, "malformed header line"))?;
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }
    let req = HttpRequest { method, path, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(HttpError::new(501, "transfer-encoding is not supported"));
    }
    let len = match req.header("content-length") {
        None => 0usize,
        Some(v) => v
            .trim()
            .parse::<usize>()
            .map_err(|_| HttpError::new(400, "bad content-length"))?,
    };
    if len > max_body {
        return Err(HttpError::new(
            413,
            format!("body of {len} bytes exceeds the {max_body}-byte limit"),
        ));
    }
    let mut req = req;
    if len > 0 {
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).map_err(|e| {
            if is_timeout(&e) {
                HttpError::new(408, "timed out reading request body")
            } else {
                HttpError::new(400, format!("short body: {e}"))
            }
        })?;
        req.body = body;
    }
    Ok(ReadOutcome::Request(req))
}

/// Canonical reason phrase for the statuses this edge emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one fixed-length response; `close` adds `Connection: close`.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    close: bool,
) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        status,
        reason(status),
        content_type,
        body.len()
    )?;
    if close {
        w.write_all(b"Connection: close\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<ReadOutcome, HttpError> {
        read_request(&mut BufReader::new(raw.as_bytes()), 1024)
    }

    fn request(raw: &str) -> HttpRequest {
        match parse(raw) {
            Ok(ReadOutcome::Request(r)) => r,
            other => panic!(
                "expected a request, got {:?}",
                other.map(|_| "non-request outcome")
            ),
        }
    }

    #[test]
    fn parses_post_with_body_and_headers() {
        let r = request(
            "POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
             Content-Length: 11\r\n\r\n{\"input\":1}",
        );
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/infer");
        assert_eq!(r.header("content-type"), Some("application/json"));
        assert_eq!(r.header("CONTENT-TYPE"), Some("application/json"));
        assert_eq!(r.body_str().unwrap(), "{\"input\":1}");
        assert!(!r.wants_close());
    }

    #[test]
    fn parses_get_without_body_and_connection_close() {
        let r = request("GET /metrics HTTP/1.0\r\nConnection: close\r\n\r\n");
        assert_eq!(r.method, "GET");
        assert!(r.body.is_empty());
        assert!(r.wants_close());
    }

    #[test]
    fn eof_before_any_byte_is_closed() {
        assert!(matches!(parse("").unwrap(), ReadOutcome::Closed));
    }

    #[test]
    fn malformed_heads_are_400() {
        for raw in [
            "GARBAGE\r\n\r\n",                        // no target/version
            "GET /\r\n\r\n",                          // no version
            "GET / SPDY/3\r\n\r\n",                   // wrong protocol
            "GET / HTTP/1.1\r\nno-colon-here\r\n\r\n", // bad header
            "POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n", // bad length
            "POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort", // truncated body
        ] {
            let e = parse(raw).unwrap_err();
            assert_eq!(e.status, 400, "{raw:?} -> {e}");
        }
    }

    #[test]
    fn oversized_body_is_413_and_chunked_is_501() {
        let e = parse("POST / HTTP/1.1\r\nContent-Length: 9999\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 413);
        let e = parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert_eq!(e.status, 501);
    }

    #[test]
    fn overlong_head_line_is_400() {
        let raw = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_HEAD_BYTES));
        assert_eq!(parse(&raw).unwrap_err().status, 400);
    }

    /// Reader that yields `WouldBlock`, as a timed-out socket does.
    struct TimeoutReader;
    impl io::Read for TimeoutReader {
        fn read(&mut self, _buf: &mut [u8]) -> io::Result<usize> {
            Err(io::Error::new(io::ErrorKind::WouldBlock, "timed out"))
        }
    }

    #[test]
    fn timeout_before_any_byte_is_idle() {
        let mut r = BufReader::new(TimeoutReader);
        assert!(matches!(read_request(&mut r, 1024).unwrap(), ReadOutcome::Idle));
    }

    #[test]
    fn timeout_mid_request_is_408() {
        // head arrives, then the body stalls
        let head = "POST / HTTP/1.1\r\nContent-Length: 5\r\n\r\n";
        let mut r = BufReader::new(head.as_bytes().chain(TimeoutReader));
        assert_eq!(read_request(&mut r, 1024).unwrap_err().status, 408);
    }

    #[test]
    fn write_response_frames_and_closes() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", false).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(!s.contains("Connection: close"));
        assert!(s.ends_with("\r\n\r\n{}"));
        let mut out = Vec::new();
        write_response(&mut out, 503, "text/plain", b"busy", true).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(s.contains("Connection: close\r\n"));
    }

    #[test]
    fn keep_alive_reads_two_requests_off_one_stream() {
        let raw = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut r = BufReader::new(raw.as_bytes());
        let a = match read_request(&mut r, 64).unwrap() {
            ReadOutcome::Request(a) => a,
            _ => panic!("first request"),
        };
        let b = match read_request(&mut r, 64).unwrap() {
            ReadOutcome::Request(b) => b,
            _ => panic!("second request"),
        };
        assert_eq!((a.path.as_str(), b.path.as_str()), ("/a", "/b"));
        assert!(matches!(read_request(&mut r, 64).unwrap(), ReadOutcome::Closed));
    }
}
