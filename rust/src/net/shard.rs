//! Shard router: one logical model spread across N in-process
//! [`Server`]s.
//!
//! Each shard is a full coordinator stack — its own worker pool,
//! bounded queue, metrics and (under an envelope) its own
//! [`Governor`] — built by a caller-supplied factory so every shard
//! compiles its own engine instances (engines are `Arc`-shared *plans*,
//! so the memory cost is workers, not weights). The router in front
//! adds three things:
//!
//! - **Placement**: a request carrying an
//!   [affinity key](crate::coordinator::InferRequest::affinity) lands
//!   on the shard that wins rendezvous (highest-random-weight) hashing
//!   over `(key, shard)` — the same key always goes to the same shard
//!   while shards stay fixed, and removing a shard only remaps the
//!   keys that lived on it. Keyless requests spread round-robin.
//! - **Shed retry**: a shard that answers [`ServeError::QueueFull`]
//!   (or died: [`ServeError::ServerStopped`]) is not the end — the
//!   router walks the remaining shards in rendezvous order, shrinking
//!   the request's relative deadline by the time already burned, and
//!   only reports the shed when every shard refused or the deadline
//!   ran out first.
//! - **Envelope split**: under a cluster [`EnergyEnvelope`] the router
//!   feeds admitted samples to an [`EnvelopeSplitter`] and re-targets
//!   every shard's governor with its demand-weighted share at each
//!   window boundary — a hot shard degrades down its frontier before a
//!   cold one starves, exactly as fleet models do under the registry's
//!   arbiter.
//!
//! [`Governor`]: crate::coordinator::Governor

// Request-handling surface: panics are banned (see clippy.toml);
// fail with typed errors instead.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::{
    Client, EnergyEnvelope, EnvelopeSplitter, GovernorSnapshot, InferRequest, MetricsSnapshot,
    Response, ServeError, Server, Ticket,
};

/// Builder for a [`ShardRouter`].
pub struct ShardRouterBuilder {
    envelope: Option<(f64, f64)>, // (cluster Gflips/sec, top Gflips/sample)
    window: Duration,
}

impl Default for ShardRouterBuilder {
    fn default() -> Self {
        ShardRouterBuilder::new()
    }
}

impl ShardRouterBuilder {
    /// A router with no cluster envelope (shards keep whatever budget
    /// or governor their factory gave them) and a 200 ms demand window.
    pub fn new() -> ShardRouterBuilder {
        ShardRouterBuilder { envelope: None, window: Duration::from_millis(200) }
    }

    /// Run the cluster under `envelope` (Gflips/sec across *all*
    /// shards). `top_gflips_per_sample` prices shard demand for the
    /// split — pass the cost of the menu's most accurate point, i.e.
    /// what serving a shard's whole load at full accuracy would draw.
    /// The factory receives each shard's initial equal slice to build
    /// its governor from.
    pub fn envelope(mut self, envelope: EnergyEnvelope, top_gflips_per_sample: f64) -> Self {
        self.envelope = Some((envelope.rate(), top_gflips_per_sample));
        self
    }

    /// Demand window for the envelope re-split (default 200 ms).
    pub fn window(mut self, w: Duration) -> Self {
        self.window = w;
        self
    }

    /// Build `n` shards through `make(shard, envelope_slice)` — the
    /// factory returns each shard's fully-built [`Server`], attaching
    /// the passed envelope slice as its governor envelope when one is
    /// given (`None` without a cluster envelope).
    pub fn build<F>(self, n: usize, mut make: F) -> Result<ShardRouter>
    where
        F: FnMut(usize, Option<EnergyEnvelope>) -> Result<Server>,
    {
        if n == 0 {
            bail!("a shard router needs at least one shard");
        }
        let now = Instant::now();
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let slice = self
                .envelope
                .map(|(rate, _)| EnergyEnvelope::gflips_per_sec(rate / n as f64));
            let server = make(i, slice)?;
            let client = server.client();
            shards.push(Shard {
                server,
                client,
                requests: AtomicU64::new(0),
                shed: AtomicU64::new(0),
                retries: AtomicU64::new(0),
            });
        }
        Ok(ShardRouter {
            shards,
            splitter: self
                .envelope
                .map(|(rate, _)| EnvelopeSplitter::new(rate, self.window, n, now)),
            top_cost: self.envelope.map(|(_, c)| c).unwrap_or(0.0),
            rr: AtomicUsize::new(0),
        })
    }
}

struct Shard {
    server: Server,
    client: Client,
    requests: AtomicU64,
    shed: AtomicU64,
    retries: AtomicU64,
}

/// N in-process [`Server`]s behind one submit surface. See the
/// [module docs](self) for placement, retry and envelope semantics.
pub struct ShardRouter {
    shards: Vec<Shard>,
    splitter: Option<EnvelopeSplitter>,
    top_cost: f64,
    rr: AtomicUsize,
}

/// A [`Ticket`] plus the shard that admitted the request.
pub struct ShardTicket {
    /// Index of the shard serving the request.
    pub shard: usize,
    /// The underlying result handle.
    pub ticket: Ticket,
}

impl ShardTicket {
    /// Block until the result arrives (see [`Ticket::wait`]).
    pub fn wait(self) -> Result<Response, ServeError> {
        self.ticket.wait()
    }
}

/// Point-in-time view of a [`ShardRouter`].
#[derive(Clone, Debug)]
pub struct RouterSnapshot {
    /// Per-shard status, in shard order.
    pub shards: Vec<ShardStatus>,
    /// The cluster envelope rate being split (Gflips/sec), when one is
    /// set.
    pub envelope_rate: Option<f64>,
}

/// One shard's slice of a [`RouterSnapshot`].
#[derive(Clone, Debug)]
pub struct ShardStatus {
    /// Requests this shard admitted.
    pub requests: u64,
    /// Requests this shard refused ([`ServeError::QueueFull`] /
    /// [`ServeError::ServerStopped`]) — each refusal either retried on
    /// another shard or surfaced to the caller.
    pub shed: u64,
    /// Requests that landed here after at least one other shard shed
    /// them.
    pub retries: u64,
    /// Requests currently queued on the shard.
    pub queue_depth: usize,
    /// The shard's current envelope share (Gflips/sec) under a cluster
    /// envelope.
    pub envelope_share: Option<f64>,
    /// The splitter's EWMA demand estimate for the shard (samples/sec)
    /// under a cluster envelope.
    pub demand_rate: Option<f64>,
    /// The shard's governor state, when it runs one.
    pub governor: Option<GovernorSnapshot>,
    /// The shard's full serving metrics (per-point residency, latency
    /// per priority class, shed/expired counters).
    pub metrics: MetricsSnapshot,
}

/// 64-bit FNV-1a over `bytes`, folded into `seed`.
fn fnv1a(mut seed: u64, bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    for &b in bytes {
        seed ^= b as u64;
        seed = seed.wrapping_mul(PRIME);
    }
    seed
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Rendezvous (highest-random-weight) shard preference order for an
/// affinity `key` over `n` shards: every shard's weight is the FNV-1a
/// hash of the key folded with the shard index, sorted descending.
/// Deterministic, and stable as long as `n` is — the property the
/// router's cache-affinity story rests on. Exposed so the scenario
/// replay harness ([`crate::scenario`]) places keyed trace events on
/// exactly the shard the real router would pick.
pub fn rendezvous_order(key: &str, n: usize) -> Vec<usize> {
    let h0 = fnv1a(FNV_OFFSET, key.as_bytes());
    let mut order: Vec<usize> = (0..n).collect();
    // ties (impossible in practice) break on shard index for
    // determinism
    order.sort_by_key(|&i| (std::cmp::Reverse(fnv1a(h0, &(i as u64).to_le_bytes())), i));
    order
}

impl ShardRouter {
    /// Start building a router.
    pub fn builder() -> ShardRouterBuilder {
        ShardRouterBuilder::new()
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The client of shard 0 — for surface queries that are identical
    /// on every shard (registered models, sample length, budget),
    /// since all shards serve the same menu.
    pub fn primary(&self) -> &Client {
        &self.shards[0].client
    }

    /// Shard preference order for `req`: rendezvous order of its
    /// affinity key, or round-robin rotation when it has none.
    fn order(&self, req: &InferRequest) -> Vec<usize> {
        let n = self.shards.len();
        match &req.affinity {
            Some(key) => rendezvous_order(key, n),
            None => {
                let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                (start..n).chain(0..start).collect()
            }
        }
    }

    /// Submit one request. Walks the shards in preference order,
    /// retrying sheds on the next shard with the deadline shrunk by the
    /// time already spent; non-capacity rejections (bad input, unknown
    /// point/model, NaN budget) surface immediately — no shard would
    /// answer differently.
    pub fn submit(&self, req: InferRequest) -> Result<ShardTicket, ServeError> {
        let t0 = Instant::now();
        let order = self.order(&req);
        let mut last = ServeError::ServerStopped;
        for (attempt, &i) in order.iter().enumerate() {
            let mut try_req = req.clone();
            if let Some(d) = req.deadline {
                // charge routing time against the caller's deadline so
                // a retry cannot serve later than the caller allowed
                let elapsed = t0.elapsed();
                if elapsed >= d {
                    return Err(ServeError::DeadlineExceeded);
                }
                try_req = try_req.deadline(d - elapsed);
            }
            match self.shards[i].client.submit(try_req) {
                Ok(ticket) => {
                    self.shards[i].requests.fetch_add(1, Ordering::Relaxed);
                    if attempt > 0 {
                        self.shards[i].retries.fetch_add(1, Ordering::Relaxed);
                    }
                    self.note_admitted(i);
                    return Ok(ShardTicket { shard: i, ticket });
                }
                Err(e @ (ServeError::QueueFull { .. } | ServeError::ServerStopped)) => {
                    self.shards[i].shed.fetch_add(1, Ordering::Relaxed);
                    last = e;
                }
                Err(e) => return Err(e),
            }
        }
        Err(last)
    }

    /// Blocking convenience: submit with default QoS and wait.
    pub fn infer(&self, input: Vec<f32>) -> Result<Response, ServeError> {
        self.submit(InferRequest::new(input))?.wait()
    }

    /// Land one admitted sample on the envelope splitter; at a window
    /// boundary, push every shard's fresh share into its governor.
    fn note_admitted(&self, shard: usize) {
        let Some(sp) = &self.splitter else { return };
        if let Some(shares) = sp.observe(Instant::now(), shard, 1, |_| self.top_cost) {
            for (i, &share) in shares.iter().enumerate() {
                self.shards[i].client.set_envelope_rate(share);
            }
        }
    }

    /// Per-shard status plus the cluster envelope, for `/metrics` and
    /// `/v1/governor`.
    pub fn snapshot(&self) -> RouterSnapshot {
        let split = self.splitter.as_ref().map(|s| s.snapshot());
        RouterSnapshot {
            envelope_rate: self.splitter.as_ref().map(|s| s.total_rate()),
            shards: self
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| ShardStatus {
                    requests: s.requests.load(Ordering::Relaxed),
                    shed: s.shed.load(Ordering::Relaxed),
                    retries: s.retries.load(Ordering::Relaxed),
                    queue_depth: s.client.queue_depth(),
                    envelope_share: split.as_ref().map(|sp| sp.shares[i]),
                    demand_rate: split.as_ref().map(|sp| sp.demand_rate[i]),
                    governor: s.client.governor(),
                    metrics: s.client.metrics(),
                })
                .collect(),
        }
    }

    /// Stop every shard: queues stop accepting, in-flight batches
    /// finish, workers join.
    pub fn shutdown(self) {
        for shard in self.shards {
            shard.server.shutdown();
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;
    use crate::coordinator::server::tests_support::{Gate, GateEngine, MockEngine};
    use crate::coordinator::{Menu, Server, SharedPoint};
    use std::sync::Arc;

    fn mock_shard(_i: usize, env: Option<EnergyEnvelope>) -> Result<Server> {
        let menu = Menu::shared(vec![SharedPoint {
            measured_gflips_per_sample: None,
            name: "p".into(),
            giga_flips_per_sample: 1.0,
            engine: Arc::new(MockEngine::new(4, 2, 1)),
        }]);
        let mut b = Server::builder().workers(1).queue_depth(4);
        if let Some(e) = env {
            b = b.envelope(e);
        }
        b.serve(menu)
    }

    fn router(n: usize) -> ShardRouter {
        ShardRouter::builder().build(n, mock_shard).unwrap()
    }

    #[test]
    fn keyless_requests_round_robin_across_shards() {
        let r = router(3);
        let mut seen = [0u64; 3];
        for _ in 0..9 {
            let t = r.submit(InferRequest::new(vec![1.0, 2.0])).unwrap();
            seen[t.shard] += 1;
            t.wait().unwrap();
        }
        assert_eq!(seen, [3, 3, 3], "round-robin must spread evenly");
        let snap = r.snapshot();
        assert!(snap.shards.iter().all(|s| s.requests == 3 && s.shed == 0));
        assert!(snap.envelope_rate.is_none());
        r.shutdown();
    }

    #[test]
    fn affinity_keys_stick_to_one_shard() {
        let r = router(4);
        for key in ["user-1", "user-2", "session-xyz"] {
            let mut shards = std::collections::BTreeSet::new();
            for _ in 0..5 {
                let t = r
                    .submit(InferRequest::new(vec![0.0, 0.0]).affinity(key))
                    .unwrap();
                shards.insert(t.shard);
                t.wait().unwrap();
            }
            assert_eq!(shards.len(), 1, "key {key} must always land on one shard");
        }
        r.shutdown();
    }

    #[test]
    fn affinity_keys_spread_over_shards() {
        // rendezvous hashing must not degenerate to one hot shard
        let r = router(4);
        let mut seen = std::collections::BTreeSet::new();
        for k in 0..32 {
            let t = r
                .submit(InferRequest::new(vec![0.0, 0.0]).affinity(format!("key-{k}")))
                .unwrap();
            seen.insert(t.shard);
            t.wait().unwrap();
        }
        assert!(seen.len() >= 3, "32 keys landed on only {seen:?}");
        r.shutdown();
    }

    /// An affinity key whose rendezvous order on a 2-shard router puts
    /// shard 0 first — found deterministically against the same hash
    /// the router uses.
    fn key_preferring_shard0() -> String {
        (0..)
            .map(|i| format!("k{i}"))
            .find(|k| {
                let h0 = fnv1a(FNV_OFFSET, k.as_bytes());
                fnv1a(h0, &0u64.to_le_bytes()) > fnv1a(h0, &1u64.to_le_bytes())
            })
            .unwrap()
    }

    #[test]
    fn shed_requests_retry_on_the_next_shard() {
        // shard 0: gated engine with queue_depth 1 (fills instantly);
        // shard 1: free. An affinity key pinned to shard 0 makes the
        // targeting deterministic.
        let gate = Gate::new();
        let g2 = gate.clone();
        let r = ShardRouter::builder()
            .build(2, move |i, _| {
                if i == 0 {
                    let menu = Menu::shared(vec![SharedPoint {
                        measured_gflips_per_sample: None,
                        name: "p".into(),
                        giga_flips_per_sample: 1.0,
                        engine: Arc::new(GateEngine::new(1, 2, 1, g2.clone())),
                    }]);
                    Server::builder().workers(1).queue_depth(1).serve(menu)
                } else {
                    mock_shard(i, None)
                }
            })
            .unwrap();
        let key = key_preferring_shard0();
        // occupy shard 0: one executing (held at the gate) + one queued
        let hold = r
            .submit(InferRequest::new(vec![1.0, 1.0]).affinity(key.as_str()))
            .unwrap();
        assert_eq!(hold.shard, 0);
        gate.wait_entered(1);
        let queued = r
            .submit(InferRequest::new(vec![1.0, 1.0]).affinity(key.as_str()))
            .unwrap();
        assert_eq!(queued.shard, 0);
        // shard 0 is now full: the router must shed there and land the
        // request on shard 1 despite the affinity preference
        let t = r
            .submit(InferRequest::new(vec![2.0, 3.0]).affinity(key.as_str()))
            .unwrap();
        assert_eq!(t.shard, 1);
        let resp = t.wait().unwrap();
        assert_eq!(resp.output, vec![5.0]); // echo-sum engine
        let snap = r.snapshot();
        assert_eq!(snap.shards[0].shed, 1);
        assert_eq!(snap.shards[1].retries, 1);
        gate.open();
        hold.wait().unwrap();
        queued.wait().unwrap();
        r.shutdown();
    }

    #[test]
    fn expired_deadline_rejected_before_any_shard() {
        let r = router(2);
        let e = r
            .submit(InferRequest::new(vec![0.0, 0.0]).deadline(Duration::ZERO))
            .unwrap_err();
        assert_eq!(e, ServeError::DeadlineExceeded);
        r.shutdown();
    }

    #[test]
    fn non_capacity_errors_do_not_retry() {
        let r = router(2);
        // wrong input length: every shard would reject identically
        let e = r.submit(InferRequest::new(vec![0.0])).unwrap_err();
        assert_eq!(e, ServeError::BadInput { expected: 2, got: 1 });
        // a pinned unknown point is admitted and rejected by the
        // scheduler — through the ticket, once, with no shed counted
        let t = r
            .submit(InferRequest::new(vec![0.0, 0.0]).pin_point("ghost"))
            .unwrap();
        assert_eq!(t.wait(), Err(ServeError::UnknownPoint("ghost".into())));
        let snap = r.snapshot();
        assert!(snap.shards.iter().all(|s| s.shed == 0), "rejections are not sheds");
        r.shutdown();
    }

    #[test]
    fn envelope_router_targets_governors_with_shares() {
        let r = ShardRouter::builder()
            .envelope(EnergyEnvelope::gflips_per_sec(8.0), 1.0)
            .window(Duration::from_millis(1))
            .build(2, mock_shard)
            .unwrap();
        let snap = r.snapshot();
        assert_eq!(snap.envelope_rate, Some(8.0));
        // equal slices before any demand window closes
        assert_eq!(
            snap.shards.iter().map(|s| s.envelope_share).collect::<Vec<_>>(),
            vec![Some(4.0), Some(4.0)]
        );
        assert!(snap.shards[0].governor.is_some(), "envelope shards run governors");
        // drive traffic until at least one 1 ms window closes and the
        // splitter re-targets
        for _ in 0..64 {
            r.infer(vec![1.0, 1.0]).unwrap();
        }
        let snap = r.snapshot();
        let total: f64 = snap.shards.iter().map(|s| s.envelope_share.unwrap()).sum();
        assert!((total - 8.0).abs() < 1e-9, "shares must keep summing to the envelope");
        assert!(
            snap.shards.iter().any(|s| s.demand_rate.unwrap() > 0.0),
            "demand must have been observed"
        );
        r.shutdown();
    }

    #[test]
    fn builder_rejects_zero_shards() {
        assert!(ShardRouter::builder().build(0, mock_shard).is_err());
    }
}
