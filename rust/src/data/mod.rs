//! Datasets and the binary tensor interchange format.
//!
//! `python/compile/datasets.py` generates the synthetic corpora at
//! build time (`make artifacts`) and writes them in the `.ptns` binary
//! tensor format implemented by [`tensor_io`]; the Rust side loads them
//! for the PTQ experiments. [`synth`] additionally provides pure-Rust
//! generators so unit tests and benches run without artifacts.

pub mod dataset;
pub mod synth;
pub mod tensor_io;

pub use dataset::Dataset;
pub use tensor_io::{read_tensor, write_tensor, TensorData};
