//! Dataset loading from `artifacts/data/<name>/` (written by
//! `python -m compile.datasets`).

use super::tensor_io::{read_tensor, TensorData};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A classification dataset split: inputs `[n, ...]` and labels `[n]`.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Input shape per sample (without the leading batch dim).
    pub sample_shape: Vec<usize>,
    /// Flattened inputs, `n × prod(sample_shape)`.
    pub x: Vec<f32>,
    /// Labels.
    pub y: Vec<u32>,
    /// Number of label classes.
    pub classes: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// Whether the split holds no samples.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Size of one flattened sample.
    pub fn sample_len(&self) -> usize {
        self.sample_shape.iter().product()
    }

    /// Input slice of sample `i`.
    pub fn sample(&self, i: usize) -> &[f32] {
        let d = self.sample_len();
        &self.x[i * d..(i + 1) * d]
    }

    /// First `n` samples as a new dataset (cheap experiment subsets).
    pub fn take(&self, n: usize) -> Dataset {
        let n = n.min(self.len());
        let d = self.sample_len();
        Dataset {
            sample_shape: self.sample_shape.clone(),
            x: self.x[..n * d].to_vec(),
            y: self.y[..n].to_vec(),
            classes: self.classes,
        }
    }

    /// Load the split `"train"` / `"test"` / `"calib"` from a dataset
    /// directory containing `<split>_x.ptns` and `<split>_y.ptns`.
    pub fn load(dir: &Path, split: &str) -> Result<Dataset> {
        let xt = read_tensor(&dir.join(format!("{split}_x.ptns")))?;
        let yt = read_tensor(&dir.join(format!("{split}_y.ptns")))?;
        let (xshape, x) = xt.into_f32().context("inputs must be f32")?;
        let (yshape, yraw) = match yt {
            TensorData::I32(s, d) => (s, d),
            other => bail!("labels must be i32, got {:?}", other.shape()),
        };
        if xshape.is_empty() || yshape.len() != 1 || xshape[0] != yshape[0] {
            bail!("shape mismatch: x {xshape:?} vs y {yshape:?}");
        }
        let y: Vec<u32> = yraw
            .iter()
            .map(|&v| {
                if v < 0 {
                    bail!("negative label {v}")
                } else {
                    Ok(v as u32)
                }
            })
            .collect::<Result<_>>()?;
        let classes = y.iter().copied().max().unwrap_or(0) as usize + 1;
        Ok(Dataset { sample_shape: xshape[1..].to_vec(), x, y, classes })
    }

    /// Build from an in-memory [`super::synth::SynthBatch`].
    pub fn from_synth(b: super::synth::SynthBatch) -> Dataset {
        let sample_shape = if b.h == 1 && b.w == 1 {
            vec![b.c]
        } else {
            vec![b.c, b.h, b.w]
        };
        Dataset { sample_shape, x: b.x, y: b.y, classes: b.classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tensor_io::write_tensor;

    #[test]
    fn roundtrip_via_files() {
        let dir = std::env::temp_dir().join("pann_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let x = TensorData::F32(vec![3, 2, 2], (0..12).map(|i| i as f32).collect());
        let y = TensorData::I32(vec![3], vec![0, 2, 1]);
        write_tensor(&dir.join("test_x.ptns"), &x).unwrap();
        write_tensor(&dir.join("test_y.ptns"), &y).unwrap();
        let ds = Dataset::load(&dir, "test").unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.sample_shape, vec![2, 2]);
        assert_eq!(ds.classes, 3);
        assert_eq!(ds.sample(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn from_synth_works() {
        let ds = Dataset::from_synth(crate::data::synth::digits(8, 1));
        assert_eq!(ds.sample_shape, vec![1, 16, 16]);
        assert_eq!(ds.len(), 8);
    }

    #[test]
    fn take_subsets() {
        let ds = Dataset::from_synth(crate::data::synth::blobs(20, 2));
        let s = ds.take(5);
        assert_eq!(s.len(), 5);
        assert_eq!(s.sample(4), ds.sample(4));
    }
}
