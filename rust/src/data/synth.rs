//! Pure-Rust synthetic data generators.
//!
//! These mirror (in spirit, not bit-for-bit) the build-time generators
//! of `python/compile/datasets.py`, so unit tests, benches and the
//! quickstart example run even before `make artifacts`. The ImageNet /
//! CIFAR / MHEALTH corpora of the paper are substituted by these
//! generators per DESIGN.md.

use crate::util::Rng;

/// A labelled classification batch: images `[n, c, h, w]` flattened
/// row-major, labels in `[0, classes)`.
#[derive(Clone, Debug)]
pub struct SynthBatch {
    /// Flattened images, `n × c × h × w` row-major.
    pub x: Vec<f32>,
    /// Labels in `[0, classes)`.
    pub y: Vec<u32>,
    /// Number of samples.
    pub n: usize,
    /// Channels per image.
    pub c: usize,
    /// Image height.
    pub h: usize,
    /// Image width.
    pub w: usize,
    /// Number of label classes.
    pub classes: usize,
}

/// 16×16 single-channel "digit glyph" images: each class is a fixed
/// stroke pattern on a 4×4 cell grid, rendered with random intensity,
/// translation jitter and additive noise — a stand-in for small-image
/// classification (CIFAR/ImageNet rows of the paper).
pub fn digits(n: usize, seed: u64) -> SynthBatch {
    let (h, w, classes) = (16usize, 16usize, 10usize);
    // Stroke masks per class on a 4x4 grid (1 = lit cell), loosely
    // seven-segment-like so classes share local features.
    const GLYPHS: [[u8; 16]; 10] = [
        [1, 1, 1, 1, 1, 0, 0, 1, 1, 0, 0, 1, 1, 1, 1, 1], // 0 ring
        [0, 0, 1, 0, 0, 1, 1, 0, 0, 0, 1, 0, 0, 1, 1, 1], // 1
        [1, 1, 1, 0, 0, 0, 1, 0, 0, 1, 0, 0, 1, 1, 1, 1], // 2
        [1, 1, 1, 1, 0, 0, 1, 1, 0, 0, 0, 1, 1, 1, 1, 1], // 3
        [1, 0, 0, 1, 1, 0, 0, 1, 1, 1, 1, 1, 0, 0, 0, 1], // 4
        [1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 1, 1, 1, 1, 1, 0], // 5
        [0, 1, 1, 0, 1, 0, 0, 0, 1, 1, 1, 0, 1, 1, 1, 1], // 6
        [1, 1, 1, 1, 0, 0, 0, 1, 0, 0, 1, 0, 0, 1, 0, 0], // 7
        [0, 1, 1, 0, 1, 1, 1, 1, 1, 0, 0, 1, 0, 1, 1, 0], // 8
        [1, 1, 1, 1, 1, 0, 1, 1, 0, 0, 0, 1, 0, 1, 1, 0], // 9
    ];
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; n * h * w];
    let mut y = vec![0u32; n];
    for img in 0..n {
        let cls = rng.below(classes);
        y[img] = cls as u32;
        let dy = rng.range_i64(-1, 2) as isize;
        let dx = rng.range_i64(-1, 2) as isize;
        let gain = 0.7 + 0.3 * rng.f32();
        for py in 0..h {
            for px in 0..w {
                let gy = ((py as isize - dy).clamp(0, 15) as usize) / 4;
                let gx = ((px as isize - dx).clamp(0, 15) as usize) / 4;
                let lit = GLYPHS[cls][gy * 4 + gx] as f32;
                let noise = 0.08 * rng.normal() as f32;
                x[img * h * w + py * w + px] = (lit * gain + noise).clamp(0.0, 1.0);
            }
        }
    }
    SynthBatch { x, y, n, c: 1, h, w, classes }
}

/// 64-dimensional Gaussian-mixture classification ("blobs"): class
/// means on a scaled hypercube, isotropic noise — the MLP workload.
pub fn blobs(n: usize, seed: u64) -> SynthBatch {
    let (dim, classes) = (64usize, 10usize);
    let mut rng = Rng::new(seed ^ 0x5107);
    // fixed class means
    let mut means = vec![0.0f32; classes * dim];
    let mut mrng = Rng::new(77);
    for m in means.iter_mut() {
        *m = mrng.normal() as f32 * 1.2;
    }
    let mut x = vec![0.0f32; n * dim];
    let mut y = vec![0u32; n];
    for i in 0..n {
        let cls = rng.below(classes);
        y[i] = cls as u32;
        for j in 0..dim {
            x[i * dim + j] = means[cls * dim + j] + rng.normal() as f32 * 0.9;
        }
    }
    SynthBatch { x, y, n, c: dim, h: 1, w: 1, classes }
}

/// MHEALTH-like activity windows: 6 synthetic IMU channels × 32 time
/// steps; each of 12 activities is a characteristic mixture of
/// sinusoids + drift + noise. Flattened to `[n, 6*32]`.
pub fn har(n: usize, seed: u64) -> SynthBatch {
    let (ch, t, classes) = (6usize, 32usize, 12usize);
    let mut rng = Rng::new(seed ^ 0xA11_0_4A2);
    let mut x = vec![0.0f32; n * ch * t];
    let mut y = vec![0u32; n];
    for i in 0..n {
        let cls = rng.below(classes);
        y[i] = cls as u32;
        let freq = 0.5 + 0.35 * cls as f32;
        let amp = 0.4 + 0.12 * (cls % 4) as f32;
        let phase = rng.f32() * std::f32::consts::TAU;
        for c in 0..ch {
            let cshift = c as f32 * 0.7;
            for s in 0..t {
                let tt = s as f32 / t as f32;
                let sig = amp * (freq * std::f32::consts::TAU * tt * 4.0 + phase + cshift).sin()
                    + 0.1 * (cls as f32 / classes as f32)
                    + 0.15 * rng.normal() as f32;
                x[i * ch * t + c * t + s] = sig;
            }
        }
    }
    SynthBatch { x, y, n, c: ch * t, h: 1, w: 1, classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_shapes_and_labels() {
        let b = digits(64, 1);
        assert_eq!(b.x.len(), 64 * 256);
        assert_eq!(b.y.len(), 64);
        assert!(b.y.iter().all(|&c| c < 10));
        assert!(b.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic() {
        let a = digits(16, 7);
        let b = digits(16, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn classes_distinguishable() {
        // Mean images of different classes must differ substantially —
        // otherwise the dataset carries no signal.
        let b = digits(500, 3);
        let hw = 256;
        let mut means = vec![vec![0.0f64; hw]; 10];
        let mut counts = [0usize; 10];
        for i in 0..b.n {
            let c = b.y[i] as usize;
            counts[c] += 1;
            for j in 0..hw {
                means[c][j] += b.x[i * hw + j] as f64;
            }
        }
        for c in 0..10 {
            for v in means[c].iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let dist01: f64 = (0..hw).map(|j| (means[0][j] - means[1][j]).powi(2)).sum();
        assert!(dist01 > 1.0, "class means too close: {dist01}");
    }

    #[test]
    fn blobs_and_har_shapes() {
        let b = blobs(32, 1);
        assert_eq!(b.x.len(), 32 * 64);
        assert!(b.y.iter().all(|&c| c < 10));
        let h = har(32, 1);
        assert_eq!(h.x.len(), 32 * 192);
        assert!(h.y.iter().all(|&c| c < 12));
    }
}
