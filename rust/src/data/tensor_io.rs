//! `.ptns` binary tensor format shared with `python/compile/tensor_io.py`.
//!
//! Layout (little endian):
//! ```text
//! magic   4 bytes  "PTNS"
//! version 1 byte   (1)
//! dtype   1 byte   0 = f32, 1 = i32, 2 = u8
//! ndim    1 byte
//! pad     1 byte   (0)
//! dims    ndim × u32
//! data    product(dims) × sizeof(dtype)
//! ```

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"PTNS";

/// A loaded tensor: shape plus typed payload.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    /// 32-bit float payload.
    F32(Vec<usize>, Vec<f32>),
    /// 32-bit signed integer payload.
    I32(Vec<usize>, Vec<i32>),
    /// Byte payload.
    U8(Vec<usize>, Vec<u8>),
}

impl TensorData {
    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            TensorData::F32(s, _) | TensorData::I32(s, _) | TensorData::U8(s, _) => s,
        }
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Unwrap f32 payload (errors otherwise).
    pub fn into_f32(self) -> Result<(Vec<usize>, Vec<f32>)> {
        match self {
            TensorData::F32(s, d) => Ok((s, d)),
            other => bail!("expected f32 tensor, got {:?} dtype", dtype_code(&other)),
        }
    }

    /// Unwrap i32 payload (errors otherwise).
    pub fn into_i32(self) -> Result<(Vec<usize>, Vec<i32>)> {
        match self {
            TensorData::I32(s, d) => Ok((s, d)),
            other => bail!("expected i32 tensor, got {:?} dtype", dtype_code(&other)),
        }
    }
}

fn dtype_code(t: &TensorData) -> u8 {
    match t {
        TensorData::F32(..) => 0,
        TensorData::I32(..) => 1,
        TensorData::U8(..) => 2,
    }
}

/// Write a tensor to a file.
pub fn write_tensor(path: &Path, t: &TensorData) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, dtype_code(t), t.shape().len() as u8, 0u8])?;
    for &d in t.shape() {
        f.write_all(&(d as u32).to_le_bytes())?;
    }
    match t {
        TensorData::F32(_, d) => {
            for v in d {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        TensorData::I32(_, d) => {
            for v in d {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        TensorData::U8(_, d) => f.write_all(d)?,
    }
    Ok(())
}

/// Read a tensor from a file.
pub fn read_tensor(path: &Path) -> Result<TensorData> {
    let raw = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    parse_tensor(&raw).with_context(|| format!("parse {}", path.display()))
}

/// Parse a tensor from bytes.
pub fn parse_tensor(raw: &[u8]) -> Result<TensorData> {
    if raw.len() < 8 || &raw[0..4] != MAGIC {
        bail!("bad magic (not a .ptns tensor)");
    }
    let (version, dtype, ndim) = (raw[4], raw[5], raw[6] as usize);
    if version != 1 {
        bail!("unsupported version {version}");
    }
    let mut off = 8;
    if raw.len() < off + 4 * ndim {
        bail!("truncated dims");
    }
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(u32::from_le_bytes(raw[off..off + 4].try_into().unwrap()) as usize);
        off += 4;
    }
    let n: usize = shape.iter().product();
    let need = |sz: usize| -> Result<()> {
        if raw.len() != off + n * sz {
            bail!("payload size mismatch: file {} vs expected {}", raw.len() - off, n * sz);
        }
        Ok(())
    };
    Ok(match dtype {
        0 => {
            need(4)?;
            let mut d = Vec::with_capacity(n);
            let mut rd = &raw[off..];
            let mut buf = [0u8; 4];
            for _ in 0..n {
                rd.read_exact(&mut buf)?;
                d.push(f32::from_le_bytes(buf));
            }
            TensorData::F32(shape, d)
        }
        1 => {
            need(4)?;
            let mut d = Vec::with_capacity(n);
            let mut rd = &raw[off..];
            let mut buf = [0u8; 4];
            for _ in 0..n {
                rd.read_exact(&mut buf)?;
                d.push(i32::from_le_bytes(buf));
            }
            TensorData::I32(shape, d)
        }
        2 => {
            need(1)?;
            TensorData::U8(shape, raw[off..].to_vec())
        }
        other => bail!("unknown dtype code {other}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let dir = std::env::temp_dir().join("pann_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t1.ptns");
        let t = TensorData::F32(vec![2, 3], vec![1.0, -2.5, 3.25, 0.0, 1e-9, -1e9]);
        write_tensor(&p, &t).unwrap();
        assert_eq!(read_tensor(&p).unwrap(), t);
    }

    #[test]
    fn roundtrip_i32_u8() {
        let dir = std::env::temp_dir().join("pann_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t2.ptns");
        let t = TensorData::I32(vec![4], vec![-7, 0, 9, i32::MAX]);
        write_tensor(&p, &t).unwrap();
        assert_eq!(read_tensor(&p).unwrap(), t);
        let p3 = dir.join("t3.ptns");
        let t3 = TensorData::U8(vec![2, 2], vec![0, 255, 4, 16]);
        write_tensor(&p3, &t3).unwrap();
        assert_eq!(read_tensor(&p3).unwrap(), t3);
    }

    #[test]
    fn rejects_corrupt() {
        assert!(parse_tensor(b"NOPE").is_err());
        assert!(parse_tensor(b"PTNS\x01\x00\x01\x00\x05\x00\x00\x00").is_err()); // truncated
        // wrong payload length
        let mut buf = Vec::new();
        buf.extend_from_slice(b"PTNS");
        buf.extend_from_slice(&[1, 0, 1, 0]);
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 4]); // only one f32 instead of two
        assert!(parse_tensor(&buf).is_err());
    }
}
