//! Static soundness analysis: the overflow-bound prover.
//!
//! PANN's energy savings come from running reductions at the narrowest
//! accumulator width that is still *exact* — and "exact" has to be a
//! theorem, not a heuristic. This module is the theorem: exact i128
//! interval arithmetic over a layer's quantized operand ranges
//! ([`interval::Interval`]) producing a per-layer soundness
//! certificate ([`cert::KernelCert`]) that states which accumulator
//! widths (i64 wide, wrapping-i32 narrow, packed-i16 lanes) provably
//! cannot produce a wrong answer.
//!
//! Two consumers:
//!
//! - the plan compiler ([`crate::nn::ExecutionPlan`]) certifies every
//!   layer at compile time and selects kernels from the certificate —
//!   a layer only runs narrow/packed arithmetic when the certificate
//!   admits it, and compilation *fails* if even i64 accumulation
//!   cannot be proven safe;
//! - `pann-cli verify --menu` re-derives certificates offline to audit
//!   a serialized menu artifact without running inference (see
//!   `EXPERIMENTS.md` §Verify for the exit-code contract).
//!
//! The concurrency half of the soundness story (loom models, TSan,
//! Miri) lives in `tests/loom.rs` and CI; `ARCHITECTURE.md`'s
//! "Soundness & verification matrix" maps every invariant to the tool
//! that checks it.

pub mod cert;
pub mod interval;

pub use cert::KernelCert;
pub use interval::Interval;
