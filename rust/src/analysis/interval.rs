//! Exact integer interval arithmetic in i128.
//!
//! The prover's only numeric primitive: closed integer intervals
//! `[lo, hi]` with corner-product multiplication and n-fold sum
//! scaling. All operations use saturating i128 arithmetic — the
//! quantities being bounded (|act| ≤ 2^31, |code| ≤ 2^31, depth ≤
//! 2^32) keep true extrema far below the i128 saturation points, and
//! even if a pathological synthetic config saturated, saturation only
//! pushes corners *outward*, so the `fits_*` verdicts stay sound
//! (a saturated bound can only turn a true "fits" into "doesn't fit",
//! never the reverse).

/// A closed integer interval `[lo, hi]` over i128.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    /// Inclusive lower bound.
    pub lo: i128,
    /// Inclusive upper bound.
    pub hi: i128,
}

impl Interval {
    /// A new interval. Panics if `lo > hi` (a programming error in the
    /// caller, never data-dependent).
    pub fn new(lo: i128, hi: i128) -> Self {
        assert!(lo <= hi, "interval bounds inverted: [{lo}, {hi}]");
        Interval { lo, hi }
    }

    /// The degenerate single-point interval `[v, v]`.
    pub fn point(v: i128) -> Self {
        Interval { lo: v, hi: v }
    }

    /// Exact product interval: the hull of the four corner products.
    ///
    /// For integer intervals this is exact (the extrema of `x·y` over
    /// a box are attained at corners), not merely an over-approximation.
    pub fn mul(self, other: Interval) -> Interval {
        let c = [
            self.lo.saturating_mul(other.lo),
            self.lo.saturating_mul(other.hi),
            self.hi.saturating_mul(other.lo),
            self.hi.saturating_mul(other.hi),
        ];
        Interval {
            lo: *c.iter().min().expect("nonempty"),
            hi: *c.iter().max().expect("nonempty"),
        }
    }

    /// The interval containing every sum of exactly `n` values drawn
    /// from `self`: `[n·lo, n·hi]`.
    ///
    /// This is the accumulator hull for a reduction of depth `n` whose
    /// per-element products all lie in `self`. Partial sums of `k ≤ n`
    /// elements lie in `[k·lo, k·hi] ⊆ [min(n·lo, 0), max(n·hi, 0)]`,
    /// and `0` always fits every machine width, so a `fits_*` verdict
    /// on this interval also covers every intermediate partial sum.
    pub fn sum_n(self, n: u64) -> Interval {
        let n = n as i128;
        Interval {
            lo: self.lo.saturating_mul(n),
            hi: self.hi.saturating_mul(n),
        }
    }

    /// Difference interval `self − other` (hull over both operands).
    pub fn sub(self, other: Interval) -> Interval {
        Interval {
            lo: self.lo.saturating_sub(other.hi),
            hi: self.hi.saturating_sub(other.lo),
        }
    }

    /// Does every value in the interval fit in i16?
    pub fn fits_i16(self) -> bool {
        self.lo >= i16::MIN as i128 && self.hi <= i16::MAX as i128
    }

    /// Does every value in the interval fit in i32?
    pub fn fits_i32(self) -> bool {
        self.lo >= i32::MIN as i128 && self.hi <= i32::MAX as i128
    }

    /// Does every value in the interval fit in i64?
    pub fn fits_i64(self) -> bool {
        self.lo >= i64::MIN as i128 && self.hi <= i64::MAX as i128
    }

    /// Is `v` inside the interval?
    pub fn contains(self, v: i128) -> bool {
        self.lo <= v && v <= self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corner_products_are_exact() {
        // mixed-sign × mixed-sign: extrema at corners
        let a = Interval::new(-3, 5);
        let b = Interval::new(-7, 2);
        let p = a.mul(b);
        let mut lo = i128::MAX;
        let mut hi = i128::MIN;
        for x in -3..=5i128 {
            for y in -7..=2i128 {
                lo = lo.min(x * y);
                hi = hi.max(x * y);
            }
        }
        assert_eq!((p.lo, p.hi), (lo, hi));
    }

    #[test]
    fn sum_n_scales_both_ends() {
        let p = Interval::new(-4, 9);
        let s = p.sum_n(10);
        assert_eq!((s.lo, s.hi), (-40, 90));
    }

    #[test]
    fn fits_checks_are_inclusive() {
        assert!(Interval::new(i32::MIN as i128, i32::MAX as i128).fits_i32());
        assert!(!Interval::new(i32::MIN as i128 - 1, 0).fits_i32());
        assert!(!Interval::new(0, i32::MAX as i128 + 1).fits_i32());
        assert!(Interval::point(i16::MAX as i128).fits_i16());
        assert!(!Interval::point(i16::MAX as i128 + 1).fits_i16());
    }

    #[test]
    fn saturation_never_understates() {
        // a deliberately saturating product still fails every fits check
        let huge = Interval::new(0, i128::MAX);
        let p = huge.mul(huge);
        assert_eq!(p.hi, i128::MAX);
        assert!(!p.fits_i64());
    }
}
