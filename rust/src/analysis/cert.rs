//! Per-layer overflow-soundness certificates.
//!
//! A [`KernelCert`] is the prover's verdict for one quantized GEMM
//! reduction: given the layer's activation-code interval, weight-code
//! interval (the effective per-element multiplier range, i.e. `p − n`
//! for split banks), reduction depth and bank layout, it states —
//! by exact i128 interval arithmetic — which accumulator widths are
//! provably safe:
//!
//! - **i64 (wide)**: the true dot product, and for split banks each
//!   partial bank sum and their difference, fit i64 without wrapping.
//! - **i32 (narrow)**: the true dot product fits i32. Wrapping-i32
//!   arithmetic is a commutative ring, so *intermediate* wraps are
//!   harmless — the final wrapped value equals the true sum exactly
//!   when the true sum is representable. The same argument covers the
//!   split-narrow fold (`p.wrapping_sub(n)` reproduces the code
//!   exactly because codes are certified to fit i32 first).
//! - **packed i16**: the narrow verdict *and* both operand streams fit
//!   i16 lanes (`pmaddwd` / NEON `smlal` pairwise sums also stay in
//!   the wrapping-i32 ring, including the `(−32768)²·2` edge, which
//!   wraps to `i32::MIN` identically on both scalar and SIMD paths).
//!
//! The plan compiler consumes certificates for kernel selection
//! (replacing the former `2^30` heuristic) and `pann-cli verify`
//! re-derives them offline to audit artifacts without running
//! inference.

use super::interval::Interval;

/// Prover verdict for one layer's GEMM reduction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCert {
    /// Activation-code interval (quantized input codes).
    pub act: Interval,
    /// Effective weight-code interval (per-element multiplier; for
    /// split banks this is the `p − n` range, i.e. the original code).
    pub weight: Interval,
    /// Reduction depth: number of multiply–accumulates per output.
    pub depth: u64,
    /// Whether the weights are stored as split W⁺/W⁻ banks.
    pub split: bool,
    /// True accumulator interval: `(act ⊗ weight) · depth`.
    pub acc: Interval,
    /// Split-bank positive partial-sum interval (`[0,0]` when unified).
    pub pos_acc: Interval,
    /// Split-bank negative partial-sum interval (`[0,0]` when unified).
    pub neg_acc: Interval,
    /// i64 accumulation is provably exact (wide kernels).
    pub i64_ok: bool,
    /// Wrapping-i32 accumulation provably reproduces the true sum
    /// (narrow kernels).
    pub i32_ok: bool,
    /// The packed-i16 lane format is provably exact (narrow verdict
    /// plus both operand streams fit i16).
    pub packed_i16_ok: bool,
}

impl KernelCert {
    /// Prove bounds for one reduction.
    ///
    /// `act` and `weight` are the per-element operand intervals,
    /// `depth` the reduction length, `split` whether the weight bank
    /// is stored as W⁺/W⁻ halves (which adds the partial-sum
    /// obligations on the wide path).
    pub fn certify(act: Interval, weight: Interval, depth: u64, split: bool) -> KernelCert {
        let acc = act.mul(weight).sum_n(depth);
        let (pos_acc, neg_acc, i64_ok) = if split {
            // The split banks are p = max(code, 0) and n = max(−code, 0);
            // each bank's partial sum must independently fit i64 (the
            // wide split kernel folds a·p and a·n terms in i64 lanes),
            // and so must their difference hull.
            let pos = Interval::new(0, weight.hi.max(0));
            let neg = Interval::new(0, (-weight.lo).max(0));
            let pos_acc = act.mul(pos).sum_n(depth);
            let neg_acc = act.mul(neg).sum_n(depth);
            let ok = pos_acc.fits_i64()
                && neg_acc.fits_i64()
                && pos_acc.sub(neg_acc).fits_i64();
            (pos_acc, neg_acc, ok)
        } else {
            (Interval::point(0), Interval::point(0), acc.fits_i64())
        };
        // Narrow validity additionally requires the operand codes to be
        // representable in the i32 operand slabs at all; the compiler
        // rejects plans where they aren't before certifying, but the
        // certificate re-checks so an offline audit can't be fooled.
        let i32_ok = acc.fits_i32() && act.fits_i32() && weight.fits_i32();
        let packed_i16_ok = i32_ok && act.fits_i16() && weight.fits_i16();
        KernelCert {
            act,
            weight,
            depth,
            split,
            acc,
            pos_acc,
            neg_acc,
            i64_ok,
            i32_ok,
            packed_i16_ok,
        }
    }

    /// Does the certificate admit the narrow (wrapping-i32) path?
    pub fn admits_narrow(&self) -> bool {
        self.i32_ok
    }

    /// Does the certificate admit the packed-i16 lane format?
    pub fn admits_packed(&self) -> bool {
        self.packed_i16_ok
    }

    /// Does the certificate prove the wide (i64) path exact?
    pub fn admits_wide(&self) -> bool {
        self.i64_ok
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tight_fit_admits_narrow_exactly_at_the_boundary() {
        // act ∈ [0, 3], code ∈ [0, 715827882], depth 1:
        // max = 3·715827882 = 2147483646 = i32::MAX − 1 → fits.
        let c = KernelCert::certify(
            Interval::new(0, 3),
            Interval::new(0, 715_827_882),
            1,
            false,
        );
        assert!(c.i32_ok && c.i64_ok);
        // one more on the code range pushes max to i32::MAX + 2 → wraps
        let c = KernelCert::certify(
            Interval::new(0, 3),
            Interval::new(0, 715_827_883),
            1,
            false,
        );
        assert!(!c.i32_ok);
        assert!(c.i64_ok);
    }

    #[test]
    fn negative_extremum_also_blocks_narrow() {
        // act ∈ [0, 2^16], code ∈ [−2^15, 0], depth 2:
        // min = 2·(−2^31) = −2^32 < i32::MIN
        let c = KernelCert::certify(
            Interval::new(0, 1 << 16),
            Interval::new(-(1 << 15), 0),
            2,
            false,
        );
        assert!(!c.i32_ok);
        assert!(c.i64_ok);
    }

    #[test]
    fn split_partial_sums_are_checked_independently() {
        // codes ∈ [−K, K] with K·act·depth each fitting i64 but the
        // bank partial sums are what the wide-split obligation bounds
        let k = 1i128 << 30;
        let c = KernelCert::certify(Interval::new(0, 1 << 20), Interval::new(-k, k), 1 << 12, true);
        // pos partial: 2^20 · 2^30 · 2^12 = 2^62 fits i64; diff hull 2^63 doesn't
        assert!(c.pos_acc.fits_i64() && c.neg_acc.fits_i64());
        assert!(!c.i64_ok, "difference hull must be part of the obligation");
    }

    #[test]
    fn packed_requires_i16_operands() {
        let c = KernelCert::certify(Interval::new(0, 40_000), Interval::new(-3, 3), 8, false);
        assert!(c.i32_ok, "sum fits i32");
        assert!(!c.packed_i16_ok, "act codes exceed i16 lanes");
        let c = KernelCert::certify(Interval::new(0, 255), Interval::new(-3, 3), 8, false);
        assert!(c.packed_i16_ok);
    }
}
